"""Generate the §Dry-run and §Roofline tables for EXPERIMENTS.md from
results/dryrun/*.json (run after repro.launch.dryrun).

  PYTHONPATH=src python tools/mk_experiments.py > results/roofline_tables.md
"""
import json
import pathlib
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.bench_roofline import analyse, model_flops  # noqa: E402

HBM_PER_CHIP = 16e9   # TPU v5e


def fmt_s(x):
    if x >= 1:
        return f"{x:7.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:6.1f}ms"
    return f"{x * 1e6:6.0f}us"


def main(dirpath="results/dryrun"):
    recs = {}
    for f in sorted(pathlib.Path(dirpath).glob("*.json")):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r

    print("## §Dry-run — per-cell compile + memory (single-pod 16x16 = 256 "
          "chips; multi-pod 2x16x16 = 512 chips)\n")
    print("| arch | shape | mesh | status | peak GB/dev | TPU-adj GB/dev | "
          "fits 16GB | compile s |")
    print("|---|---|---|---|---|---|---|---|")
    for (a, s, m), r in sorted(recs.items()):
        if r.get("skipped"):
            print(f"| {a} | {s} | {m} | SKIP ({r['reason'][:40]}...) | - | - | - | - |")
            continue
        if not r["ok"]:
            print(f"| {a} | {s} | {m} | **FAIL** {r['error'][:60]} | - | - | - | - |")
            continue
        peak = r["peak_bytes_per_device"] / 1e9
        adj = r.get("peak_tpu_adjusted", 0) / 1e9
        fits = "yes" if adj * 1e9 <= HBM_PER_CHIP else "**no**"
        print(f"| {a} | {s} | {m} | ok | {peak:.2f} | {adj:.2f} | {fits} | "
              f"{r['compile_s']:.0f} |")

    print("\n\n## §Roofline — three-term roofline per cell (single-pod, "
          "197 TF/s bf16, 819 GB/s HBM, 50 GB/s link)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "MODEL_FLOPS/HLO | MFU bound |")
    print("|---|---|---|---|---|---|---|---|")
    for (a, s, m), r in sorted(recs.items()):
        if m != "single_pod_16x16" or not r.get("ok"):
            continue
        an = analyse(r)
        print(f"| {a} | {s} | {fmt_s(an['t_compute_s'])} | "
              f"{fmt_s(an['t_memory_s'])} | {fmt_s(an['t_collective_s'])} | "
              f"**{an['dominant']}** | {an['useful_ratio']:.2f} | "
              f"{an['mfu_bound']:.3f} |")

    # pick hillclimb candidates
    print("\n\n## Hillclimb candidate selection\n")
    cands = []
    for (a, s, m), r in sorted(recs.items()):
        if m != "single_pod_16x16" or not r.get("ok"):
            continue
        an = analyse(r)
        cands.append(an)
    if cands:
        worst = min((c for c in cands if c["shape"] == "train_4k"),
                    key=lambda c: c["mfu_bound"], default=None)
        coll = max(cands, key=lambda c: c["t_collective_s"]
                   / max(c["step_time_bound_s"], 1e-12))
        print(f"- worst MFU bound (train): {worst['arch']} x {worst['shape']}"
              f" ({worst['mfu_bound']:.3f})" if worst else "-")
        print(f"- most collective-bound: {coll['arch']} x {coll['shape']} "
              f"(coll {fmt_s(coll['t_collective_s'])} vs bound "
              f"{fmt_s(coll['step_time_bound_s'])})")


if __name__ == "__main__":
    main(*sys.argv[1:])

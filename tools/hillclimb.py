import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimb driver: named variants per chosen cell, roofline terms
before/after, appended to results/perf_log.json.

  PYTHONPATH=src:. python tools/hillclimb.py <cell> <variant>

Variants encode one hypothesis each (see EXPERIMENTS.md §Perf)."""
import json
import pathlib
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax  # noqa: E402

from repro.configs import REGISTRY, SHAPES  # noqa: E402
from repro.launch.cellrun import rules_for_cell, run_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.parallel.sharding import LogicalRules, make_rules  # noqa: E402
from benchmarks.bench_roofline import analyse  # noqa: E402


def variant(cell: str, name: str):
    """Returns (cfg, shape, rules_or_None) for a named variant."""
    arch, shape_name = cell.split("/")
    cfg = REGISTRY[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    if name == "baseline":
        return cfg, shape, None, mesh

    if name == "decode_resident_tp":
        # HYPOTHESIS: decode is collective-bound because ZeRO-3 re-gathers
        # every layer's weights per emitted token; keeping weights RESIDENT
        # (TP over model; no dp sharding) removes those gathers entirely.
        rules = rules_for_cell(cfg, shape, mesh)
        r = dict(rules.rules)
        r["fsdp"] = ()
        r["tp_fsdp"] = ("model",)
        return cfg, shape, LogicalRules(r, mesh), mesh

    if name == "decode_resident_2d":
        # mixtral: full residency does not fit (282 GB bf16 / 16-way TP =
        # 17.6 GB > HBM); keep TP on F and ZeRO only the D dim over data
        # (one 100 MB gather per layer instead of 2.5 GB).
        rules = rules_for_cell(cfg, shape, mesh)
        r = dict(rules.rules)
        r["fsdp"] = ("data",)
        r["tp_fsdp"] = ("model",)
        return cfg, shape, LogicalRules(r, mesh), mesh

    if name == "train_remat_dots":
        # HYPOTHESIS: with peak well under HBM, full remat wastes memory
        # bandwidth on recompute; saving dot outputs cuts HLO bytes.
        return cfg.with_(remat="dots"), shape, None, mesh

    if name == "train_remat_none":
        return cfg.with_(remat="none"), shape, None, mesh

    if name == "train_bigger_attn_chunks":
        # HYPOTHESIS: fewer, larger attention k-chunks => fewer passes over
        # the (bq x bk) tiles => lower bytes-accessed (memory term).
        return cfg, shape, None, mesh  # handled via attn block_k... (cfg knob)

    if name == "train_capacity_1.0":
        # HYPOTHESIS: capacity factor 1.25 pads every expert batch by 25%;
        # dropping to 1.0 cuts expert matmul FLOPs+bytes ~20% at the cost
        # of more dropped tokens under imbalance (quality knob, documented).
        return cfg.with_(capacity_factor=1.0, remat="dots"), shape, None, mesh

    if name == "train_ep_over_all":
        # qwen3: EP currently spans the 16-way model axis only; spanning
        # (data x model) = 256 ways puts 1 expert per 2 devices, halving
        # per-device expert weight traffic in the a2a exchange.
        rules = rules_for_cell(cfg, shape, mesh)
        r = dict(rules.rules)
        r["expert"] = ("model", "data")
        return cfg, shape, LogicalRules(r, mesh), mesh

    raise SystemExit(f"unknown variant {name}")


def main():
    cell, name = sys.argv[1], sys.argv[2]
    cfg, shape, rules, mesh = variant(cell, name)
    res = run_cell(cfg, shape, mesh, "single_pod_16x16", rules=rules,
                   verbose=True)
    out = {"cell": cell, "variant": name, "ok": res.ok, "error": res.error}
    if res.ok:
        out.update(analyse(res.to_dict()))
        out["peak_gb"] = res.peak_bytes_per_device / 1e9
        out["peak_adj_gb"] = res.peak_tpu_adjusted / 1e9
        out["collectives"] = {k: round(v / 1e9, 2)
                              for k, v in res.collective_per_device.items()}
    log = pathlib.Path("results/perf_log.json")
    hist = json.loads(log.read_text()) if log.exists() else []
    hist.append(out)
    log.write_text(json.dumps(hist, indent=1, default=str))
    if res.ok:
        print(f"\n{cell} [{name}]: compute={out['t_compute_s']:.3f}s "
              f"memory={out['t_memory_s']:.3f}s "
              f"collective={out['t_collective_s']:.3f}s "
              f"dominant={out['dominant']} mfu_bound={out['mfu_bound']:.4f}")


if __name__ == "__main__":
    main()

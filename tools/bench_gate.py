#!/usr/bin/env python
"""Performance regression gates for the simulation engine AND the workload
subsystem.

Each gate replays a small fixed configuration and compares best-of-N wall
clock against the ``gate`` entry of its committed baseline, failing (exit 1)
on more than ``--threshold`` regression (default 25%):

  engine     benchmarks/bench_engine.py  vs BENCH_engine.json -- guards the
             incremental flow solver / indexed dispatch fast path;
  workloads  benchmarks/bench_workloads.py vs BENCH_workloads.json -- guards
             the open-loop ARRIVAL path + JSONL trace replay, with
             correctness canaries (all tasks complete, the provisioner both
             grows and shrinks, replayed metrics identical);
  joins      benchmarks/bench_joins.py vs BENCH_joins.json -- guards k-input
             partial-overlap dispatch, with canaries (data-aware beats
             first-available on cache-hit ratio, incremental scores bit-
             match the brute-force reference, v1 traces replay identical);
  policies   benchmarks/bench_policies.py vs BENCH_policies.json -- guards
             the experiment-API sweep path, with canaries (exponential
             allocation responds at least as well as one-at-a-time under
             bursty arrivals, sim + runtime RunReport schemas identical,
             rebalance release beats discard on post-shrink hit ratio);
  fleet      benchmarks/bench_fleet.py vs BENCH_fleet.json -- guards the
             multi-process fleet (repro.fleet), with canaries (every cell
             drains, aggregate cache bandwidth rises monotonically
             1 -> 2 -> 4 hosts, and a recorded trace replayed batch-
             synchronously matches the single-process runtime EXACTLY on
             scheduling-determined RunReport fields);
  dispatch   benchmarks/bench_dispatch.py vs BENCH_dispatch.json -- guards
             the batched-wire central loop, with canaries (the batched
             wire is >= 3x the unbatched one on the same completion
             storm, hierarchical tasks/s rises monotonically with host
             count, and hierarchical + batched batch-synchronous replay
             still matches single-process placement exactly);
  obs        benchmarks/bench_obs.py vs BENCH_obs.json -- guards the
             observability layer (repro.obs), with canaries (events-on
             central-loop CPU <= 10% over events-off on the dispatch
             storm, zero dropped events at the default ring capacity,
             and sim<->fleet per-task placement agreement >= 99% under
             serial replay);
  dags       benchmarks/bench_dags.py vs BENCH_dags.json -- guards the
             DAG ready-set + producer-placement layer, with canaries
             (producer-placement scoring beats the outputs-ignored
             baseline on cache-hit ratio over the N=24 all-pairs grid,
             incremental scores with produced oids bit-match the
             brute-force reference, the reduce tree fully drains, and a
             dep-free workload is bit-identical under both scoring modes
             AND to the committed baseline fingerprint);
  serve      benchmarks/bench_serve.py vs BENCH_serve.json -- guards the
             serving path (repro.serve.diffusion), with canaries
             (max-cache-hit beats first-available on reused-KV bytes
             over the 200-session chat workload, the provisioner both
             grows and shrinks under diurnal sessions, and an events-off
             serve run is bit-identical to events-on on the
             scheduling-determined report fields under barrier replay);
  telemetry  benchmarks/bench_telemetry.py vs BENCH_telemetry.json --
             guards the live metrics plane (repro.obs.metrics), with
             canaries (metrics-on central-loop CPU <= 10% over
             metrics-off on the completion storm with a live sampler
             attached, a metrics-off run scheduling-identical to
             metrics-on, and 4-host merged per-host bandwidth gauges
             within 5% of the run ledger's bytes_by_kind totals).

    PYTHONPATH=src python tools/bench_gate.py                # repo root
    PYTHONPATH=src python -m benchmarks.run --gate           # via the runner

Regenerate a baseline (intentional engine change / new hardware) with:

    PYTHONPATH=src python -m benchmarks.bench_engine --out BENCH_engine.json
    PYTHONPATH=src python -m benchmarks.bench_workloads \
        --out BENCH_workloads.json
    PYTHONPATH=src python -m benchmarks.bench_joins --out BENCH_joins.json
    PYTHONPATH=src python -m benchmarks.bench_policies \
        --out BENCH_policies.json
    PYTHONPATH=src python -m benchmarks.bench_fleet --out BENCH_fleet.json
    PYTHONPATH=src python -m benchmarks.bench_dispatch \
        --out BENCH_dispatch.json
    PYTHONPATH=src python -m benchmarks.bench_obs --out BENCH_obs.json
    PYTHONPATH=src python -m benchmarks.bench_dags --out BENCH_dags.json
    PYTHONPATH=src python -m benchmarks.bench_serve --out BENCH_serve.json
    PYTHONPATH=src python -m benchmarks.bench_telemetry \
        --out BENCH_telemetry.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def _check_gate(name: str, baseline_path: Path, measure, shape: tuple,
                threshold: float, update: bool,
                canaries=()) -> int:
    """Generic wall-clock gate. ``measure()`` -> current gate dict;
    ``shape`` is the (n_nodes, n_tasks) the baseline must match;
    ``canaries`` is a list of (label, fn(base, cur) -> ok) checks."""
    if not baseline_path.exists():
        print(f"bench_gate[{name}]: no baseline at {baseline_path}; run the "
              f"matching benchmarks module first", file=sys.stderr)
        return 1
    baseline = json.loads(baseline_path.read_text())
    gate = baseline.get("gate")
    if not gate:
        print(f"bench_gate[{name}]: baseline has no 'gate' entry",
              file=sys.stderr)
        return 1
    if (gate.get("n_nodes"), gate.get("n_tasks")) != shape:
        print(f"bench_gate[{name}]: baseline gate shape {gate.get('n_nodes')}"
              f"x{gate.get('n_tasks')} != code's {shape[0]}x{shape[1]}; "
              f"regenerate the baseline", file=sys.stderr)
        return 1

    current = measure()
    base_wall, cur_wall = gate["wall_s"], current["wall_s"]
    ratio = cur_wall / max(base_wall, 1e-9)
    verdict = "OK" if ratio <= 1.0 + threshold else "REGRESSION"
    print(f"bench_gate[{name}]: wall {cur_wall:.3f}s vs baseline "
          f"{base_wall:.3f}s ({ratio:.2f}x, threshold "
          f"{1.0 + threshold:.2f}x) -> {verdict}")
    for label, check in canaries:
        if not check(gate, current):
            print(f"bench_gate[{name}]: canary failed: {label}",
                  file=sys.stderr)
            return 1
    if verdict == "REGRESSION":
        if update:
            baseline["gate"] = current
            baseline_path.write_text(
                json.dumps(baseline, indent=2, sort_keys=True) + "\n")
            print(f"bench_gate[{name}]: baseline gate updated in "
                  f"{baseline_path}")
            return 0
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline",
                    default=str(REPO_ROOT / "BENCH_engine.json"))
    ap.add_argument("--workloads-baseline",
                    default=str(REPO_ROOT / "BENCH_workloads.json"))
    ap.add_argument("--joins-baseline",
                    default=str(REPO_ROOT / "BENCH_joins.json"))
    ap.add_argument("--policies-baseline",
                    default=str(REPO_ROOT / "BENCH_policies.json"))
    ap.add_argument("--fleet-baseline",
                    default=str(REPO_ROOT / "BENCH_fleet.json"))
    ap.add_argument("--dispatch-baseline",
                    default=str(REPO_ROOT / "BENCH_dispatch.json"))
    ap.add_argument("--obs-baseline",
                    default=str(REPO_ROOT / "BENCH_obs.json"))
    ap.add_argument("--dags-baseline",
                    default=str(REPO_ROOT / "BENCH_dags.json"))
    ap.add_argument("--serve-baseline",
                    default=str(REPO_ROOT / "BENCH_serve.json"))
    ap.add_argument("--telemetry-baseline",
                    default=str(REPO_ROOT / "BENCH_telemetry.json"))
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed fractional wall-clock regression")
    ap.add_argument("--repeats", type=int, default=3,
                    help="runs per measurement; best-of-N is compared")
    ap.add_argument("--only", choices=["engine", "workloads", "joins",
                                       "policies", "fleet", "dispatch",
                                       "obs", "dags", "serve", "telemetry"],
                    default=None,
                    help="run a single gate instead of all")
    ap.add_argument("--update", action="store_true",
                    help="rewrite a regressing baseline's gate entry "
                         "instead of failing")
    args = ap.parse_args(argv)

    sys.path.insert(0, str(REPO_ROOT))          # make `benchmarks` importable
    sys.path.insert(0, str(REPO_ROOT / "src"))
    from benchmarks import (bench_dags, bench_dispatch, bench_engine,
                            bench_fleet, bench_joins, bench_obs,
                            bench_policies, bench_serve, bench_telemetry,
                            bench_workloads)

    rc = 0
    if args.only in (None, "engine"):
        rc = max(rc, _check_gate(
            "engine", Path(args.baseline),
            lambda: bench_engine.gate_measure(repeats=args.repeats),
            (bench_engine.GATE_NODES, bench_engine.GATE_TASKS),
            args.threshold, args.update,
            canaries=[("completed count matches baseline",
                       lambda b, c: c["n_completed"] == b["n_completed"])]))
    if args.only in (None, "workloads"):
        rc = max(rc, _check_gate(
            "workloads", Path(args.workloads_baseline),
            lambda: bench_workloads.gate_measure(repeats=args.repeats),
            (bench_workloads.GATE_NODES, bench_workloads.GATE_TASKS),
            args.threshold, args.update,
            canaries=[
                ("completed count matches baseline",
                 lambda b, c: c["n_completed"] == b["n_completed"]),
                ("provisioner grew the pool",
                 lambda b, c: c["n_allocated"] > 0),
                ("provisioner shrank the pool",
                 lambda b, c: c["n_released"] > 0),
                ("JSONL replay metrics identical",
                 lambda b, c: bool(c["replay_identical"])),
            ]))
    if args.only in (None, "joins"):
        rc = max(rc, _check_gate(
            "joins", Path(args.joins_baseline),
            lambda: bench_joins.gate_measure(repeats=args.repeats),
            (bench_joins.GATE_NODES, bench_joins.GATE_TASKS),
            args.threshold, args.update,
            canaries=[
                ("completed count matches baseline",
                 lambda b, c: c["n_completed"] == b["n_completed"]),
                ("data-aware beats first-available on cache-hit ratio",
                 lambda b, c: c["hit_advantage"] > 0),
                ("incremental scores bit-match brute-force reference",
                 lambda b, c: bool(c["scores_match_reference"])),
                ("v1 trace replays to bit-identical RunMetrics",
                 lambda b, c: bool(c["v1_replay_identical"])),
            ]))
    if args.only in (None, "policies"):
        rc = max(rc, _check_gate(
            "policies", Path(args.policies_baseline),
            lambda: bench_policies.gate_measure(repeats=args.repeats),
            (bench_policies.GATE_NODES, bench_policies.GATE_TASKS),
            args.threshold, args.update,
            canaries=[
                ("completed count matches baseline",
                 lambda b, c: c["n_completed"] == b["n_completed"]),
                ("exponential responds at least as well as one-at-a-time "
                 "under bursty arrivals",
                 lambda b, c: c["bursty_exp_avg_slowdown"]
                 <= c["bursty_one_avg_slowdown"]),
                ("sim + runtime RunReport schemas identical",
                 lambda b, c: bool(c["schema_parity"])),
                ("rebalance release beats discard on post-shrink hit ratio",
                 lambda b, c: c["rebalance_hit_advantage"] >= 0),
            ]))
    if args.only in (None, "fleet"):
        rc = max(rc, _check_gate(
            "fleet", Path(args.fleet_baseline),
            lambda: bench_fleet.gate_measure(repeats=args.repeats),
            (bench_fleet.GATE_NODES, bench_fleet.GATE_TASKS),
            args.threshold, args.update,
            canaries=[
                ("completed count matches baseline",
                 lambda b, c: c["n_completed"] == b["n_completed"]),
                ("every host-count cell drained",
                 lambda b, c: bool(c["all_drained"])),
                ("aggregate cache bandwidth monotonic 1 -> 2 -> 4 hosts",
                 lambda b, c: bool(c["bw_monotonic"])),
                ("fleet trace replay matches single-process exactly",
                 lambda b, c: bool(c["parity"])),
            ]))
    if args.only in (None, "dispatch"):
        rc = max(rc, _check_gate(
            "dispatch", Path(args.dispatch_baseline),
            lambda: bench_dispatch.gate_measure(repeats=args.repeats),
            (bench_dispatch.GATE_NODES, bench_dispatch.GATE_TASKS),
            args.threshold, args.update,
            canaries=[
                ("completed count matches baseline",
                 lambda b, c: c["n_completed"] == b["n_completed"]),
                ("batched wire >= 3x unbatched on the same storm",
                 lambda b, c: c["batched_speedup"] >= 3.0),
                ("every hierarchical curve cell drained",
                 lambda b, c: bool(c["curve_drained"])),
                ("hierarchical tasks/s monotonic 1 -> 2 -> 4 hosts",
                 lambda b, c: bool(c["curve_monotonic"])),
                ("hierarchical+batched replay matches single-process",
                 lambda b, c: bool(c["parity"])),
            ]))
    if args.only in (None, "obs"):
        rc = max(rc, _check_gate(
            "obs", Path(args.obs_baseline),
            lambda: bench_obs.gate_measure(repeats=args.repeats),
            (bench_obs.GATE_NODES, bench_obs.GATE_TASKS),
            args.threshold, args.update,
            canaries=[
                ("completed count matches baseline",
                 lambda b, c: c["n_completed"] == b["n_completed"]),
                ("events-on central CPU <= 10% over events-off",
                 lambda b, c: c["overhead_ratio"] <= 1.10),
                ("zero dropped events at default ring capacity",
                 lambda b, c: c["dropped"] == 0),
                ("sim<->fleet placement agreement >= 99%",
                 lambda b, c: c["placement_agreement"] >= 0.99),
            ]))
    if args.only in (None, "dags"):
        rc = max(rc, _check_gate(
            "dags", Path(args.dags_baseline),
            lambda: bench_dags.gate_measure(repeats=args.repeats),
            (bench_dags.GATE_NODES, bench_dags.GATE_TASKS),
            args.threshold, args.update,
            canaries=[
                ("completed count matches baseline",
                 lambda b, c: c["n_completed"] == b["n_completed"]),
                ("producer placement beats outputs-ignored on hit ratio",
                 lambda b, c: c["hit_delta"] > 0),
                ("incremental scores (produced oids) bit-match reference",
                 lambda b, c: bool(c["scores_match_reference"])),
                ("reduce tree fully released and drained",
                 lambda b, c: bool(c["tree_all_completed"])),
                ("dep-free workload bit-identical under both scoring modes",
                 lambda b, c: bool(c["dep_free_knob_inert"])),
                ("dep-free metrics fingerprint matches committed baseline",
                 lambda b, c: c["dep_free_fingerprint"]
                 == b["dep_free_fingerprint"]),
            ]))
    if args.only in (None, "serve"):
        rc = max(rc, _check_gate(
            "serve", Path(args.serve_baseline),
            lambda: bench_serve.gate_measure(repeats=args.repeats),
            (bench_serve.GATE_NODES, bench_serve.GATE_TASKS),
            args.threshold, args.update,
            canaries=[
                ("completed count matches baseline",
                 lambda b, c: c["n_completed"] == b["n_completed"]),
                ("max-cache-hit beats first-available on reused-KV bytes",
                 lambda b, c: c["reused_kv_gap"] > 0),
                ("provisioner grew the replica pool",
                 lambda b, c: c["drp_allocated"] > 0),
                ("provisioner shrank the replica pool",
                 lambda b, c: c["drp_released"] > 0),
                ("events-off report bit-identical to events-on",
                 lambda b, c: bool(c["events_identical"])),
            ]))
    if args.only in (None, "telemetry"):
        rc = max(rc, _check_gate(
            "telemetry", Path(args.telemetry_baseline),
            lambda: bench_telemetry.gate_measure(repeats=args.repeats),
            (bench_telemetry.GATE_NODES, bench_telemetry.GATE_TASKS),
            args.threshold, args.update,
            canaries=[
                ("completed count matches baseline",
                 lambda b, c: c["n_completed"] == b["n_completed"]),
                ("metrics-on central CPU <= 10% over metrics-off",
                 lambda b, c: c["overhead_ratio"] <= 1.10),
                ("completion counter matches completions",
                 lambda b, c: bool(c["counter_matches_completions"])),
                ("metrics-off run scheduling-identical to metrics-on",
                 lambda b, c: bool(c["metrics_off_identical"])),
                ("per-host bandwidth gauges reconcile with ledger "
                 "within 5%",
                 lambda b, c: c["bw_gap"] <= 0.05),
            ]))
    return rc


if __name__ == "__main__":
    sys.exit(main())

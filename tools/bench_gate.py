#!/usr/bin/env python
"""Engine-performance regression gate.

Replays benchmarks/bench_engine.py's small fixed configuration (GATE_NODES x
GATE_TASKS, incremental solver, best-of-N wall clock) and compares against
the ``gate`` entry of the committed BENCH_engine.json baseline.  Fails (exit
1) when wall-clock regresses more than ``--threshold`` (default 25%) -- the
guard that keeps the incremental engine from quietly rotting back toward the
naive solver's O(F^2) behaviour.

    PYTHONPATH=src python tools/bench_gate.py                # repo root
    PYTHONPATH=src python -m benchmarks.run --gate           # via the runner

Regenerate the baseline (e.g. after an intentional engine change or on new
hardware) with:

    PYTHONPATH=src python -m benchmarks.bench_engine --out BENCH_engine.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=str(REPO_ROOT / "BENCH_engine.json"))
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed fractional wall-clock regression")
    ap.add_argument("--repeats", type=int, default=3,
                    help="runs per measurement; best-of-N is compared")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline's gate entry instead of failing")
    args = ap.parse_args(argv)

    sys.path.insert(0, str(REPO_ROOT))          # make `benchmarks` importable
    sys.path.insert(0, str(REPO_ROOT / "src"))
    from benchmarks.bench_engine import GATE_NODES, GATE_TASKS, gate_measure

    baseline_path = Path(args.baseline)
    if not baseline_path.exists():
        print(f"bench_gate: no baseline at {baseline_path}; run "
              f"`python -m benchmarks.bench_engine` first", file=sys.stderr)
        return 1
    baseline = json.loads(baseline_path.read_text())
    gate = baseline.get("gate")
    if not gate:
        print("bench_gate: baseline has no 'gate' entry", file=sys.stderr)
        return 1
    if (gate.get("n_nodes"), gate.get("n_tasks")) != (GATE_NODES, GATE_TASKS):
        print(f"bench_gate: baseline gate shape {gate.get('n_nodes')}x"
              f"{gate.get('n_tasks')} != code's {GATE_NODES}x{GATE_TASKS}; "
              f"regenerate the baseline", file=sys.stderr)
        return 1

    current = gate_measure(repeats=args.repeats)
    base_wall, cur_wall = gate["wall_s"], current["wall_s"]
    ratio = cur_wall / max(base_wall, 1e-9)
    verdict = "OK" if ratio <= 1.0 + args.threshold else "REGRESSION"
    print(f"bench_gate: engine wall {cur_wall:.3f}s vs baseline "
          f"{base_wall:.3f}s ({ratio:.2f}x, threshold "
          f"{1.0 + args.threshold:.2f}x) -> {verdict}")
    # a correctness canary rides along: the gate run must complete every task
    if current["n_completed"] != gate["n_completed"]:
        print(f"bench_gate: completed {current['n_completed']} != baseline "
              f"{gate['n_completed']} -- engine behaviour changed",
              file=sys.stderr)
        return 1
    if verdict == "REGRESSION":
        if args.update:
            baseline["gate"] = current
            baseline_path.write_text(
                json.dumps(baseline, indent=2, sort_keys=True) + "\n")
            print(f"bench_gate: baseline gate updated in {baseline_path}")
            return 0
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

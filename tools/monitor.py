#!/usr/bin/env python
"""Terminal fleet monitor: live view of a run's telemetry plane.

Two attachment modes (DESIGN.md §13):

  --attach HOST:PORT   poll a running engine's `TelemetryServer` (one JSON
                       line per poll; read-only, cannot perturb the run
                       beyond a registry snapshot)
  --tail PATH          follow a `Telemetry` JSONL sink (works live -- the
                       sink flushes per sample -- or post-mortem)

Either way the dashboard shows the central dispatcher view (queue depth,
pool size, pump/dispatch counters), a per-host table (age of the last
stats frame, cache bytes, delivered cache bandwidth derived from
successive cumulative byte gauges, tasks done), the cluster-wide
aggregate, and the health-event tail.

Examples:
  python tools/monitor.py --attach 127.0.0.1:7771
  python tools/monitor.py --tail /tmp/run.metrics.jsonl
  python tools/monitor.py --tail /tmp/run.metrics.jsonl --once
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs.metrics import (METRICS_SCHEMA_VERSION,  # noqa: E402
                               fetch_telemetry, merge_snapshots)

_CLEAR = "\x1b[2J\x1b[H"


def _mb(n: float) -> str:
    return f"{n / 1e6:10.1f}"


def _rate(prev: dict | None, cur: dict, dt: float, *names: str) -> float:
    """Delivered bytes/s between two samples of cumulative byte gauges."""
    if prev is None or dt <= 0:
        return 0.0
    pg, cg = prev.get("gauges", {}), cur.get("gauges", {})
    d = sum(cg.get(n, 0) - pg.get(n, 0) for n in names)
    return max(d, 0) / dt


def render(sample: dict, health: list[dict],
           prev: dict | None = None) -> str:
    """One dashboard frame from a telemetry sample (and the previous one,
    for bandwidth rates).  Pure string-building, so tests can pin it."""
    out: list[str] = []
    t = sample.get("t", 0.0)
    central = sample.get("metrics", {})
    c, g = central.get("counters", {}), central.get("gauges", {})
    hosts = sample.get("hosts", {})
    out.append(f"== data-diffusion monitor ==  t={t:.2f}s  "
               f"hosts={len(hosts)}")
    out.append(f"  queue={g.get('sched.queue_depth', 0):>6}  "
               f"pool={g.get('pool.size', 0):>4}  "
               f"submitted={c.get('sched.tasks_submitted', 0):>7}  "
               f"completed={c.get('sched.tasks_completed', 0):>7}  "
               f"failed={c.get('sched.tasks_failed', 0)}")
    out.append(f"  pumps={c.get('sched.pump_calls', 0):>7}  "
               f"dispatches={c.get('sched.dispatches', 0):>7}  "
               f"leases={c.get('wire.leases', 0)}  "
               f"claims={c.get('wire.claims', 0)}  "
               f"conflicts={c.get('wire.claim_conflicts', 0)}")
    if hosts:
        prev_hosts = (prev or {}).get("hosts", {})
        dt = t - (prev or {}).get("t", t)
        out.append("")
        out.append("  host     age_s   cache_MB    tasks   bw_MB/s "
                   "(local+c2c+store)")
        agg = {"counters": {}, "gauges": {}, "histograms": {}}
        agg_bw = 0.0
        for h in sorted(hosts):
            snap = hosts[h].get("metrics", {})
            hg = snap.get("gauges", {})
            pv = prev_hosts.get(h, {}).get("metrics")
            bw = _rate(pv, snap, dt, "bw.bytes_local", "bw.bytes_c2c",
                       "bw.bytes_store")
            agg_bw += bw
            agg = merge_snapshots(agg, snap)
            out.append(f"  {h:<7}{hosts[h].get('age_s', 0.0):>7.2f} "
                       f"{_mb(hg.get('cache.bytes', 0))} "
                       f"{int(hg.get('host.tasks_done', 0)):>8} "
                       f"{bw / 1e6:>9.1f}")
        ag = agg.get("gauges", {})
        out.append(f"  TOTAL          {_mb(ag.get('cache.bytes', 0))} "
                   f"{int(ag.get('host.tasks_done', 0)):>8} "
                   f"{agg_bw / 1e6:>9.1f}")
    else:
        # single-process runs: central gauges carry the cache/bw totals
        bw = _rate((prev or {}).get("metrics"), central,
                   t - (prev or {}).get("t", t),
                   "bw.bytes_local", "bw.bytes_c2c", "bw.bytes_store")
        out.append(f"  cache_MB={g.get('cache.bytes', 0) / 1e6:.1f}  "
                   f"hits={g.get('cache.hits', 0)}  "
                   f"misses={g.get('cache.misses', 0)}  "
                   f"bw_MB/s={bw / 1e6:.1f}")
    if health:
        out.append("")
        out.append("  health (last {}):".format(min(len(health), 5)))
        for ev in health[-5:]:
            out.append(f"    [{ev.get('severity', '?'):>7}] "
                       f"t={ev.get('t', 0.0):.2f} {ev.get('rule', '?')} "
                       f"host={ev.get('host') or '-'} "
                       f"{ev.get('detail', '')}")
    return "\n".join(out)


def _attach_loop(addr: str, interval: float, once: bool) -> int:
    host, _, port = addr.rpartition(":")
    prev = None
    while True:
        try:
            rec = fetch_telemetry(host or "127.0.0.1", int(port))
        except OSError as e:
            print(f"monitor: cannot reach {addr}: {e}", file=sys.stderr)
            return 1
        sample = rec.get("sample")
        if sample is None:
            frame = "== data-diffusion monitor ==  (no samples yet)"
        else:
            frame = render(sample, rec.get("health", []), prev)
            prev = sample
        if once:
            print(frame)
            return 0
        print(_CLEAR + frame, flush=True)
        time.sleep(interval)


def _tail_loop(path: str, interval: float, once: bool) -> int:
    """Follow a metrics sink.  Tolerates a file that is still being
    written: incomplete trailing lines are retried on the next poll."""
    f = open(path)
    header = json.loads(f.readline())
    if header.get("kind") != "metrics_header" \
            or header.get("schema_version") != METRICS_SCHEMA_VERSION:
        print(f"monitor: {path} is not a v{METRICS_SCHEMA_VERSION} "
              f"metrics sink", file=sys.stderr)
        return 1
    prev = sample = None
    health: list[dict] = []
    buf = ""
    while True:
        buf += f.read()
        *lines, buf = buf.split("\n")
        for line in lines:
            if not line.strip():
                continue
            rec = json.loads(line)
            if rec.get("kind") == "metrics":
                prev, sample = sample, rec
            elif rec.get("kind") == "health":
                health.append(rec)
        if sample is not None:
            frame = render(sample, health, prev)
        else:
            frame = "== data-diffusion monitor ==  (no samples yet)"
        if once:
            print(frame)
            return 0
        print(_CLEAR + frame, flush=True)
        time.sleep(interval)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--attach", metavar="HOST:PORT",
                     help="poll a running engine's TelemetryServer")
    src.add_argument("--tail", metavar="PATH",
                     help="follow a Telemetry JSONL sink")
    ap.add_argument("--interval", type=float, default=0.5,
                    help="redraw interval in seconds (default 0.5)")
    ap.add_argument("--once", action="store_true",
                    help="print one frame and exit (no screen clearing)")
    args = ap.parse_args(argv)
    try:
        if args.attach:
            return _attach_loop(args.attach, args.interval, args.once)
        return _tail_loop(args.tail, args.interval, args.once)
    except KeyboardInterrupt:
        return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""Experiment CLI: describe / run / sweep a declarative ExperimentSpec JSON
on either engine (DESIGN.md §7).

Validate a spec and summarise what it would run:

    PYTHONPATH=src python tools/run_experiment.py describe spec.json

Execute it (the SAME spec file runs on both engines):

    PYTHONPATH=src python tools/run_experiment.py run spec.json --engine sim
    PYTHONPATH=src python tools/run_experiment.py run spec.json \
        --engine runtime --time-scale 0

Sweep a cartesian grid over spec fields (seed-paired; writes
manifest.json + results.jsonl to --out-dir):

    PYTHONPATH=src python tools/run_experiment.py sweep spec.json \
        --set provisioner.policy=one-at-a-time,additive,exponential \
        --set 'cache.capacity_bytes=[0,50000000000]' \
        --seeds 0,1 --out-dir results/sweep

``--set path=v1,v2,...`` values are JSON-parsed individually (falling back
to strings); a value starting with ``[`` is parsed as one JSON list of cell
values, so whole dicts (e.g. arrival bindings) can be swept too.

An example spec document lives in the `repro.experiments` module docstring;
``describe`` round-trips the file through the strict parser, so typos in
field names hard-error instead of silently falling back to defaults.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.experiments import (ExperimentSpec, RunReport, Sweep,  # noqa: E402
                               build_workload, run_experiment)


def _load_spec(path: str) -> ExperimentSpec:
    try:
        return ExperimentSpec.load(path)
    except (OSError, ValueError) as e:
        raise SystemExit(f"run_experiment: bad spec {path!r}: {e}")


def _warn_drops(rep: RunReport) -> None:
    """Surface recorder ring overflow: a lossy trace silently weakens every
    downstream consumer (diff replay, chrome export), so say so loudly."""
    dropped = rep.telemetry.get("recorder_dropped", 0)
    if dropped:
        print(f"# WARNING: recorder dropped {dropped} event(s) (ring full) "
              f"-- trace/divergence output is incomplete; raise "
              f"observe.ring_capacity", file=sys.stderr)


def _report_out(rep: RunReport, out: str | None, *, quiet_pool: bool = True):
    d = rep.as_dict()
    if out:
        Path(out).write_text(json.dumps(d, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {out}", file=sys.stderr)
    if quiet_pool and len(d["pool_log"]) > 8:
        # keep stdout readable; the full membership log lives in --out
        d["pool_log"] = (d["pool_log"][:4]
                         + [f"... {len(rep.pool_log) - 4} more samples"])
    json.dump(d, sys.stdout, indent=2, sort_keys=True)
    print()


def cmd_describe(args) -> int:
    spec = _load_spec(args.spec)
    wl = build_workload(spec.workload)
    print(json.dumps({
        "spec": spec.to_dict(),
        "fingerprint": spec.fingerprint(),
        "workload": {
            "n_tasks": len(wl),
            "n_objects": len(wl.objects),
            "arrival_span_s": wl.duration,
            "offered_load_tps": wl.offered_load(),
            "mean_inputs_per_task": wl.mean_inputs_per_task(),
            "total_input_bytes": sum(ob.size_bytes for ob in wl.objects),
        },
    }, indent=2, sort_keys=True))
    return 0


def cmd_run(args) -> int:
    from repro.experiments import RuntimeEngine, make_engine

    spec = _load_spec(args.spec)
    run_kw = {}
    if args.engine in ("runtime", "serve"):
        run_kw = {"time_scale": args.time_scale, "timeout": args.timeout,
                  "barrier_every": args.barrier_every}
    if args.engine == "runtime" and args.task_fn is not None:
        # fleet runs name their callable; hosts resolve module:attr
        eng = RuntimeEngine(task_fn_name=args.task_fn)
    else:
        eng = make_engine(args.engine)
    try:
        eng.prepare(spec)
        rep = eng.run(**run_kw)
        if args.trace_out:
            # arrivals + measured per-task outcomes, one file (trace v3):
            # the input to the `diff` subcommand's sim-twin replay
            from repro.workloads import record_v3

            record_v3(eng.workload, args.trace_out, eng.last_outcomes)
            print(f"# wrote {args.trace_out} "
                  f"({len(eng.last_outcomes)} outcomes)", file=sys.stderr)
    finally:
        eng.shutdown()
    _warn_drops(rep)
    _report_out(rep, args.out)
    return 0


def cmd_diff(args) -> int:
    """sim<->real divergence: replay a v3 trace's arrival half through the
    sim twin of the spec, join predicted vs measured outcomes by task id."""
    import dataclasses

    from repro.obs import diff_outcomes, format_divergence, sim_replay_outcomes
    from repro.workloads import read_outcomes

    spec = _load_spec(args.spec)
    try:
        measured = read_outcomes(args.trace)
    except (OSError, ValueError) as e:
        raise SystemExit(f"run_experiment: bad trace {args.trace!r}: {e}")
    predicted = sim_replay_outcomes(spec, trace_path=args.trace)
    div = diff_outcomes(measured, predicted)
    if args.out:
        Path(args.out).write_text(json.dumps(div, indent=2, sort_keys=True)
                                  + "\n")
        print(f"# wrote {args.out}", file=sys.stderr)
    if args.report:
        # attach the divergence to an existing report file in place
        # (RunReport.task_divergence is the programmatic surface)
        rep = RunReport.from_dict(json.loads(Path(args.report).read_text()))
        _warn_drops(rep)  # a lossy recording skews the divergence join too
        rep = dataclasses.replace(rep, task_divergence=div)
        Path(args.report).write_text(
            json.dumps(rep.as_dict(), indent=2, sort_keys=True) + "\n")
        print(f"# updated {args.report} (task_divergence)", file=sys.stderr)
    print(format_divergence(div))
    return 0


def _parse_set(items: list[str]) -> dict[str, list]:
    grid: dict[str, list] = {}
    for item in items:
        if "=" not in item:
            raise SystemExit(f"run_experiment: bad --set {item!r} "
                             f"(want path=v1,v2,...)")
        path, _, raw = item.partition("=")
        if raw.lstrip().startswith("["):
            try:
                values = json.loads(raw)
            except json.JSONDecodeError as e:
                raise SystemExit(f"run_experiment: bad --set JSON list "
                                 f"for {path!r}: {e}")
        else:
            values = []
            for tok in raw.split(","):
                try:
                    values.append(json.loads(tok))
                except json.JSONDecodeError:
                    values.append(tok)
        grid[path] = values
    return grid


def cmd_sweep(args) -> int:
    spec = _load_spec(args.spec)
    grid = _parse_set(args.set or [])
    if not grid:
        raise SystemExit("run_experiment: sweep needs at least one --set")
    seeds = [int(s) for s in args.seeds.split(",")] if args.seeds else None
    run_kw = {}
    if args.engine in ("runtime", "serve"):
        run_kw = {"time_scale": args.time_scale, "timeout": args.timeout}

    def progress(cell, rep):
        print(f"# cell {cell.index}: {cell.overrides} -> "
              f"completed {rep.n_completed}, hit {rep.cache_hit_ratio:.3f}, "
              f"slowdown {rep.avg_slowdown:.2f}x, "
              f"alloc +{rep.n_allocated}/-{rep.n_released}", file=sys.stderr)

    sw = Sweep(spec, grid, seeds=seeds, engine=args.engine)
    results = sw.run(out_dir=args.out_dir, run_kw=run_kw, progress=progress)
    print(json.dumps({
        "sweep": sw.name,
        "n_cells": len(results),
        "out_dir": args.out_dir,
        "cells": [{"index": c.index, "overrides": c.overrides,
                   "n_completed": r.n_completed,
                   "cache_hit_ratio": r.cache_hit_ratio,
                   "avg_slowdown": r.avg_slowdown,
                   "performance_index": r.performance_index}
                  for c, r in results],
    }, indent=2, sort_keys=True))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    d = sub.add_parser("describe", help="validate a spec + summarise it")
    d.add_argument("spec")
    d.set_defaults(fn=cmd_describe)

    r = sub.add_parser("run", help="execute a spec on one engine")
    r.add_argument("spec")
    r.add_argument("--engine", default="sim",
                   choices=["sim", "runtime", "serve"])
    r.add_argument("--time-scale", type=float, default=0.0,
                   help="runtime engine: wall s per workload s (0 = ASAP)")
    r.add_argument("--timeout", type=float, default=600.0)
    r.add_argument("--barrier-every", type=int, default=None,
                   help="runtime engine: batch-synchronous replay in "
                        "chunks of N (deterministic; the fleet-parity "
                        "submission mode) instead of arrival pacing")
    r.add_argument("--task-fn", default=None, metavar="MODULE:ATTR",
                   help="runtime engine, fleet specs (hosts>0): named task "
                        "callable each host resolves locally")
    r.add_argument("--out", default=None, help="also write the report JSON")
    r.add_argument("--trace-out", default=None, metavar="PATH",
                   help="write a v3 trace (arrivals + measured per-task "
                        "outcomes) for the diff subcommand")
    r.set_defaults(fn=cmd_run)

    f = sub.add_parser("diff", help="sim<->real per-task divergence: replay "
                                    "a recorded v3 trace through the sim "
                                    "twin and join outcomes by task id")
    f.add_argument("spec", help="the spec the trace was recorded under")
    f.add_argument("trace", help="v3 trace written by run --trace-out")
    f.add_argument("--out", default=None,
                   help="also write the divergence dict as JSON")
    f.add_argument("--report", default=None,
                   help="report JSON file (from run --out) to update in "
                        "place with task_divergence")
    f.set_defaults(fn=cmd_diff)

    s = sub.add_parser("sweep", help="cartesian grid over spec fields")
    s.add_argument("spec")
    s.add_argument("--engine", default="sim",
                   choices=["sim", "runtime", "serve"])
    s.add_argument("--set", action="append", metavar="PATH=V1,V2",
                   help="grid axis (repeatable)")
    s.add_argument("--seeds", default=None,
                   help="comma-separated seed-paired replications")
    s.add_argument("--time-scale", type=float, default=0.0)
    s.add_argument("--timeout", type=float, default=600.0)
    s.add_argument("--out-dir", default=None,
                   help="write manifest.json + results.jsonl here")
    s.set_defaults(fn=cmd_sweep)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""Experiment CLI: describe / run / sweep a declarative ExperimentSpec JSON
on either engine (DESIGN.md §7).

Validate a spec and summarise what it would run:

    PYTHONPATH=src python tools/run_experiment.py describe spec.json

Execute it (the SAME spec file runs on both engines):

    PYTHONPATH=src python tools/run_experiment.py run spec.json --engine sim
    PYTHONPATH=src python tools/run_experiment.py run spec.json \
        --engine runtime --time-scale 0

Sweep a cartesian grid over spec fields (seed-paired; writes
manifest.json + results.jsonl to --out-dir):

    PYTHONPATH=src python tools/run_experiment.py sweep spec.json \
        --set provisioner.policy=one-at-a-time,additive,exponential \
        --set 'cache.capacity_bytes=[0,50000000000]' \
        --seeds 0,1 --out-dir results/sweep

``--set path=v1,v2,...`` values are JSON-parsed individually (falling back
to strings); a value starting with ``[`` is parsed as one JSON list of cell
values, so whole dicts (e.g. arrival bindings) can be swept too.

An example spec document lives in the `repro.experiments` module docstring;
``describe`` round-trips the file through the strict parser, so typos in
field names hard-error instead of silently falling back to defaults.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.experiments import (ExperimentSpec, RunReport, Sweep,  # noqa: E402
                               build_workload, run_experiment)


def _load_spec(path: str) -> ExperimentSpec:
    try:
        return ExperimentSpec.load(path)
    except (OSError, ValueError) as e:
        raise SystemExit(f"run_experiment: bad spec {path!r}: {e}")


def _report_out(rep: RunReport, out: str | None, *, quiet_pool: bool = True):
    d = rep.as_dict()
    if out:
        Path(out).write_text(json.dumps(d, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {out}", file=sys.stderr)
    if quiet_pool and len(d["pool_log"]) > 8:
        # keep stdout readable; the full membership log lives in --out
        d["pool_log"] = (d["pool_log"][:4]
                         + [f"... {len(rep.pool_log) - 4} more samples"])
    json.dump(d, sys.stdout, indent=2, sort_keys=True)
    print()


def cmd_describe(args) -> int:
    spec = _load_spec(args.spec)
    wl = build_workload(spec.workload)
    print(json.dumps({
        "spec": spec.to_dict(),
        "fingerprint": spec.fingerprint(),
        "workload": {
            "n_tasks": len(wl),
            "n_objects": len(wl.objects),
            "arrival_span_s": wl.duration,
            "offered_load_tps": wl.offered_load(),
            "mean_inputs_per_task": wl.mean_inputs_per_task(),
            "total_input_bytes": sum(ob.size_bytes for ob in wl.objects),
        },
    }, indent=2, sort_keys=True))
    return 0


def cmd_run(args) -> int:
    spec = _load_spec(args.spec)
    run_kw = {}
    engine = args.engine
    if args.engine == "runtime":
        run_kw = {"time_scale": args.time_scale, "timeout": args.timeout,
                  "barrier_every": args.barrier_every}
        if args.task_fn is not None:
            # fleet runs name their callable; hosts resolve module:attr
            from repro.experiments import RuntimeEngine
            engine = RuntimeEngine(task_fn_name=args.task_fn)
    try:
        rep = run_experiment(spec, engine=engine, **run_kw)
    finally:
        if not isinstance(engine, str):
            engine.shutdown()
    _report_out(rep, args.out)
    return 0


def _parse_set(items: list[str]) -> dict[str, list]:
    grid: dict[str, list] = {}
    for item in items:
        if "=" not in item:
            raise SystemExit(f"run_experiment: bad --set {item!r} "
                             f"(want path=v1,v2,...)")
        path, _, raw = item.partition("=")
        if raw.lstrip().startswith("["):
            try:
                values = json.loads(raw)
            except json.JSONDecodeError as e:
                raise SystemExit(f"run_experiment: bad --set JSON list "
                                 f"for {path!r}: {e}")
        else:
            values = []
            for tok in raw.split(","):
                try:
                    values.append(json.loads(tok))
                except json.JSONDecodeError:
                    values.append(tok)
        grid[path] = values
    return grid


def cmd_sweep(args) -> int:
    spec = _load_spec(args.spec)
    grid = _parse_set(args.set or [])
    if not grid:
        raise SystemExit("run_experiment: sweep needs at least one --set")
    seeds = [int(s) for s in args.seeds.split(",")] if args.seeds else None
    run_kw = {}
    if args.engine == "runtime":
        run_kw = {"time_scale": args.time_scale, "timeout": args.timeout}

    def progress(cell, rep):
        print(f"# cell {cell.index}: {cell.overrides} -> "
              f"completed {rep.n_completed}, hit {rep.cache_hit_ratio:.3f}, "
              f"slowdown {rep.avg_slowdown:.2f}x, "
              f"alloc +{rep.n_allocated}/-{rep.n_released}", file=sys.stderr)

    sw = Sweep(spec, grid, seeds=seeds, engine=args.engine)
    results = sw.run(out_dir=args.out_dir, run_kw=run_kw, progress=progress)
    print(json.dumps({
        "sweep": sw.name,
        "n_cells": len(results),
        "out_dir": args.out_dir,
        "cells": [{"index": c.index, "overrides": c.overrides,
                   "n_completed": r.n_completed,
                   "cache_hit_ratio": r.cache_hit_ratio,
                   "avg_slowdown": r.avg_slowdown,
                   "performance_index": r.performance_index}
                  for c, r in results],
    }, indent=2, sort_keys=True))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    d = sub.add_parser("describe", help="validate a spec + summarise it")
    d.add_argument("spec")
    d.set_defaults(fn=cmd_describe)

    r = sub.add_parser("run", help="execute a spec on one engine")
    r.add_argument("spec")
    r.add_argument("--engine", default="sim", choices=["sim", "runtime"])
    r.add_argument("--time-scale", type=float, default=0.0,
                   help="runtime engine: wall s per workload s (0 = ASAP)")
    r.add_argument("--timeout", type=float, default=600.0)
    r.add_argument("--barrier-every", type=int, default=None,
                   help="runtime engine: batch-synchronous replay in "
                        "chunks of N (deterministic; the fleet-parity "
                        "submission mode) instead of arrival pacing")
    r.add_argument("--task-fn", default=None, metavar="MODULE:ATTR",
                   help="runtime engine, fleet specs (hosts>0): named task "
                        "callable each host resolves locally")
    r.add_argument("--out", default=None, help="also write the report JSON")
    r.set_defaults(fn=cmd_run)

    s = sub.add_parser("sweep", help="cartesian grid over spec fields")
    s.add_argument("spec")
    s.add_argument("--engine", default="sim", choices=["sim", "runtime"])
    s.add_argument("--set", action="append", metavar="PATH=V1,V2",
                   help="grid axis (repeatable)")
    s.add_argument("--seeds", default=None,
                   help="comma-separated seed-paired replications")
    s.add_argument("--time-scale", type=float, default=0.0)
    s.add_argument("--timeout", type=float, default=600.0)
    s.add_argument("--out-dir", default=None,
                   help="write manifest.json + results.jsonl here")
    s.set_defaults(fn=cmd_sweep)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())

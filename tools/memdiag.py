import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re, jax
from repro.configs import REGISTRY, SHAPES
from repro.launch.cellrun import _compile_once
from repro.launch.mesh import make_production_mesh

arch, shape_name = sys.argv[1], sys.argv[2]
mesh = make_production_mesh(multi_pod=False)
cfg = REGISTRY[arch]; shape = SHAPES[shape_name]
c, _, _ = _compile_once(cfg, shape, mesh, None, True)
ma = c.memory_analysis()
print(f"{arch} x {shape_name}: temp={ma.temp_size_in_bytes/1e9:.2f}GB args={ma.argument_size_in_bytes/1e9:.2f}GB out={ma.output_size_in_bytes/1e9:.2f}GB alias={ma.alias_size_in_bytes/1e9:.2f}GB")
txt = c.as_text()
sizes = {}
for m in re.finditer(r"(bf16|f32|s32|u32|s8|pred)\[([\d,]+)\]", txt):
    dims = [int(d) for d in m.group(2).split(",")]
    n = 1
    for d in dims: n *= d
    b = n * {"bf16":2,"f32":4,"s32":4,"u32":4,"s8":1,"pred":1}[m.group(1)]
    key = f"{m.group(1)}[{m.group(2)}]"
    if b > 100e6: sizes[key] = max(sizes.get(key,0), b)
for kk, vv in sorted(sizes.items(), key=lambda x:-x[1])[:14]:
    print(f"  {vv/1e9:7.2f} GB  {kk}  x{txt.count(kk)}")

#!/usr/bin/env python
"""Workload CLI: generate seeded traces, replay them through the simulator.

Generate a trace (versioned JSONL, bit-reproducible from the seed):

    PYTHONPATH=src python tools/mk_workload.py generate \
        --arrivals sine --rate 16 --amplitude 15 --period 120 \
        --popularity zipf --alpha 1.1 \
        --tasks 5000 --objects 250 --object-mb 10 --compute-s 0.5 \
        --seed 0 --out sine.jsonl

Replay it through an engine (optionally elastic) and print the run's
unified RunReport as JSON -- ``run`` is a thin wrapper that builds an
``repro.experiments.ExperimentSpec`` from the flags and executes it
(see tools/run_experiment.py for the full spec-file CLI):

    PYTHONPATH=src python tools/mk_workload.py run sine.jsonl \
        --nodes 64 --policy max-compute-util --provision

``run`` accepts either a trace file or ``-`` plus the same generation flags
(generate-and-run without touching disk).

Multi-input (join) workloads -- each task stacks K correlated objects, the
§4.3 shape -- via ``--inputs-per-task K --input-corr C`` on both paths:

    PYTHONPATH=src python tools/mk_workload.py run - \
        --popularity zipf --inputs-per-task 3 --input-corr 0.8 \
        --tasks 2000 --objects 200 --nodes 64 --policy max-cache-hit

Structured DAG pipelines (tasks depend on other tasks' produced outputs;
recorded as trace v4, held/released by the dispatcher's ready-set) replace
the arrival/popularity recipe via ``--dag`` on both paths:

    PYTHONPATH=src python tools/mk_workload.py run - \
        --dag all_pairs --dag-n 16 --nodes 16 --policy max-compute-util

Multi-turn serving sessions (each turn a k-input join over block-aligned
prefix-KV pages; Zipf-shared system prompts; see repro.workloads.sessions)
via ``--sessions N`` on both paths, typically driven through the serve
engine:

    PYTHONPATH=src python tools/mk_workload.py run - \
        --sessions 200 --turns 3 --zipf-s 1.2 --block 64 \
        --arrivals diurnal --nodes 4 --engine serve
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.core.provisioner import AllocationPolicy                # noqa: E402
from repro.core.testbeds import TESTBEDS                           # noqa: E402
from repro.experiments import (CacheSpec, ClusterSpec,             # noqa: E402
                               ExperimentSpec, ProvisionerSpec,
                               WorkloadSpec, run_experiment)
from repro import workloads as W                                   # noqa: E402

MB = 10**6


def _build_arrivals(args) -> W.ArrivalProcess:
    if args.arrivals == "batch":
        return W.BatchArrivals()
    if args.arrivals == "poisson":
        return W.PoissonArrivals(args.rate)
    if args.arrivals == "sine":
        amp = args.amplitude if args.amplitude is not None else 0.9 * args.rate
        return W.SineWaveArrivals(mean_rate=args.rate, amplitude=amp,
                                  period_s=args.period)
    if args.arrivals == "bursty":
        burst = args.burst_rate if args.burst_rate is not None \
            else 10 * args.rate
        return W.BurstyArrivals(base_rate=args.rate, burst_rate=burst,
                                burst_every_s=args.period,
                                burst_len_s=args.burst_len)
    if args.arrivals == "diurnal":
        peak = args.burst_rate if args.burst_rate is not None \
            else 10 * args.rate
        return W.DiurnalArrivals(peak_rate=peak, trough_rate=args.rate,
                                 day_s=args.period)
    raise SystemExit(f"unknown arrivals {args.arrivals!r}")


def _build_popularity(args) -> W.PopularityModel:
    k, corr = args.inputs_per_task, args.input_corr
    if args.popularity == "scan":
        return W.UniformScan(k=k)
    if args.popularity == "zipf":
        return W.ZipfPopularity(alpha=args.alpha, k=k, corr=corr)
    if args.popularity == "shifting":
        return W.ShiftingWorkingSet(working_set=args.working_set,
                                    shift_every=args.shift_every,
                                    k=k, corr=corr)
    if args.popularity == "stacking":
        return W.StackingTrace(locality=args.locality,
                               shuffle_seed=args.seed, k=k, corr=corr)
    raise SystemExit(f"unknown popularity {args.popularity!r}")


def _dag_binding(args) -> dict:
    """The ``{"kind": ..., ...kwargs}`` DAG binding the flags describe --
    the same dict WorkloadSpec.dag takes, so generate and run agree."""
    base = {"object_bytes": int(args.object_mb * MB), "dt": args.dag_dt,
            "seed": args.seed}
    if args.dag == "all_pairs":
        return {"kind": "all_pairs", "n_objects": args.dag_n, **base}
    if args.dag == "reduce_tree":
        return {"kind": "reduce_tree", "n_leaves": args.dag_n,
                "fanin": args.fanin, **base}
    if args.dag == "stacking_pyramid":
        return {"kind": "stacking_pyramid", "n_groups": args.dag_n,
                "group_size": args.group_size, **base}
    raise SystemExit(f"unknown dag {args.dag!r}")


def _sessions_binding(args) -> dict:
    """The ``{"kind": "chat", ...}`` session binding the flags describe --
    the same dict WorkloadSpec.sessions takes, so generate and run agree."""
    return {"kind": "chat", "n_sessions": args.sessions,
            "turns_per_session": args.turns,
            "n_system_prompts": args.system_prompts,
            "zipf_s": args.zipf_s,
            "system_prompt_blocks": args.sys_blocks,
            "turn_blocks": args.turn_blocks,
            "block": args.block,
            "model": args.model,
            "kv_bytes_per_token": args.kv_bpt,
            "think_time_s": args.think_s,
            "turn_seconds": args.turn_s,
            "arrivals": _build_arrivals(args).spec(),
            "seed": args.seed}


def _generate(args) -> W.Workload:
    if args.sessions is not None and args.dag is not None:
        raise SystemExit("--sessions and --dag are mutually exclusive")
    if args.sessions is not None:
        return W.build_sessions(_sessions_binding(args), name=args.name)
    if args.dag is not None:
        return W.build_dag(_dag_binding(args), name=args.name)
    return W.generate(
        args.name, _build_arrivals(args), _build_popularity(args),
        n_tasks=args.tasks, n_objects=args.objects,
        object_bytes=int(args.object_mb * MB),
        compute_seconds=args.compute_s,
        store_metadata_ops=args.meta_ops, seed=args.seed)


def _add_gen_flags(p: argparse.ArgumentParser) -> None:
    p.add_argument("--name", default="wl")
    p.add_argument("--arrivals", default="poisson",
                   choices=["batch", "poisson", "sine", "bursty", "diurnal"])
    p.add_argument("--rate", type=float, default=8.0,
                   help="mean (poisson/sine) / base (bursty) / trough "
                        "(diurnal) arrival rate, tasks/s")
    p.add_argument("--amplitude", type=float, default=None,
                   help="sine amplitude (default 0.9*rate)")
    p.add_argument("--period", type=float, default=120.0,
                   help="sine/diurnal period or bursty inter-burst gap, s")
    p.add_argument("--burst-rate", type=float, default=None,
                   help="bursty burst rate / diurnal peak rate, tasks/s")
    p.add_argument("--burst-len", type=float, default=10.0)
    p.add_argument("--popularity", default="zipf",
                   choices=["scan", "zipf", "shifting", "stacking"])
    p.add_argument("--alpha", type=float, default=1.1)
    p.add_argument("--working-set", type=int, default=32)
    p.add_argument("--shift-every", type=int, default=500)
    p.add_argument("--locality", type=int, default=10)
    p.add_argument("--inputs-per-task", type=int, default=1, metavar="K",
                   help="join width: objects read per task (k-input tasks; "
                        "the §4.3 stacked reads)")
    p.add_argument("--input-corr", type=float, default=1.0, metavar="C",
                   help="probability an extra input comes from the primary "
                        "draw's neighborhood / stack group instead of an "
                        "independent draw (0..1; ignored by --popularity "
                        "scan)")
    p.add_argument("--dag", default=None,
                   choices=["all_pairs", "reduce_tree", "stacking_pyramid"],
                   help="emit a structured DAG pipeline instead of the "
                        "arrival/popularity recipe (tasks carry deps on "
                        "their producers; trace records as v4)")
    p.add_argument("--dag-n", type=int, default=8, metavar="N",
                   help="DAG size: n_objects (all_pairs) / n_leaves "
                        "(reduce_tree) / n_groups (stacking_pyramid)")
    p.add_argument("--fanin", type=int, default=2,
                   help="reduce_tree children per reduce task")
    p.add_argument("--group-size", type=int, default=4,
                   help="stacking_pyramid images per stack")
    p.add_argument("--dag-dt", type=float, default=0.0,
                   help="seconds between DAG task arrivals (0 = all at t=0; "
                        "the ready-set alone sequences the stages)")
    p.add_argument("--sessions", type=int, default=None, metavar="N",
                   help="emit N multi-turn serving sessions instead of the "
                        "arrival/popularity recipe (inputs are prefix-KV "
                        "page chains; --arrivals flags pace the session "
                        "starts)")
    p.add_argument("--turns", type=int, default=3,
                   help="turns per session (each extends the prefix chain)")
    p.add_argument("--system-prompts", type=int, default=8,
                   help="distinct system prompts shared Zipf-style")
    p.add_argument("--zipf-s", type=float, default=1.1,
                   help="Zipf skew over system prompts")
    p.add_argument("--block", type=int, default=64,
                   help="tokens per KV page (prefix-chain alignment)")
    p.add_argument("--sys-blocks", type=int, default=4,
                   help="blocks per system prompt")
    p.add_argument("--turn-blocks", type=int, default=2,
                   help="new blocks appended per turn")
    p.add_argument("--think-s", type=float, default=4.0,
                   help="seconds between a session's turns")
    p.add_argument("--turn-s", type=float, default=0.05,
                   help="compute seconds per turn (decode proxy)")
    p.add_argument("--model", default=None,
                   help="arch id (repro.configs) to derive KV bytes/token")
    p.add_argument("--kv-bpt", type=int, default=4096,
                   help="KV bytes per token when --model is not given")
    p.add_argument("--tasks", type=int, default=5_000)
    p.add_argument("--objects", type=int, default=250)
    p.add_argument("--object-mb", type=float, default=10.0)
    p.add_argument("--compute-s", type=float, default=0.5)
    p.add_argument("--meta-ops", type=int, default=0)
    p.add_argument("--seed", type=int, default=0)


def cmd_generate(args) -> int:
    wl = _generate(args)
    n = W.record(wl, args.out)
    print(f"# wrote {n} tasks / {len(wl.objects)} objects to {args.out} "
          f"(arrival span {wl.duration:.1f}s, "
          f"offered {wl.offered_load():.2f} tasks/s)", file=sys.stderr)
    return 0


def _experiment_spec(args) -> ExperimentSpec:
    """The declarative equivalent of the flags: ``run`` is now a thin
    wrapper over repro.experiments (the spec-driven engine construction is
    bit-identical to the historical hand-built SimConfig path)."""
    if args.trace == "-" and args.sessions is not None:
        wspec = WorkloadSpec(name=args.name, sessions=_sessions_binding(args))
    elif args.trace == "-" and args.dag is not None:
        wspec = WorkloadSpec(name=args.name, dag=_dag_binding(args))
    elif args.trace == "-":
        wspec = WorkloadSpec(
            name=args.name,
            arrivals=_build_arrivals(args).spec(),
            popularity=_build_popularity(args).spec(),
            n_tasks=args.tasks, n_objects=args.objects,
            object_bytes=int(args.object_mb * MB),
            compute_seconds=args.compute_s,
            store_metadata_ops=args.meta_ops, seed=args.seed)
    else:
        wspec = WorkloadSpec(trace_path=args.trace)
    prov = None
    if args.provision:
        prov = ProvisionerSpec(
            policy=args.alloc_policy, min_executors=1,
            max_executors=args.nodes, queue_threshold=2,
            idle_timeout_s=args.idle_timeout, trigger_cooldown_s=1.0)
    return ExperimentSpec(
        name=args.name,
        cluster=ClusterSpec(testbed=args.testbed,
                            n_nodes=1 if prov else args.nodes),
        cache=CacheSpec(capacity_bytes=int(args.cache_gb * 1e9)),
        policy=args.policy,
        provisioner=prov,
        workload=wspec,
        seed=args.sim_seed)


def cmd_run(args) -> int:
    rep = run_experiment(_experiment_spec(args), engine=args.engine)
    out = rep.as_dict()
    out.pop("pool_log")   # membership log can be long; spec+engine rerun it
    json.dump(out, sys.stdout, indent=2, sort_keys=True)
    print()
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    g = sub.add_parser("generate", help="generate a JSONL trace")
    _add_gen_flags(g)
    g.add_argument("--out", default="workload.jsonl")
    g.set_defaults(fn=cmd_generate)

    r = sub.add_parser("run", help="run a trace (or '-' to generate inline) "
                                   "through an engine (a thin wrapper over "
                                   "tools/run_experiment.py's spec API)")
    r.add_argument("trace")
    _add_gen_flags(r)
    r.add_argument("--nodes", type=int, default=16)
    r.add_argument("--policy", default="max-compute-util")
    r.add_argument("--engine", default="sim",
                   choices=["sim", "runtime", "serve"])
    r.add_argument("--testbed", default="anl_uc", choices=sorted(TESTBEDS))
    r.add_argument("--cache-gb", type=float, default=100.0)
    r.add_argument("--provision", action="store_true",
                   help="start from 1 node and let the DRP grow/shrink")
    r.add_argument("--alloc-policy", default="exponential",
                   choices=[p.value for p in AllocationPolicy])
    r.add_argument("--idle-timeout", type=float, default=5.0)
    r.add_argument("--sim-seed", type=int, default=0)
    r.set_defaults(fn=cmd_run)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())

"""Quickstart: data diffusion in 60 seconds.

Runs the paper's core experiment in miniature, twice -- once data-UNAWARE
(first-available: every byte comes from persistent storage) and once
data-AWARE (max-compute-util: bytes diffuse into executor caches and tasks
follow them) -- and prints the byte ledgers side by side.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (ANL_UC, DispatchPolicy, make_objects, uniform_tasks)
from repro.core.simulator import DiffusionSim, SimConfig

MB = 10**6
N_NODES = 16
LOCALITY = 10          # each file accessed 10x (Table 2's knob)


def run(policy: DispatchPolicy, caching: bool):
    cfg = SimConfig(testbed=ANL_UC, n_nodes=N_NODES, policy=policy,
                    cache_capacity_bytes=50 * 10**9, caching_enabled=caching)
    sim = DiffusionSim(cfg)
    objs = make_objects("f", 80, 20 * MB)
    sim.add_objects(objs)
    sim.submit(uniform_tasks(objs, accesses_per_object=LOCALITY,
                             compute_seconds=0.05))
    return sim.run()


def main():
    print(f"workload: 80 x 20MB files, locality {LOCALITY}, "
          f"{N_NODES} nodes (ANL/UC testbed model)\n")
    for name, policy, caching in (
            ("first-available (data-unaware, no caches)",
             DispatchPolicy.FIRST_AVAILABLE, False),
            ("max-compute-util (data diffusion)",
             DispatchPolicy.MAX_COMPUTE_UTIL, True)):
        r = run(policy, caching)
        gb = {k: v / 1e9 for k, v in r.bytes_by_kind.items()}
        print(f"== {name}")
        print(f"   makespan            {r.t_last_complete:9.1f} s")
        print(f"   read throughput     {r.read_throughput() * 8 / 1e9:9.2f} Gb/s")
        print(f"   cache hit ratio     {r.global_hit_ratio:9.2%}"
              f"   (ideal {1 - 1 / LOCALITY:.0%})")
        print(f"   bytes from store    {gb.get('store_read', 0):9.2f} GB")
        print(f"   bytes cache-to-cache{gb.get('c2c', 0):9.2f} GB")
        print(f"   bytes local         {gb.get('local', 0):9.2f} GB\n")
    print("the diffusion run reads the store once per file and serves the "
          "other 9 accesses from executor caches -- the paper's Figure 11/13 "
          "economics in miniature.")


if __name__ == "__main__":
    main()

"""Quickstart: data diffusion in 60 seconds.

Runs the paper's core experiment in miniature through the workload layer
(repro.workloads), three times:

  1. data-UNAWARE (first-available): every byte comes from persistent storage;
  2. data-AWARE (max-compute-util): bytes diffuse into executor caches and
     tasks follow them;
  3. ELASTIC: the same diffusion engine under an open-loop sine-wave demand
     curve, with the DynamicResourceProvisioner growing and shrinking the
     pool as arrivals rise and fall (the paper's §3.1 elasticity story).

Everything is seeded, so the printed numbers are identical run-to-run.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import (ANL_UC, DispatchPolicy, DynamicResourceProvisioner,
                        make_objects)
from repro.core.provisioner import AllocationPolicy
from repro.core.simulator import DiffusionSim, SimConfig
from repro.workloads import (BatchArrivals, MetricsCollector,
                             SineWaveArrivals, UniformScan, ZipfPopularity,
                             generate)

MB = 10**6
N_NODES = 16
LOCALITY = 10          # each file accessed 10x (Table 2's knob)
SEED = 0

OBJECTS = make_objects("f", 80, 20 * MB)

#: closed-loop batch: 80 files x locality 10 = 800 tasks, all arriving at t=0
BATCH = generate("quickstart", BatchArrivals(), UniformScan(),
                 n_tasks=80 * LOCALITY, objects=OBJECTS,
                 compute_seconds=0.05, seed=SEED)


def run(policy: DispatchPolicy, caching: bool):
    cfg = SimConfig(testbed=ANL_UC, n_nodes=N_NODES, policy=policy,
                    cache_capacity_bytes=50 * 10**9, caching_enabled=caching,
                    seed=SEED)
    sim = DiffusionSim(cfg)
    sim.submit_workload(BATCH)
    return sim.run()


def run_elastic():
    wl = generate("sine",
                  SineWaveArrivals(mean_rate=8.0, amplitude=7.5, period_s=60.0),
                  ZipfPopularity(1.1), n_tasks=600, objects=OBJECTS,
                  compute_seconds=0.5, seed=SEED)
    prov = DynamicResourceProvisioner(
        min_executors=1, max_executors=N_NODES,
        policy=AllocationPolicy.EXPONENTIAL, queue_threshold=2,
        idle_timeout_s=4.0, trigger_cooldown_s=1.0)
    cfg = SimConfig(testbed=ANL_UC, n_nodes=1,
                    policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                    cache_capacity_bytes=50 * 10**9, provisioner=prov,
                    seed=SEED)
    sim = DiffusionSim(cfg)
    sim.submit_workload(wl)
    r = sim.run()
    return prov, MetricsCollector(ANL_UC).collect(r, n_submitted=sim.n_submitted)


def main():
    print(f"workload: 80 x 20MB files, locality {LOCALITY}, "
          f"{N_NODES} nodes (ANL/UC testbed model)\n")
    for name, policy, caching in (
            ("first-available (data-unaware, no caches)",
             DispatchPolicy.FIRST_AVAILABLE, False),
            ("max-compute-util (data diffusion)",
             DispatchPolicy.MAX_COMPUTE_UTIL, True)):
        r = run(policy, caching)
        gb = {k: v / 1e9 for k, v in r.bytes_by_kind.items()}
        print(f"== {name}")
        print(f"   makespan            {r.t_last_complete:9.1f} s")
        print(f"   read throughput     {r.read_throughput() * 8 / 1e9:9.2f} Gb/s")
        print(f"   cache hit ratio     {r.global_hit_ratio:9.2%}"
              f"   (ideal {1 - 1 / LOCALITY:.0%})")
        print(f"   bytes from store    {gb.get('store_read', 0):9.2f} GB")
        print(f"   bytes cache-to-cache{gb.get('c2c', 0):9.2f} GB")
        print(f"   bytes local         {gb.get('local', 0):9.2f} GB\n")
    print("the diffusion run reads the store once per file and serves the "
          "other 9 accesses from executor caches -- the paper's Figure 11/13 "
          "economics in miniature.\n")

    prov, m = run_elastic()
    print("== elastic (sine-wave arrivals + dynamic resource provisioner)")
    print(f"   tasks completed     {m.n_completed:9d}")
    print(f"   pool               {m.low_executors:4d} -> {m.peak_executors:d} "
          f"executors (allocated {prov.n_allocated}, "
          f"released {prov.n_released})")
    print(f"   cache hit ratio     {m.cache_hit_ratio:9.2%}")
    print(f"   avg slowdown        {m.avg_slowdown:9.2f}x")
    print(f"   performance index   {m.performance_index:9.3f}   "
          f"(ideal core-s / allocated core-s)")
    print("\nas demand rises the provisioner acquires executors; when the "
          "sine trough drains the queue, idle executors are released -- "
          "the elasticity the paper claims, measured end-to-end.")


if __name__ == "__main__":
    main()

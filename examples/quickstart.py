"""Quickstart: data diffusion in 60 seconds.

Runs the paper's core experiment in miniature through the experiment API
(repro.experiments): each run is one declarative :class:`ExperimentSpec`
executed by the discrete-event engine, three times:

  1. data-UNAWARE (first-available): every byte comes from persistent storage;
  2. data-AWARE (max-compute-util): bytes diffuse into executor caches and
     tasks follow them;
  3. ELASTIC: the same diffusion engine under an open-loop sine-wave demand
     curve, with the DynamicResourceProvisioner growing and shrinking the
     pool as arrivals rise and fall (the paper's §3.1 elasticity story);
  4. OBSERVED: the diffusion run again with lifecycle recording on
     (repro.obs, DESIGN.md §10) -- exports a Chrome-trace JSON you can open
     in chrome://tracing or Perfetto, and diffs the run's measured per-task
     outcomes against a fresh replay prediction (placement + byte-split
     agreement; on the deterministic sim twin both are exactly 100%).

Everything is seeded, so the printed numbers are identical run-to-run (and
identical to what the pre-spec, hand-constructed SimConfig path produced --
the specs below build bit-identical engines).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.experiments import (CacheSpec, ClusterSpec, ExperimentSpec,
                               ProvisionerSpec, WorkloadSpec, build_workload,
                               run_experiment)

MB = 10**6
N_NODES = 16
LOCALITY = 10          # each file accessed 10x (Table 2's knob)
SEED = 0

#: closed-loop batch: 80 files x locality 10 = 800 tasks, all arriving at t=0
BATCH_WORKLOAD = WorkloadSpec(
    name="quickstart",
    arrivals={"kind": "BatchArrivals", "at_s": 0.0},
    popularity={"kind": "UniformScan", "stride": 1, "k": 1},
    n_tasks=80 * LOCALITY, n_objects=80, object_bytes=20 * MB,
    object_prefix="f", compute_seconds=0.05, seed=SEED)


def batch_spec(policy: str, caching: bool) -> ExperimentSpec:
    return ExperimentSpec(
        name="quickstart",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=N_NODES),
        cache=CacheSpec(capacity_bytes=50 * 10**9, enabled=caching),
        policy=policy,
        workload=BATCH_WORKLOAD,
        seed=SEED)


#: open-loop sine-wave demand over the same 80-file catalog
ELASTIC = ExperimentSpec(
    name="quickstart-elastic",
    cluster=ClusterSpec(testbed="anl_uc", n_nodes=1),
    cache=CacheSpec(capacity_bytes=50 * 10**9),
    policy="max-compute-util",
    provisioner=ProvisionerSpec(
        policy="exponential", min_executors=1, max_executors=N_NODES,
        queue_threshold=2, idle_timeout_s=4.0, trigger_cooldown_s=1.0),
    workload=WorkloadSpec(
        name="sine",
        arrivals={"kind": "SineWaveArrivals", "mean_rate": 8.0,
                  "amplitude": 7.5, "period_s": 60.0, "phase": 0.0},
        popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 1,
                    "corr": 1.0},
        n_tasks=600, n_objects=80, object_bytes=20 * MB, object_prefix="f",
        compute_seconds=0.5, seed=SEED),
    seed=SEED)


def main():
    print(f"workload: 80 x 20MB files, locality {LOCALITY}, "
          f"{N_NODES} nodes (ANL/UC testbed model)\n")
    batch = build_workload(BATCH_WORKLOAD)   # generated once, run twice
    for name, policy, caching in (
            ("first-available (data-unaware, no caches)",
             "first-available", False),
            ("max-compute-util (data diffusion)",
             "max-compute-util", True)):
        r = run_experiment(batch_spec(policy, caching), engine="sim",
                           workload=batch)
        gb = {k: v / 1e9 for k, v in r.bytes_by_kind.items()}
        print(f"== {name}")
        print(f"   makespan            {r.t_last_complete:9.1f} s")
        print(f"   read throughput     {r.read_bandwidth_bps * 8 / 1e9:9.2f} Gb/s")
        print(f"   cache hit ratio     {r.cache_hit_ratio:9.2%}"
              f"   (ideal {1 - 1 / LOCALITY:.0%})")
        print(f"   bytes from store    {gb.get('store_read', 0):9.2f} GB")
        print(f"   bytes cache-to-cache{gb.get('c2c', 0):9.2f} GB")
        print(f"   bytes local         {gb.get('local', 0):9.2f} GB\n")
    print("the diffusion run reads the store once per file and serves the "
          "other 9 accesses from executor caches -- the paper's Figure 11/13 "
          "economics in miniature.\n")

    m = run_experiment(ELASTIC, engine="sim")
    print("== elastic (sine-wave arrivals + dynamic resource provisioner)")
    print(f"   tasks completed     {m.n_completed:9d}")
    print(f"   pool               {m.low_executors:4d} -> {m.peak_executors:d} "
          f"executors (allocated {m.n_allocated}, "
          f"released {m.n_released})")
    print(f"   cache hit ratio     {m.cache_hit_ratio:9.2%}")
    print(f"   avg slowdown        {m.avg_slowdown:9.2f}x")
    print(f"   performance index   {m.performance_index:9.3f}   "
          f"(ideal core-s / allocated core-s)")
    print("\nas demand rises the provisioner acquires executors; when the "
          "sine trough drains the queue, idle executors are released -- "
          "the elasticity the paper claims, measured end-to-end.\n")

    observed()


def observed():
    """The PR-7 observability loop in miniature: record -> export -> diff."""
    import dataclasses

    from repro.experiments import ObserveSpec, SimEngine
    from repro.obs import (chrome_trace, diff_outcomes, format_divergence,
                           sim_replay_outcomes)

    spec = dataclasses.replace(batch_spec("max-compute-util", True),
                               observe=ObserveSpec(events=True))
    eng = SimEngine()
    try:
        eng.prepare(spec, workload=build_workload(BATCH_WORKLOAD))
        eng.run()
        events = eng.recorder.events()
        measured = eng.last_outcomes
    finally:
        eng.shutdown()

    out = "quickstart_trace.json"
    trace = chrome_trace(events, out)
    spans = sum(1 for e in trace["traceEvents"] if e["ph"] == "X")
    print("== observed (lifecycle recording + sim-twin divergence)")
    print(f"   events recorded     {len(events):9d}   (0 dropped)")
    print(f"   chrome trace        {out}  ({spans} task spans -- open in "
          f"chrome://tracing)")
    # diff the measured outcomes against a fresh prediction of the same
    # spec -- the same join `tools/run_experiment.py diff` runs on a
    # recorded FLEET trace, where the agreement numbers become interesting
    predicted = sim_replay_outcomes(spec)
    div = diff_outcomes(measured, predicted)
    # latencies=False: quantile lines carry engine wall-clock noise on a
    # real fleet; agreement lines are deterministic and belong in a demo
    for line in format_divergence(div, latencies=False).splitlines():
        print(f"   {line}")


if __name__ == "__main__":
    main()

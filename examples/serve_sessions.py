"""KV-cache diffusion on a 4-replica serving pool (DESIGN.md §12).

Two runs over the same multi-turn chat population, each through the REAL
scheduling stack (`repro.core` dispatcher, LocationIndex, provisioner):

  serve   the serve engine -- replicas are live worker threads -- under
          batch-synchronous replay, so placement (and every number
          printed) is bit-deterministic run-to-run: later turns re-read
          their session's prefix pages and Zipf-shared system prompts
          from replica caches instead of recomputing prefill;
  sim     the SAME session model under diurnal demand on an elastic
          1..8 replica pool: the DynamicResourceProvisioner grows the
          pool at the daily peak and releases it in the trough -- the
          pool trajectory is the autoscaling story in one line.

Everything printed is scheduling-determined (byte counters, request
counts, sim-time pool samples), never wall clock, so the output is
identical on every run.

  PYTHONPATH=src python examples/serve_sessions.py
  PYTHONPATH=src python examples/serve_sessions.py --sessions 120 --days 3
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.experiments import run_experiment
from repro.experiments.spec import ProvisionerSpec
from repro.serve.diffusion import format_pool, kv_summary, session_spec

SEED = 0
REPLICAS = 4


def serve_demo(n_sessions: int, turns: int) -> int:
    binding = {"kind": "chat", "n_sessions": n_sessions,
               "turns_per_session": turns, "n_system_prompts": 8,
               "kv_bytes_per_token": 1024, "block": 32,
               "think_time_s": 0.0, "turn_seconds": 0.0,
               "arrivals": {"kind": "BatchArrivals", "at_s": 0.0}}
    rep = run_experiment(
        session_spec("serve-demo", binding, n_replicas=REPLICAS, seed=SEED),
        engine="serve", barrier_every=1, timeout=300)
    s = kv_summary(rep)
    print(f"serve: {REPLICAS} replicas, {n_sessions} sessions x "
          f"{turns} turns = {rep.n_completed} requests "
          f"({rep.n_failed} failed)")
    print(f"  reused token fraction  {s['reused_token_fraction']:.3f} "
          f"({s['reused_kv_bytes'] / 1e6:.1f} MB reused, "
          f"{s['recomputed_kv_bytes'] / 1e6:.1f} MB recomputed prefill)")
    print(f"  reuse locality         {s['local_kv_bytes'] / 1e6:.1f} MB "
          f"local, {s['peer_kv_bytes'] / 1e6:.1f} MB fetched from peers")
    print(f"  requests by reuse      {s['full_reuse_requests']} full / "
          f"{s['partial_reuse_requests']} partial / "
          f"{s['cold_requests']} cold")
    return 0 if rep.n_failed == 0 else 1


def diurnal_demo(n_sessions: int, days: int) -> int:
    day_s = 60.0
    binding = {"kind": "chat", "n_sessions": n_sessions,
               "turns_per_session": 2, "kv_bytes_per_token": 1024,
               "block": 32, "think_time_s": 5.0, "turn_seconds": 1.0,
               "arrivals": {"kind": "DiurnalArrivals", "peak_rate": 8.0,
                            "trough_rate": 0.5, "day_s": day_s}}
    spec = session_spec(
        "serve-diurnal", binding, n_replicas=1, seed=SEED,
        provisioner=ProvisionerSpec(
            policy="exponential", min_executors=1, max_executors=8,
            queue_threshold=2, idle_timeout_s=5.0, trigger_cooldown_s=1.0))
    rep = run_experiment(spec, engine="sim")
    s = kv_summary(rep)
    print(f"sim:   diurnal demand over ~{days} compressed days "
          f"({rep.n_completed} requests, elastic 1..8 replicas)")
    print(f"  replicas allocated     +{rep.n_allocated} grown, "
          f"-{rep.n_released} released (peak {rep.peak_executors}, "
          f"trough {rep.low_executors})")
    print(f"  reused token fraction  {s['reused_token_fraction']:.3f}")
    print(f"  pool trajectory        {format_pool(rep, max_points=12)}")
    return 0 if rep.n_completed == rep.n_tasks else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sessions", type=int, default=80,
                    help="chat sessions per run")
    ap.add_argument("--turns", type=int, default=3,
                    help="turns per session (serve run)")
    ap.add_argument("--days", type=int, default=2,
                    help="compressed diurnal days (sim run)")
    args = ap.parse_args(argv)
    rc = serve_demo(args.sessions, args.turns)
    # session count sized so the workload spans the requested day count at
    # the diurnal curve's mean rate ((peak + trough) / 2 ~ 4.25/s)
    rc = max(rc, diurnal_demo(int(args.days * 60.0 * 4.25), args.days))
    return rc


if __name__ == "__main__":
    sys.exit(main())

"""End-to-end serving driver: batched requests, prefix-cache-aware routing.

Serves a small LM across logical replicas; requests share prompt prefixes
(the serving analogue of Table 2's locality), so the data-aware router
reuses prefix KV exactly like the paper's scheduler reuses cached files.

  PYTHONPATH=src python examples/serve_lm.py --requests 24 --policy max-compute-util
  PYTHONPATH=src python examples/serve_lm.py --policy first-available   # contrast
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.policies import DispatchPolicy
from repro.models.config import ModelConfig
from repro.serve import Request, ServeEngine

TINY = ModelConfig(name="serve-demo", family="dense", n_layers=4,
                   d_model=128, n_heads=8, n_kv_heads=4, d_ff=512,
                   vocab_size=4096, head_dim=16)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--policy", default="max-compute-util")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    eng = ServeEngine(TINY, n_replicas=args.replicas,
                      policy=DispatchPolicy(args.policy), max_seq=96,
                      seed=args.seed)
    rng = np.random.default_rng(args.seed)
    bases = [list(rng.integers(2, TINY.vocab_size, 48)) for _ in range(3)]
    done = []
    for wave in range(0, args.requests, 8):
        reqs = []
        for i in range(wave, min(wave + 8, args.requests)):
            prompt = bases[i % 3] + list(rng.integers(2, TINY.vocab_size, 8))
            reqs.append(Request(rid=i, prompt=prompt,
                                max_new_tokens=args.max_new))
        done += eng.generate(reqs)
    total_prompt = sum(len(r.prompt) for r in done)
    print(f"served {len(done)} requests x {args.max_new} tokens on "
          f"{args.replicas} replicas, policy={args.policy}")
    print(f"  prompt tokens total:   {total_prompt}")
    print(f"  prefill computed:      {eng.prefill_tokens}")
    print(f"  reused from prefix KV: {eng.reused_tokens} "
          f"({eng.reused_tokens / max(total_prompt, 1):.1%})")
    print(f"  router: {eng.router.stats()}")
    print(f"  sample output: {done[0].output}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Live fleet telemetry end to end: attach, overload, reconcile.

The PR-10 acceptance walk (DESIGN.md §13) on a real 4-host fleet:

  1. start a fleet run with the metrics plane ON and a `TelemetryServer`
     bound to a local port -- the same endpoint ``tools/monitor.py
     --attach`` uses -- and print the attach command so you can watch the
     full dashboard in a second terminal;
  2. drive it with a deliberately overloaded Poisson arrival stream --
     tasks run ``io_dwell_task`` (service time = input bytes at the
     simulated per-node disk rate), and arrivals come in at ~5x the
     pool's aggregate service capacity -- polling the endpoint while the
     run is live and printing per-host queue depth / cache bytes /
     aggregate bandwidth as they move;
  3. the backlog builds monotonically, so the `HealthMonitor`'s
     ``backlog_growth`` rule MUST fire -- the script exits nonzero if it
     does not;
  4. after the drain, reconcile ``RunReport.telemetry`` against the run
     ledger: summed per-host ``bw.*`` gauges == ``bytes_by_kind`` exactly,
     central completion counter == ``n_completed``, summed per-host
     ``host.tasks_done`` == ``n_completed``.

  PYTHONPATH=src python examples/fleet_monitor.py
  PYTHONPATH=src python examples/fleet_monitor.py --hosts 2 --tasks 150
"""
import argparse
import sys
import threading
import time

sys.path.insert(0, "src")

from repro.experiments import (CacheSpec, ClusterSpec, ExperimentSpec,
                               ObserveSpec, RuntimeEngine, WorkloadSpec)
from repro.fleet.runtime import BENCH_DISK_BW
from repro.obs import fetch_telemetry

OBJECT_BYTES = 400_000
INPUTS_PER_TASK = 2


def build_spec(hosts: int, tasks: int, rate: float) -> ExperimentSpec:
    return ExperimentSpec(
        name="fleet-monitor-demo",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=hosts),
        cache=CacheSpec(capacity_bytes=10**12),
        policy="max-compute-util",
        workload=WorkloadSpec(
            name="overload",
            arrivals={"kind": "PoissonArrivals", "rate_per_s": rate},
            popularity={"kind": "ZipfPopularity", "alpha": 1.1,
                        "k": INPUTS_PER_TASK, "corr": 0.8},
            n_tasks=tasks, n_objects=48, object_bytes=OBJECT_BYTES,
            seed=11),
        observe=ObserveSpec(metrics=True, metrics_interval_s=0.05,
                            metrics_port=0),       # 0 = any free port
        seed=3, hosts=hosts, threads_per_host=1)


def live_line(port: int) -> str:
    """One compact monitor line from the status endpoint (the full-screen
    version of this is ``tools/monitor.py --attach``)."""
    rec = fetch_telemetry("127.0.0.1", port)
    sample = rec.get("sample") or {}
    central = sample.get("metrics", {})
    g = central.get("gauges", {})
    hosts = sample.get("hosts", {})
    cache = {h: int(d["metrics"].get("gauges", {}).get("cache.bytes", 0))
             for h, d in sorted(hosts.items())}
    bw = sum(d["metrics"].get("gauges", {}).get(k, 0)
             for d in hosts.values()
             for k in ("bw.bytes_local", "bw.bytes_c2c", "bw.bytes_store"))
    per_host = " ".join(f"{h}:{b // 1000}kB" for h, b in cache.items())
    return (f"t={sample.get('t', 0):6.2f}s  "
            f"queue={int(g.get('sched.queue_depth', 0)):4d}  "
            f"pool={int(g.get('pool.size', 0))}  "
            f"cache[{per_host}]  bw={bw / 1e6:.1f}MB  "
            f"health={len(rec.get('health', []))}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--tasks", type=int, default=300)
    ap.add_argument("--rate", type=float, default=400.0,
                    help="Poisson arrival rate (tasks/s); default ~5x the "
                         "pool's service capacity, so the backlog grows")
    ap.add_argument("--poll-s", type=float, default=0.3)
    args = ap.parse_args(argv)

    spec = build_spec(args.hosts, args.tasks, args.rate)
    eng = RuntimeEngine(task_fn_name="repro.fleet.runtime:io_dwell_task")
    eng.prepare(spec)
    port = eng.tel_server.port
    service_s = INPUTS_PER_TASK * OBJECT_BYTES / BENCH_DISK_BW
    print(f"== fleet monitor demo: {args.hosts} hosts x 1 thread, "
          f"{args.tasks} tasks at {args.rate:.0f}/s "
          f"(capacity ~{args.hosts / service_s:.0f}/s) ==")
    print(f"attach the dashboard:  PYTHONPATH=src python tools/monitor.py "
          f"--attach 127.0.0.1:{port}\n")

    stop = threading.Event()

    def poll() -> None:
        while not stop.wait(args.poll_s):
            try:
                print("  " + live_line(port))
            except OSError:
                return

    watcher = threading.Thread(target=poll, daemon=True, name="demo-poller")
    watcher.start()
    try:
        rep = eng.run(time_scale=1.0, timeout=300.0,
                      payload_factory=lambda ob: b"x" * ob.size_bytes)
    finally:
        stop.set()
        watcher.join(timeout=5.0)
        eng.shutdown()

    tel = rep.telemetry
    events = tel.get("health_events", [])
    fired = sorted({e["rule"] for e in events})
    print(f"\ncompleted {rep.n_completed}/{args.tasks} in "
          f"{rep.makespan_s:.2f}s; {tel.get('n_samples', 0)} samples; "
          f"health events: {fired or 'none'}")

    # -- reconcile the telemetry plane against the run ledger -------------
    merged = tel.get("merged", {})
    g, c = merged.get("gauges", {}), merged.get("counters", {})
    checks = [
        ("backlog_growth health event fired", "backlog_growth" in fired),
        ("bw.bytes_local == ledger local",
         g.get("bw.bytes_local", -1) == rep.bytes_by_kind.get("local", 0)),
        ("bw.bytes_c2c == ledger c2c",
         g.get("bw.bytes_c2c", -1) == rep.bytes_by_kind.get("c2c", 0)),
        ("bw.bytes_store == ledger store_read",
         g.get("bw.bytes_store", -1)
         == rep.bytes_by_kind.get("store_read", 0)),
        ("central sched.tasks_completed == n_completed",
         c.get("sched.tasks_completed", -1) == rep.n_completed),
        ("sum per-host host.tasks_done == n_completed",
         sum(h.get("metrics", {}).get("gauges", {}).get("host.tasks_done", 0)
             for h in tel.get("hosts", {}).values()) == rep.n_completed),
        (f"all {args.hosts} hosts reported stats frames",
         len(tel.get("hosts", {})) == args.hosts),
    ]
    ok = True
    for label, passed in checks:
        print(f"  [{'ok' if passed else 'FAIL'}] {label}")
        ok = ok and passed
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

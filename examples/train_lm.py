"""End-to-end training driver: an LM trained with the diffusion data
pipeline, checkpoint/restart, and the full training substrate.

Default runs a ~10M-param config for 60 steps on CPU in a couple of
minutes; ``--preset 100m --steps 300`` is the deliverable-scale run
(~100M params, several hundred steps -- give it a few hours on 1 CPU core,
or a single real accelerator).

  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core.policies import DispatchPolicy
from repro.data.dataset import ShardSpec
from repro.data.pipeline import DiffusionDataPipeline, PipelineConfig
from repro.models.config import LayerSpec, ModelConfig
from repro.train import adamw, train

PRESETS = {
    "10m": ModelConfig(name="lm-10m", family="dense", n_layers=4,
                       d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                       vocab_size=8192, head_dim=32),
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                        vocab_size=32768, head_dim=64),
    "moe-30m": ModelConfig(name="lm-moe-30m", family="moe", n_layers=4,
                           d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                           vocab_size=8192, head_dim=32,
                           pattern=(LayerSpec(mlp="moe"),),
                           n_experts=8, top_k=2),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--shards", type=int, default=12)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = PRESETS[args.preset]
    n_params = cfg.param_count()
    print(f"training {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.global_batch}x{args.seq_len}")
    pipe_cfg = PipelineConfig(
        global_batch=args.global_batch, seq_len=args.seq_len,
        n_hosts=args.hosts, policy=DispatchPolicy.MAX_COMPUTE_UTIL,
        host_cache_bytes=1 << 28, seed=args.seed)
    spec = ShardSpec(n_shards=args.shards,
                     tokens_per_shard=max(pipe_cfg.tokens_per_batch, 1 << 17),
                     vocab_size=cfg.vocab_size, seed=args.seed)
    pipeline = DiffusionDataPipeline(pipe_cfg, spec)
    try:
        res = train(cfg, pipeline, n_steps=args.steps,
                    ckpt_dir=args.ckpt_dir, ckpt_every=25,
                    optimizer=adamw(3e-4, warmup=20, total=args.steps),
                    seed=args.seed)
    finally:
        pipeline.close()
    print(f"\nfinal loss: {res.losses[-1]:.4f} "
          f"(first: {res.losses[0]:.4f})")
    print(f"resumed from checkpoint: {res.resumed_from}")
    print(f"diffusion pipeline ledger: {res.pipeline_stats}")
    print("rerun the same command to watch restart-from-checkpoint resume.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""End-to-end reproduction of the paper's application (§5): SDSS image
stacking over data diffusion, with the REAL compute executed by the Pallas
stacking kernel (repro/kernels/stacking, interpret mode on CPU).

Two layers run together here:
  * scheduling plane: the threaded DiffusionRuntime moves (synthetic) image
    files through executor caches under max-compute-util, exactly as §5.3;
  * compute plane: each task extracts its object's ROI and the coadd runs
    through stack_rois (calibrate -> sub-pixel shift -> accumulate).

  PYTHONPATH=src python examples/astronomy_stacking.py --locality 10
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs.astro_stacking import ROI_SHAPE, workload
from repro.core import DataObject, DispatchPolicy, Task
from repro.core.runtime import DiffusionRuntime
from repro.kernels.stacking import ops as st_ops


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--locality", type=float, default=10, choices=[1, 2, 3, 4, 5, 10, 20, 30])
    ap.add_argument("--objects", type=int, default=96,
                    help="number of stacking objects (scaled workload)")
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--policy", default="max-compute-util")
    args = ap.parse_args(argv)

    wl = workload(args.locality)
    n_files = max(int(args.objects / args.locality), 1)
    rng = np.random.default_rng(0)
    h, w = ROI_SHAPE

    rt = DiffusionRuntime(n_executors=args.hosts,
                          policy=DispatchPolicy(args.policy),
                          cache_capacity_bytes=1 << 30)
    # synthetic "FITS" files: a stack of image tiles per file
    for i in range(n_files):
        tiles = rng.normal(500, 100, size=(8, h, w)).astype(np.float32)
        rt.put_object(DataObject(f"img{i}", tiles.nbytes), tiles)

    def stack_object(inputs):
        (tiles,) = inputs.values()
        n = tiles.shape[0]
        sky = tiles.mean(axis=(1, 2)) * 0.1
        cal = np.ones(n, np.float32)
        dy = rng.random(n).astype(np.float32)
        dx = rng.random(n).astype(np.float32)
        return np.asarray(st_ops.stack_rois(tiles, sky, cal, dy, dx))

    tasks = [Task(inputs=(f"img{i % n_files}",), fn=stack_object)
             for i in range(args.objects)]
    t0 = time.time()
    rt.submit(tasks)
    ok = rt.wait(300)
    dt = time.time() - t0
    assert ok, "stacking timed out"
    results = [t.result for t in tasks]
    assert all(r.shape == ROI_SHAPE for r in results)
    lg = rt.ledger
    ideal = wl.ideal_cache_hit_ratio
    print(f"stacked {len(results)} objects over {n_files} files "
          f"(locality {args.locality}) on {args.hosts} hosts in {dt:.2f}s")
    print(f"  cache hit ratio: {lg.global_hit_ratio:.2%} "
          f"(paper ideal 1-1/L = {ideal:.0%}; paper achieves >=90% of it)")
    print(f"  bytes: store={lg.bytes_store / 1e6:.1f}MB "
          f"c2c={lg.bytes_c2c / 1e6:.1f}MB local={lg.bytes_local / 1e6:.1f}MB")
    print(f"  sample stacked-pixel mean: {float(results[0].mean()):.2f}")
    rt.shutdown()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

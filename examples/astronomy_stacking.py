"""End-to-end reproduction of the paper's application (§5): SDSS image
stacking over data diffusion, with the REAL compute executed by the Pallas
stacking kernel (repro/kernels/stacking, interpret mode on CPU).

Default mode is the full stack-then-mosaic PIPELINE (PR 8): a
``stacking_pyramid`` DAG of ``--groups`` stack tasks (each coadding
``--group-size`` image files into one produced stack) feeding ONE mosaic
task that reads every produced stack.  The mosaic arrives at t=0 like
everything else -- the dispatcher's ready-set holds it until all stacks
complete, and producer-placement scoring routes it at the executors whose
caches hold the freshly written stacks (DESIGN.md §11).  One task callable
serves both stages, dispatching on the input oid shape: catalog images
(``astro.g{g}.o{k}``) -> calibrate/shift/accumulate through
``st_ops.stack_rois``; produced stacks (``astro.stack{g}``) -> a pure
coadd through the same kernel with zero shift/sky.

``--flat`` keeps the historical PR-level shape: a seeded §4.3 StackingTrace
(every file accessed ``locality`` times, order shuffled) of independent
one-stage tasks.

All randomness is derived from fixed seeds (file content from the file's
group/index, shift offsets from the task's input ids), so the stacked and
mosaicked pixels -- and the printed summary -- are identical run-to-run
regardless of thread timing.

  PYTHONPATH=src python examples/astronomy_stacking.py
  PYTHONPATH=src python examples/astronomy_stacking.py --groups 12 --hosts 6
  PYTHONPATH=src python examples/astronomy_stacking.py --flat --locality 10
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.astro_stacking import ROI_SHAPE, workload
from repro.core import DataObject
from repro.experiments import (CacheSpec, ClusterSpec, ExperimentSpec,
                               RuntimeEngine, WorkloadSpec)
from repro.kernels.stacking import ops as st_ops

SEED = 0
H, W = ROI_SHAPE
TILES_PER_FILE = 8
FILE_BYTES = TILES_PER_FILE * H * W * 4


def _coadd(tiles: np.ndarray, seed_ids) -> np.ndarray:
    """Calibrate -> sub-pixel shift -> accumulate via the Pallas kernel.
    Shift offsets are seeded by the input ids, never a shared stream, so
    the pixels are independent of thread scheduling order."""
    n = tiles.shape[0]
    sky = tiles.mean(axis=(1, 2)) * 0.1
    cal = np.ones(n, np.float32)
    task_rng = np.random.default_rng([SEED + 1, *seed_ids])
    dy = task_rng.random(n).astype(np.float32)
    dx = task_rng.random(n).astype(np.float32)
    return np.asarray(st_ops.stack_rois(tiles, sky, cal, dy, dx))


# --------------------------------------------------------------------------
# pipeline mode (default): stacking_pyramid DAG, one two-stage task_fn
# --------------------------------------------------------------------------

def run_pipeline(args) -> int:
    spec = ExperimentSpec(
        name="astro",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=args.hosts),
        cache=CacheSpec(capacity_bytes=1 << 30),
        policy=args.policy,
        workload=WorkloadSpec(
            name="astro",
            dag={"kind": "stacking_pyramid", "n_groups": args.groups,
                 "group_size": args.group_size, "object_bytes": FILE_BYTES,
                 "stack_bytes": H * W * 4, "mosaic_bytes": H * W * 4,
                 "seed": SEED}),
        seed=SEED)

    def make_tiles(ob: DataObject) -> np.ndarray:
        """Catalog image content derived from the file's (group, index):
        identical every run."""
        g, k = ob.oid.split(".")[1:]          # "astro.g{g}.o{k}"
        file_rng = np.random.default_rng([SEED, int(g[1:]), int(k[1:])])
        return file_rng.normal(500, 100, size=(TILES_PER_FILE, H, W)) \
            .astype(np.float32)

    def stack_or_mosaic(inputs):
        """ONE callable for both stages, dispatched on the input oids."""
        oids = list(inputs)
        if all(o.split(".")[-1].startswith("stack") for o in oids):
            # mosaic stage: inputs are PRODUCED stacks (h, w); pure coadd
            # through the same kernel (zero sky, unit cal, zero shift)
            tiles = np.stack([np.asarray(v) for v in inputs.values()])
            zeros = np.zeros(tiles.shape[0], np.float32)
            return np.asarray(st_ops.stack_rois(
                tiles, zeros, np.ones(tiles.shape[0], np.float32),
                zeros, zeros))
        # stack stage: inputs are catalog files of TILES_PER_FILE tiles
        tiles = np.concatenate([np.asarray(v) for v in inputs.values()],
                               axis=0)
        seed_ids = [int(o.split(".")[2][1:]) for o in oids]
        return _coadd(tiles, seed_ids)

    eng = RuntimeEngine().prepare(spec)
    rep = eng.run(task_fn=stack_or_mosaic, payload_factory=make_tiles,
                  time_scale=args.time_scale, timeout=600.0)
    done = {t.tid: t for t in eng.runtime.dispatcher.completed}
    stacks = [done[f"astro-stack{g}"].result for g in range(args.groups)]
    mosaic = done["astro-mosaic"].result
    assert all(s.shape == ROI_SHAPE for s in stacks)
    assert mosaic.shape == ROI_SHAPE
    print(f"# wall time {rep.wall_s:.2f}s (time_scale {args.time_scale})",
          file=sys.stderr)
    print(f"stacked {args.groups} groups x {args.group_size} files, then "
          f"mosaicked, on {args.hosts} hosts")
    print(f"  cache hit ratio: {rep.cache_hit_ratio:.2%} "
          f"(mosaic inputs all scheduler-produced)")
    print(f"  slowdown: from-arrival {rep.slowdown_from_arrival:.2f} "
          f"from-ready {rep.slowdown_from_ready:.2f} "
          f"(gap = mosaic dep-wait)")
    cached = (rep.bytes_by_kind["c2c"] + rep.bytes_by_kind["local"]) / 1e6
    print(f"  bytes: store={rep.bytes_by_kind['store_read'] / 1e6:.1f}MB "
          f"cache-served={cached:.1f}MB")
    print(f"  mosaic pixel mean: {float(mosaic.mean()):.2f}")
    eng.shutdown()
    return 0


# --------------------------------------------------------------------------
# flat mode (--flat): the historical one-stage StackingTrace shape
# --------------------------------------------------------------------------

def run_flat(args) -> int:
    wl_cfg = workload(args.locality)
    locality = max(int(args.locality), 1)
    n_files = max(int(args.objects / args.locality), 1)

    # one declarative spec: Poisson arrivals x §4.3 stacking-trace
    # popularity over an img{i} catalog, on --hosts 1GiB-cache workers
    spec = ExperimentSpec(
        name="astro",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=args.hosts),
        cache=CacheSpec(capacity_bytes=1 << 30),
        policy=args.policy,
        workload=WorkloadSpec(
            name="astro",
            arrivals={"kind": "PoissonArrivals",
                      "rate_per_s": max(args.objects / 2.0, 1.0)},
            popularity={"kind": "StackingTrace", "locality": locality,
                        "shuffle_seed": SEED, "k": args.stack_width,
                        "corr": 1.0},
            n_tasks=args.objects, n_objects=n_files,
            object_bytes=FILE_BYTES, object_prefix="img", seed=SEED),
        seed=SEED)

    def make_tiles(ob: DataObject) -> np.ndarray:
        """File content derived from the file id: identical every run."""
        file_rng = np.random.default_rng([SEED, int(ob.oid[3:])])
        return file_rng.normal(500, 100, size=(TILES_PER_FILE, H, W)) \
            .astype(np.float32)

    def stack_object(inputs):
        # one file (classic) or a whole stack group (k-input join): coadd
        # every tile of every input file into one ROI
        tiles = np.concatenate(list(inputs.values()), axis=0)
        return _coadd(tiles, [int(oid[3:]) for oid in inputs])

    eng = RuntimeEngine().prepare(spec)
    rep = eng.run(task_fn=stack_object, payload_factory=make_tiles,
                  time_scale=args.time_scale, timeout=600.0)
    done = {t.tid: t for t in eng.runtime.dispatcher.completed}
    results = [done[f"astro-{i}"].result for i in range(args.objects)]
    assert all(r.shape == ROI_SHAPE for r in results)
    ideal = wl_cfg.ideal_cache_hit_ratio
    # deterministic summary -> stdout; wall-clock timing -> stderr (the only
    # run-to-run-variable quantity in this example)
    print(f"# wall time {rep.wall_s:.2f}s (time_scale {args.time_scale})",
          file=sys.stderr)
    print(f"stacked {len(results)} objects over {n_files} files "
          f"(locality {args.locality}) on {args.hosts} hosts")
    print(f"  cache hit ratio: {rep.cache_hit_ratio:.2%} "
          f"(paper ideal 1-1/L = {ideal:.0%}; paper achieves >=90% of it)")
    cached = (rep.bytes_by_kind["c2c"] + rep.bytes_by_kind["local"]) / 1e6
    print(f"  bytes: store={rep.bytes_by_kind['store_read'] / 1e6:.1f}MB "
          f"cache-served={cached:.1f}MB")
    print(f"  sample stacked-pixel mean: {float(results[0].mean()):.2f}")
    eng.shutdown()
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--flat", action="store_true",
                    help="historical one-stage StackingTrace workload "
                         "instead of the stack-then-mosaic pipeline")
    ap.add_argument("--groups", type=int, default=8,
                    help="pipeline: stack tasks (mosaic fan-in)")
    ap.add_argument("--group-size", type=int, default=4,
                    help="pipeline: image files coadded per stack")
    ap.add_argument("--locality", type=float, default=10,
                    choices=[1, 2, 3, 4, 5, 10, 20, 30])
    ap.add_argument("--objects", type=int, default=96,
                    help="flat: number of stacking objects (scaled workload)")
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--policy", default="max-compute-util")
    ap.add_argument("--stack-width", type=int, default=1,
                    help="flat: files coadded per request (k-input joins "
                         "over stack groups; 1 = classic one-file tasks)")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="wall seconds per workload second for the paced "
                         "submitter (0 = submit as fast as possible)")
    args = ap.parse_args(argv)
    return run_flat(args) if args.flat else run_pipeline(args)


if __name__ == "__main__":
    raise SystemExit(main())

"""End-to-end reproduction of the paper's application (§5): SDSS image
stacking over data diffusion, with the REAL compute executed by the Pallas
stacking kernel (repro/kernels/stacking, interpret mode on CPU).

Three layers run together here, all bound by one declarative
:class:`ExperimentSpec` executed on the threaded engine
(``repro.experiments.RuntimeEngine``):
  * workload plane: a seeded ``repro.workloads`` StackingTrace (the §4.3
    trace shape: every file accessed ``locality`` times, order shuffled)
    paced into the runtime by the open-loop submitter thread;
  * scheduling plane: the threaded DiffusionRuntime moves (synthetic) image
    files through executor caches under max-compute-util, exactly as §5.3;
  * compute plane: each task extracts its object's ROI and the coadd runs
    through stack_rois (calibrate -> sub-pixel shift -> accumulate).

All randomness is derived from fixed seeds (file content from the file id,
shift offsets from the task's input ids), so the stacked pixels -- and the
printed summary -- are identical run-to-run regardless of thread timing,
and identical to the pre-spec construction path (the spec builds the exact
historical DiffusionRuntime).

``--stack-width K`` turns each request into the paper's true many-files
stack: a k-input join over the primary file's stack group (K=1 keeps the
historical one-file-per-task shape and byte-identical output).

  PYTHONPATH=src python examples/astronomy_stacking.py --locality 10
  PYTHONPATH=src python examples/astronomy_stacking.py --stack-width 3
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.astro_stacking import ROI_SHAPE, workload
from repro.core import DataObject
from repro.experiments import (CacheSpec, ClusterSpec, ExperimentSpec,
                               RuntimeEngine, WorkloadSpec)
from repro.kernels.stacking import ops as st_ops

SEED = 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--locality", type=float, default=10, choices=[1, 2, 3, 4, 5, 10, 20, 30])
    ap.add_argument("--objects", type=int, default=96,
                    help="number of stacking objects (scaled workload)")
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--policy", default="max-compute-util")
    ap.add_argument("--stack-width", type=int, default=1,
                    help="files coadded per request (k-input joins over "
                         "stack groups; 1 = classic one-file tasks)")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="wall seconds per workload second for the paced "
                         "submitter (0 = submit as fast as possible)")
    args = ap.parse_args(argv)

    wl_cfg = workload(args.locality)
    locality = max(int(args.locality), 1)
    n_files = max(int(args.objects / args.locality), 1)
    h, w = ROI_SHAPE

    # one declarative spec: Poisson arrivals x §4.3 stacking-trace
    # popularity over an img{i} catalog, on --hosts 1GiB-cache workers
    spec = ExperimentSpec(
        name="astro",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=args.hosts),
        cache=CacheSpec(capacity_bytes=1 << 30),
        policy=args.policy,
        workload=WorkloadSpec(
            name="astro",
            arrivals={"kind": "PoissonArrivals",
                      "rate_per_s": max(args.objects / 2.0, 1.0)},
            popularity={"kind": "StackingTrace", "locality": locality,
                        "shuffle_seed": SEED, "k": args.stack_width,
                        "corr": 1.0},
            n_tasks=args.objects, n_objects=n_files,
            object_bytes=8 * h * w * 4, object_prefix="img", seed=SEED),
        seed=SEED)

    def make_tiles(ob: DataObject) -> np.ndarray:
        """File content derived from the file id: identical every run."""
        file_rng = np.random.default_rng([SEED, int(ob.oid[3:])])
        return file_rng.normal(500, 100, size=(8, h, w)).astype(np.float32)

    def stack_object(inputs):
        # one file (classic) or a whole stack group (k-input join): coadd
        # every tile of every input file into one ROI
        tiles = np.concatenate(list(inputs.values()), axis=0)
        n = tiles.shape[0]
        sky = tiles.mean(axis=(1, 2)) * 0.1
        cal = np.ones(n, np.float32)
        # shift offsets seeded by the *input ids*, not a shared stream, so
        # results do not depend on thread scheduling order
        task_rng = np.random.default_rng(
            [SEED + 1] + [int(oid[3:]) for oid in inputs])
        dy = task_rng.random(n).astype(np.float32)
        dx = task_rng.random(n).astype(np.float32)
        return np.asarray(st_ops.stack_rois(tiles, sky, cal, dy, dx))

    eng = RuntimeEngine().prepare(spec)
    rep = eng.run(task_fn=stack_object, payload_factory=make_tiles,
                  time_scale=args.time_scale, timeout=600.0)
    done = {t.tid: t for t in eng.runtime.dispatcher.completed}
    results = [done[f"astro-{i}"].result for i in range(args.objects)]
    assert all(r.shape == ROI_SHAPE for r in results)
    ideal = wl_cfg.ideal_cache_hit_ratio
    # deterministic summary -> stdout; wall-clock timing -> stderr (the only
    # run-to-run-variable quantity in this example)
    print(f"# wall time {rep.wall_s:.2f}s (time_scale {args.time_scale})",
          file=sys.stderr)
    print(f"stacked {len(results)} objects over {n_files} files "
          f"(locality {args.locality}) on {args.hosts} hosts")
    print(f"  cache hit ratio: {rep.cache_hit_ratio:.2%} "
          f"(paper ideal 1-1/L = {ideal:.0%}; paper achieves >=90% of it)")
    cached = (rep.bytes_by_kind["c2c"] + rep.bytes_by_kind["local"]) / 1e6
    print(f"  bytes: store={rep.bytes_by_kind['store_read'] / 1e6:.1f}MB "
          f"cache-served={cached:.1f}MB")
    print(f"  sample stacked-pixel mean: {float(results[0].mean()):.2f}")
    eng.shutdown()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Property-based tests for the per-executor cache (paper §3.2.2)."""
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (not in image)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.cache import EvictionPolicy, ExecutorCache
from repro.core.objects import DataObject

POLICIES = list(EvictionPolicy)

ops = st.lists(
    st.one_of(
        st.tuples(st.just("put"), st.integers(0, 30), st.integers(1, 40)),
        st.tuples(st.just("get"), st.integers(0, 30), st.just(0)),
        st.tuples(st.just("drop"), st.integers(0, 30), st.just(0)),
    ),
    max_size=120,
)


@pytest.mark.parametrize("policy", POLICIES)
@settings(max_examples=60, deadline=None)
@given(script=ops, capacity=st.integers(1, 120))
def test_cache_invariants(policy, script, capacity):
    cache = ExecutorCache(capacity, policy, seed=7)
    sizes = {}
    for op, oid_i, size in script:
        oid = f"o{oid_i}"
        if op == "put":
            sizes.setdefault(oid, size)
            cache.put(DataObject(oid, sizes[oid]))
        elif op == "get":
            cache.get(oid)
        else:
            cache.drop(oid)
        # INVARIANT: byte accounting exact, never over capacity
        assert cache.used_bytes == sum(sizes[o] for o in cache.contents())
        assert cache.used_bytes <= capacity
    # idempotent re-put of a resident object evicts nothing
    if cache.contents():
        o = next(iter(cache.contents()))
        assert cache.put(DataObject(o, sizes[o])) == []


@pytest.mark.parametrize("policy", POLICIES)
def test_oversized_object_rejected(policy):
    c = ExecutorCache(10, policy)
    c.put(DataObject("big", 11))
    assert "big" not in c
    assert c.stats.rejected == 1


def test_lru_evicts_least_recently_used():
    c = ExecutorCache(3, EvictionPolicy.LRU)
    for o in "abc":
        c.put(DataObject(o, 1))
    c.get("a")  # freshen a; LRU victim is now b
    assert c.put(DataObject("d", 1)) == ["b"]


def test_fifo_evicts_first_inserted_despite_access():
    c = ExecutorCache(3, EvictionPolicy.FIFO)
    for o in "abc":
        c.put(DataObject(o, 1))
    c.get("a")  # access must NOT save a under FIFO
    assert c.put(DataObject("d", 1)) == ["a"]


def test_lfu_evicts_least_frequent_with_fifo_ties():
    c = ExecutorCache(3, EvictionPolicy.LFU)
    for o in "abc":
        c.put(DataObject(o, 1))
    c.get("a"), c.get("a"), c.get("b")
    assert c.put(DataObject("d", 1)) == ["c"]  # freq: a=3,b=2,c=1
    assert c.put(DataObject("e", 1)) == ["d"]  # tie d/e... d freq=1 oldest


def test_pinned_objects_never_evicted():
    c = ExecutorCache(2, EvictionPolicy.LRU)
    c.put(DataObject("a", 1))
    c.pin("a")
    c.put(DataObject("b", 1))
    evicted = c.put(DataObject("c", 1))
    assert "a" not in evicted and "a" in c
    c.unpin("a")
    assert c.put(DataObject("d", 1)) == ["a"]


def test_random_eviction_is_seeded_deterministic():
    runs = []
    for _ in range(2):
        c = ExecutorCache(3, EvictionPolicy.RANDOM, seed=123)
        for i in range(10):
            c.put(DataObject(f"o{i}", 1))
        runs.append(sorted(c.contents()))
    assert runs[0] == runs[1]

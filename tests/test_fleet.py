"""Fleet tests: Channel seams, multi-process execution, failure semantics,
and the single-process-parity contract (DESIGN.md §8).

The heavyweight facts verified here:

  * a FleetRuntime runs the unchanged Dispatcher stack over real OS
    processes and drains workloads end-to-end (msgpack AND forced-JSON
    codecs);
  * SIGKILLing a host mid-workload re-queues its in-flight tasks through
    the PR 2 ``executor_left`` path, the run drains with every task
    accounted (``wait()`` cannot leak), and the global byte ledger equals
    the sum of completed tasks' per-task ledgers exactly (the ledger merge
    is race-free: zombie attempts are dropped with their counters);
  * a recorded JSONL trace replayed batch-synchronously yields IDENTICAL
    scheduling-determined RunReport fields on the in-process runtime and a
    multi-host fleet;
  * DRP integration moves whole hosts (allocate_quantum rounding +
    whole-idle-host release).
"""
from __future__ import annotations

import threading
import time

import pytest

from repro.core import (AllocationPolicy, DataObject, DiffusionRuntime,
                        DynamicResourceProvisioner, Task)
from repro.core.channel import CallbackChannel, ChannelClosed, LocalChannel
from repro.experiments import (CacheSpec, ClusterSpec, ExperimentSpec,
                               RuntimeEngine, WorkloadSpec, run_experiment)
from repro.fleet import FleetRuntime, reports_scheduling_equal
from repro.workloads import ARRIVALS, POPULARITY, generate, record, replay


# --------------------------------------------------------------------------
# channel units (the in-process seam implementations)
# --------------------------------------------------------------------------

class TestChannels:
    def test_local_channel_orders_and_closes(self):
        ch = LocalChannel()
        for i in range(5):
            ch.send(i)
        assert [ch.recv() for _ in range(5)] == list(range(5))
        ch.close()
        with pytest.raises(ChannelClosed):
            ch.recv()
        with pytest.raises(ChannelClosed):
            ch.send(99)

    def test_local_channel_drains_before_close_signal(self):
        ch = LocalChannel()
        ch.send("pending")
        ch.close()
        assert ch.recv() == "pending"   # queued work survives the close
        with pytest.raises(ChannelClosed):
            ch.recv()

    def test_local_channel_recv_timeout(self):
        ch = LocalChannel()
        with pytest.raises(TimeoutError):
            ch.recv(timeout=0.01)

    def test_callback_channel_is_synchronous(self):
        seen = []
        ch = CallbackChannel(seen.append)
        ch.send(1)
        assert seen == [1]              # delivered before send returns
        with pytest.raises(ChannelClosed):
            ch.recv()
        ch.close()
        with pytest.raises(ChannelClosed):
            ch.send(2)

    def test_runtime_routes_both_seams_through_channels(self):
        """The docstring's Channel abstraction is real: the dispatch inbox
        and the update path are Channel objects the fleet can substitute."""
        from repro.core.channel import Channel

        rt = DiffusionRuntime(n_executors=1)
        try:
            assert isinstance(rt.update_channel, Channel)
            assert isinstance(next(iter(rt.workers.values())).inbox, Channel)
        finally:
            rt.shutdown()


# --------------------------------------------------------------------------
# fleet end-to-end
# --------------------------------------------------------------------------

def _put_all(rt, n_objects=12, size=1000):
    objs = [DataObject(f"o{i}", size) for i in range(n_objects)]
    for ob in objs:
        rt.put_object(ob, b"x" * size)
    return objs


def _conservation(rt):
    """Global ledger must equal the sum over completed tasks -- exactly."""
    lg, d = rt.ledger, rt.dispatcher
    sums = [0] * 6
    for t in d.completed:
        sums[0] += t.bytes_local
        sums[1] += t.bytes_cache_to_cache
        sums[2] += t.bytes_store
        sums[3] += t.cache_hits
        sums[4] += t.peer_hits
        sums[5] += t.cache_misses - t.peer_hits
    assert sums == [lg.bytes_local, lg.bytes_c2c, lg.bytes_store,
                    lg.local_hits, lg.peer_hits, lg.store_reads]


@pytest.mark.parametrize("codec", ["auto", "json"])
def test_fleet_end_to_end(codec):
    rt = FleetRuntime(hosts=2, threads_per_host=2, codec=codec,
                      task_fn_name="repro.fleet.runtime:fleet_task")
    try:
        _put_all(rt)
        rt.submit(Task(inputs=(f"o{i % 12}",)) for i in range(60))
        assert rt.wait(60)
        d = rt.dispatcher
        assert len(d.completed) == 60 and not d.failed
        # one ledger access per input, every task ran host-side
        lg = rt.ledger
        assert lg.local_hits + lg.peer_hits + lg.store_reads == 60
        assert all(t.cache_hits + t.cache_misses == 1 for t in d.completed)
        _conservation(rt)
    finally:
        rt.shutdown()


def test_sigkill_host_mid_workload_drains_and_conserves():
    """The headline failure-semantics contract: SIGKILL a host while its
    executors hold in-flight tasks -> those tasks re-queue and run
    elsewhere, the run drains (no wait() leak), membership shrinks by
    exactly one whole host, and the ledger merge stays race-free."""
    rt = FleetRuntime(hosts=3, threads_per_host=2,
                      task_fn_name="repro.fleet.runtime:slow_task",
                      heartbeat_timeout_s=2.0)
    try:
        _put_all(rt, n_objects=16)
        n = 240
        rt.submit(Task(inputs=(f"o{i % 16}",)) for i in range(n))
        time.sleep(0.15)          # let work spread across all hosts
        victim_eids = set(rt.manager.handles["h1"].eids)
        rt.manager.kill_host("h1")
        assert rt.wait(60), "wait() leaked after host SIGKILL"
        d = rt.dispatcher
        assert len(d.completed) + len(d.failed) == n
        assert not d.failed       # default max_attempts=3 absorbs one kill
        assert victim_eids.isdisjoint(rt.workers)
        assert len(rt.workers) == 4
        # the retried tail ran on survivors (pre-kill completions keep
        # their victim eids -- they finished before the host died)
        retried = [t for t in d.completed if t.attempts > 0]
        assert all(t.executor in rt.workers for t in retried)
        _conservation(rt)
        # the pool log recorded the host's executors leaving
        assert [n for _, n in rt.pool_log][-1] == 4
    finally:
        rt.shutdown()


def test_sigkill_with_single_attempt_accounts_terminal_failures():
    """max_attempts=1 turns every in-flight task on the killed host into a
    terminal failure at executor_left time; wait() must still drain (the
    removal path accounts them) and completed+failed must cover every
    submitted task."""
    rt = FleetRuntime(hosts=2, threads_per_host=2,
                      task_fn_name="repro.fleet.runtime:slow_task")
    try:
        _put_all(rt)
        n = 160
        tasks = [Task(inputs=(f"o{i % 12}",)) for i in range(n)]
        for t in tasks:
            t.max_attempts = 1
        rt.submit(tasks)
        time.sleep(0.1)
        rt.manager.kill_host("h0")
        assert rt.wait(60), "wait() leaked terminal failures"
        d = rt.dispatcher
        assert len(d.completed) + len(d.failed) == n
        _conservation(rt)
    finally:
        rt.shutdown()


def test_trace_replay_parity_single_process_vs_fleet(tmp_path):
    """Record a k-input Zipf trace to JSONL, replay it batch-synchronously
    on the in-process runtime and on a 2-host fleet: every scheduling-
    determined quantity (placement included) must agree exactly."""
    wl = generate("par",
                  ARRIVALS["PoissonArrivals"](rate_per_s=100.0),
                  POPULARITY["ZipfPopularity"](alpha=1.1, k=2, corr=0.8),
                  n_tasks=150, n_objects=32, object_bytes=50_000, seed=7)
    trace = tmp_path / "trace.jsonl"
    record(wl, trace)
    replayed = replay(trace)

    def run(rt):
        th = rt.submit_workload(replayed,
                                payload_factory=lambda ob: b"p",
                                barrier_every=4)
        th.join(120)
        assert not th.is_alive() and rt.wait(60)
        d, lg = rt.dispatcher, rt.ledger
        per_task = sorted((t.tid, t.executor, t.cache_hits, t.peer_hits,
                           t.cache_misses) for t in d.completed)
        agg = (len(d.completed), lg.local_hits, lg.peer_hits,
               lg.store_reads, lg.bytes_local, lg.bytes_c2c, lg.bytes_store)
        rt.shutdown()
        return agg, per_task

    agg1, per1 = run(DiffusionRuntime(n_executors=4,
                                      cache_capacity_bytes=10**12, seed=3))
    agg2, per2 = run(FleetRuntime(hosts=2, threads_per_host=2,
                                  cache_capacity_bytes=10**12, seed=3))
    assert agg1 == agg2
    assert per1 == per2   # identical placement, task by task


def test_engine_fleet_report_parity_and_rejections():
    def spec(hosts, tph, n_nodes):
        return ExperimentSpec(
            name="fleet-spec",
            cluster=ClusterSpec(testbed="anl_uc", n_nodes=n_nodes),
            cache=CacheSpec(capacity_bytes=10**11),
            policy="max-compute-util",
            workload=WorkloadSpec(
                name="fs",
                arrivals={"kind": "PoissonArrivals", "rate_per_s": 100.0},
                popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 1,
                            "corr": 1.0},
                n_tasks=80, n_objects=24, object_bytes=10**5, seed=5),
            seed=2, hosts=hosts, threads_per_host=tph)

    r1 = run_experiment(spec(0, 1, 4), engine="runtime",
                        barrier_every=4, timeout=120.0)
    r2 = run_experiment(spec(2, 2, 4), engine="runtime",
                        barrier_every=4, timeout=180.0)
    assert reports_scheduling_equal(r1, r2) == {}
    assert r2.n_completed == 80

    with pytest.raises(ValueError, match="sim engine does not support"):
        run_experiment(spec(2, 2, 4), engine="sim")
    with pytest.raises(ValueError, match="layout mismatch"):
        spec(2, 2, 5)
    with pytest.raises(ValueError, match="threads_per_host"):
        spec(0, 2, 4)
    eng = RuntimeEngine().prepare(spec(2, 2, 4))
    try:
        with pytest.raises(ValueError, match="task callable"):
            eng.run(task_fn=lambda payloads: None)
    finally:
        eng.shutdown()


# --------------------------------------------------------------------------
# whole-host provisioning
# --------------------------------------------------------------------------

class TestWholeHostProvisioning:
    def test_allocate_quantum_rounds_requests(self):
        prov = DynamicResourceProvisioner(
            min_executors=0, max_executors=8,
            policy=AllocationPolicy.ONE_AT_A_TIME,
            trigger_cooldown_s=0.0, allocate_quantum=2)
        acts = prov.step(1.0, queue_len=5, live_executors=0,
                         inflight_allocations=0, idle_executors=[])
        assert acts.allocate == 2      # +1 request buys one whole host
        acts = prov.step(2.0, queue_len=5, live_executors=7,
                         inflight_allocations=0, idle_executors=[])
        assert acts.allocate == 0      # no room for a whole host below max

    def test_zero_room_is_not_a_trigger(self):
        """max not a quantum multiple: the sub-host remainder must not
        churn policy state (exponential burst, cooldown clock) on ticks
        that can never allocate."""
        prov = DynamicResourceProvisioner(
            min_executors=0, max_executors=10,
            policy=AllocationPolicy.EXPONENTIAL,
            trigger_cooldown_s=0.0, allocate_quantum=4)
        acts = prov.step(1.0, 5, 0, 0, [])
        assert acts.allocate == 4      # burst 1 rounded up to one host
        for t in range(2, 50):
            # pool somehow at 8 (say, a second driver): remainder 2 < 4
            acts = prov.step(float(t), 5, 8, 0, [])
            assert acts.allocate == 0
        assert prov._exp_burst <= 4    # no unbounded doubling at room==0
        assert prov.n_allocated == 4

    def test_release_truncates_to_whole_quanta(self):
        prov = DynamicResourceProvisioner(
            min_executors=1, max_executors=8, allocate_quantum=2)
        acts = prov.step(100.0, queue_len=0, live_executors=6,
                         inflight_allocations=0,
                         idle_executors=["a", "b", "c", "d", "e"])
        # releasable = 6-1 = 5 -> truncated to 4 (two whole hosts)
        assert acts.release == ["a", "b", "c", "d"]

    def test_quantum_one_is_bit_identical_legacy(self):
        old = DynamicResourceProvisioner(max_executors=8,
                                         policy=AllocationPolicy.ADDITIVE,
                                         additive_k=3, trigger_cooldown_s=0.0)
        acts = old.step(1.0, 5, 4, 0, [])
        assert acts.allocate == 3 and old.n_allocated == 3

    def test_fleet_grows_and_releases_whole_hosts(self):
        rt = FleetRuntime(hosts=1, threads_per_host=2)
        try:
            _put_all(rt)
            assert len(rt.workers) == 2
            # grow via the provisioning hook: 4 executors = 2 hosts
            rt.provision_grow(4)
            assert len(rt.workers) == 6
            assert len(rt.manager.live_handles()) == 3
            rt.submit(Task(inputs=(f"o{i % 12}",)) for i in range(30))
            assert rt.wait(30)
            # release: only whole-idle hosts are offered, and releasing
            # them removes every executor of those hosts
            idle = rt.provision_idle(time.monotonic(), idle_for_s=0.0)
            assert idle and len(idle) % 2 == 0
            keep_host = rt.manager.live_handles()[0].host_id
            victims = [e for e in idle
                       if rt.workers[e].host.host_id != keep_host]
            rt.provision_release(victims[:2])
            assert len(rt.manager.live_handles()) == 2
            assert len(rt.workers) == 4
            # pool stays serviceable after the release
            rt.submit(Task(inputs=("o0",)) for _ in range(10))
            assert rt.wait(30)
            assert len(rt.dispatcher.completed) == 40
        finally:
            rt.shutdown()

    def test_fleet_engine_drp_allocates_host_multiples(self):
        spec = ExperimentSpec(
            name="fleet-drp",
            cluster=ClusterSpec(testbed="anl_uc", n_nodes=2),
            cache=CacheSpec(capacity_bytes=10**9),
            policy="max-compute-util",
            provisioner={"policy": "additive", "additive_k": 2,
                         "min_executors": 2, "max_executors": 8,
                         "queue_threshold": 2, "idle_timeout_s": 30.0,
                         "trigger_cooldown_s": 0.0, "period_s": 0.05},
            workload=WorkloadSpec(
                name="drp",
                arrivals={"kind": "PoissonArrivals", "rate_per_s": 400.0},
                popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 1,
                            "corr": 1.0},
                n_tasks=300, n_objects=32, object_bytes=10**5, seed=9),
            seed=1, hosts=1, threads_per_host=2)
        spec = ExperimentSpec.from_dict(spec.to_dict())   # exercise strict IO
        eng = RuntimeEngine()
        try:
            eng.prepare(spec)
            rep = eng.run(time_scale=0.02, timeout=180.0)
        finally:
            eng.shutdown()
        assert rep.n_completed == 300
        assert rep.n_allocated > 0
        assert rep.n_allocated % 2 == 0        # whole hosts only
        assert rep.peak_executors % 2 == 0
        assert rep.peak_executors > 2


# --------------------------------------------------------------------------
# hierarchical dispatch (PR 6): local claims, lease reclaim, replay parity
# --------------------------------------------------------------------------

class TestHierarchicalDispatch:
    def test_local_dispatch_end_to_end_with_claims(self):
        """A deep backlog makes the central grant lease slices; hosts score
        and claim work locally, and every claim reconciles centrally."""
        rt = FleetRuntime(hosts=2, threads_per_host=2, local_dispatch=True,
                          task_fn_name="repro.fleet.runtime:fleet_task")
        try:
            _put_all(rt)
            rt.submit(Task(inputs=(f"o{i % 12}",)) for i in range(120))
            assert rt.wait(60)
            d = rt.dispatcher
            assert len(d.completed) == 120 and not d.failed
            st = rt.dispatch_stats()
            assert st["leases"] > 0 and st["claims"] > 0
            assert st["claims"] + st["claim_conflicts"] <= st["leases"]
            _conservation(rt)
        finally:
            rt.shutdown()

    def test_sigkill_host_with_outstanding_leases_drains(self):
        """Killing a host that holds lease slices returns the unclaimed
        tasks to the queue front; the run still drains with every task
        accounted exactly once."""
        rt = FleetRuntime(hosts=3, threads_per_host=2, local_dispatch=True,
                          task_fn_name="repro.fleet.runtime:slow_task",
                          heartbeat_timeout_s=2.0)
        try:
            _put_all(rt, n_objects=16)
            n = 200
            rt.submit(Task(inputs=(f"o{i % 16}",)) for i in range(n))
            time.sleep(0.15)
            rt.manager.kill_host("h1")
            assert rt.wait(60), "wait() leaked after killing a lease holder"
            d = rt.dispatcher
            assert len(d.completed) == n and not d.failed
            st = rt.dispatch_stats()
            assert st["leases"] > 0
            _conservation(rt)
        finally:
            rt.shutdown()

    def test_hierarchical_batched_replay_matches_single_process(self):
        """Batch-synchronous replay (B <= pool) on a hierarchical fleet --
        batching ON, at both wire_batch extremes -- is placement-identical
        to the single-process runtime, and leases never engage (barrier
        chunks drain against an all-idle pool; DESIGN.md §9)."""
        wl = generate("hier",
                      ARRIVALS["PoissonArrivals"](rate_per_s=100.0),
                      POPULARITY["ZipfPopularity"](alpha=1.1, k=2, corr=0.8),
                      n_tasks=120, n_objects=32, object_bytes=50_000,
                      seed=11)

        def run(rt):
            th = rt.submit_workload(wl, payload_factory=lambda ob: b"p",
                                    barrier_every=4)
            th.join(120)
            assert not th.is_alive() and rt.wait(60)
            d = rt.dispatcher
            per_task = sorted((t.tid, t.executor, t.cache_hits, t.peer_hits,
                               t.cache_misses) for t in d.completed)
            st = rt.dispatch_stats() if isinstance(rt, FleetRuntime) else {}
            rt.shutdown()
            return per_task, st

        base, _ = run(DiffusionRuntime(n_executors=4,
                                       cache_capacity_bytes=10**12, seed=3))
        for wb in (64, 1):
            per, st = run(FleetRuntime(hosts=2, threads_per_host=2,
                                       cache_capacity_bytes=10**12, seed=3,
                                       local_dispatch=True, wire_batch=wb))
            assert per == base, f"placement drift at wire_batch={wb}"
            assert st["leases"] == 0 and st["claims"] == 0


class TestDispatchStatsConservation:
    """Regression guard for a dispatch_stats() double-count: the live-handle
    snapshot used to be taken BEFORE the runtime lock, so a host retiring in
    the gap was counted twice -- once from the stale live list, once from
    the counters `_drop_host_locked` had just folded into ``stats``."""

    COUNTERS = ("frames_sent", "msgs_sent", "frames_recv", "msgs_recv",
                "leases", "claims", "claim_conflicts", "dispatches")

    def test_no_reading_exceeds_final_totals_under_sigkill(self):
        """Wire/lease counters are monotone and every unit is counted
        exactly once (live handle XOR folded stats), so no concurrent
        dispatch_stats() reading may ever exceed the final totals taken
        after every host died and folded.  A double-count during
        retirement shows up as a reading ABOVE the final value."""
        rt = FleetRuntime(hosts=3, threads_per_host=2, local_dispatch=True,
                          task_fn_name="repro.fleet.runtime:slow_task",
                          heartbeat_timeout_s=2.0)
        readings: list[dict] = []
        stop = threading.Event()

        def hammer():
            while not stop.is_set():
                readings.append(rt.dispatch_stats())

        th = threading.Thread(target=hammer, daemon=True)
        try:
            _put_all(rt, n_objects=16)
            n = 200
            rt.submit(Task(inputs=(f"o{i % 16}",)) for i in range(n))
            th.start()
            time.sleep(0.15)
            rt.manager.kill_host("h1")   # dies holding leases mid-batch
            assert rt.wait(60), "wait() leaked after SIGKILL"
            assert len(rt.dispatcher.completed) == n
            # retire the survivors too, so EVERY host's counters fold
            for h in list(rt.manager.live_handles()):
                rt.manager.kill_host(h.host_id)
            deadline = time.monotonic() + 15
            while rt.manager.live_handles() and time.monotonic() < deadline:
                time.sleep(0.02)
            assert not rt.manager.live_handles(), "hosts never retired"
            stop.set()
            th.join(10)
            final = rt.dispatch_stats()
        finally:
            stop.set()
            rt.shutdown()
        assert readings, "stats hammer never ran"
        for d in readings:
            for k in self.COUNTERS:
                assert d[k] <= final[k], \
                    f"{k} read {d[k]} > final {final[k]}: double-count"
        # lease conservation across the kill: every lease produced at most
        # one claim or conflict; the rest were reclaimed, never re-counted
        assert final["leases"] > 0
        assert final["claims"] + final["claim_conflicts"] <= final["leases"]
        # frames carry >= 1 logical message each, in both directions
        assert final["msgs_sent"] >= final["frames_sent"] > 0
        assert final["msgs_recv"] >= final["frames_recv"] > 0

    def test_stats_decompose_into_folded_plus_live(self):
        """At quiescence the report is exactly stats (retired hosts folded
        in) plus the live connections' wire counters -- the identity the
        locked snapshot preserves."""
        rt = FleetRuntime(hosts=2, threads_per_host=2,
                          task_fn_name="repro.fleet.runtime:fleet_task")
        try:
            _put_all(rt)
            rt.submit(Task(inputs=(f"o{i % 12}",)) for i in range(40))
            assert rt.wait(60)
            with rt._lock:
                expect = rt.stats.as_dict()
                for h in rt.manager.live_handles():
                    expect["frames_sent"] += h.frames_sent
                    expect["msgs_sent"] += h.msgs_sent
                    expect["frames_recv"] += h.frames_recv
                    expect["msgs_recv"] += h.msgs_recv
            got = rt.dispatch_stats()
            # heartbeats may land between the two snapshots: recv counters
            # are monotone, everything else must match exactly
            for k in ("frames_sent", "msgs_sent", "leases", "claims",
                      "claim_conflicts", "dispatches"):
                assert got[k] == expect[k], k
            assert got["frames_recv"] >= expect["frames_recv"]
            assert got["msgs_recv"] >= expect["msgs_recv"]
        finally:
            rt.shutdown()


def test_fleet_event_forwarding_reaches_central_ring():
    """Observability frames ride the host's one BatchingChannel outbox:
    a recorded fleet run lands host-side exec/input events in the central
    recorder, interleaved so each task's exec events precede its central
    task_done in ring order (the frame is enqueued before the flushed
    done; DESIGN.md §10)."""
    from repro.obs import Recorder, lifecycle_fingerprints

    rec = Recorder()
    rt = FleetRuntime(hosts=2, threads_per_host=2, recorder=rec,
                      task_fn_name="repro.fleet.runtime:fleet_task")
    try:
        _put_all(rt)
        n = 40
        rt.submit(Task(inputs=(f"o{i % 12}",)) for i in range(n))
        assert rt.wait(60)
        assert len(rt.dispatcher.completed) == n
    finally:
        rt.shutdown()
    events = rec.events()
    fps = lifecycle_fingerprints(events)
    assert len(fps) == n
    for tid, (kinds, exec_idx, inputs) in fps.items():
        assert kinds[0] == "task_arrived"
        assert kinds[-1] == "task_done"
        # host-side exec events arrived before the central done
        assert kinds.index("exec_end") < kinds.index("task_done"), tid
        assert exec_idx is not None and len(inputs) == 1
    assert rec.dropped == 0


def test_bind_host_loopback_alias():
    """Multi-machine seam: bind the whole fleet (central listener, host
    peer servers) to a loopback alias; hosts advertise it in their hello
    and cache-to-cache traffic flows through it."""
    rt = FleetRuntime(hosts=2, threads_per_host=1, bind_host="127.0.0.2",
                      task_fn_name="repro.fleet.runtime:fleet_task")
    try:
        assert rt.manager.addr[0] == "127.0.0.2"
        for h in rt.manager.live_handles():
            assert h.peer_host == "127.0.0.2"     # advertised, not assumed
        _put_all(rt, n_objects=8)
        rt.submit(Task(inputs=(f"o{i % 8}", f"o{(i + 3) % 8}"))
                  for i in range(60))
        assert rt.wait(60)
        assert len(rt.dispatcher.completed) == 60
        assert rt.ledger.peer_hits > 0            # c2c went over the alias
        _conservation(rt)
    finally:
        rt.shutdown()

"""DynamicResourceProvisioner (Falkon §3.1): all four allocation policies,
exponential-burst reset, trigger cooldown, idle-timeout release."""
import pytest

from repro.core.provisioner import (AllocationPolicy,
                                    DynamicResourceProvisioner)


def _prov(policy, **kw):
    kw.setdefault("min_executors", 0)
    kw.setdefault("max_executors", 16)
    kw.setdefault("queue_threshold", 1)
    kw.setdefault("idle_timeout_s", 10.0)
    kw.setdefault("trigger_cooldown_s", 1.0)
    return DynamicResourceProvisioner(policy=policy, **kw)


# --------------------------- allocation policies -----------------------------

def test_one_at_a_time_allocates_single_executor_per_trigger():
    p = _prov(AllocationPolicy.ONE_AT_A_TIME)
    for i in range(3):
        acts = p.step(now=float(i * 2), queue_len=5, live_executors=i,
                      inflight_allocations=0, idle_executors=[])
        assert acts.allocate == 1
    assert p.n_allocated == 3


def test_additive_allocates_k_per_trigger():
    p = _prov(AllocationPolicy.ADDITIVE, additive_k=4)
    acts = p.step(now=0.0, queue_len=9, live_executors=0,
                  inflight_allocations=0, idle_executors=[])
    assert acts.allocate == 4
    acts = p.step(now=5.0, queue_len=9, live_executors=4,
                  inflight_allocations=0, idle_executors=[])
    assert acts.allocate == 4


def test_exponential_doubles_per_consecutive_trigger():
    p = _prov(AllocationPolicy.EXPONENTIAL, max_executors=64)
    got = []
    live = 0
    for i in range(4):
        acts = p.step(now=float(i * 2), queue_len=99, live_executors=live,
                      inflight_allocations=0, idle_executors=[])
        got.append(acts.allocate)
        live += acts.allocate
    assert got == [1, 2, 4, 8]


def test_exponential_burst_resets_when_queue_drains():
    p = _prov(AllocationPolicy.EXPONENTIAL, max_executors=64)
    p.step(now=0.0, queue_len=9, live_executors=0,
           inflight_allocations=0, idle_executors=[])
    p.step(now=2.0, queue_len=9, live_executors=1,
           inflight_allocations=0, idle_executors=[])
    assert p._exp_burst == 4                       # primed to keep doubling
    # queue drains below threshold: the burst resets to 1
    p.step(now=4.0, queue_len=0, live_executors=3,
           inflight_allocations=0, idle_executors=[])
    acts = p.step(now=6.0, queue_len=9, live_executors=3,
                  inflight_allocations=0, idle_executors=[])
    assert acts.allocate == 1


def test_all_at_once_jumps_to_max():
    p = _prov(AllocationPolicy.ALL_AT_ONCE, max_executors=16)
    acts = p.step(now=0.0, queue_len=1, live_executors=3,
                  inflight_allocations=1, idle_executors=[])
    assert acts.allocate == 12                     # max - live - inflight


@pytest.mark.parametrize("policy", list(AllocationPolicy))
def test_never_exceeds_max_executors(policy):
    p = _prov(policy, max_executors=8, additive_k=100)
    acts = p.step(now=0.0, queue_len=1000, live_executors=6,
                  inflight_allocations=1, idle_executors=[])
    assert acts.allocate <= 1                      # only one slot of room
    acts = p.step(now=5.0, queue_len=1000, live_executors=8,
                  inflight_allocations=0, idle_executors=[])
    assert acts.allocate == 0                      # pool already at max


def test_below_threshold_queue_never_triggers():
    p = _prov(AllocationPolicy.ALL_AT_ONCE, queue_threshold=4)
    acts = p.step(now=0.0, queue_len=3, live_executors=0,
                  inflight_allocations=0, idle_executors=[])
    assert acts.allocate == 0 and p.n_allocated == 0


# --------------------------- cooldown ----------------------------------------

def test_trigger_cooldown_suppresses_back_to_back_allocation():
    p = _prov(AllocationPolicy.ONE_AT_A_TIME, trigger_cooldown_s=5.0)
    assert p.step(now=0.0, queue_len=9, live_executors=0,
                  inflight_allocations=0, idle_executors=[]).allocate == 1
    # within the cooldown window: no trigger even though the queue is deep
    assert p.step(now=2.0, queue_len=9, live_executors=0,
                  inflight_allocations=1, idle_executors=[]).allocate == 0
    # once the cooldown elapses, triggering resumes
    assert p.step(now=5.0, queue_len=9, live_executors=1,
                  inflight_allocations=0, idle_executors=[]).allocate == 1


# --------------------------- release -----------------------------------------

def test_idle_timeout_release_down_to_min():
    p = _prov(AllocationPolicy.ALL_AT_ONCE, min_executors=2)
    acts = p.step(now=100.0, queue_len=0, live_executors=5,
                  inflight_allocations=0,
                  idle_executors=["e0", "e1", "e2", "e3", "e4"])
    assert acts.release == ["e0", "e1", "e2"]      # 5 live - 2 min
    assert p.n_released == 3


def test_no_release_while_queue_nonempty():
    p = _prov(AllocationPolicy.ALL_AT_ONCE, min_executors=0)
    acts = p.step(now=100.0, queue_len=1, live_executors=4,
                  inflight_allocations=0, idle_executors=["e0", "e1"])
    assert acts.release == []


def test_release_limited_to_idle_set():
    p = _prov(AllocationPolicy.ALL_AT_ONCE, min_executors=0)
    acts = p.step(now=100.0, queue_len=0, live_executors=8,
                  inflight_allocations=0, idle_executors=["e5"])
    assert acts.release == ["e5"]                  # busy executors stay

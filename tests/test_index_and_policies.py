"""Location index (§3.2.3) + the four dispatch policies (§3.2.2)."""
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (not in image)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.index import (IndexUpdate, LocationIndex, ShardedIndex,
                              prls_aggregate_throughput, prls_latency_model)
from repro.core.objects import Task
from repro.core.policies import DispatchPolicy, decide


# --------------------------- index ------------------------------------------

def test_index_roundtrip_and_invalidation():
    ix = LocationIndex()
    ix.insert("a", "e0"); ix.insert("a", "e1"); ix.insert("b", "e0")
    assert ix.lookup("a") == {"e0", "e1"}
    assert ix.holdings("e0") == {"a", "b"}
    assert ix.drop_executor("e0") == 2          # failure invalidation
    assert ix.lookup("a") == {"e1"}
    assert ix.lookup("b") == frozenset()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 20), st.integers(0, 5)), max_size=80))
def test_sharded_index_matches_central(pairs):
    """The sharded (beyond-paper) index is observably identical."""
    central, sharded = LocationIndex(), ShardedIndex(4)
    for oid_i, ex_i in pairs:
        oid, ex = f"o{oid_i}", f"e{ex_i}"
        central.insert(oid, ex)
        sharded.insert(oid, ex)
    for oid_i in {p[0] for p in pairs}:
        assert central.lookup(f"o{oid_i}") == sharded.lookup(f"o{oid_i}")


def test_index_perf_is_microseconds_scale():
    """Paper: 1-3 us inserts, 0.25-1 us lookups (Java 2008).  We assert a
    generous 25 us bound -- the argument (µs-scale central index beats a
    distributed one until ~32K nodes) survives an order of magnitude."""
    t = LocationIndex().time_ops(50_000)
    assert t["insert_s"] < 25e-6
    assert t["lookup_s"] < 25e-6


def test_prls_model_matches_paper_anchors():
    # ~0.5 ms at 1 node, ~3 ms at 15 nodes, ~15 ms at 1M nodes (§3.2.3)
    assert abs(prls_latency_model(1) - 0.5e-3) < 1e-4
    assert abs(prls_latency_model(15) - 2.5e-3) < 1e-3
    assert abs(prls_latency_model(1_000_000) - 15e-3) < 5e-3
    # paper: >32K P-RLS nodes needed to match ~4.18M lookups/s
    assert prls_aggregate_throughput(32_000) > 2e6


def test_loose_coherence_batch_apply():
    ix = LocationIndex()
    ix.apply_batch([IndexUpdate("e0", added=("a", "b")),
                    IndexUpdate("e0", removed=("a",)),
                    IndexUpdate("e1", added=("a",))])
    assert ix.lookup("a") == {"e1"}
    assert ix.lookup("b") == {"e0"}


# --------------------------- policies -----------------------------------------

def _setup():
    ix = LocationIndex()
    ix.insert("x", "e1")
    ix.insert("y", "e2")
    sizes = {"x": 100, "y": 10}
    return ix, sizes


def test_first_available_ignores_locality_and_ships_no_hints():
    ix, sizes = _setup()
    t = Task(inputs=("x",))
    d = decide(DispatchPolicy.FIRST_AVAILABLE, t, ["e0", "e1"], [], ix, sizes)
    assert d.executor == "e0"       # first, not the holder e1
    assert d.hints == {}            # executor must hit persistent storage


def test_first_cache_available_ships_hints():
    ix, sizes = _setup()
    t = Task(inputs=("x",))
    d = decide(DispatchPolicy.FIRST_CACHE_AVAILABLE, t, ["e0", "e1"], [], ix, sizes)
    assert d.executor == "e0"
    assert d.hints == {"x": ("e1",)}   # peer fetch possible


def test_max_compute_util_prefers_cached_bytes_among_available():
    ix, sizes = _setup()
    t = Task(inputs=("x", "y"))
    # e1 caches 100 bytes of inputs, e2 caches 10
    d = decide(DispatchPolicy.MAX_COMPUTE_UTIL, t, ["e0", "e1", "e2"], [], ix, sizes)
    assert d.executor == "e1"
    # but NEVER waits: if only e0 is free, use it
    d = decide(DispatchPolicy.MAX_COMPUTE_UTIL, t, ["e0"], ["e1", "e2"], ix, sizes)
    assert d.executor == "e0"


def test_max_cache_hit_waits_for_busy_holder():
    ix, sizes = _setup()
    t = Task(inputs=("x",))
    d = decide(DispatchPolicy.MAX_CACHE_HIT, t, ["e0"], ["e1"], ix, sizes)
    assert d.executor is None and d.wait_for == "e1"   # defining behaviour
    # nothing cached anywhere -> degrade to first available
    t2 = Task(inputs=("z",))
    d2 = decide(DispatchPolicy.MAX_CACHE_HIT, t2, ["e0"], ["e1"], ix, sizes)
    assert d2.executor == "e0"

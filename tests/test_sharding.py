"""Logical-axis sharding rules: dedup + divisibility (the mixtral case)."""
import subprocess
import sys
import textwrap

from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import LogicalRules


def _rules(shape=(16, 16), axes=("data", "model")):
    class FakeMesh:
        axis_names = axes
        devices = type("D", (), {"shape": shape})()
    return FakeMesh()


def mk(rules_dict, mesh_shape=(16, 16), axes=("data", "model")):
    import jax
    # a real (CPU) mesh is not needed for spec computation: LogicalRules only
    # reads axis_names/devices.shape
    mesh = _rules(mesh_shape, axes)
    return LogicalRules(rules_dict, mesh)


def test_divisibility_guard_drops_non_dividing_axes():
    r = mk({"tp": ("model",), "fsdp": ("data",)})
    # whisper vocab 51865 % 16 != 0 -> tp dropped on that dim
    assert r.spec_for_shape(("tp", "fsdp"), (51865, 512)) == P(None, "data")
    assert r.spec_for_shape(("tp", "fsdp"), (51200, 512)) == P("model", "data")


def test_mixtral_expert_dim_does_not_consume_model_axis():
    """8 experts cannot use the 16-way axis; d_ff MUST still get it."""
    r = mk({"expert": ("model",), "fsdp": ("data",), "tp": ("model",)})
    spec = r.spec_for_shape(("expert", "fsdp", "tp"), (8, 6144, 16384))
    assert spec == P(None, "data", "model")


def test_multi_axis_logical_name():
    r = mk({"batch": ("pod", "data", "model")}, (2, 16, 16),
           ("pod", "data", "model"))
    # 256 over 2*16*16=512: pod*data=32 divides, then model would need 512
    assert r.spec_for_shape(("batch",), (256,)) == P(("pod", "data"))
    assert r.spec_for_shape(("batch",), (512,)) == P(("pod", "data", "model"))
    # batch=1 (long_500k): everything dropped
    assert r.spec_for_shape(("batch",), (1,)) == P()


def test_axis_used_once_across_dims():
    r = mk({"tp": ("model",), "act_seq": ("model",), "batch": ("data",)})
    # act_seq claims model on dim1 => vocab dim gets nothing
    spec = r.spec_for_shape(("batch", "act_seq", "tp"), (256, 4096, 32000))
    assert spec == P("data", "model")


EP_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.config import LayerSpec, ModelConfig
    from repro.models.moe import moe_block, moe_block_sharded
    from repro.models.transformer import init_params
    from repro.parallel.sharding import make_rules

    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      head_dim=8, pattern=(LayerSpec(mlp="moe"),),
                      n_experts=4, top_k=2, capacity_factor=8.0,
                      dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    p = {k: v[0] for k, v in params["blocks"]["sub0"].items()
         if k.startswith("w_")}
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))

    ref, _ = jax.jit(lambda x: moe_block(x, p, cfg.top_k, cfg.mlp_act,
                                         cfg.capacity_factor))(x)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = make_rules(mesh, extra={"act_seq": ()})
    ep, _ = jax.jit(lambda x: moe_block_sharded(x, p, cfg, rules))(x)
    err = float(jnp.max(jnp.abs(ref - ep)))
    assert err < 2e-4, f"EP mismatch {err}"
    print("EP_OK", err)
""")


def test_moe_ep_matches_reference_on_multidevice():
    """shard_map EP MoE == capacity-einsum reference (8 fake devices).
    capacity_factor is large so neither path drops tokens."""
    out = subprocess.run([sys.executable, "-c", EP_EQUIV],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"}, cwd="/root/repo")
    assert "EP_OK" in out.stdout, out.stdout + out.stderr

"""Prefix-cache-aware serving: router policies + engine correctness."""
import numpy as np
import pytest

from repro.core.cache import EvictionPolicy
from repro.core.policies import DispatchPolicy
from repro.models.config import ModelConfig
from repro.serve import PrefixAwareRouter, Request, ServeEngine
from repro.serve.kvcache import prefix_chain, prefix_oid

TINY = ModelConfig(name="tiny-serve", family="dense", n_layers=2, d_model=32,
                   n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                   head_dim=8)


def test_prefix_chain_is_block_aligned_and_content_addressed():
    toks = list(range(200))
    chain = prefix_chain(toks, block=64)
    assert len(chain) == 3                       # 64, 128, 192
    assert chain[0] == prefix_oid(toks[:64])
    # content addressing: same prefix -> same oid, different -> different
    assert prefix_oid(toks[:64]) == prefix_oid(list(range(64)))
    assert prefix_oid(toks[:64]) != prefix_oid([1] + toks[1:64])


def _drive(policy, n_prompts=32, n_bases=4):
    rng = np.random.default_rng(0)
    router = PrefixAwareRouter(4, policy, EvictionPolicy.LRU,
                               replica_cache_bytes=1 << 24,
                               kv_bytes_per_token=64, block=16,
                               slots_per_replica=2)
    bases = [list(rng.integers(0, 100, 64)) for _ in range(n_bases)]
    reused = 0
    total = 0
    inflight = []
    for i in range(n_prompts):
        prompt = bases[i % n_bases] + list(rng.integers(0, 100, 16))
        r = router.route(prompt)
        reused += r.reused_prefix_tokens
        total += len(prompt)
        inflight.append((prompt, r))
        if len(inflight) >= 6:   # completions lag routing: replicas stay
            pr, rr = inflight.pop(0)      # busy, availability matters
            router.complete(pr, rr)
    for pr, rr in inflight:
        router.complete(pr, rr)
    return reused / total, router


def test_data_aware_routing_beats_data_unaware():
    """The paper's Figure-3 ordering, serving edition: the data-aware
    policies reuse more prefix KV than first-available.  max-cache-hit
    (waits for the holder -- max locality) shows the cleanest separation;
    max-compute-util trades locality for utilization (paper §3.2.2) so it
    is only required not to regress."""
    frac_fa, _ = _drive(DispatchPolicy.FIRST_AVAILABLE)
    frac_mcu, _ = _drive(DispatchPolicy.MAX_COMPUTE_UTIL)
    frac_mch, _ = _drive(DispatchPolicy.MAX_CACHE_HIT)
    assert frac_mch >= frac_fa + 0.08
    assert frac_mcu >= frac_fa - 1e-9


def test_router_eviction_keeps_index_coherent():
    _, router = _drive(DispatchPolicy.MAX_COMPUTE_UTIL, n_prompts=64,
                       n_bases=16)
    for rid, rep in router.replicas.items():
        for oid in rep.cache.contents():
            assert rid in router.index.lookup(oid)
        for oid, size in router.sizes.items():
            if rid in router.index.lookup(oid):
                assert oid in rep.cache


def test_serve_engine_generates_and_reuses():
    eng = ServeEngine(TINY, n_replicas=2,
                      policy=DispatchPolicy.MAX_COMPUTE_UTIL, max_seq=64)
    rng = np.random.default_rng(1)
    base = list(rng.integers(2, 100, 32))
    reqs1 = [Request(rid=i, prompt=base + list(rng.integers(2, 100, 4)),
                     max_new_tokens=4) for i in range(4)]
    out1 = eng.generate(reqs1)
    assert all(len(r.output) == 4 for r in out1)
    before = eng.reused_tokens
    reqs2 = [Request(rid=9 + i, prompt=base + list(rng.integers(2, 100, 4)),
                     max_new_tokens=4) for i in range(4)]
    eng.generate(reqs2)
    assert eng.reused_tokens > before            # second wave hits caches


def test_serve_engine_greedy_matches_forward():
    """serve_step replay == forward logits => generation is trustworthy."""
    import jax
    import jax.numpy as jnp
    from repro.models import init_params, make_forward
    eng = ServeEngine(TINY, n_replicas=1, max_seq=16)
    prompt = list(range(2, 10))
    req = Request(rid=0, prompt=prompt, max_new_tokens=1)
    eng.generate([req])
    toks = np.zeros((1, 16), np.int32)
    toks[0, : len(prompt)] = prompt
    logits, _ = jax.jit(make_forward(TINY))(eng.params,
                                            {"tokens": jnp.asarray(toks)})
    expect = int(jnp.argmax(logits[0, len(prompt) - 1]))
    assert req.output[0] == expect

"""Wire-protocol tests: framing + codec round-trips (DESIGN.md §8).

Every payload class the fleet ships is round-tripped under BOTH codecs
(msgpack when present, and the forced-JSON fallback): numpy arrays,
raw bytes, k-input task dispatch messages, empty payloads, and the
runtime's shape-only store sentinel (PR 4) -- which must decode to the
sentinel *object*, because a None payload reads as a cache miss.
"""
from __future__ import annotations

import socket
import struct
import threading

import numpy as np
import pytest

from repro.core.channel import ChannelClosed
from repro.core.runtime import SHAPE_ONLY_PAYLOAD
from repro.fleet import wire
from repro.fleet.wire import (MAX_FRAME, PeerGone, SocketChannel, WireError,
                              decode, encode, recv_msg, send_msg)

CODECS = ["msgpack", "json"] if wire.HAVE_MSGPACK else ["json"]


@pytest.fixture(params=CODECS)
def codec(request):
    return request.param


def rt(obj, codec):
    return decode(encode(obj, codec), codec)


# --------------------------------------------------------------------------
# codec round-trips
# --------------------------------------------------------------------------

def test_scalars_and_structures(codec):
    msg = {"t": "task", "n": 3, "f": 1.5, "flag": True, "none": None,
           "nested": {"deep": [1, "two", 3.0, False, None]}}
    assert rt(msg, codec) == msg


def test_tuples_become_lists(codec):
    assert rt({"inputs": ("a", "b")}, codec) == {"inputs": ["a", "b"]}


def test_bytes_round_trip(codec):
    for b in (b"", b"\x00\xff" * 100, bytes(range(256))):
        assert rt({"payload": b}, codec) == {"payload": b}


@pytest.mark.parametrize("dtype", ["float32", "float64", "int32", "uint8",
                                   "complex64", "bool"])
def test_ndarray_round_trip(codec, dtype):
    arr = (np.arange(24).reshape(2, 3, 4) % 2).astype(dtype)
    out = rt(arr, codec)
    assert isinstance(out, np.ndarray)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    assert np.array_equal(out, arr)


def test_ndarray_empty_and_noncontiguous(codec):
    empty = np.zeros((0, 5), dtype=np.float32)
    out = rt(empty, codec)
    assert out.shape == (0, 5) and out.dtype == np.float32
    base = np.arange(36, dtype=np.int64).reshape(6, 6)
    sliced = base[::2, ::3]          # non-contiguous view
    assert not sliced.flags["C_CONTIGUOUS"]
    assert np.array_equal(rt(sliced, codec), sliced)


def test_shape_only_sentinel_is_identity(codec):
    """The PR 4 sentinel must cross the wire as ITSELF: the runtime's
    cache-hit test is `payload is not None`, and the hosts' store replicas
    hold whatever decode() returns."""
    out = rt({"payload": SHAPE_ONLY_PAYLOAD}, codec)
    assert out["payload"] is SHAPE_ONLY_PAYLOAD


def test_k_input_task_message(codec):
    """A realistic 3-input dispatch with hints + routes survives."""
    msg = {"t": "task", "eid": "w3", "tid": "wl-17",
           "inputs": [["a", 100], ["b", 200], ["a", 100]],   # dup oids stay
           "outputs": [["wl-17.out", 64]],
           "hints": {"a": ["w0", "w3"], "b": ["w1"]},
           "routes": {"w0": ["127.0.0.1", 4242], "w1": ["127.0.0.1", 4243]}}
    out = rt(msg, codec)
    assert out["inputs"] == msg["inputs"]
    assert out["hints"] == msg["hints"]
    assert out["routes"]["w0"] == ["127.0.0.1", 4242]


def test_empty_payloads(codec):
    assert rt({}, codec) == {}
    assert rt([], codec) == []
    assert rt({"payload": b""}, codec) == {"payload": b""}


def test_reserved_and_bad_keys_hard_error(codec):
    with pytest.raises(WireError):
        encode({"__wire__": "nope"}, codec)
    with pytest.raises(WireError):
        encode({1: "int key"}, codec)
    with pytest.raises(WireError):
        encode({"fn": object()}, codec)


def test_unknown_tag_hard_errors(codec):
    data = encode({"x": 1}, codec)
    # hand-craft an unknown tag through the raw codec
    import json as _json
    bad = _json.dumps({"__wire__": "martian"}).encode()
    with pytest.raises(WireError):
        decode(bad, "json")
    assert decode(data, codec) == {"x": 1}


# --------------------------------------------------------------------------
# framing over real sockets
# --------------------------------------------------------------------------

def _pair():
    a, b = socket.socketpair()
    return a, b


def test_framed_messages_preserve_order(codec):
    a, b = _pair()
    msgs = [{"i": i, "data": b"x" * i} for i in range(50)]
    def send():
        for m in msgs:
            send_msg(a, m, codec)
    th = threading.Thread(target=send)
    th.start()
    got = [recv_msg(b, codec) for _ in range(50)]
    th.join()
    assert got == [decode(encode(m, codec), codec) for m in msgs]
    a.close(); b.close()


def test_large_frame(codec):
    a, b = _pair()
    arr = np.random.default_rng(0).random(200_000)   # ~1.6 MB payload
    th = threading.Thread(target=send_msg, args=(a, {"arr": arr}, codec))
    th.start()
    out = recv_msg(b, codec)
    th.join()
    assert np.array_equal(out["arr"], arr)
    a.close(); b.close()


def test_oversized_frame_header_rejected():
    a, b = _pair()
    a.sendall(struct.pack(">I", MAX_FRAME + 1))
    with pytest.raises(WireError):
        recv_msg(b)
    a.close(); b.close()


def test_eof_raises_peer_gone(codec):
    a, b = _pair()
    a.close()
    with pytest.raises(PeerGone):
        recv_msg(b, codec)
    b.close()


def test_eof_mid_frame_raises_peer_gone(codec):
    a, b = _pair()
    payload = encode({"x": 1}, codec)
    a.sendall(struct.pack(">I", len(payload)) + payload[:1])
    a.close()
    with pytest.raises(PeerGone):
        recv_msg(b, codec)
    b.close()


def test_socket_channel_pair(codec):
    a, b = _pair()
    ca, cb = SocketChannel(a, codec), SocketChannel(b, codec)
    ca.send({"hello": 1})
    assert cb.recv() == {"hello": 1}
    assert ca.bytes_sent > 4
    ca.close()
    with pytest.raises(ChannelClosed):
        cb.recv()
    with pytest.raises(ChannelClosed):
        ca.send({"x": 1})
    cb.close()

"""Wire-protocol tests: framing + codec round-trips (DESIGN.md §8) and the
batched wire (DESIGN.md §9).

Every payload class the fleet ships is round-tripped under BOTH codecs
(msgpack when present, and the forced-JSON fallback): numpy arrays,
raw bytes, k-input task dispatch messages, empty payloads, and the
runtime's shape-only store sentinel (PR 4) -- which must decode to the
sentinel *object*, because a None payload reads as a cache miss.

The batching half covers `BatchingChannel` (bounded coalescing, flush
semantics, the batch=1 degenerate), batch-frame codec round-trips, and --
through a fake-host harness driving the REAL `_on_remote_batch` receive
path -- randomized updates/done/hb interleavings under random frame
chunkings: every task completes exactly once, the byte ledger conserves,
and frames from a dead host can never resurrect its index entries.
"""
from __future__ import annotations

import random
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.core import Task
from repro.core.channel import BatchingChannel, ChannelClosed, LocalChannel
from repro.core.runtime import SHAPE_ONLY_PAYLOAD
from repro.fleet import FleetRuntime, wire
from repro.fleet.manager import HostHandle
from repro.fleet.runtime import _RemoteExecutor
from repro.fleet.wire import (MAX_FRAME, PeerGone, SocketChannel, WireError,
                              decode, encode, recv_msg, send_msg)

CODECS = ["msgpack", "json"] if wire.HAVE_MSGPACK else ["json"]


@pytest.fixture(params=CODECS)
def codec(request):
    return request.param


def rt(obj, codec):
    return decode(encode(obj, codec), codec)


# --------------------------------------------------------------------------
# codec round-trips
# --------------------------------------------------------------------------

def test_scalars_and_structures(codec):
    msg = {"t": "task", "n": 3, "f": 1.5, "flag": True, "none": None,
           "nested": {"deep": [1, "two", 3.0, False, None]}}
    assert rt(msg, codec) == msg


def test_tuples_become_lists(codec):
    assert rt({"inputs": ("a", "b")}, codec) == {"inputs": ["a", "b"]}


def test_bytes_round_trip(codec):
    for b in (b"", b"\x00\xff" * 100, bytes(range(256))):
        assert rt({"payload": b}, codec) == {"payload": b}


@pytest.mark.parametrize("dtype", ["float32", "float64", "int32", "uint8",
                                   "complex64", "bool"])
def test_ndarray_round_trip(codec, dtype):
    arr = (np.arange(24).reshape(2, 3, 4) % 2).astype(dtype)
    out = rt(arr, codec)
    assert isinstance(out, np.ndarray)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    assert np.array_equal(out, arr)


def test_ndarray_empty_and_noncontiguous(codec):
    empty = np.zeros((0, 5), dtype=np.float32)
    out = rt(empty, codec)
    assert out.shape == (0, 5) and out.dtype == np.float32
    base = np.arange(36, dtype=np.int64).reshape(6, 6)
    sliced = base[::2, ::3]          # non-contiguous view
    assert not sliced.flags["C_CONTIGUOUS"]
    assert np.array_equal(rt(sliced, codec), sliced)


def test_shape_only_sentinel_is_identity(codec):
    """The PR 4 sentinel must cross the wire as ITSELF: the runtime's
    cache-hit test is `payload is not None`, and the hosts' store replicas
    hold whatever decode() returns."""
    out = rt({"payload": SHAPE_ONLY_PAYLOAD}, codec)
    assert out["payload"] is SHAPE_ONLY_PAYLOAD


def test_k_input_task_message(codec):
    """A realistic 3-input dispatch with hints + routes survives."""
    msg = {"t": "task", "eid": "w3", "tid": "wl-17",
           "inputs": [["a", 100], ["b", 200], ["a", 100]],   # dup oids stay
           "outputs": [["wl-17.out", 64]],
           "hints": {"a": ["w0", "w3"], "b": ["w1"]},
           "routes": {"w0": ["127.0.0.1", 4242], "w1": ["127.0.0.1", 4243]}}
    out = rt(msg, codec)
    assert out["inputs"] == msg["inputs"]
    assert out["hints"] == msg["hints"]
    assert out["routes"]["w0"] == ["127.0.0.1", 4242]


def test_empty_payloads(codec):
    assert rt({}, codec) == {}
    assert rt([], codec) == []
    assert rt({"payload": b""}, codec) == {"payload": b""}


def test_reserved_and_bad_keys_hard_error(codec):
    with pytest.raises(WireError):
        encode({"__wire__": "nope"}, codec)
    with pytest.raises(WireError):
        encode({1: "int key"}, codec)
    with pytest.raises(WireError):
        encode({"fn": object()}, codec)


def test_unknown_tag_hard_errors(codec):
    data = encode({"x": 1}, codec)
    # hand-craft an unknown tag through the raw codec
    import json as _json
    bad = _json.dumps({"__wire__": "martian"}).encode()
    with pytest.raises(WireError):
        decode(bad, "json")
    assert decode(data, codec) == {"x": 1}


# --------------------------------------------------------------------------
# framing over real sockets
# --------------------------------------------------------------------------

def _pair():
    a, b = socket.socketpair()
    return a, b


def test_framed_messages_preserve_order(codec):
    a, b = _pair()
    msgs = [{"i": i, "data": b"x" * i} for i in range(50)]
    def send():
        for m in msgs:
            send_msg(a, m, codec)
    th = threading.Thread(target=send)
    th.start()
    got = [recv_msg(b, codec) for _ in range(50)]
    th.join()
    assert got == [decode(encode(m, codec), codec) for m in msgs]
    a.close(); b.close()


def test_large_frame(codec):
    a, b = _pair()
    arr = np.random.default_rng(0).random(200_000)   # ~1.6 MB payload
    th = threading.Thread(target=send_msg, args=(a, {"arr": arr}, codec))
    th.start()
    out = recv_msg(b, codec)
    th.join()
    assert np.array_equal(out["arr"], arr)
    a.close(); b.close()


def test_oversized_frame_header_rejected():
    a, b = _pair()
    a.sendall(struct.pack(">I", MAX_FRAME + 1))
    with pytest.raises(WireError):
        recv_msg(b)
    a.close(); b.close()


def test_eof_raises_peer_gone(codec):
    a, b = _pair()
    a.close()
    with pytest.raises(PeerGone):
        recv_msg(b, codec)
    b.close()


def test_eof_mid_frame_raises_peer_gone(codec):
    a, b = _pair()
    payload = encode({"x": 1}, codec)
    a.sendall(struct.pack(">I", len(payload)) + payload[:1])
    a.close()
    with pytest.raises(PeerGone):
        recv_msg(b, codec)
    b.close()


def test_socket_channel_pair(codec):
    a, b = _pair()
    ca, cb = SocketChannel(a, codec), SocketChannel(b, codec)
    ca.send({"hello": 1})
    assert cb.recv() == {"hello": 1}
    assert ca.bytes_sent > 4
    ca.close()
    with pytest.raises(ChannelClosed):
        cb.recv()
    with pytest.raises(ChannelClosed):
        ca.send({"x": 1})
    cb.close()


# --------------------------------------------------------------------------
# batched wire: BatchingChannel units + batch-frame codec round-trips
# --------------------------------------------------------------------------

class TestBatchingChannel:
    def test_threshold_flush_preserves_order(self):
        inner = LocalChannel()
        ch = BatchingChannel(inner, max_batch=3)
        for i in range(5):
            ch.send(i)
        assert inner.recv() == {"t": "batch", "msgs": [0, 1, 2]}
        assert inner.empty()            # 3, 4 still buffered
        ch.flush()
        assert inner.recv() == {"t": "batch", "msgs": [3, 4]}
        assert ch.batches_sent == 2 and ch.msgs_sent == 5

    def test_single_message_flush_goes_bare(self):
        inner = LocalChannel()
        ch = BatchingChannel(inner, max_batch=8)
        ch.send({"t": "done", "tid": "x"}, flush=True)
        assert inner.recv() == {"t": "done", "tid": "x"}   # no wrapper

    def test_max_batch_one_degenerates_to_inner_channel(self):
        inner = LocalChannel()
        ch = BatchingChannel(inner, max_batch=1)
        for i in range(4):
            ch.send(i)
            assert inner.recv() == i    # forwarded immediately, bare

    def test_send_side_only(self):
        with pytest.raises(ChannelClosed):
            BatchingChannel(LocalChannel(), max_batch=4).recv()

    def test_close_flushes_pending_then_closes_inner(self):
        inner = LocalChannel()
        ch = BatchingChannel(inner, max_batch=8)
        ch.send("u")
        ch.send("d")
        ch.close()
        assert inner.recv() == {"t": "batch", "msgs": ["u", "d"]}
        with pytest.raises(ChannelClosed):
            inner.recv()

    def test_updates_before_done_across_batch_boundaries(self):
        """The §8 ordering contract batched: an attempt's updates precede
        its done in the FLATTENED frame stream even when the boundary
        falls between them."""
        inner = LocalChannel()
        ch = BatchingChannel(inner, max_batch=2)
        sent = [{"t": "updates", "n": 0}, {"t": "updates", "n": 1},
                {"t": "done"}]
        for m in sent[:-1]:
            ch.send(m)
        ch.send(sent[-1], flush=True)
        flat = []
        while not inner.empty():
            f = inner.recv()
            flat.extend(f["msgs"] if isinstance(f, dict)
                        and f.get("t") == "batch" else [f])
        assert flat == sent


def test_batch_frame_round_trip(codec):
    frame = {"t": "batch", "msgs": [
        {"t": "updates", "eid": "w0", "added": ["a", "b"], "removed": ["c"]},
        {"t": "done", "eid": "w0", "tid": "t1", "ok": True,
         "ledger": {"bytes_local": 5, "bytes_cache_to_cache": 0,
                    "bytes_store": 7, "cache_hits": 1, "peer_hits": 0,
                    "cache_misses": 1}},
        {"t": "hb", "host": "h0"}]}
    assert rt(frame, codec) == frame


# --------------------------------------------------------------------------
# fake-host harness: the REAL _on_remote_batch receive path, no sockets
# --------------------------------------------------------------------------

class _FakeProc:
    """Process stand-in so HostManager monitor/reap accept the handle."""
    pid = 0
    exitcode = None

    def is_alive(self):
        return True

    def terminate(self):
        pass

    def join(self, timeout=None):
        pass


def _fake_fleet(n_hosts=2, tph=2, wire_batch=64, **rt_kw):
    """A hosts=0 FleetRuntime with fake in-process host handles: dispatch
    frames land in a LocalChannel per host, and the test feeds replies
    straight into the production `_on_remote_batch`."""
    rt_ = FleetRuntime(hosts=0, threads_per_host=tph, wire_batch=wire_batch,
                       heartbeat_timeout_s=60.0, **rt_kw)
    handles = []
    for h in range(n_hosts):
        handle = HostHandle(f"h{h}", _FakeProc(), LocalChannel(),
                            peer_host="127.0.0.1", peer_port=0)
        with rt_._lock:
            for _ in range(tph):
                eid = f"w{rt_._next_worker_id}"
                rt_._next_worker_id += 1
                rt_.workers[eid] = _RemoteExecutor(eid, handle, rt_)
                handle.eids.append(eid)
                rt_.dispatcher.executor_joined(eid, time.monotonic())
        rt_.manager.handles[handle.host_id] = handle
        handles.append(handle)
    return rt_, handles


def _drain_dispatched(handles):
    """Unwrap every frame queued on the fake hosts' dispatch channels,
    returning (handle, task_msg) pairs in wire order."""
    out = []
    for h in handles:
        while not h.chan.empty():
            m = h.chan.recv()
            inner = (m["msgs"] if isinstance(m, dict)
                     and m.get("t") == "batch" else [m])
            for msg in inner:
                if isinstance(msg, dict) and msg.get("t") == "task":
                    out.append((h, msg))
    return out


def _reply_msgs(msg, caches, peer_every=0, counter=[0]):
    """Scripted host reply for one task msg: LRU-churn one coalesced
    updates frame + the done frame.  Every ``peer_every``-th miss is
    served cache-to-cache (peer hit) instead of from the store, so the
    conservation identity store_reads == misses - peer_hits is exercised
    with a non-trivial peer term."""
    eid = msg["eid"]
    cache = caches.setdefault(eid, [])
    before = set(cache)
    led = {"bytes_local": 0, "bytes_cache_to_cache": 0, "bytes_store": 0,
           "cache_hits": 0, "peer_hits": 0, "cache_misses": 0}
    for oid, size in msg["inputs"]:
        if oid in cache:
            cache.remove(oid)
            cache.append(oid)
            led["cache_hits"] += 1
            led["bytes_local"] += size
            continue
        led["cache_misses"] += 1
        counter[0] += 1
        if peer_every and counter[0] % peer_every == 0:
            led["peer_hits"] += 1
            led["bytes_cache_to_cache"] += size
        else:
            led["bytes_store"] += size
        cache.append(oid)
        while len(cache) > 4:
            cache.pop(0)
    # one coalesced NET delta per attempt (an oid evicted then re-admitted
    # within the attempt must appear in neither list)
    added = [o for o in cache if o not in before]
    removed = sorted(before - set(cache))
    replies = []
    if added or removed:
        replies.append({"t": "updates", "eid": eid,
                        "added": added, "removed": removed})
    replies.append({"t": "done", "eid": eid, "tid": msg["tid"],
                    "ok": True, "ledger": led})
    return replies


def _ledger_conserves(rt_):
    lg, d = rt_.ledger, rt_.dispatcher
    sums = [0] * 6
    for t in d.completed:
        sums[0] += t.bytes_local
        sums[1] += t.bytes_cache_to_cache
        sums[2] += t.bytes_store
        sums[3] += t.cache_hits
        sums[4] += t.peer_hits
        sums[5] += t.cache_misses - t.peer_hits
    assert sums == [lg.bytes_local, lg.bytes_c2c, lg.bytes_store,
                    lg.local_hits, lg.peer_hits, lg.store_reads]


def test_randomized_interleavings_complete_and_conserve(codec):
    """Random frame chunkings of updates/done/hb streams -- round-tripped
    through the codec exactly like the real wire -- drive every task to
    completion exactly once with an exactly-conserved ledger, regardless
    of how batch boundaries fall."""
    rng = random.Random(0xD15BA7C4)
    for trial in range(3):
        rt_, handles = _fake_fleet(wire_batch=rng.choice([1, 4, 64]))
        try:
            n, n_oids = 60, 16
            with rt_._lock:
                for i in range(n_oids):
                    rt_.dispatcher.sizes[f"o{i}"] = 1000
            oids = [f"o{i}" for i in range(n_oids)]
            rt_.submit(Task(inputs=tuple(rng.sample(oids, 2)))
                       for _ in range(n))
            caches, outbox = {}, {h.host_id: [] for h in handles}
            counter = [0]
            spins = 0
            while len(rt_.dispatcher.completed) < n:
                spins += 1
                assert spins < 10_000, "drive loop wedged"
                for h, msg in _drain_dispatched(handles):
                    outbox[h.host_id].extend(
                        _reply_msgs(msg, caches, peer_every=5,
                                    counter=counter))
                    if rng.random() < 0.3:
                        outbox[h.host_id].append({"t": "hb",
                                                  "host": h.host_id})
                for h in handles:
                    buf = outbox[h.host_id]
                    while buf:
                        k = rng.randint(1, min(6, len(buf)))
                        chunk = [buf.pop(0) for _ in range(k)]
                        frame = (chunk[0] if len(chunk) == 1
                                 else {"t": "batch", "msgs": chunk})
                        frame = decode(encode(frame, codec), codec)
                        inner = (frame["msgs"]
                                 if frame.get("t") == "batch" else [frame])
                        rt_._on_remote_batch(h, inner)
            d = rt_.dispatcher
            assert len(d.completed) == n and not d.failed
            assert rt_.ledger.peer_hits > 0      # the peer term is live
            _ledger_conserves(rt_)
            # index coherence at drain: central locations == the caches
            # the scripted hosts actually hold (§8, batched)
            for eid, cache in caches.items():
                if eid in rt_.workers:
                    assert rt_.dispatcher.index.holdings(eid) == set(cache)
        finally:
            rt_.shutdown()


def test_dead_host_frames_cannot_resurrect_index_entries():
    """Late updates/done frames from a declared-dead host are dropped by
    the membership guard: no index resurrection, no double accounting,
    and the re-queued task still runs exactly once (elsewhere)."""
    rt_, (h0, h1) = _fake_fleet(n_hosts=2, tph=1)
    try:
        with rt_._lock:
            rt_.dispatcher.sizes["a"] = 10
        eid0, eid1 = h0.eids[0], h1.eids[0]
        rt_._on_remote_batch(h0, [{"t": "updates", "eid": eid0,
                                   "added": ["a"], "removed": []}])
        assert eid0 in rt_.dispatcher.index.lookup("a")
        # an update claiming ANOTHER host's executor is refused outright
        rt_._on_remote_batch(h0, [{"t": "updates", "eid": eid1,
                                   "added": ["a"], "removed": []}])
        assert eid1 not in rt_.dispatcher.index.lookup("a")

        # give h0's executor an in-flight task, then declare the host dead
        rt_.submit([Task(inputs=("a",))])
        inflight = [(h, m) for h, m in _drain_dispatched((h0, h1))
                    if m["eid"] == eid0]
        rt_._on_host_dead(h0)
        assert eid0 not in rt_.dispatcher.index.lookup("a")
        assert eid0 not in rt_.workers

        # late frames from the corpse: dropped, nothing resurrects
        rt_._on_remote_batch(h0, [{"t": "updates", "eid": eid0,
                                   "added": ["a"], "removed": []}])
        assert eid0 not in rt_.dispatcher.index.lookup("a")
        before = rt_.ledger.store_reads
        for h, m in inflight:
            for reply in _reply_msgs(m, {}):
                rt_._on_remote_batch(h0, [reply])
        assert rt_.ledger.store_reads == before
        assert not rt_.dispatcher.completed

        # the re-queued task drains once on the survivor
        caches = {}
        for h, m in _drain_dispatched((h1,)):
            rt_._on_remote_batch(h1, _reply_msgs(m, caches))
        assert [t.executor for t in rt_.dispatcher.completed] == [eid1]
        _ledger_conserves(rt_)
    finally:
        rt_.shutdown()


def test_unleased_claim_is_a_conflict():
    """A claim frame with no backing lease (or from a dead handle) falls
    back to central authority: counted as a conflict, never bound."""
    rt_, (h0,) = _fake_fleet(n_hosts=1, tph=1)
    try:
        eid = h0.eids[0]
        rt_._on_remote_batch(h0, [{"t": "claim", "eid": eid,
                                   "tid": "ghost"}])
        st = rt_.dispatch_stats()
        assert st["claim_conflicts"] == 1 and st["claims"] == 0
        h0.dead = True
        rt_._on_remote_batch(h0, [{"t": "claim", "eid": eid,
                                   "tid": "ghost"}])
        assert rt_.dispatch_stats()["claim_conflicts"] == 2
    finally:
        h0.dead = False
        rt_.shutdown()

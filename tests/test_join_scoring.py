"""Partial-overlap (join) scoring: the incremental executor->score maps and
the windowed MCU selection must bit-match a brute-force reference scorer on
randomized k-input queues with mid-queue evictions and executor churn.

Extends the tests/test_index_and_policies.py pattern without requiring
hypothesis (not in the image): seeded randomized walks over the Dispatcher
API, asserting ``scores_match_reference()`` -- incremental maps == a from-
scratch index rescan -- after *every* operation, plus an independent
re-implementation of the documented MCU selection rule (max cached bytes,
ties to higher overlap fraction, then earlier queue position) that each
``next_dispatches`` result is compared against.
"""
import random

import pytest

from repro.core import ANL_UC
from repro.core.index import IndexUpdate
from repro.core.objects import DataObject, Task, TaskState
from repro.core.policies import DispatchPolicy
from repro.core.scheduler import Dispatcher
from repro.core.simulator import DiffusionSim, SimConfig
from repro.workloads import (MetricsCollector, PoissonArrivals,
                             ZipfPopularity, generate)

MB = 10**6


# ---------------- reference dispatch (independent re-implementation) --------

def _predict_mcu(d: Dispatcher) -> list[tuple[str, str]]:
    """(tid, eid) pairs _dispatch_mcu must produce, derived ONLY from
    reference_scores() + the documented selection rule.  Assumes 1 slot per
    executor (what the walk uses)."""
    ref = d.reference_scores()
    live = [t.tid for t in d.queue]                     # ascending position
    free = [e for e in d._exec_order
            if d.executors[e].alive and d.executors[e].available]
    out: list[tuple[str, str]] = []
    while live and free:
        window = live[:d.queue_window]
        taken: set[str] = set()
        bound: list[str] = []
        for eid in free:
            best = None                                 # (tid, score, total, pos)
            for tid, score in ref.get(eid, {}).items():
                if tid in taken or tid not in window:
                    continue
                pos = window.index(tid)
                total = d.input_bytes_total(tid)
                if best is None or score > best[1] \
                        or (score == best[1]
                            and (total < best[2]
                                 or (total == best[2] and pos < best[3]))):
                    best = (tid, score, total, pos)
            if best is None:
                tid = next((w for w in window if w not in taken), None)
                if tid is None:
                    break
            else:
                tid = best[0]
            taken.add(tid)
            bound.append(eid)
            out.append((tid, eid))
        if not bound:
            break
        live = [t for t in live if t not in taken]
        free = [e for e in free if e not in bound]
    return out


# ---------------- randomized walk -------------------------------------------

def _walk(seed: int, steps: int = 350) -> None:
    rng = random.Random(seed)
    d = Dispatcher(DispatchPolicy.MAX_COMPUTE_UTIL)
    oids = [f"o{i}" for i in range(24)]
    objs = [DataObject(o, rng.choice((1, 4, 10)) * MB) for o in oids]
    d.register_objects(objs)
    next_eid, live_eids = 0, []

    def join(now: float) -> None:
        nonlocal next_eid
        eid = f"e{next_eid}"
        next_eid += 1
        d.executor_joined(eid, now)
        live_eids.append(eid)

    for _ in range(3):
        join(0.0)
    inflight: list[Task] = []
    now = 0.0
    for step in range(steps):
        now += 1.0
        # drop tasks churn/retry bookkeeping took back from us
        inflight = [t for t in inflight
                    if t.state in (TaskState.DISPATCHED, TaskState.RUNNING)]
        op = rng.random()
        if op < 0.28:                                   # k-input arrival
            k = rng.randint(1, 4)
            d.submit([Task(inputs=tuple(rng.sample(oids, k)))], now)
        elif op < 0.50 and live_eids:                   # cache insertions
            eid = rng.choice(live_eids)
            added = tuple(rng.sample(oids, rng.randint(1, 3)))
            d.apply_index_updates([IndexUpdate(eid, added=added)])
        elif op < 0.65 and live_eids:                   # mid-queue evictions
            eid = rng.choice(live_eids)
            held = sorted(d.index.holdings(eid))
            if held:
                removed = tuple(rng.sample(held, min(len(held), 2)))
                d.apply_index_updates([IndexUpdate(eid, removed=removed)])
        elif op < 0.78:                                 # dispatch round
            want = _predict_mcu(d)
            got = [(disp.task.tid, disp.executor)
                   for disp in d.next_dispatches(now)]
            assert got == want, f"seed {seed} step {step}: {got} != {want}"
            inflight.extend(d.tasks[tid] for tid, _ in got)
        elif op < 0.86 and inflight:                    # completion / failure
            t = inflight.pop(rng.randrange(len(inflight)))
            d.task_finished(t, now, ok=rng.random() < 0.9)
        elif op < 0.92 and len(live_eids) > 1:          # churn: executor dies
            eid = live_eids.pop(rng.randrange(len(live_eids)))
            d.executor_left(eid, now, failed=rng.random() < 0.5)
        elif op < 0.96 and live_eids:                   # cache wiped in place
            d.invalidate_executor(rng.choice(live_eids))
        else:                                           # churn: executor joins
            join(now)
        assert d.scores_match_reference(), \
            f"incremental/reference divergence at seed {seed} step {step}"


@pytest.mark.parametrize("seed", range(6))
def test_incremental_scores_bit_match_reference(seed):
    _walk(seed)


# ---------------- tie-break semantics ----------------------------------------

def _mkdisp(sizes: dict[str, int]) -> Dispatcher:
    d = Dispatcher(DispatchPolicy.MAX_COMPUTE_UTIL)
    d.executor_joined("e0", 0.0)
    d.register_objects([DataObject(o, sz) for o, sz in sizes.items()])
    return d


def test_partial_overlap_bytes_beat_smaller_full_hit():
    """2-of-3 inputs cached (20 MB) out-scores a full 1-of-1 hit (15 MB)."""
    d = _mkdisp({"a1": 10 * MB, "a2": 10 * MB, "a3": 10 * MB, "c1": 15 * MB})
    for oid in ("a1", "a2", "c1"):
        d.index.insert(oid, "e0")
    full = Task(inputs=("c1",))
    join = Task(inputs=("a1", "a2", "a3"))
    d.submit([full, join], 0.0)          # full is EARLIER in the queue
    out = d.next_dispatches(0.0)
    assert out[0].task is join           # 20 MB overlap > 15 MB full hit


def test_byte_tie_breaks_toward_higher_overlap_fraction():
    """Equal cached bytes: 1-of-1 (fraction 1.0) beats 2-of-3 (0.67)."""
    d = _mkdisp({"a1": 10 * MB, "a2": 10 * MB, "a3": 10 * MB, "b1": 20 * MB})
    for oid in ("a1", "a2", "b1"):
        d.index.insert(oid, "e0")
    join = Task(inputs=("a1", "a2", "a3"))   # 20 of 30 MB cached
    single = Task(inputs=("b1",))            # 20 of 20 MB cached
    d.submit([join, single], 0.0)            # join is EARLIER in the queue
    out = d.next_dispatches(0.0)
    assert out[0].task is single             # same bytes, less left to fetch


def test_fraction_tie_falls_back_to_queue_order():
    d = _mkdisp({"a": 10 * MB, "b": 10 * MB})
    d.index.insert("a", "e0")
    d.index.insert("b", "e0")
    first = Task(inputs=("a",))
    second = Task(inputs=("b",))
    d.submit([first, second], 0.0)
    assert d.next_dispatches(0.0)[0].task is first


# ---------------- end-to-end: joins through the engine ------------------------

def _join_run(policy: DispatchPolicy, seed: int = 3):
    wl = generate(
        "joins", PoissonArrivals(8.0),
        ZipfPopularity(alpha=1.1, k=3, corr=0.8),
        n_tasks=400, n_objects=60, object_bytes=5 * MB,
        compute_seconds=0.05, seed=seed)
    cfg = SimConfig(testbed=ANL_UC, n_nodes=8, policy=policy,
                    cache_capacity_bytes=10**12, seed=seed)
    sim = DiffusionSim(cfg)
    sim.submit_workload(wl)
    r = sim.run()
    assert sim.dispatcher.scores_match_reference()   # drained => both empty
    return MetricsCollector(ANL_UC).collect(r, n_submitted=sim.n_submitted)


def test_data_aware_beats_first_available_on_joins():
    mch = _join_run(DispatchPolicy.MAX_CACHE_HIT)
    fa = _join_run(DispatchPolicy.FIRST_AVAILABLE)
    assert mch.n_completed == fa.n_completed == 400
    assert mch.cache_hit_ratio > fa.cache_hit_ratio

"""PR 9: serving through the diffusion stack (DESIGN.md §12).

Covers the session workload generator (determinism, prefix-chain
monotonicity, trace round-trip), the sessions spec binding, the
router-vs-core regression lock, the serve engine's RunReport parity with
sim/runtime, and the sim<->serve divergence diff under serial replay.
No jax imports anywhere -- the serve *scheduling* half is pure Python.
"""
import dataclasses
import random

import pytest

from repro.core.policies import DispatchPolicy
from repro.experiments import (ExperimentSpec, ObserveSpec, WorkloadSpec,
                               build_workload, engine_names, make_engine,
                               run_experiment)
from repro.serve import PrefixAwareRouter, prefix_chain
from repro.serve.diffusion import (SERVE_MAPPING, ServeDiffusionEngine,
                                   check_serve_spec, kv_summary,
                                   session_spec, verify_route)
from repro.workloads import (SESSIONS, SessionModel, Workload, build_sessions,
                             chat_sessions, record, record_v3, replay)

FAST = {"kind": "chat", "n_sessions": 16, "turns_per_session": 3,
        "kv_bytes_per_token": 256, "block": 16,
        "think_time_s": 0.0, "turn_seconds": 0.0,
        "arrivals": {"kind": "BatchArrivals", "at_s": 0.0}}


# --------------------------------------------------------------------------
# session generator
# --------------------------------------------------------------------------

class TestSessionModel:
    def test_seeded_determinism(self):
        a = SessionModel(n_sessions=24, seed=5).generate()
        b = SessionModel(n_sessions=24, seed=5).generate()
        assert a.events == b.events
        assert a.objects == b.objects

    def test_seed_changes_workload(self):
        a = SessionModel(n_sessions=24, seed=5).generate()
        b = SessionModel(n_sessions=24, seed=6).generate()
        assert a.events != b.events

    def test_prefix_chain_monotone_across_turns(self):
        """Turn j+1's inputs must extend turn j's verbatim -- the KV pages
        of an earlier turn are a strict prefix of every later turn's."""
        wl = SessionModel(n_sessions=10, turns_per_session=4, seed=1).generate()
        turns: dict[int, dict[int, tuple]] = {}
        for e in wl.events:
            sid, j = e.tid.rsplit("-s", 1)[1].split(".t")
            turns.setdefault(int(sid), {})[int(j)] = e.inputs
        assert len(turns) == 10
        for per_session in turns.values():
            assert sorted(per_session) == [1, 2, 3, 4]
            for j in range(2, 5):
                prev, cur = per_session[j - 1], per_session[j]
                assert len(cur) > len(prev)
                assert cur[:len(prev)] == prev

    def test_turn_growth_is_turn_blocks(self):
        m = SessionModel(n_sessions=4, turns_per_session=3,
                         system_prompt_blocks=5, turn_blocks=2, seed=0)
        wl = m.generate()
        widths = sorted({len(e.inputs) for e in wl.events})
        assert widths == [7, 9, 11]    # 5 + j*2 for j in 1..3

    def test_system_prompt_sharing(self):
        """With one system prompt, every session's first pages collide --
        the hot shared prefix the Zipf skew models."""
        m = SessionModel(n_sessions=8, n_system_prompts=1,
                         system_prompt_blocks=3, seed=2)
        wl = m.generate()
        first_turn_heads = {e.inputs[:3] for e in wl.events
                            if e.tid.endswith(".t1")}
        assert len(first_turn_heads) == 1

    def test_pages_uniform_and_model_sizing(self):
        m = SessionModel(n_sessions=4, kv_bytes_per_token=128, block=32)
        wl = m.generate()
        assert {ob.size_bytes for ob in wl.objects} == {128 * 32}
        # a real ModelConfig drives sizing when model= is set
        m2 = SessionModel(n_sessions=2, model="whisper-base", block=32)
        from repro.configs import get_config
        from repro.serve import kv_bytes_per_token
        expect = max(kv_bytes_per_token(get_config("whisper-base")), 1) * 32
        assert {ob.size_bytes for ob in m2.generate().objects} == {expect}

    def test_trace_round_trip(self, tmp_path):
        wl = SessionModel(n_sessions=12, seed=3).generate()
        p = tmp_path / "sess.jsonl"
        record(wl, p)
        back = replay(p)
        assert back.events == wl.events
        assert sorted(ob.oid for ob in back.objects) == \
            sorted(ob.oid for ob in wl.objects)

    def test_registry_and_binding_round_trip(self):
        assert "chat" in SESSIONS
        m = SessionModel(n_sessions=6, zipf_s=1.5, seed=9)
        again = build_sessions(m.spec())
        assert again.events == m.generate().events

    def test_bad_bindings(self):
        with pytest.raises(ValueError, match="unknown sessions kind"):
            build_sessions({"kind": "nope"})
        with pytest.raises(ValueError, match="n_sessions"):
            SessionModel(n_sessions=0)
        with pytest.raises(ValueError, match="arrivals"):
            SessionModel(arrivals={"kind": "NotAProcess"})


# --------------------------------------------------------------------------
# spec binding
# --------------------------------------------------------------------------

class TestSessionsBinding:
    def test_build_workload_routes_sessions(self):
        ws = WorkloadSpec(name="s", sessions=dict(FAST))
        wl = build_workload(ws)
        assert isinstance(wl, Workload)
        assert len(wl) == FAST["n_sessions"] * FAST["turns_per_session"]
        assert wl.name == "s"              # spec name override wins

    def test_exactly_one_binding(self):
        with pytest.raises(ValueError, match="EXACTLY ONE"):
            WorkloadSpec(sessions=dict(FAST),
                         dag={"kind": "all_pairs", "n_objects": 2})
        with pytest.raises(ValueError, match="EXACTLY ONE"):
            WorkloadSpec(sessions=dict(FAST), trace_path="x.jsonl")

    def test_dead_knobs_hard_error(self):
        with pytest.raises(ValueError, match="sessions-bound"):
            WorkloadSpec(sessions=dict(FAST), n_tasks=100)
        with pytest.raises(ValueError, match="sessions-bound"):
            WorkloadSpec(sessions=dict(FAST), seed=7)

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown sessions kind"):
            WorkloadSpec(sessions={"kind": "mystery"})

    def test_spec_json_round_trip(self):
        spec = session_spec("rt", FAST, n_replicas=3, seed=4)
        again = ExperimentSpec.from_dict(spec.to_dict())
        assert again == spec
        assert again.workload.sessions == dict(FAST)


# --------------------------------------------------------------------------
# router vs core regression lock
# --------------------------------------------------------------------------

def _drive_verified(policy, n_prompts=48, seed=0):
    rng = random.Random(seed)
    r = PrefixAwareRouter(4, policy=policy, kv_bytes_per_token=64,
                          block=16, slots_per_replica=2)
    bases = [[rng.randrange(999) for _ in range(64)] for _ in range(4)]
    inflight, results = [], []
    for _ in range(n_prompts):
        p = bases[rng.randrange(4)] + [rng.randrange(999)
                                       for _ in range(16 * rng.randrange(3))]
        results.append(verify_route(r, p))
        inflight.append((p, results[-1]["route_result"]))
        if len(inflight) > 4:
            pp, rr = inflight.pop(0)
            r.complete(pp, rr)
    return results


class TestRouterRegressionLock:
    @pytest.mark.parametrize("policy", [
        DispatchPolicy.MAX_COMPUTE_UTIL, DispatchPolicy.MAX_CACHE_HIT,
        DispatchPolicy.FIRST_AVAILABLE, DispatchPolicy.FIRST_CACHE_AVAILABLE])
    def test_placement_and_scores_match_dispatcher(self, policy):
        for v in _drive_verified(policy):
            assert v["scores_agree"], \
                f"router scores drifted from reference_scores: {v}"
            assert v["placement_agrees"], \
                f"router placement drifted from decide(): {v}"
            assert v["prediction"]["incremental_consistent"]

    def test_page_sizing_not_cumulative(self):
        """Every chain oid is ONE page: scoring an m-page chain must give
        m * page_bytes, not the old O(m^2) cumulative inflation."""
        r = PrefixAwareRouter(2, kv_bytes_per_token=64, block=16)
        prompt = list(range(64))          # 4 pages
        res = r.route(prompt)
        r.complete(prompt, res)
        scores = r.reference_scores(prompt)
        assert scores[res.replica] == 4 * r.page_bytes
        assert all(r.sizes[oid] == r.page_bytes
                   for oid in prefix_chain(prompt, r.block))

    def test_saturated_fallback_is_least_busy(self):
        r = PrefixAwareRouter(3, policy=DispatchPolicy.FIRST_AVAILABLE,
                              slots_per_replica=1)
        routed = [r.route([i] * 16) for i in range(3)]   # saturate all
        assert {x.replica for x in routed} == {"r0", "r1", "r2"}
        # all busy: overload must spread, not pile onto r0
        overflow = [r.route([9, i] * 8).replica for i in range(3)]
        assert overflow == ["r0", "r1", "r2"]

    def test_reused_tokens_counted_on_chosen_replica(self):
        r = PrefixAwareRouter(2, kv_bytes_per_token=64, block=16)
        prompt = list(range(48))
        first = r.route(prompt)
        r.complete(prompt, first)
        again = r.route(prompt + list(range(100, 116)))
        assert again.replica == first.replica
        assert again.reused_prefix_tokens == 48
        assert again.reused_bytes == 48 * 64


# --------------------------------------------------------------------------
# serve engine: report parity + lifecycle + rejects
# --------------------------------------------------------------------------

class TestServeEngine:
    def test_registered_lazily(self):
        assert "serve" in engine_names()
        assert isinstance(make_engine("serve"), ServeDiffusionEngine)

    def test_end_to_end_report(self):
        spec = session_spec("e2e", FAST, n_replicas=4, seed=1)
        rep = run_experiment(spec, engine="serve")
        assert rep.engine == "serve"
        assert rep.n_completed == len(build_workload(spec.workload))
        assert rep.n_failed == 0
        s = kv_summary(rep)
        # later turns + shared system prompts MUST reuse KV
        assert s["reused_kv_bytes"] > 0
        assert 0.0 < s["reused_token_fraction"] < 1.0
        assert s["n_requests"] == rep.n_completed

    def test_schema_parity_with_sim_and_runtime(self):
        spec = session_spec("parity", FAST, n_replicas=4, seed=1)
        serve = run_experiment(spec, engine="serve")
        sim = run_experiment(spec, engine="sim")
        assert serve.schema() == sim.schema()
        d = serve.diff(sim)
        # diff() runs field-by-field over the shared schema, masking
        # identity fields (engine, wall clock) by design -- what's left
        # are comparable metric values of matching types
        assert "engine" not in d and "wall_s" not in d
        for field_name, (a, b) in d.items():
            assert type(a) is type(b), field_name
        # same submission count on both engines, field read via diff's
        # shared schema rather than ad hoc attributes
        assert serve.n_tasks == sim.n_tasks

    def test_serve_rejects_hosts(self):
        spec = dataclasses.replace(session_spec("rej", FAST),
                                   hosts=2, threads_per_host=2)
        with pytest.raises(ValueError, match="serve engine does not support"):
            make_engine("serve").prepare(spec)

    def test_serve_rejects_dag(self):
        spec = ExperimentSpec(
            name="rej-dag",
            workload=WorkloadSpec(dag={"kind": "all_pairs", "n_objects": 2}))
        with pytest.raises(ValueError, match="not serve-legal"):
            check_serve_spec(spec)

    def test_inherits_runtime_rejects(self):
        spec = dataclasses.replace(session_spec("rej2", FAST),
                                   flow_solver="naive")
        with pytest.raises(ValueError, match="does not support"):
            make_engine("serve").prepare(spec)

    def test_mapping_table_shape(self):
        assert len(SERVE_MAPPING) >= 6
        for row in SERVE_MAPPING:
            assert len(row) == 3 and all(isinstance(c, str) for c in row)
        concepts = [r[0] for r in SERVE_MAPPING]
        assert "model replica" in concepts


# --------------------------------------------------------------------------
# sim twin: obs lifecycle + divergence diff on the serve path
# --------------------------------------------------------------------------

def _serial_sessions(n_sessions=10, turns=2):
    """Session workload re-spaced to 1 task/s (>> service time), the serial
    regime where sim<->serve replay is exact (DESIGN.md §12)."""
    binding = {"kind": "chat", "n_sessions": n_sessions,
               "turns_per_session": turns, "kv_bytes_per_token": 256,
               "block": 16, "turn_seconds": 0.001,
               "arrivals": {"kind": "PoissonArrivals", "rate_per_s": 2.0}}
    wl = build_sessions(binding, name="twin")
    events = [dataclasses.replace(e, t=float(i))
              for i, e in enumerate(wl.events)]
    return binding, Workload("twin", wl.objects, events, spec=None)


class TestSimServeTwin:
    def test_serve_emits_lifecycle_events(self):
        spec = session_spec("obs", FAST, n_replicas=4,
                            observe=ObserveSpec(events=True))
        eng = make_engine("serve")
        try:
            eng.prepare(spec)
            rep = eng.run(barrier_every=1, timeout=120)
            kinds = {e["kind"] for e in eng.recorder.events()}
        finally:
            eng.shutdown()
        assert rep.n_completed > 0
        assert {"task_arrived", "task_dispatched", "task_done",
                "exec_start", "exec_end"} <= kinds

    def test_serial_replay_divergence(self, tmp_path):
        from repro.obs import sim_twin_spec
        from repro.obs.diff import diff_outcomes, sim_replay_outcomes

        binding, serial = _serial_sessions()
        spec = session_spec("twin", binding, n_replicas=4, seed=2,
                            observe=ObserveSpec(events=True))
        eng = make_engine("serve")
        try:
            eng.prepare(spec, workload=serial)
            rep = eng.run(barrier_every=1, timeout=240)
            outcomes = eng.last_outcomes
        finally:
            eng.shutdown()
        assert rep.n_completed == len(serial)
        p = tmp_path / "twin.jsonl"
        record_v3(serial, p, outcomes)
        predicted = sim_replay_outcomes(sim_twin_spec(spec, str(p)), str(p))
        div = diff_outcomes(outcomes, predicted)
        assert div["placement_agreement"] >= 0.99

    def test_sim_engine_runs_sessions_binding(self):
        """The sim binding: the SAME sessions spec at a scale the threaded
        pool can't touch (the >=1e5-session scale point is gated in
        benchmarks/bench_serve.py)."""
        spec = session_spec("simside", FAST)
        rep = run_experiment(spec, engine="sim")
        assert rep.engine == "sim"
        assert rep.n_completed == len(build_workload(spec.workload))
        assert kv_summary(rep)["reused_kv_bytes"] > 0

"""repro.obs: recorder semantics, lifecycle-event parity across all three
engines, Chrome-trace export, and the sim<->real divergence diff
(DESIGN.md §10).

The headline fact verified here: under serial replay (arrivals spaced far
apart relative to service time, ``barrier_every=1`` so every dispatch
decision is made against an all-idle pool) the simulator, the in-process
runtime, and a multi-process fleet emit IDENTICAL per-task lifecycle
fingerprints -- same kind sequences, same placement, same per-input
source/byte triples -- and the divergence diff reports 100% placement
agreement.
"""
from __future__ import annotations

import json
import random

import pytest

from repro.core import DataObject
from repro.experiments import (ClusterSpec, ExperimentSpec, ObserveSpec,
                               RuntimeEngine, SimEngine, WorkloadSpec)
from repro.obs import (EVENT_SCHEMA_VERSION, Recorder, chrome_trace,
                       diff_outcomes, exec_index, format_divergence,
                       lifecycle_fingerprints, load_events, outcome_record,
                       sim_replay_outcomes, sim_twin_spec)
from repro.workloads import TaskEvent, Workload, record_v3

# --------------------------------------------------------------------------
# recorder units
# --------------------------------------------------------------------------

class TestRecorder:
    def test_emit_and_snapshot(self):
        rec = Recorder(capacity=8, clock=lambda: 1.5)
        rec.emit("task_arrived", tid="t0")
        rec.emit("pool", t=9.0, eid="w0", size=1, delta=1)
        evs = rec.events()
        assert evs == [
            {"t": 1.5, "kind": "task_arrived", "tid": "t0"},
            {"t": 9.0, "kind": "pool", "eid": "w0", "size": 1, "delta": 1},
        ]
        assert rec.emitted == 2 and rec.dropped == 0 and len(rec) == 2

    def test_ring_drops_oldest_and_counts(self):
        rec = Recorder(capacity=3, clock=lambda: 0.0)
        for i in range(10):
            rec.emit("pump", n=i)
        assert [e["n"] for e in rec.events()] == [7, 8, 9]   # newest kept
        assert rec.emitted == 10 and rec.dropped == 7

    def test_capacity_validated(self):
        with pytest.raises(ValueError, match=">= 1"):
            Recorder(capacity=0)

    def test_drain_empties_ingest_refills(self):
        rec = Recorder(capacity=4, clock=lambda: 0.0)
        rec.emit("pump", n=1)
        evs = rec.drain()
        assert len(evs) == 1 and len(rec) == 0
        rec.ingest(evs)                      # fleet-forwarding path
        assert rec.events() == evs and rec.emitted == 2

    def test_dump_load_roundtrip(self, tmp_path):
        rec = Recorder(capacity=4, clock=lambda: 0.0)
        for i in range(6):                   # 2 dropped
            rec.emit("pump", n=i)
        path = tmp_path / "events.jsonl"
        assert rec.dump(path) == 4
        header, evs = load_events(path)
        assert header["schema_version"] == EVENT_SCHEMA_VERSION
        assert header["n_events"] == 4
        assert header["emitted"] == 6 and header["dropped"] == 2
        assert evs == rec.events()

    def test_load_rejects_truncation_and_foreign_files(self, tmp_path):
        rec = Recorder(clock=lambda: 0.0)
        rec.emit("pump", n=0)
        path = tmp_path / "e.jsonl"
        rec.dump(path)
        lines = path.read_text().splitlines()
        path.write_text(lines[0] + "\n")     # header promises 1, has 0
        with pytest.raises(ValueError, match="truncated"):
            load_events(path)
        path.write_text(json.dumps({"kind": "header", "version": 2}) + "\n")
        with pytest.raises(ValueError, match="not an events sink"):
            load_events(path)


def test_exec_index_normalizes_engine_naming():
    assert exec_index("e3") == exec_index("w3") == 3
    assert exec_index("host-2.w11") == 11
    assert exec_index(None) is None
    assert exec_index("oddball") == "oddball"


# --------------------------------------------------------------------------
# ObserveSpec plumbing
# --------------------------------------------------------------------------

class TestObserveSpec:
    def test_defaults_off_and_roundtrip(self):
        spec = ExperimentSpec(name="o", workload=_wspec())
        assert spec.observe == ObserveSpec()
        assert not spec.observe.events
        spec2 = ExperimentSpec(name="o2", workload=_wspec(),
                               observe=ObserveSpec(events=True,
                                                   ring_capacity=128))
        back = ExperimentSpec.from_dict(spec2.to_dict())
        assert back == spec2
        assert back.observe.ring_capacity == 128

    def test_validation(self):
        with pytest.raises(ValueError, match="ring_capacity"):
            ObserveSpec(ring_capacity=0)
        with pytest.raises(ValueError, match="sink_path requires"):
            ObserveSpec(sink_path="/tmp/x.jsonl")   # events off

    def test_unknown_observe_field_hard_errors(self):
        d = ExperimentSpec(name="o", workload=_wspec()).to_dict()
        d["observe"] = {"events": True, "ringcap": 9}
        with pytest.raises(ValueError, match="ringcap"):
            ExperimentSpec.from_dict(d)


# --------------------------------------------------------------------------
# cross-engine lifecycle parity (the tentpole contract)
# --------------------------------------------------------------------------

def _wspec(n_tasks=40):
    return WorkloadSpec(
        name="par",
        arrivals={"kind": "BatchArrivals", "at_s": 0.0},
        popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 2,
                    "corr": 1.0},
        n_tasks=n_tasks, n_objects=12, object_bytes=10_000, seed=7)


def _serial_workload(n_tasks=40):
    """Arrivals spaced 1 s apart vs ~50 ms service time: every dispatch
    decision on every engine is made against an all-idle pool, which is
    the regime where sim and real placement coincide exactly."""
    rng = random.Random(7)
    objs = [DataObject(f"p.o{i}", 10_000) for i in range(12)]
    events = [TaskEvent(t=float(i), tid=f"p-{i}",
                        inputs=tuple(o.oid for o in rng.sample(objs, 2)),
                        outputs=(), compute_seconds=0.0,
                        store_metadata_ops=0)
              for i in range(n_tasks)]
    return Workload("par", objs, events, spec=None)


def _spec(hosts, tph, *, sink=None):
    return ExperimentSpec(
        name="obs-parity",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=4),
        policy="max-compute-util",
        workload=_wspec(),
        observe=ObserveSpec(events=True, sink_path=sink),
        seed=3, hosts=hosts, threads_per_host=tph)


EXPECTED_KINDS = ("task_arrived", "task_queued", "task_dispatched",
                  "exec_start", "exec_end", "task_done")


@pytest.fixture(scope="module")
def engine_runs():
    """One observed serial replay per engine (sim / in-process runtime /
    2-host fleet); shared across the parity assertions below."""
    wl = _serial_workload()
    runs = {}
    eng = SimEngine()
    try:
        eng.prepare(_spec(0, 1), workload=wl)
        rep = eng.run()
        runs["sim"] = (rep, eng.recorder.events(), eng.last_outcomes)
    finally:
        eng.shutdown()
    for label, hosts, tph in (("runtime", 0, 1), ("fleet", 2, 2)):
        eng = RuntimeEngine()
        try:
            eng.prepare(_spec(hosts, tph), workload=wl)
            rep = eng.run(barrier_every=1, timeout=180.0)
            runs[label] = (rep, eng.recorder.events(), eng.last_outcomes)
        finally:
            eng.shutdown()
    return runs


class TestLifecycleParity:
    def test_all_engines_complete(self, engine_runs):
        for label, (rep, _, outcomes) in engine_runs.items():
            assert rep.n_completed == 40, label
            assert len(outcomes) == 40, label

    def test_per_task_event_order(self, engine_runs):
        """Every completed task's lifecycle reads arrived -> queued ->
        dispatched -> inputs -> exec_start -> exec_end -> done (leases
        never engage under serial replay)."""
        for label, (_, events, _) in engine_runs.items():
            fps = lifecycle_fingerprints(events)
            assert len(fps) == 40, label
            for tid, (kinds, exec_idx, inputs) in fps.items():
                assert kinds == EXPECTED_KINDS, (label, tid, kinds)
                assert exec_idx is not None, (label, tid)
                assert len(inputs) == 2, (label, tid)

    def test_fingerprints_identical_across_engines(self, engine_runs):
        """The tentpole: same kinds, same placement, same per-input
        source/byte triples on sim, runtime, and a real 4-executor fleet."""
        fp_sim = lifecycle_fingerprints(engine_runs["sim"][1])
        fp_rt = lifecycle_fingerprints(engine_runs["runtime"][1])
        fp_fl = lifecycle_fingerprints(engine_runs["fleet"][1])
        assert fp_sim == fp_rt
        assert fp_sim == fp_fl

    def test_divergence_diff_reports_full_agreement(self, engine_runs):
        """Measured fleet outcomes joined against the sim twin's replay:
        placement agreement must be 100% in the serial regime."""
        predicted = engine_runs["sim"][2]
        for label in ("runtime", "fleet"):
            div = diff_outcomes(engine_runs[label][2], predicted)
            assert div["n_matched"] == 40
            assert div["n_only_measured"] == div["n_only_predicted"] == 0
            assert div["placement_agreement"] == 1.0, label
            assert div["bytes_agreement"] == 1.0, label
        text = format_divergence(div)
        assert "placement agreement  100.0%" in text

    def test_no_drops_at_default_capacity(self, engine_runs):
        for label, (rep, events, _) in engine_runs.items():
            assert events, label

    def test_trace_v3_diff_loop_end_to_end(self, engine_runs, tmp_path):
        """record_v3(fleet outcomes) -> sim_replay_outcomes(twin spec) ->
        diff: the full CLI loop, in-process."""
        wl = _serial_workload()
        measured = engine_runs["fleet"][2]
        trace = tmp_path / "fleet.jsonl"
        record_v3(wl, trace, measured)
        spec = _spec(2, 2)
        predicted = sim_replay_outcomes(spec, trace_path=str(trace))
        div = diff_outcomes(measured, predicted)
        assert div["placement_agreement"] == 1.0
        assert div["latency_error_s"]["queue_s"]["n"] == 40

    def test_sim_twin_spec_strips_fleet_and_observe(self):
        spec = _spec(2, 2)
        twin = sim_twin_spec(spec)
        assert twin.hosts == 0 and twin.threads_per_host == 1
        assert not twin.observe.events
        assert twin.cluster == spec.cluster and twin.seed == spec.seed


# --------------------------------------------------------------------------
# events-off runs are untouched; sinks write
# --------------------------------------------------------------------------

def test_events_off_runs_identically_and_without_recorder():
    wl = _serial_workload(n_tasks=10)
    off = _spec(0, 1)
    off = ExperimentSpec.from_dict({**off.to_dict(),
                                    "observe": {"events": False}})
    eng = SimEngine()
    try:
        eng.prepare(off, workload=wl)
        rep_off = eng.run()
        assert eng.recorder is None          # no ring allocated at all
    finally:
        eng.shutdown()
    eng = SimEngine()
    try:
        eng.prepare(_spec(0, 1), workload=wl)
        rep_on = eng.run()
    finally:
        eng.shutdown()
    assert rep_off.diff(rep_on) == {}        # recording changed no metric


def test_sink_path_writes_jsonl(tmp_path):
    sink = tmp_path / "sink.jsonl"
    eng = SimEngine()
    try:
        eng.prepare(_spec(0, 1, sink=str(sink)),
                    workload=_serial_workload(n_tasks=5))
        eng.run()
    finally:
        eng.shutdown()
    header, events = load_events(sink)
    assert header["dropped"] == 0
    assert len(lifecycle_fingerprints(events)) == 5


# --------------------------------------------------------------------------
# Chrome-trace export golden
# --------------------------------------------------------------------------

GOLDEN_EVENTS = [
    {"t": 10.0, "kind": "pool", "eid": "w0", "size": 1, "delta": 1},
    {"t": 10.0, "kind": "pool", "eid": "w1", "size": 2, "delta": 1},
    {"t": 10.5, "kind": "task_arrived", "tid": "a"},
    {"t": 10.5, "kind": "pump", "bound": 1, "queue": 0},
    {"t": 10.5, "kind": "input", "tid": "a", "eid": "w1", "oid": "o1",
     "source": "store", "bytes": 100},
    {"t": 10.6, "kind": "exec_start", "tid": "a", "eid": "w1"},
    {"t": 10.8, "kind": "exec_end", "tid": "a", "eid": "w1", "ok": True},
    {"t": 11.0, "kind": "input", "tid": "b", "eid": "w0", "oid": "o1",
     "source": "peer", "peer": "w1", "bytes": 100},
    {"t": 11.1, "kind": "exec_start", "tid": "b", "eid": "w0"},
    {"t": 11.2, "kind": "exec_end", "tid": "b", "eid": "w0", "ok": True},
]

GOLDEN_TRACE = [
    {"ph": "M", "pid": 0, "tid": 1, "name": "thread_name",
     "args": {"name": "w0"}},
    {"ph": "M", "pid": 0, "tid": 2, "name": "thread_name",
     "args": {"name": "w1"}},
    {"ph": "M", "pid": 0, "tid": 3, "name": "thread_name",
     "args": {"name": "dep_wait"}},
    {"ph": "M", "pid": 0, "tid": 4, "name": "thread_name",
     "args": {"name": "queue_wait"}},
    {"ph": "C", "pid": 0, "tid": 0, "name": "pool_size", "ts": 0.0,
     "args": {"executors": 1}},
    {"ph": "C", "pid": 0, "tid": 0, "name": "pool_size", "ts": 0.0,
     "args": {"executors": 2}},
    {"ph": "C", "pid": 0, "tid": 0, "name": "queue_depth", "ts": 500000.0,
     "args": {"tasks": 0}},
    {"ph": "C", "pid": 0, "tid": 0, "name": "cache_bytes", "ts": 500000.0,
     "args": {"bytes": 100}},
    {"ph": "X", "pid": 0, "tid": 2, "name": "a", "cat": "task",
     "ts": 600000.0, "dur": 200000.0, "args": {"executor": "w1"}},
    {"ph": "C", "pid": 0, "tid": 0, "name": "cache_bytes", "ts": 1000000.0,
     "args": {"bytes": 200}},
    {"ph": "X", "pid": 0, "tid": 1, "name": "b", "cat": "task",
     "ts": 1100000.0, "dur": 100000.0, "args": {"executor": "w0"}},
]


GOLDEN_SAMPLES = [
    {"kind": "metrics", "t": 10.4,
     "metrics": {"counters": {}, "histograms": {},
                 "gauges": {"sched.queue_depth": 3, "pool.size": 2,
                            "cache.bytes": 50}}},
    {"kind": "metrics", "t": 11.4,
     "metrics": {"counters": {}, "histograms": {},
                 "gauges": {"sched.queue_depth": 0, "pool.size": 2}},
     "hosts": {"h1": {"metrics": {"gauges": {"cache.bytes": 90}}},
               "h0": {"metrics": {"gauges": {"cache.bytes": 110}}}}},
]

# sampled counter tracks (DESIGN.md §13): single-process samples carry one
# sampled_cache_bytes track; per-host samples fan out per host id.  The
# first event (t=10.0) predates the first sample (t=10.4), so events set
# the shared timebase and sample timestamps land at +0.4 s / +1.4 s.
GOLDEN_SAMPLED_TRACKS = [
    {"ph": "C", "pid": 0, "tid": 0, "name": "sampled_queue_depth",
     "ts": 400000.0, "args": {"tasks": 3}},
    {"ph": "C", "pid": 0, "tid": 0, "name": "sampled_pool_size",
     "ts": 400000.0, "args": {"executors": 2}},
    {"ph": "C", "pid": 0, "tid": 0, "name": "sampled_cache_bytes",
     "ts": 400000.0, "args": {"bytes": 50}},
    {"ph": "C", "pid": 0, "tid": 0, "name": "sampled_queue_depth",
     "ts": 1400000.0, "args": {"tasks": 0}},
    {"ph": "C", "pid": 0, "tid": 0, "name": "sampled_pool_size",
     "ts": 1400000.0, "args": {"executors": 2}},
    {"ph": "C", "pid": 0, "tid": 0, "name": "sampled_cache_bytes:h0",
     "ts": 1400000.0, "args": {"bytes": 110}},
    {"ph": "C", "pid": 0, "tid": 0, "name": "sampled_cache_bytes:h1",
     "ts": 1400000.0, "args": {"bytes": 90}},
]


def test_chrome_trace_golden(tmp_path):
    """Pinned end-to-end export: thread-name metadata per executor, X spans
    pairing exec_start/exec_end, counter tracks, microsecond timestamps
    rebased to the first event."""
    path = tmp_path / "trace.json"
    out = chrome_trace(GOLDEN_EVENTS, path)
    assert out["displayTimeUnit"] == "ms"
    assert out["traceEvents"] == GOLDEN_TRACE
    assert json.loads(path.read_text()) == out   # file round-trips


def test_chrome_trace_golden_with_samples():
    """Pinned sampled-track export: passing telemetry samples adds the
    sampled_* counter tracks on the SAME rebased timebase as the events,
    without disturbing the event-derived tracks."""
    out = chrome_trace(GOLDEN_EVENTS, samples=GOLDEN_SAMPLES)
    sampled = [e for e in out["traceEvents"]
               if e["name"].startswith("sampled_")]
    assert sampled == GOLDEN_SAMPLED_TRACKS
    rest = [e for e in out["traceEvents"]
            if not e["name"].startswith("sampled_")]
    assert rest == GOLDEN_TRACE   # event tracks byte-identical


def test_chrome_trace_sample_only_timebase():
    """A sample stream with no events still produces a valid trace, rebased
    to the first sample."""
    out = chrome_trace([], samples=GOLDEN_SAMPLES)
    # [0]/[1] are the dep_wait/queue_wait thread-name metadata rows
    assert out["traceEvents"][4] == {
        "ph": "C", "pid": 0, "tid": 0, "name": "sampled_cache_bytes",
        "ts": 0.0, "args": {"bytes": 50}}


def test_chrome_trace_from_real_run_is_valid(tmp_path):
    eng = SimEngine()
    try:
        eng.prepare(_spec(0, 1), workload=_serial_workload(n_tasks=10))
        rep = eng.run()
        events = eng.recorder.events()
    finally:
        eng.shutdown()
    out = chrome_trace(events)
    all_spans = [e for e in out["traceEvents"] if e["ph"] == "X"]
    spans = [e for e in all_spans if e["cat"] == "task"]
    assert len(spans) == rep.n_completed == 10
    # dep-free run: every task also gets a queue-wait span, never a dep-wait
    assert len([e for e in all_spans if e["cat"] == "queue_wait"]) == 10
    assert not [e for e in all_spans if e["cat"] == "dep_wait"]
    names = {e["args"]["name"] for e in out["traceEvents"]
             if e["ph"] == "M"}
    assert names == ({s["args"]["executor"] for s in spans}
                     | {"dep_wait", "queue_wait"})
    assert all(s["dur"] >= 0 and s["ts"] >= 0 for s in all_spans)


# --------------------------------------------------------------------------
# outcome records
# --------------------------------------------------------------------------

def test_outcome_record_rebasing():
    class T:
        tid, executor, attempts = "t", "w0", 0
        submit_time, dispatch_time, start_time, end_time = (
            100.0, 101.0, 101.5, 103.5)
        bytes_local = bytes_cache_to_cache = bytes_store = 0
        cache_hits = peer_hits = cache_misses = 0

    rec = outcome_record(T(), base=100.0)
    assert rec["t_submit"] == 0.0 and rec["t_end"] == 3.5
    assert rec["queue_s"] == 1.0
    assert rec["exec_s"] == 2.0
    assert rec["turnaround_s"] == 3.5
    # latency fields are base-independent
    rec2 = outcome_record(T(), base=0.0)
    assert all(rec[k] == rec2[k]
               for k in ("queue_s", "exec_s", "turnaround_s"))

"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan import ops as ms_ops
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.stacking import ops as st_ops
from repro.kernels.stacking.ref import stack_rois_ref

KEY = jax.random.PRNGKey(42)


# --------------------------- flash attention ---------------------------------

FA_CASES = [
    # (B, S, H, KV, D, causal, window, softcap, dtype)
    (2, 64, 4, 2, 16, True, 0, 0.0, jnp.float32),
    (1, 128, 8, 2, 32, True, 32, 0.0, jnp.float32),     # SWA
    (2, 64, 4, 4, 24, True, 0, 50.0, jnp.float32),      # softcap, odd Dh
    (1, 256, 4, 1, 16, True, 0, 0.0, jnp.float32),      # MQA
    (2, 96, 4, 2, 16, True, 0, 0.0, jnp.float32),       # ragged seq (pad)
    (1, 64, 4, 2, 16, False, 0, 0.0, jnp.float32),      # bidirectional
    (2, 64, 4, 2, 16, True, 16, 30.0, jnp.float32),     # SWA + softcap
    (2, 64, 8, 8, 16, True, 0, 0.0, jnp.bfloat16),      # MHA bf16
]


@pytest.mark.parametrize("B,S,H,KV,D,causal,window,softcap,dtype", FA_CASES)
def test_flash_attention_matches_ref(B, S, H, KV, D, causal, window, softcap, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32).astype(dtype)
    if not causal and S % 32:
        pytest.skip("non-causal ragged falls back to ref (documented)")
    out = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 softcap=softcap, block_q=32, block_k=32)
    ref = jnp.swapaxes(attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=causal, window=window, softcap=softcap), 1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_ref_vjp_gradients():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    from repro.kernels.flash_attention.ops import flash_attention_with_ref_vjp

    def f_kernel(q, k, v):
        return flash_attention_with_ref_vjp(q, k, v, causal=True).sum()

    def f_ref(q, k, v):
        return jnp.swapaxes(attention_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), causal=True), 1, 2).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


# --------------------------- mamba scan -------------------------------------

MS_CASES = [
    (1, 32, 16, 4, 16, 16),
    (2, 96, 48, 8, 16, 32),    # I % block, S % chunk nontrivial
    (2, 128, 64, 16, 32, 64),
    (1, 50, 24, 4, 16, 32),    # ragged S (padding path)
]


@pytest.mark.parametrize("B,S,I,N,bi,ck", MS_CASES)
def test_mamba_scan_matches_ref(B, S, I, N, bi, ck):
    ks = jax.random.split(KEY, 6)
    u = jax.random.normal(ks[0], (B, S, I))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, I)))
    A = -jnp.exp(jax.random.normal(ks[2], (I, N)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    D = jax.random.normal(ks[5], (I,))
    h0 = jnp.full((B, I, N), 0.05)
    y, hl = ms_ops.mamba_scan(u, dt, A, Bm, Cm, D, h0=h0, block_i=bi, chunk=ck)
    yr, hlr = mamba_scan_ref(u, dt, A, Bm, Cm, D, h0=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr), atol=2e-4, rtol=1e-3)


def test_mamba_scan_state_chaining():
    """Running two halves with carried state == running the whole."""
    B, S, I, N = 1, 64, 16, 8
    ks = jax.random.split(KEY, 6)
    u = jax.random.normal(ks[0], (B, S, I))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, I)))
    A = -jnp.exp(jax.random.normal(ks[2], (I, N)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    D = jax.random.normal(ks[5], (I,))
    y_full, h_full = ms_ops.mamba_scan(u, dt, A, Bm, Cm, D, chunk=16)
    y1, h1 = ms_ops.mamba_scan(u[:, :32], dt[:, :32], A, Bm[:, :32],
                               Cm[:, :32], D, chunk=16)
    y2, h2 = ms_ops.mamba_scan(u[:, 32:], dt[:, 32:], A, Bm[:, 32:],
                               Cm[:, 32:], D, h0=h1, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               atol=2e-4, rtol=1e-3)


# --------------------------- stacking ---------------------------------------

@pytest.mark.parametrize("N,H,W,bn", [(8, 16, 16, 4), (37, 24, 40, 8),
                                      (100, 100, 100, 16), (3, 8, 8, 8)])
def test_stacking_matches_ref(N, H, W, bn):
    ks = jax.random.split(KEY, 5)
    rois = jax.random.normal(ks[0], (N, H, W)) * 100 + 500
    sky = jax.random.normal(ks[1], (N,)) * 10
    cal = jax.random.uniform(ks[2], (N,), minval=0.5, maxval=1.5)
    dy = jax.random.uniform(ks[3], (N,))
    dx = jax.random.uniform(ks[4], (N,))
    out = st_ops.stack_rois(rois, sky, cal, dy, dx, block_n=bn, mean=False)
    ref = stack_rois_ref(rois, sky, cal, dy, dx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=np.abs(np.asarray(ref)).max() * 1e-5)


def test_stacking_integer_shift_exactness():
    """dy=dx=0 must be an exact calibrated sum (no interpolation blur)."""
    rois = jnp.arange(2 * 4 * 4, dtype=jnp.float32).reshape(2, 4, 4)
    out = st_ops.stack_rois(rois, jnp.zeros(2), jnp.ones(2),
                            jnp.zeros(2), jnp.zeros(2), mean=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rois.sum(0)))

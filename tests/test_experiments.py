"""Experiment API (repro.experiments): spec round-trip strictness, engine
adapters bit-identical to the legacy construction paths, RunReport schema
parity across engines, and the seed-paired sweep runner.

The bit-identity tests are the PR's regression lock: a spec-driven
`SimEngine`/`RuntimeEngine` run must produce exactly the numbers the
historical hand-written `SimConfig` / `DiffusionRuntime(...)` glue
produced, so every committed baseline (BENCH_*.json, example stdout)
stays valid as entry points migrate to specs.
"""
from __future__ import annotations

import json

import pytest

from repro.core import DispatchPolicy, DynamicResourceProvisioner
from repro.core.provisioner import AllocationPolicy
from repro.core.runtime import DiffusionRuntime
from repro.core.simulator import DiffusionSim, SimConfig
from repro.core.testbeds import ANL_UC
from repro.experiments import (CacheSpec, ClusterSpec, ExperimentSpec,
                               ProvisionerSpec, RunReport, RuntimeEngine,
                               SimEngine, Sweep, WorkloadSpec,
                               build_workload, check_alias_map, load_results,
                               run_experiment, with_overrides)
from repro.workloads import (MetricsCollector, PoissonArrivals,
                             SineWaveArrivals, ZipfPopularity, generate,
                             record)

MB = 10**6


def small_spec(n_tasks=200, n_nodes=8, policy="max-compute-util",
               **spec_kw) -> ExperimentSpec:
    return ExperimentSpec(
        name="t",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=n_nodes),
        cache=CacheSpec(capacity_bytes=10**12),
        policy=policy,
        workload=WorkloadSpec(
            name="t",
            arrivals={"kind": "PoissonArrivals", "rate_per_s": 40.0},
            popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 1,
                        "corr": 1.0},
            n_tasks=n_tasks, n_objects=32, object_bytes=10 * MB,
            compute_seconds=0.05, seed=7),
        seed=3,
        **spec_kw)


def elastic_spec(n_tasks=250) -> ExperimentSpec:
    return ExperimentSpec(
        name="t-elastic",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=1),
        cache=CacheSpec(capacity_bytes=10**12),
        policy="max-compute-util",
        provisioner=ProvisionerSpec(
            policy="exponential", min_executors=1, max_executors=12,
            queue_threshold=2, idle_timeout_s=4.0, trigger_cooldown_s=1.0),
        workload=WorkloadSpec(
            name="sine",
            arrivals={"kind": "SineWaveArrivals", "mean_rate": 8.0,
                      "amplitude": 7.0, "period_s": 40.0, "phase": 0.0},
            popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 1,
                        "corr": 1.0},
            n_tasks=n_tasks, n_objects=32, object_bytes=10 * MB,
            compute_seconds=0.3, seed=7),
        seed=3)


# ---------------------------------------------------------------------------
# spec serialisation
# ---------------------------------------------------------------------------

class TestSpecRoundTrip:
    @pytest.mark.parametrize("spec", [
        small_spec(),
        elastic_spec(),
        ExperimentSpec(name="trace",
                       workload=WorkloadSpec(trace_path="/tmp/x.jsonl")),
    ], ids=["fixed", "elastic", "trace"])
    def test_bit_equal_through_json(self, spec):
        d1 = spec.to_dict()
        spec2 = ExperimentSpec.from_dict(json.loads(json.dumps(d1)))
        assert spec2 == spec
        assert spec2.to_dict() == d1
        assert spec2.fingerprint() == spec.fingerprint()

    def test_unknown_field_errors_top_level(self):
        d = small_spec().to_dict()
        d["bogus"] = 1
        with pytest.raises(ValueError, match="unknown field.*bogus"):
            ExperimentSpec.from_dict(d)

    @pytest.mark.parametrize("section", ["cluster", "cache", "workload",
                                         "provisioner"])
    def test_unknown_field_errors_nested(self, section):
        d = elastic_spec().to_dict()
        d[section]["bogus"] = 1
        with pytest.raises(ValueError, match=f"spec.{section}.*bogus"):
            ExperimentSpec.from_dict(d)

    def test_invalid_values_hard_error(self):
        with pytest.raises(ValueError):
            small_spec(policy="warp-speed")
        with pytest.raises(ValueError):
            ClusterSpec(testbed="does-not-exist")
        with pytest.raises(ValueError):
            CacheSpec(eviction="mru")
        with pytest.raises(ValueError):
            ProvisionerSpec(policy="psychic")
        with pytest.raises(ValueError, match="unknown arrivals kind"):
            WorkloadSpec(arrivals={"kind": "Nope"},
                         popularity={"kind": "ZipfPopularity"},
                         n_tasks=1, n_objects=1)
        # binding must be exactly one of trace_path / dag / generator
        with pytest.raises(ValueError, match="EXACTLY ONE"):
            WorkloadSpec(trace_path="x.jsonl",
                         arrivals={"kind": "PoissonArrivals"},
                         popularity={"kind": "ZipfPopularity"})
        with pytest.raises(ValueError, match="generator binding"):
            WorkloadSpec(n_tasks=10, n_objects=10)
        # generator knobs on a trace binding would be silently dropped
        with pytest.raises(ValueError, match="silently ignored"):
            WorkloadSpec(trace_path="x.jsonl", compute_seconds=2.0)
        with pytest.raises(ValueError, match="silently ignored"):
            WorkloadSpec(trace_path="x.jsonl", seed=5)
        # missing required fields get the strict ValueError, not TypeError
        with pytest.raises(ValueError, match="missing required"):
            ExperimentSpec.from_dict({"name": "x"})

    def test_with_overrides(self):
        spec = elastic_spec()
        s2 = with_overrides(spec, {
            "provisioner.policy": "additive",
            "cache.capacity_bytes": 5,
            "workload.arrivals": {"kind": "PoissonArrivals",
                                  "rate_per_s": 1.0},
        })
        assert s2.provisioner.policy == "additive"
        assert s2.cache.capacity_bytes == 5
        assert s2.workload.arrivals["kind"] == "PoissonArrivals"
        # the base spec is untouched (frozen tree)
        assert spec.provisioner.policy == "exponential"
        # dict-leaf override
        s3 = with_overrides(spec, {"workload.arrivals.mean_rate": 2.0})
        assert s3.workload.arrivals["mean_rate"] == 2.0
        with pytest.raises(ValueError, match="no field"):
            with_overrides(spec, {"cache.nope": 1})
        # a typo'd dict key must hard-error, not be silently inserted
        # (it would only blow up later, deep in generator construction)
        with pytest.raises(ValueError, match="no key"):
            with_overrides(spec, {"workload.arrivals.mean_rte": 2.0})
        # a dict assigned to a sub-spec field parses strictly into the
        # dataclass (a raw dict would skip validation, then crash in an
        # engine long after the manifest was written)
        s4 = with_overrides(spec, {"cache": {"capacity_bytes": 1,
                                             "eviction": "fifo"}})
        assert isinstance(s4.cache, CacheSpec)
        assert s4.cache.eviction == "fifo" and s4.cache.enabled is True
        with pytest.raises(ValueError, match="unknown field"):
            with_overrides(spec, {"cache": {"capacity_bytes": 1,
                                            "bogus": 2}})
        with pytest.raises(ValueError, match="is None"):
            with_overrides(small_spec(), {"provisioner.policy": "additive"})
        with pytest.raises(ValueError):      # validation re-runs
            with_overrides(spec, {"policy": "warp-speed"})

    def test_alias_map_in_sync_with_engines(self):
        check_alias_map()   # raises RuntimeError on drift


# ---------------------------------------------------------------------------
# engine adapters: unsupported-knob hard errors
# ---------------------------------------------------------------------------

class TestEngineKnobRejection:
    def test_runtime_rejects_sim_only_knobs(self):
        for overrides in ({"flow_solver": "naive"},
                          {"release_policy": "rebalance"},
                          {"write_outputs_to": "store"},
                          {"index_update_interval_s": 0.5},
                          {"speculation_factor": 1.5},
                          {"cluster.cpus_per_node": 2}):
            spec = with_overrides(small_spec(), overrides)
            with pytest.raises(ValueError, match="does not support"):
                RuntimeEngine().prepare(spec)

    def test_sim_rejects_runtime_only_knobs(self):
        spec = with_overrides(small_spec(), {"index_update_batch": 4})
        with pytest.raises(ValueError, match="does not support"):
            SimEngine().prepare(spec)
        # ...but the runtime accepts it
        RuntimeEngine().prepare(spec).shutdown()

    def test_batching_knobs_validate_against_layout(self):
        """wire_batch / local_dispatch are wire-level knobs: meaningless
        off the fleet (hosts == 0) and bounded below at 1."""
        with pytest.raises(ValueError, match="wire_batch"):
            with_overrides(small_spec(), {"wire_batch": 0})
        with pytest.raises(ValueError, match="fleet"):
            with_overrides(small_spec(), {"wire_batch": 8})
        with pytest.raises(ValueError, match="fleet"):
            with_overrides(small_spec(), {"local_dispatch": True})
        # a fleet layout accepts both (constructed only -- no spawn here)
        spec = small_spec(n_nodes=4, hosts=2, threads_per_host=2)
        spec = with_overrides(spec, {"wire_batch": 8,
                                     "local_dispatch": True})
        assert spec.wire_batch == 8 and spec.local_dispatch is True
        # the knobs survive the strict to_dict/from_dict round trip
        back = ExperimentSpec.from_dict(spec.to_dict())
        assert back.wire_batch == 8 and back.local_dispatch is True


# ---------------------------------------------------------------------------
# bit-identity vs. the legacy construction paths
# ---------------------------------------------------------------------------

def legacy_workload():
    """Hand-written equivalent of small_spec()'s workload binding."""
    return generate(
        "t", PoissonArrivals(40.0), ZipfPopularity(alpha=1.1, k=1, corr=1.0),
        n_tasks=200, n_objects=32, object_bytes=10 * MB,
        compute_seconds=0.05, seed=7)


class TestLegacyBitIdentity:
    def test_sim_fixed_pool(self):
        cfg = SimConfig(testbed=ANL_UC, n_nodes=8,
                        policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                        cache_capacity_bytes=10**12, seed=3)
        sim = DiffusionSim(cfg)
        sim.submit_workload(legacy_workload())
        r = sim.run()
        m_legacy = MetricsCollector(ANL_UC).collect(
            r, n_submitted=sim.n_submitted)

        rep = run_experiment(small_spec(), engine="sim")
        assert rep.n_completed == m_legacy.n_completed
        assert rep.makespan_s == m_legacy.makespan_s
        assert rep.cache_hit_ratio == m_legacy.cache_hit_ratio
        assert rep.avg_slowdown == m_legacy.avg_slowdown
        assert rep.bytes_by_kind == dict(r.bytes_by_kind)
        assert rep.t_last_complete == r.t_last_complete
        # every shared metric field, not just the headline ones
        for f in ("n_tasks", "n_failed", "busy_span_s", "tasks_per_second",
                  "local_hits", "peer_hits", "store_reads",
                  "local_hit_ratio", "mean_inputs_per_task",
                  "full_hit_tasks", "partial_hit_tasks", "zero_hit_tasks",
                  "read_bandwidth_bps", "moved_bandwidth_bps", "efficiency",
                  "p95_slowdown", "performance_index", "peak_executors",
                  "low_executors", "executor_seconds"):
            assert getattr(rep, f) == getattr(m_legacy, f), f

    def test_sim_elastic_pool(self):
        spec = elastic_spec()
        prov = DynamicResourceProvisioner(
            min_executors=1, max_executors=12,
            policy=AllocationPolicy.EXPONENTIAL, queue_threshold=2,
            idle_timeout_s=4.0, trigger_cooldown_s=1.0)
        cfg = SimConfig(testbed=ANL_UC, n_nodes=1,
                        policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                        cache_capacity_bytes=10**12, provisioner=prov,
                        seed=3)
        sim = DiffusionSim(cfg)
        sim.submit_workload(build_workload(spec.workload))
        r = sim.run()
        m_legacy = MetricsCollector(ANL_UC).collect(
            r, n_submitted=sim.n_submitted)

        rep = run_experiment(spec, engine="sim")
        assert rep.n_allocated == prov.n_allocated
        assert rep.n_released == prov.n_released
        assert rep.makespan_s == m_legacy.makespan_s
        assert rep.performance_index == m_legacy.performance_index
        assert rep.pool_log == tuple(tuple(p) for p in r.pool_log)
        assert rep.n_allocated > 0 and rep.n_released > 0

    def test_runtime_single_worker(self):
        """1-worker runs are deterministic (FIFO queue, one consumer), so
        the spec path must reproduce the legacy ledger bit-for-bit."""
        spec = small_spec(n_tasks=80, n_nodes=1)
        wl = build_workload(spec.workload)

        rt = DiffusionRuntime(n_executors=1,
                              policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                              cache_capacity_bytes=10**12, seed=3)
        th = rt.submit_workload(wl, payload_factory=lambda ob: b"x",
                                time_scale=0.0)
        th.join(60)
        assert rt.wait(60)
        legacy = rt.ledger
        n_legacy = len(rt.dispatcher.completed)
        rt.shutdown()

        rep = run_experiment(spec, engine="runtime", time_scale=0.0,
                             timeout=60.0)
        assert rep.n_completed == n_legacy == 80
        assert rep.local_hits == legacy.local_hits
        assert rep.peer_hits == legacy.peer_hits
        assert rep.store_reads == legacy.store_reads
        assert rep.bytes_by_kind == {"local": float(legacy.bytes_local),
                                     "c2c": float(legacy.bytes_c2c),
                                     "store_read": float(legacy.bytes_store)}
        assert rep.cache_hit_ratio == legacy.global_hit_ratio
        assert rep.local_hit_ratio == legacy.local_hit_ratio

    def test_runtime_honours_cache_spec(self):
        """cache.enabled=False (the data-unaware baseline) must actually
        disable runtime caching -- the DiffusionRuntime ctor historically
        dropped its cache kwargs on the floor (only configure_caches took
        effect), which made this translation a silent no-op."""
        spec = with_overrides(small_spec(n_tasks=60, n_nodes=2),
                              {"cache.enabled": False})
        rep = run_experiment(spec, engine="runtime", timeout=60.0)
        assert rep.cache_hit_ratio == 0.0
        assert rep.local_hits == 0 and rep.peer_hits == 0
        assert rep.store_reads == 60
        # and the sim agrees on the data-unaware ledger shape
        rep_sim = run_experiment(spec, engine="sim")
        assert rep_sim.cache_hit_ratio == 0.0
        assert rep_sim.store_reads == 60


# ---------------------------------------------------------------------------
# cross-engine schema parity + report plumbing
# ---------------------------------------------------------------------------

class TestRunReport:
    def test_schema_parity_sim_vs_runtime(self):
        spec = small_spec(n_tasks=60, n_nodes=4)
        rep_sim = run_experiment(spec, engine="sim")
        rep_rt = run_experiment(spec, engine="runtime", timeout=60.0)
        assert rep_sim.schema() == rep_rt.schema() == RunReport.schema()
        assert set(rep_sim.as_dict()) == set(rep_rt.as_dict())
        assert rep_sim.engine == "sim" and rep_rt.engine == "runtime"
        assert rep_sim.spec_sha == rep_rt.spec_sha == spec.fingerprint()
        # both engines fill every field with a real value
        for name, d in (("sim", rep_sim.as_dict()),
                        ("runtime", rep_rt.as_dict())):
            for k, v in d.items():
                assert v is not None, (name, k)
        # same spec, same counts on both engines (clocks differ, counts
        # must not: both drained the identical 60 tasks)
        assert rep_rt.n_completed == rep_sim.n_completed == 60
        d = rep_sim.diff(rep_rt)
        assert "n_completed" not in d and "n_tasks" not in d

    def test_sim_runs_are_reproducible(self):
        spec = small_spec(n_tasks=100)
        a = run_experiment(spec, engine="sim")
        b = run_experiment(spec, engine="sim")
        assert a.diff(b) == {}

    def test_report_dict_round_trip(self):
        rep = run_experiment(small_spec(n_tasks=50), engine="sim")
        back = RunReport.from_dict(json.loads(json.dumps(rep.as_dict())))
        assert back == rep
        with pytest.raises(ValueError, match="unknown"):
            RunReport.from_dict({**rep.as_dict(), "bogus": 1})
        with pytest.raises(ValueError, match="missing"):
            d = rep.as_dict()
            d.pop("cache_hit_ratio")
            RunReport.from_dict(d)

    def test_report_dispatch_stats_round_trip_and_diff_ignore(self):
        """dispatch_stats is carried, survives serialization, and -- like
        pool_log -- is excluded from diff() so wire-counter noise never
        breaks replay-parity checks."""
        rep = run_experiment(small_spec(n_tasks=50), engine="sim")
        assert rep.dispatch_stats == {}          # sim has no wire
        d = rep.as_dict()
        d["dispatch_stats"] = {"frames_sent": 9, "msgs_sent": 40,
                               "leases": 3, "claims": 2}
        back = RunReport.from_dict(json.loads(json.dumps(d)))
        assert back.dispatch_stats["msgs_sent"] == 40
        assert back.diff(rep) == {}              # ignored by diff

    def test_trace_binding_matches_generator(self, tmp_path):
        gen_spec = small_spec(n_tasks=60)
        record(build_workload(gen_spec.workload), tmp_path / "t.jsonl")
        trace_spec = ExperimentSpec(
            name="t", cluster=gen_spec.cluster, cache=gen_spec.cache,
            policy=gen_spec.policy, seed=gen_spec.seed,
            workload=WorkloadSpec(trace_path=str(tmp_path / "t.jsonl")))
        a = run_experiment(gen_spec, engine="sim")
        b = run_experiment(trace_spec, engine="sim")
        assert a.diff(b) == {}


# ---------------------------------------------------------------------------
# sweep runner
# ---------------------------------------------------------------------------

class TestSweep:
    def test_seed_pairing_and_outputs(self, tmp_path):
        sw = Sweep(small_spec(n_tasks=60),
                   {"policy": ["first-available", "max-compute-util"]},
                   seeds=[0, 1])
        cells = sw.cells()
        assert len(cells) == 4
        # within a replication every cell shares the workload seed; across
        # replications the seed changes in lockstep (pairing)
        assert [c.spec.workload.seed for c in cells] == [0, 0, 1, 1]
        assert [c.spec.seed for c in cells] == [0, 0, 1, 1]

        results = sw.run(out_dir=tmp_path)
        man = json.loads((tmp_path / "manifest.json").read_text())
        assert man["n_cells"] == 4 and man["seed_paired"] is True
        assert man["cells"][2]["overrides"]["policy"] == "first-available"
        back = load_results(tmp_path)
        assert len(back) == 4
        assert back[3][1] == results[3][1]
        # data-aware beats data-unaware on the identical (paired) workload
        by_policy = {(c.overrides["policy"], c.spec.seed): r
                     for c, r in results}
        for seed in (0, 1):
            assert (by_policy[("max-compute-util", seed)].cache_hit_ratio
                    > by_policy[("first-available", seed)].cache_hit_ratio)

    def test_sweeping_seed_is_rejected(self):
        with pytest.raises(ValueError, match="seed-paired"):
            Sweep(small_spec(), {"workload.seed": [0, 1]})
        with pytest.raises(ValueError, match="seed-paired"):
            Sweep(small_spec(), {"seed": [0, 1]})


# ---------------------------------------------------------------------------
# runtime provisioner driver (wall-clock DRP ticks)
# ---------------------------------------------------------------------------

class TestRuntimeProvisioner:
    def test_allocates_under_queue_pressure(self):
        spec = ExperimentSpec(
            name="rt-elastic",
            cluster=ClusterSpec(n_nodes=1),
            cache=CacheSpec(capacity_bytes=10**9),
            policy="max-compute-util",
            provisioner=ProvisionerSpec(
                policy="exponential", min_executors=1, max_executors=4,
                queue_threshold=1, idle_timeout_s=60.0,
                trigger_cooldown_s=0.0, period_s=0.02),
            workload=WorkloadSpec(
                name="burst",
                arrivals={"kind": "BatchArrivals", "at_s": 0.0},
                popularity={"kind": "UniformScan", "stride": 1, "k": 1},
                n_tasks=60, n_objects=16, object_bytes=MB, seed=0),
            seed=0)

        def slow_task(inputs):
            import time as _t
            _t.sleep(0.01)
            return 0

        eng = RuntimeEngine().prepare(spec)
        rep = eng.run(task_fn=slow_task, time_scale=0.0, timeout=60.0)
        eng.shutdown()
        assert rep.n_completed == 60
        assert rep.n_allocated > 0           # the DRP grew the pool
        assert rep.peak_executors > 1
        assert rep.peak_executors <= 4       # ...but respected max

"""Per-assigned-architecture smoke tests (deliverable f).

Each of the 10 archs instantiates a REDUCED same-family config and runs one
forward + one train step + (where applicable) one decode step on CPU,
asserting output shapes and finiteness.  The FULL configs are exercised
shape-only by the dry-run.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY
from repro.models import (init_cache, init_params, make_forward,
                          make_serve_step, make_train_step)
from repro.train import adamw

ARCHS = sorted(REGISTRY)


def _batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["image_embeds"] = 0.01 * jnp.ones(
            (B, cfg.num_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.is_encdec:
        batch["frame_embeds"] = 0.01 * jnp.ones(
            (B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = REGISTRY[arch].reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits, aux = jax.jit(make_forward(cfg))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    opt = adamw(1e-3, 2, 10)
    state = opt.init(params)
    state, metrics = jax.jit(make_train_step(cfg, opt))(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: non-finite loss"
    assert int(metrics["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = REGISTRY[arch].reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S_cache = 2, 8
    cache = init_cache(cfg, B, S_cache)
    step = jax.jit(make_serve_step(cfg))
    batch = {"token": jax.random.randint(key, (B, 1), 0, cfg.vocab_size),
             "pos": jnp.zeros((), jnp.int32)}
    if cfg.is_encdec:
        batch["enc_out"] = 0.01 * jnp.ones((B, 8, cfg.d_model),
                                           jnp.dtype(cfg.dtype))
    logits, new_cache = step(params, cache, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward_next_token(arch):
    """Replaying a prompt through serve_step reproduces forward logits --
    the serving path and training path agree (KV-cache correctness)."""
    cfg = REGISTRY[arch].reduced()
    if cfg.is_encdec:
        pytest.skip("enc-dec comparison covered by test_serving")
    if cfg.frontend == "vision":
        pytest.skip("forward splices image embeds; decode replay is text-only")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    B, S = 1, 8
    batch = _batch(cfg, key, B, S)
    full_logits, _ = jax.jit(make_forward(cfg))(params, batch)
    cache = init_cache(cfg, B, S)
    step = jax.jit(make_serve_step(cfg))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache,
                         {"token": batch["tokens"][:, t: t + 1],
                          "pos": jnp.int32(t)})
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    import numpy as np
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=0.15, rtol=0.15)


def test_every_assigned_arch_has_exact_assigned_numbers():
    """Pin the exact assignment table (guards accidental config drift)."""
    expect = {
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }
    for name, (L, D, H, KV, F, V) in expect.items():
        c = REGISTRY[name]
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab_size) == (L, D, H, KV, F, V), name
    # MoE/ssm extras
    assert REGISTRY["qwen3-moe-30b-a3b"].n_experts == 128
    assert REGISTRY["qwen3-moe-30b-a3b"].top_k == 8
    assert REGISTRY["mixtral-8x22b"].n_experts == 8
    assert REGISTRY["mixtral-8x22b"].top_k == 2
    assert REGISTRY["jamba-1.5-large-398b"].n_experts == 16
    assert REGISTRY["falcon-mamba-7b"].ssm_state == 16

"""Workload subsystem: arrival processes, popularity models, open-loop
engine integration (ARRIVAL events + provisioner elasticity), metrics."""
import collections

import pytest

from repro.core import ANL_UC, DispatchPolicy, DynamicResourceProvisioner
from repro.core.provisioner import AllocationPolicy
from repro.core.simulator import DiffusionSim, SimConfig
from repro.workloads import (BatchArrivals, BurstyArrivals, DiurnalArrivals,
                             MetricsCollector, PoissonArrivals,
                             ShiftingWorkingSet, SineWaveArrivals,
                             StackingTrace, UniformScan, ZipfPopularity,
                             generate)

MB = 10**6


# --------------------------- arrival processes --------------------------------

def test_arrivals_deterministic_in_seed():
    p = PoissonArrivals(5.0)
    a = list(p.times(200, seed=7))
    b = list(p.times(200, seed=7))
    c = list(p.times(200, seed=8))
    assert a == b
    assert a != c
    assert all(t2 >= t1 for t1, t2 in zip(a, a[1:]))


def test_poisson_mean_rate():
    ts = list(PoissonArrivals(10.0).times(4000, seed=0))
    rate = len(ts) / ts[-1]
    assert rate == pytest.approx(10.0, rel=0.1)


def test_sine_wave_modulates_rate():
    """More arrivals land in the peak half-period than in the trough."""
    p = SineWaveArrivals(mean_rate=10.0, amplitude=9.0, period_s=100.0)
    ts = [t for t in p.times(3000, seed=1) if t < 300.0]
    phase = [(t % 100.0) for t in ts]
    peak = sum(1 for x in phase if 0 <= x < 50)       # sin > 0 half
    trough = sum(1 for x in phase if 50 <= x < 100)   # sin < 0 half
    assert peak > 3 * trough


def test_bursty_concentrates_in_bursts():
    p = BurstyArrivals(base_rate=1.0, burst_rate=50.0,
                       burst_every_s=60.0, burst_len_s=6.0)
    ts = [t for t in p.times(2000, seed=2) if t < 600.0]
    in_burst = sum(1 for t in ts if (t % 60.0) < 6.0)
    # bursts cover 10% of the time but should carry the vast majority
    assert in_burst / len(ts) > 0.75


def test_diurnal_peaks_midday():
    p = DiurnalArrivals(peak_rate=20.0, trough_rate=0.5, day_s=200.0)
    ts = [t for t in p.times(3000, seed=3) if t < 600.0]
    midday = sum(1 for t in ts if 50 <= (t % 200.0) < 150)
    night = len(ts) - midday
    assert midday > 3 * night


def test_batch_arrivals_all_at_once():
    assert list(BatchArrivals().times(5, seed=0)) == [0.0] * 5


# --------------------------- popularity models --------------------------------

def test_uniform_scan_exact_locality():
    wl = generate("scan", BatchArrivals(), UniformScan(), n_tasks=30,
                  n_objects=10, object_bytes=1, seed=0)
    counts = collections.Counter(e.inputs[0] for e in wl.events)
    assert all(v == 3 for v in counts.values())      # locality exactly 3


def test_zipf_skews_toward_low_ranks():
    wl = generate("zipf", BatchArrivals(), ZipfPopularity(alpha=1.2),
                  n_tasks=3000, n_objects=50, object_bytes=1, seed=0)
    counts = collections.Counter(e.inputs[0] for e in wl.events)
    hot = counts["zipf.o0"]
    cold = counts.get("zipf.o49", 0)
    assert hot > 10 * max(cold, 1)
    assert hot > counts.get("zipf.o5", 0)


def test_shifting_working_set_moves():
    pop = ShiftingWorkingSet(working_set=4, shift_every=100, shift_by=10)
    wl = generate("shift", BatchArrivals(), pop, n_tasks=200,
                  n_objects=40, object_bytes=1, seed=0)
    first = {e.inputs[0] for e in wl.events[:100]}
    second = {e.inputs[0] for e in wl.events[100:]}
    assert first == {f"shift.o{i}" for i in range(4)}
    assert second == {f"shift.o{i}" for i in range(10, 14)}


def test_stacking_trace_locality_and_shuffle():
    pop = StackingTrace(locality=5, shuffle_seed=4)
    wl = generate("stk", BatchArrivals(), pop, n_tasks=100,
                  n_objects=20, object_bytes=1, seed=0)
    counts = collections.Counter(e.inputs[0] for e in wl.events)
    assert all(v == 5 for v in counts.values())
    # shuffled: not simply 20 scans back-to-back
    first_20 = [e.inputs[0] for e in wl.events[:20]]
    assert len(set(first_20)) < 20


def test_generate_is_pure_function_of_seed():
    def mk(seed):
        return generate("w", PoissonArrivals(4.0), ZipfPopularity(1.0),
                        n_tasks=100, n_objects=10, object_bytes=MB, seed=seed)
    a, b, c = mk(5), mk(5), mk(6)
    assert [(e.t, e.tid, e.inputs) for e in a.events] \
        == [(e.t, e.tid, e.inputs) for e in b.events]
    assert [e.t for e in a.events] != [e.t for e in c.events]


def test_workload_rejects_unknown_inputs_and_unsorted_events():
    from repro.core import DataObject
    from repro.workloads import TaskEvent, Workload
    obs = [DataObject("a", 1)]
    with pytest.raises(ValueError, match="unknown objects"):
        Workload("w", obs, [TaskEvent(t=0.0, tid="t0", inputs=("b",))])
    with pytest.raises(ValueError, match="sorted"):
        Workload("w", obs, [TaskEvent(t=1.0, tid="t0", inputs=("a",)),
                            TaskEvent(t=0.5, tid="t1", inputs=("a",))])


# --------------------------- engine integration -------------------------------

def test_open_loop_arrivals_spread_submissions():
    """ARRIVAL events submit over simulated time, not all at t=0."""
    wl = generate("p", PoissonArrivals(2.0), UniformScan(), n_tasks=40,
                  n_objects=8, object_bytes=MB, compute_seconds=0.01, seed=0)
    cfg = SimConfig(testbed=ANL_UC, n_nodes=4,
                    policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                    cache_capacity_bytes=10**12)
    sim = DiffusionSim(cfg)
    sim.submit_workload(wl)
    r = sim.run()
    assert r.n_completed == 40
    # the run lasts at least as long as the arrival span
    assert r.makespan >= wl.duration
    submits = sorted(t.submit_time for t in r.dispatcher.completed)
    assert submits[0] > 0.0
    assert submits[-1] == pytest.approx(wl.duration)


def test_sine_wave_grows_and_shrinks_pool_and_replays_bit_identical(tmp_path):
    """The PR's acceptance scenario: an open-loop sine-wave workload drives
    the DynamicResourceProvisioner through full grow/shrink cycles, and the
    same trace replayed from its JSONL recording produces bit-identical
    metrics."""
    from repro.workloads import record, replay
    wl = generate(
        "sine", SineWaveArrivals(mean_rate=8.0, amplitude=7.5, period_s=60.0),
        ZipfPopularity(1.1), n_tasks=500, n_objects=40,
        object_bytes=10 * MB, compute_seconds=0.5, seed=11)
    path = tmp_path / "sine.jsonl"
    record(wl, path)

    def run(w):
        prov = DynamicResourceProvisioner(
            min_executors=1, max_executors=32,
            policy=AllocationPolicy.ADDITIVE, additive_k=4,
            queue_threshold=2, idle_timeout_s=4.0, trigger_cooldown_s=1.0)
        cfg = SimConfig(testbed=ANL_UC, n_nodes=1,
                        policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                        cache_capacity_bytes=10**12,
                        provisioner=prov, seed=3)
        sim = DiffusionSim(cfg)
        sim.submit_workload(w)
        r = sim.run()
        m = MetricsCollector(ANL_UC).collect(r, n_submitted=sim.n_submitted)
        return prov, m

    prov, m = run(wl)
    assert m.n_completed == 500
    assert prov.n_allocated > 0          # the pool grew under the upswing...
    assert prov.n_released > 0           # ...and shrank in the trough
    assert m.peak_executors > m.low_executors
    _, m_replayed = run(replay(path))
    assert m == m_replayed               # bit-identical metrics from JSONL


def test_runtime_paced_submitter_thread():
    """The threaded runtime consumes the same workload via a paced
    submitter; time_scale compresses the arrival clock for the test."""
    from repro.core.runtime import DiffusionRuntime
    wl = generate("rtw", PoissonArrivals(50.0), UniformScan(), n_tasks=30,
                  n_objects=6, object_bytes=100, seed=0)
    rt = DiffusionRuntime(n_executors=2,
                          policy=DispatchPolicy.MAX_COMPUTE_UTIL)
    seen = []

    def task_fn(inputs):
        (payload,) = inputs.values()
        seen.append(payload)
        return payload + 1

    th = rt.submit_workload(wl, task_fn=task_fn,
                            payload_factory=lambda ob: len(ob.oid),
                            time_scale=0.01)
    th.join(30.0)
    assert not th.is_alive()
    assert rt.wait(30.0)
    done = [t for t in rt.dispatcher.completed]
    assert len(done) == 30
    assert all(t.result == len(t.inputs[0]) + 1 for t in done)
    assert rt.ledger.global_hit_ratio > 0         # objects re-read from cache
    rt.shutdown()


def test_runtime_survives_executor_removal_mid_workload():
    """Regression: a worker removed mid-execution must not double-complete
    its in-flight task (the retry is the only completion that counts) --
    previously this corrupted _outstanding and hung wait()."""
    from repro.core.runtime import DiffusionRuntime
    for trial in range(3):
        wl = generate("fault", PoissonArrivals(500.0), UniformScan(),
                      n_tasks=60, n_objects=6, object_bytes=64, seed=trial)
        rt = DiffusionRuntime(n_executors=3,
                              policy=DispatchPolicy.MAX_COMPUTE_UTIL)
        th = rt.submit_workload(
            wl, task_fn=lambda inputs: sum(len(v) for v in inputs.values()),
            payload_factory=lambda ob: b"y" * 64, time_scale=0.005)
        rt.remove_executor("w1", failed=True)
        th.join(30.0)
        assert not th.is_alive()
        assert rt.wait(30.0), "wait() hung after mid-run executor removal"
        n_done = len(rt.dispatcher.completed)
        n_failed = len(rt.dispatcher.failed)
        assert n_done + n_failed == 60
        assert rt._outstanding == 0
        rt.shutdown()


def test_runtime_terminal_failure_on_removed_worker_does_not_leak_wait():
    """Regression: a last-attempt task running on a removed worker goes
    terminally FAILED (no retry); wait() must still drain to zero."""
    import time as _time
    from repro.core import DataObject, Task
    from repro.core.runtime import DiffusionRuntime
    rt = DiffusionRuntime(n_executors=1)
    rt.put_object(DataObject("a", 4), b"aaaa")
    t = Task(inputs=("a",), fn=lambda inputs: _time.sleep(0.5) or 1,
             max_attempts=1)
    rt.submit([t])
    _time.sleep(0.1)                         # task is running on w0
    rt.remove_executor("w0", failed=True)
    assert rt.wait(10.0), "wait() leaked after terminal in-flight failure"
    assert rt._outstanding == 0
    assert len(rt.dispatcher.failed) == 1
    rt.shutdown()


def test_runtime_executor_ids_never_reused():
    """Regression: add after remove must mint a fresh id -- reusing
    f"w{len(workers)}" overwrote a live worker and lost its task."""
    from repro.core.runtime import DiffusionRuntime
    rt = DiffusionRuntime(n_executors=3)
    rt.remove_executor("w1")
    assert rt.add_executor() == "w3"
    assert sorted(rt.workers) == ["w0", "w2", "w3"]
    rt.shutdown()


# --------------------------- metrics ------------------------------------------

def test_metrics_collector_basics():
    wl = generate("m", BatchArrivals(), UniformScan(), n_tasks=60,
                  n_objects=20, object_bytes=10 * MB,
                  compute_seconds=0.05, seed=0)
    cfg = SimConfig(testbed=ANL_UC, n_nodes=4,
                    policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                    cache_capacity_bytes=10**12)
    sim = DiffusionSim(cfg)
    sim.submit_workload(wl)
    r = sim.run()
    m = MetricsCollector(ANL_UC).collect(r, n_submitted=sim.n_submitted)
    assert m.n_tasks == m.n_completed == 60
    assert 0.0 < m.cache_hit_ratio < 1.0           # locality 3 -> some hits
    assert m.local_hit_ratio <= m.cache_hit_ratio
    assert m.read_bandwidth_bps > 0
    assert m.moved_bandwidth_bps >= m.read_bandwidth_bps
    assert 0.0 < m.efficiency <= 1.0
    assert m.avg_slowdown >= 1.0                   # can't beat the ideal
    assert m.p95_slowdown >= m.avg_slowdown * 0.5
    assert m.peak_executors == m.low_executors == 4
    assert m.executor_seconds == pytest.approx(4 * r.makespan)
    assert 0.0 < m.performance_index <= 1.0
    d = m.as_dict()
    assert d["n_completed"] == 60


def test_pool_log_records_elasticity():
    wl = generate("e", PoissonArrivals(20.0), UniformScan(), n_tasks=100,
                  n_objects=10, object_bytes=MB, compute_seconds=1.0, seed=0)
    prov = DynamicResourceProvisioner(
        min_executors=1, max_executors=8,
        policy=AllocationPolicy.EXPONENTIAL, queue_threshold=1,
        idle_timeout_s=2.0, trigger_cooldown_s=0.5)
    cfg = SimConfig(testbed=ANL_UC, n_nodes=1,
                    policy=DispatchPolicy.FIRST_AVAILABLE,
                    cache_capacity_bytes=10**12, provisioner=prov)
    sim = DiffusionSim(cfg)
    sim.submit_workload(wl)
    r = sim.run()
    assert r.pool_log[0] == (0.0, 1)
    sizes = [n for _, n in r.pool_log]
    assert max(sizes) > 1                          # growth was recorded
    assert sizes[-1] <= max(sizes)                 # and the shrink tail


# --------------------------- multi-input (join) tasks -------------------------

def test_k_input_models_emit_distinct_inputs_of_width_k():
    for pop in (UniformScan(k=3), ZipfPopularity(1.1, k=3, corr=0.6),
                ShiftingWorkingSet(working_set=8, shift_every=50, k=3,
                                   corr=0.6),
                StackingTrace(locality=3, shuffle_seed=2, k=3, corr=0.6)):
        wl = generate("j", BatchArrivals(), pop, n_tasks=120,
                      n_objects=24, object_bytes=MB, seed=5)
        for e in wl.events:
            assert len(e.inputs) == 3
            assert len(set(e.inputs)) == 3          # joins never repeat a leg
        assert wl.mean_inputs_per_task() == 3.0


def test_correlation_knob_controls_overlap():
    """corr=1 neighbours share most inputs with nearby primaries; corr=0
    joins are near-independent draws -- measured as mean pairwise overlap
    between consecutive tasks reading the same primary neighborhood."""
    def mean_overlap(corr):
        pop = ZipfPopularity(alpha=0.0, k=4, corr=corr)   # uniform primaries
        wl = generate("c", BatchArrivals(), pop, n_tasks=600,
                      n_objects=30, object_bytes=1, seed=9)
        by_primary = collections.defaultdict(list)
        for e in wl.events:
            by_primary[e.inputs[0]].append(set(e.inputs))
        pairs, total = 0, 0
        for sets in by_primary.values():
            for a, b in zip(sets, sets[1:]):
                total += len(a & b)
                pairs += 1
        return total / max(pairs, 1)
    assert mean_overlap(1.0) == pytest.approx(4.0)   # identical neighborhoods
    assert mean_overlap(1.0) > mean_overlap(0.0) + 1.0


def test_k_equals_one_is_bit_identical_to_legacy_models():
    """The k/corr knobs must not perturb the single-input draw stream."""
    for legacy, knobbed in ((ZipfPopularity(1.1), ZipfPopularity(1.1, k=1, corr=0.3)),
                            (StackingTrace(4, 7), StackingTrace(4, 7, k=1))):
        wa = generate("a", PoissonArrivals(5.0), legacy, n_tasks=150,
                      n_objects=20, object_bytes=MB, seed=3)
        wb = generate("a", PoissonArrivals(5.0), knobbed, n_tasks=150,
                      n_objects=20, object_bytes=MB, seed=3)
        assert [e.inputs for e in wa.events] == [e.inputs for e in wb.events]


def test_metrics_split_hits_per_input_for_joins():
    """A k=3 stacked workload yields partial-hit tasks (some inputs cached,
    some not) and the split covers every completed task with inputs."""
    wl = generate("jm", PoissonArrivals(10.0),
                  StackingTrace(locality=4, shuffle_seed=1, k=3, corr=1.0),
                  n_tasks=240, n_objects=24, object_bytes=5 * MB,
                  compute_seconds=0.02, seed=2)
    cfg = SimConfig(testbed=ANL_UC, n_nodes=4,
                    policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                    cache_capacity_bytes=10**12, seed=1)
    sim = DiffusionSim(cfg)
    sim.submit_workload(wl)
    m = MetricsCollector(ANL_UC).collect(sim.run(), n_submitted=sim.n_submitted)
    assert m.n_completed == 240
    assert m.mean_inputs_per_task == pytest.approx(3.0)
    assert m.full_hit_tasks + m.partial_hit_tasks + m.zero_hit_tasks == 240
    assert m.full_hit_tasks > 0            # stacks re-read -> warm stacks
    assert m.zero_hit_tasks > 0            # every object's first stack read
    # per-input ledger matches the global access counters
    d = sim.dispatcher
    assert sum(t.cache_hits for t in d.completed) == m.local_hits
    assert sum(t.peer_hits for t in d.completed) == m.peer_hits
    assert sum(t.cache_misses - t.peer_hits for t in d.completed) \
        == m.store_reads


def test_runtime_threads_per_task_join_ledger():
    """The threaded engine fills the same per-input task ledger."""
    from repro.core import DataObject
    from repro.core.runtime import DiffusionRuntime
    rt = DiffusionRuntime(n_executors=2,
                          policy=DispatchPolicy.MAX_COMPUTE_UTIL)
    for i in range(6):
        rt.put_object(DataObject(f"o{i}", 100), i)
    from repro.core.objects import Task
    t1 = Task(inputs=("o0", "o1", "o2"), fn=lambda inputs: sum(inputs.values()))
    rt.submit([t1])
    assert rt.wait(10.0)
    assert t1.cache_hits + t1.cache_misses == 3
    assert t1.bytes_store == 300            # cold caches: all from the store
    t2 = Task(inputs=("o0", "o1", "o5"), fn=lambda inputs: sum(inputs.values()))
    rt.submit([t2])                         # o0/o1 now cached somewhere
    assert rt.wait(10.0)
    assert t2.cache_hits + t2.peer_hits >= 1
    assert t2.bytes_local + t2.bytes_cache_to_cache + t2.bytes_store == 300
    rt.shutdown()


def test_uniform_scan_join_window_distinct_under_stride_collisions():
    """stride*(j2-j1) % n == 0 used to emit duplicate inputs in one task."""
    for stride, n in ((5, 10), (10, 10), (4, 8)):
        pop = UniformScan(stride=stride, k=3)
        import random as _r
        for i in range(20):
            p = pop.pick(i, _r.Random(0), n)
            assert len(p) == 3 and len(set(p)) == 3, (stride, n, p)


def test_stacking_trace_partial_last_group_keeps_full_width():
    """Primaries in the catalog's last partial stack group used to emit
    tasks narrower than k; out-of-range members must be replaced by
    independent draws instead of silently dropped."""
    pop = StackingTrace(locality=1, shuffle_seed=0, k=4, corr=1.0)
    wl = generate("pg", BatchArrivals(), pop, n_tasks=10, n_objects=10,
                  object_bytes=1, seed=0)
    for e in wl.events:
        assert len(e.inputs) == 4
        assert len(set(e.inputs)) == 4
    assert wl.mean_inputs_per_task() == 4.0

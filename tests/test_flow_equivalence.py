"""Golden equivalence: incremental flow solver == retained naive reference.

Both solvers advance a flow's byte clock only when its rate changes, from
identical float anchors, so every completion time -- and therefore the whole
discrete-event trajectory -- must be *bit-identical*, not merely close.  The
incremental solver just does it with O(affected) repricing work and without
re-pushing ETA events for unchanged rates (the invariants are written up in
DESIGN.md §3).  Seeded random workloads here cover peer fetches, evictions,
node failures (flow cancellation mid-transfer), straggler speculation
(twin-vs-original cancellation) and loose index coherence.
"""
import random

import pytest

from repro.core import ANL_UC, DataObject, DispatchPolicy, Task
from repro.core.cache import EvictionPolicy
from repro.core.simulator import DiffusionSim, SimConfig


def _random_workload(seed: int, n_objs: int = 48, n_tasks: int = 120):
    rng = random.Random(seed)
    objs = [DataObject(f"o{seed}_{i}", rng.randrange(1, 40) * 10**6)
            for i in range(n_objs)]
    tasks = []
    for i in range(n_tasks):
        inputs = tuple(ob.oid for ob in rng.sample(objs, rng.randrange(1, 4)))
        outputs = ()
        if rng.random() < 0.3:
            outputs = (DataObject(f"t{seed}_{i}.out", rng.randrange(1, 20) * 10**6),)
        tasks.append(Task(
            inputs=inputs, outputs=outputs,
            compute_seconds=rng.random() * 0.3,
            store_metadata_ops=3 if rng.random() < 0.2 else 0))
    return objs, tasks


def _run(solver: str, seed: int, **cfg_kw):
    defaults = dict(
        testbed=ANL_UC, n_nodes=6, policy=DispatchPolicy.MAX_COMPUTE_UTIL,
        cpus_per_node=2, cache_policy=EvictionPolicy.LRU,
        cache_capacity_bytes=300 * 10**6,     # small: forces evictions
        seed=seed)
    defaults.update(cfg_kw)
    sim = DiffusionSim(SimConfig(flow_solver=solver, **defaults))
    objs, tasks = _random_workload(seed)
    sim.add_objects(objs)
    sim.warm_caches(objs[: len(objs) // 2])
    sim.submit(tasks)
    r = sim.run()
    return sim, r


def _fingerprint(r):
    return (r.makespan, r.t_first_dispatch, r.t_last_complete,
            dict(r.bytes_by_kind), r.n_completed, r.n_failed,
            r.local_hits, r.peer_hits, r.store_reads)


CONFIGS = [
    {},                                                       # baseline MCU
    {"policy": DispatchPolicy.FIRST_CACHE_AVAILABLE},         # hint shipping
    {"index_update_interval_s": 2.0},                         # loose coherence
    {"fail_at": {"e2": 3.0}},                                 # cancellations
    {"speculation_factor": 2.0,                               # twin cancels
     "executor_slowdown": {"e1": 25.0}},
]


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("cfg", CONFIGS,
                         ids=["mcu", "fca", "loose-index", "node-failure",
                              "speculation"])
def test_incremental_matches_naive_bit_for_bit(seed, cfg):
    sim_n, r_n = _run("naive", seed, **cfg)
    sim_i, r_i = _run("incremental", seed, **cfg)
    assert r_n.n_completed > 0
    assert _fingerprint(r_i) == _fingerprint(r_n)
    # the full transfer trace must agree too: same flows, same start and
    # completion instants, byte for byte
    assert r_i.flow_log == r_n.flow_log
    # ... while the incremental solver does it with no more (in practice far
    # fewer) scheduled completion events and repricings
    assert sim_i.net.n_events_scheduled <= sim_n.net.n_events_scheduled
    assert sim_i.net.n_rate_recomputes <= sim_n.net.n_rate_recomputes


def test_incremental_actually_skips_work():
    """On a contended workload the incremental solver must schedule
    strictly fewer ETA events than the naive reference, not just tie."""
    sim_n, r_n = _run("naive", 7)
    sim_i, r_i = _run("incremental", 7)
    assert _fingerprint(r_i) == _fingerprint(r_n)
    assert sim_i.net.n_events_scheduled < sim_n.net.n_events_scheduled
    assert sim_i.net.n_event_skips > 0


def test_unknown_solver_rejected():
    with pytest.raises(ValueError):
        _run("quadratic", 0)

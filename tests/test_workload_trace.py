"""Trace record/replay: generate -> record -> replay is event-identical
(same tids, arrival times, inputs/outputs) for every built-in generator,
and a replayed trace drives the simulator to bit-identical metrics."""
import io
import json
from pathlib import Path

import pytest

from repro.core import ANL_UC, DispatchPolicy
from repro.core.simulator import DiffusionSim, SimConfig
from repro.workloads import (SUPPORTED_VERSIONS, TRACE_VERSION,
                             TRACE_VERSION_V3, BatchArrivals, BurstyArrivals,
                             DiurnalArrivals, MetricsCollector,
                             PoissonArrivals, ShiftingWorkingSet,
                             SineWaveArrivals, StackingTrace, UniformScan,
                             ZipfPopularity, events_fingerprint, generate,
                             read_outcomes, record, record_v3, replay)

MB = 10**6

ARRIVAL_CASES = [
    BatchArrivals(),
    PoissonArrivals(6.0),
    SineWaveArrivals(mean_rate=5.0, amplitude=4.0, period_s=40.0),
    BurstyArrivals(base_rate=1.0, burst_rate=30.0,
                   burst_every_s=30.0, burst_len_s=5.0),
    DiurnalArrivals(peak_rate=12.0, trough_rate=1.0, day_s=120.0),
]

POPULARITY_CASES = [
    UniformScan(),
    ZipfPopularity(alpha=1.0),
    ShiftingWorkingSet(working_set=5, shift_every=20, shift_by=3),
    StackingTrace(locality=4, shuffle_seed=9),
]


def _ids(objs):
    return [type(o).__name__ for o in objs]


@pytest.mark.parametrize("arrivals", ARRIVAL_CASES, ids=_ids(ARRIVAL_CASES))
@pytest.mark.parametrize("popularity", POPULARITY_CASES,
                         ids=_ids(POPULARITY_CASES))
def test_roundtrip_event_identical(arrivals, popularity, tmp_path):
    wl = generate("rt", arrivals, popularity, n_tasks=120, n_objects=15,
                  object_bytes=3 * MB, compute_seconds=0.02,
                  output_bytes=MB, store_metadata_ops=1, seed=13)
    path = tmp_path / "trace.jsonl"
    n = record(wl, path)
    assert n == 120
    wl2 = replay(path)
    assert events_fingerprint(wl2) == events_fingerprint(wl)
    assert wl2.spec == wl.spec
    # a second record of the replay is byte-identical (stable serialisation)
    buf1, buf2 = io.StringIO(), io.StringIO()
    record(wl, buf1)
    record(wl2, buf2)
    assert buf1.getvalue() == buf2.getvalue()


@pytest.mark.parametrize("arrivals", ARRIVAL_CASES, ids=_ids(ARRIVAL_CASES))
def test_replayed_trace_runs_to_identical_metrics(arrivals, tmp_path):
    wl = generate("m", arrivals, ZipfPopularity(0.9), n_tasks=80,
                  n_objects=12, object_bytes=5 * MB,
                  compute_seconds=0.05, seed=21)
    path = tmp_path / "m.jsonl"
    record(wl, path)

    def run(w):
        cfg = SimConfig(testbed=ANL_UC, n_nodes=4,
                        policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                        cache_capacity_bytes=10**12, seed=2)
        sim = DiffusionSim(cfg)
        sim.submit_workload(w)
        r = sim.run()
        return MetricsCollector(ANL_UC).collect(r, n_submitted=sim.n_submitted)

    assert run(wl) == run(replay(path))


# --------------------------- format hygiene -----------------------------------

def test_unsupported_version_rejected():
    buf = io.StringIO(json.dumps(
        {"kind": "header", "version": max(SUPPORTED_VERSIONS) + 1,
         "n_objects": 0, "n_tasks": 0}) + "\n")
    with pytest.raises(ValueError, match="unsupported trace version"):
        replay(buf)


def test_missing_header_rejected():
    buf = io.StringIO(json.dumps({"kind": "task", "t": 0.0}) + "\n")
    with pytest.raises(ValueError, match="header"):
        replay(buf)


def test_truncated_trace_rejected(tmp_path):
    wl = generate("t", BatchArrivals(), UniformScan(), n_tasks=10,
                  n_objects=3, object_bytes=1, seed=0)
    path = tmp_path / "t.jsonl"
    record(wl, path)
    lines = path.read_text().splitlines()
    path.write_text("\n".join(lines[:-2]) + "\n")   # drop two task lines
    with pytest.raises(ValueError, match="truncated"):
        replay(path)


def test_empty_file_rejected():
    with pytest.raises(ValueError, match="empty"):
        replay(io.StringIO(""))


# --------------------------- schema versioning --------------------------------

V1_FIXTURE = __file__.rsplit("/", 1)[0] + "/data/trace_v1.jsonl"


def _v1_equivalent_workload():
    """The fixture's generation recipe -- the single copy lives next to the
    gate canary in benchmarks.bench_joins; import it so test and gate can
    never drift apart."""
    import sys
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.bench_joins import v1_equivalent_workload
    return v1_equivalent_workload()


def test_committed_v1_trace_replays_bit_identically():
    """Regression: the v2 reader must keep replaying single-input-era (v1)
    traces to the exact events -- and therefore exact RunMetrics -- they
    always produced."""
    header = json.loads(Path(V1_FIXTURE).read_text().splitlines()[0])
    assert header["version"] == 1                 # fixture really is v1
    wl1 = replay(V1_FIXTURE)
    wl = _v1_equivalent_workload()
    assert events_fingerprint(wl1) == events_fingerprint(wl)

    def run(w):
        cfg = SimConfig(testbed=ANL_UC, n_nodes=4,
                        policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                        cache_capacity_bytes=10**12, seed=2)
        sim = DiffusionSim(cfg)
        sim.submit_workload(w)
        return MetricsCollector(ANL_UC).collect(sim.run(),
                                                n_submitted=sim.n_submitted)

    assert run(wl1) == run(wl)                    # bit-identical RunMetrics


def test_v2_task_lines_are_self_describing_and_joins_roundtrip(tmp_path):
    wl = generate("j2", PoissonArrivals(5.0),
                  ZipfPopularity(1.1, k=3, corr=0.5), n_tasks=50,
                  n_objects=16, object_bytes=2 * MB, seed=4)
    path = tmp_path / "j2.jsonl"
    record(wl, path)
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert lines[0]["version"] == TRACE_VERSION == 2
    task_lines = [r for r in lines if r["kind"] == "task"]
    assert all(len(r["inputs"]) == 3 for r in task_lines)
    assert all(sz == 2 * MB for r in task_lines for _, sz in r["inputs"])
    assert events_fingerprint(replay(path)) == events_fingerprint(wl)


def test_v2_input_size_drift_is_a_hard_error(tmp_path):
    wl = generate("d", BatchArrivals(), UniformScan(), n_tasks=5,
                  n_objects=3, object_bytes=7, seed=0)
    path = tmp_path / "d.jsonl"
    record(wl, path)
    lines = path.read_text().splitlines()
    bad = json.loads(lines[-1])
    bad["inputs"][0][1] = 999                     # disagree with the catalog
    path.write_text("\n".join(lines[:-1] + [json.dumps(bad)]) + "\n")
    with pytest.raises(ValueError, match="disagrees with catalog"):
        replay(path)


# --------------------------- v3: measured outcomes ----------------------------

def _fake_outcomes(wl):
    """Synthetic but schema-complete measured rows, one per task."""
    from repro.obs.events import OUTCOME_FIELDS
    out = []
    for i, e in enumerate(wl.events):
        rec = {k: 0 for k in OUTCOME_FIELDS}
        rec.update(tid=e.tid, executor=f"w{i % 3}", attempts=1,
                   queue_s=0.25 * i, exec_s=0.5, turnaround_s=0.25 * i + 0.5)
        out.append(rec)
    return out


def test_v3_roundtrip_outcomes_and_arrivals(tmp_path):
    wl = generate("v3", PoissonArrivals(4.0), ZipfPopularity(1.0, k=2),
                  n_tasks=20, n_objects=8, object_bytes=MB, seed=5)
    outcomes = _fake_outcomes(wl)
    path = tmp_path / "v3.jsonl"
    assert record_v3(wl, path, outcomes) == 20
    header = json.loads(path.read_text().splitlines()[0])
    assert header["version"] == TRACE_VERSION_V3 == 3
    assert header["n_outcomes"] == 20
    # the arrival half replays bit-identically to a v2 record of the same wl
    assert events_fingerprint(replay(path)) == events_fingerprint(wl)
    # the measured half reads back exactly (extra keys preserved)
    assert read_outcomes(path) == outcomes


def test_v3_outcome_missing_field_hard_errors_before_write(tmp_path):
    wl = generate("v3b", BatchArrivals(), UniformScan(), n_tasks=3,
                  n_objects=3, object_bytes=1, seed=0)
    outcomes = _fake_outcomes(wl)
    del outcomes[1]["executor"]
    path = tmp_path / "v3b.jsonl"
    with pytest.raises(ValueError, match="missing field.*executor"):
        record_v3(wl, path, outcomes)
    assert not path.exists()                      # nothing was written


def test_v3_truncated_outcomes_rejected(tmp_path):
    wl = generate("v3t", BatchArrivals(), UniformScan(), n_tasks=5,
                  n_objects=3, object_bytes=1, seed=0)
    path = tmp_path / "v3t.jsonl"
    record_v3(wl, path, _fake_outcomes(wl))
    lines = path.read_text().splitlines()
    path.write_text("\n".join(lines[:-1]) + "\n")  # drop one outcome row
    with pytest.raises(ValueError, match="truncated"):
        read_outcomes(path)
    with pytest.raises(ValueError, match="truncated"):
        replay(path)                               # replay also counts them


def test_read_outcomes_rejects_arrivals_only_traces(tmp_path):
    wl = generate("v2o", BatchArrivals(), UniformScan(), n_tasks=3,
                  n_objects=3, object_bytes=1, seed=0)
    path = tmp_path / "v2o.jsonl"
    record(wl, path)                               # plain v2
    with pytest.raises(ValueError, match="carries no measured outcomes"):
        read_outcomes(path)


def test_record_still_writes_v2_and_versions_tuple():
    """The plain writer did not silently bump for dep-free workloads; v3
    is record_v3-only and v4 is reserved for workloads with dep edges."""
    assert TRACE_VERSION == 2
    assert SUPPORTED_VERSIONS == (1, 2, 3, 4)
    wl = generate("v2w", BatchArrivals(), UniformScan(), n_tasks=2,
                  n_objects=2, object_bytes=1, seed=0)
    buf = io.StringIO()
    record(wl, buf)
    assert json.loads(buf.getvalue().splitlines()[0])["version"] == 2


def test_future_versions_hard_error_not_best_effort():
    """A reader must refuse what it cannot fully parse: version 5 with
    well-formed v4-looking records still raises."""
    buf = io.StringIO(
        json.dumps({"kind": "header", "version": 5, "name": "f",
                    "n_objects": 0, "n_tasks": 0, "n_outcomes": 0}) + "\n")
    with pytest.raises(ValueError, match="unsupported trace version"):
        replay(buf)

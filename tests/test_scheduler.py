"""Dispatcher state machine: placement, retries, failure, speculation."""
from repro.core.index import LocationIndex
from repro.core.objects import DataObject, Task, TaskState
from repro.core.policies import DispatchPolicy
from repro.core.scheduler import Dispatcher


def _mkdisp(policy=DispatchPolicy.MAX_COMPUTE_UTIL, n_exec=2, **kw):
    d = Dispatcher(policy, **kw)
    for i in range(n_exec):
        d.executor_joined(f"e{i}", now=0.0)
    return d


def test_fifo_dispatch_and_completion():
    d = _mkdisp(DispatchPolicy.FIRST_AVAILABLE)
    tasks = [Task(inputs=()) for _ in range(5)]
    d.submit(tasks, now=0.0)
    out = d.next_dispatches(0.0)
    assert [o.executor for o in out] == ["e0", "e1"]
    assert d.queue_len == 3
    d.task_finished(out[0].task, 1.0)
    nxt = d.next_dispatches(1.0)
    assert len(nxt) == 1 and nxt[0].executor == "e0"


def test_mcu_window_matches_task_to_freed_executor():
    d = _mkdisp(DispatchPolicy.MAX_COMPUTE_UTIL, n_exec=2)
    d.index.insert("a", "e1")
    d.sizes["a"] = 100
    t_other = Task(inputs=("z",))
    t_match = Task(inputs=("a",))
    d.submit([t_other, t_match], now=0.0)
    out = d.next_dispatches(0.0)
    # window search: e0 gets the unmatched head, e1 gets ITS cached task
    by_exec = {o.executor: o.task for o in out}
    assert by_exec["e1"] is t_match
    assert by_exec["e0"] is t_other
    assert t_match.location_hints == {"a": ("e1",)}


def test_max_cache_hit_parks_then_runs_on_holder():
    d = _mkdisp(DispatchPolicy.MAX_CACHE_HIT, n_exec=2)
    d.index.insert("a", "e1")
    d.sizes["a"] = 10
    filler = Task(inputs=())
    d.submit([filler], now=0.0)
    first = d.next_dispatches(0.0)      # filler takes e0 (degraded path)
    assert first[0].executor == "e0"
    blocker = Task(inputs=())
    d.submit([blocker], 0.0)
    assert d.next_dispatches(0.0)[0].executor == "e1"  # e1 now busy
    want = Task(inputs=("a",))
    d.submit([want], 0.0)
    assert d.next_dispatches(0.0) == []          # parked on busy e1
    assert want.state is TaskState.PENDING
    d.task_finished(blocker, 1.0)
    out = d.next_dispatches(1.0)
    assert out[0].task is want and out[0].executor == "e1"


def test_executor_failure_requeues_and_invalidates():
    d = _mkdisp(DispatchPolicy.FIRST_CACHE_AVAILABLE, n_exec=2)
    d.index.insert("a", "e0")
    t = Task(inputs=("a",))
    d.submit([t], 0.0)
    out = d.next_dispatches(0.0)
    assert out[0].executor == "e0"
    requeued = d.executor_left("e0", 1.0, failed=True)
    assert t in requeued and t.attempts == 1
    assert d.index.lookup("a") == frozenset()    # invalidated
    nxt = d.next_dispatches(1.0)
    assert nxt[0].executor == "e1"               # re-dispatched elsewhere


def test_task_fails_after_max_attempts():
    d = _mkdisp(DispatchPolicy.FIRST_AVAILABLE, n_exec=1)
    t = Task(inputs=(), max_attempts=2)
    d.submit([t], 0.0)
    for i in range(2):
        out = d.next_dispatches(float(i))
        d.task_finished(out[0].task, float(i) + 0.5, ok=False)
    assert t.state is TaskState.FAILED
    assert d.failed == [t]


def test_speculation_twins_straggler_and_first_wins():
    d = _mkdisp(DispatchPolicy.FIRST_AVAILABLE, n_exec=3,
                speculation_factor=2.0, min_completions_for_speculation=5)
    # establish a duration baseline
    for i in range(6):
        t = Task(inputs=())
        d.submit([t], float(i))
        out = d.next_dispatches(float(i))
        d.task_finished(out[0].task, float(i) + 1.0)   # 1s tasks
    slow = Task(inputs=())
    d.submit([slow], 10.0)
    d.next_dispatches(10.0)
    assert d.speculation_candidates(11.0) == []        # not late yet
    cands = d.speculation_candidates(15.0)             # 5s >> 2x p95(1s)
    assert cands == [slow]
    twin = d.make_twin(slow, 15.0)
    out = d.next_dispatches(15.0)
    assert out[0].task is twin
    cancel = d.task_finished(twin, 16.0)               # twin wins
    assert cancel == slow.tid
    assert slow.state is TaskState.DONE                # satisfied by twin


def test_elastic_join_mid_stream():
    d = _mkdisp(DispatchPolicy.FIRST_AVAILABLE, n_exec=1)
    tasks = [Task(inputs=()) for _ in range(4)]
    d.submit(tasks, 0.0)
    assert len(d.next_dispatches(0.0)) == 1
    d.executor_joined("e9", 1.0)                       # DRP grew the pool
    assert {o.executor for o in d.next_dispatches(1.0)} == {"e9"}


# ---------------------------------------------------------------------------
# TaskQueue: tombstone churn, compaction, ordered views
# ---------------------------------------------------------------------------

from repro.core.scheduler import TaskQueue  # noqa: E402


def _filled_queue(n):
    q = TaskQueue()
    ts = [Task(inputs=()) for _ in range(n)]
    for t in ts:
        q.append(t)
    return q, ts


def test_taskqueue_heavy_remove_compacts_storage():
    q, ts = _filled_queue(200)
    for t in ts[:150]:
        assert q.remove(t.tid)
    assert len(q) == 50
    # tombstones were physically compacted away at some point (the deque
    # would otherwise still hold all 200 entries)
    assert len(q._dq) < 200
    assert len(q._dq) == len(q) + q._dead
    # FIFO of the survivors is intact
    assert [t.tid for t in q] == [t.tid for t in ts[150:]]
    assert [q.popleft().tid for _ in range(50)] == [t.tid for t in ts[150:]]


def test_taskqueue_popleft_skips_tombstones():
    q, ts = _filled_queue(10)
    for t in ts[::2]:                 # kill the evens
        q.remove(t.tid)
    assert [q.popleft().tid for _ in range(5)] == [t.tid for t in ts[1::2]]
    try:
        q.popleft()
        assert False, "pop from empty TaskQueue must raise"
    except IndexError:
        pass


def test_taskqueue_first_live_after_heavy_churn():
    q, ts = _filled_queue(300)
    for t in ts[:297]:
        q.remove(t.tid)
    assert [t.tid for t in q.first_live(10)] == [t.tid for t in ts[297:]]
    assert [t.tid for t in q.first_live(2)] == [t.tid for t in ts[297:299]]
    assert ts[299].tid in q and ts[0].tid not in q
    assert not q.remove(ts[0].tid)    # double-remove is a no-op


def test_taskqueue_reappend_moves_to_back():
    q, ts = _filled_queue(3)
    q.append(ts[0])                   # same tid: tombstone + re-append
    assert len(q) == 3
    assert [t.tid for t in q] == [ts[1].tid, ts[2].tid, ts[0].tid]


def test_taskqueue_appendleft_position_total_order():
    q = TaskQueue()
    a, b, c = (Task(inputs=()) for _ in range(3))
    q.append(a)
    q.appendleft(b)                   # requeue path: back to the front
    q.append(c)
    assert [t.tid for t in q] == [b.tid, a.tid, c.tid]
    assert q.position(b.tid) < q.position(a.tid) < q.position(c.tid)
    assert bool(q) and len(q) == 3
    q.remove(a.tid)
    q.remove(b.tid)
    q.remove(c.tid)
    assert not q and len(q) == 0

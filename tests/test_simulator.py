"""Discrete-event simulator: determinism + paper-anchor validations.

The micro-benchmark anchors (Figures 3/4) are asserted within +-15% here
with scaled-down workloads; benchmarks/ runs the full-size versions.
"""
import pytest

from repro.core import (ANL_UC, DataObject, DispatchPolicy,
                        DynamicResourceProvisioner, EvictionPolicy, Task,
                        make_objects, uniform_tasks)
from repro.core.provisioner import AllocationPolicy
from repro.core.simulator import DiffusionSim, SimConfig

MB = 10**6


def _sim(policy, n_nodes=16, caching=True, cache_gb=200, **kw):
    cfg = SimConfig(testbed=ANL_UC, n_nodes=n_nodes, policy=policy,
                    cache_capacity_bytes=cache_gb * 10**9,
                    caching_enabled=caching, **kw)
    return DiffusionSim(cfg)


def test_deterministic_replay():
    outs = []
    for _ in range(2):
        sim = _sim(DispatchPolicy.MAX_COMPUTE_UTIL, n_nodes=8)
        objs = make_objects("f", 64, 10 * MB)
        sim.add_objects(objs)
        sim.warm_caches(objs)
        sim.submit(uniform_tasks(objs))
        r = sim.run()
        outs.append((r.makespan, r.n_completed, dict(r.bytes_by_kind)))
    assert outs[0] == outs[1]


def test_all_tasks_complete_and_bytes_conserve():
    sim = _sim(DispatchPolicy.MAX_COMPUTE_UTIL, n_nodes=8)
    objs = make_objects("f", 96, 25 * MB)
    sim.add_objects(objs)
    sim.submit(uniform_tasks(objs, accesses_per_object=2))
    r = sim.run()
    assert r.n_completed == 192
    consumed = (r.bytes_by_kind.get("local", 0) + r.bytes_by_kind.get("c2c", 0)
                + r.bytes_by_kind.get("store_read", 0))
    assert consumed == pytest.approx(192 * 25 * MB)


def test_fig3_anchor_max_compute_util_warm():
    """Paper Fig 3: max-compute-util @100% locality ~= 94% of ideal."""
    sim = _sim(DispatchPolicy.MAX_COMPUTE_UTIL, n_nodes=16)
    objs = make_objects("f", 160, 100 * MB)
    sim.add_objects(objs)
    sim.warm_caches(objs)
    sim.submit(uniform_tasks(objs))
    r = sim.run()
    frac = r.read_throughput() / ANL_UC.ideal_read_bw(16)
    assert 0.85 < frac < 1.0
    assert r.local_hit_ratio > 0.95


def test_fig3_anchor_gpfs_bound_configs():
    """Cold caches / no caching are bounded by the 3.4 Gb/s GPFS ceiling."""
    for policy, caching in [(DispatchPolicy.FIRST_AVAILABLE, False),
                            (DispatchPolicy.MAX_COMPUTE_UTIL, True)]:
        sim = _sim(policy, n_nodes=16, caching=caching)
        objs = make_objects("f", 160, 100 * MB)
        sim.add_objects(objs)
        sim.submit(uniform_tasks(objs))
        r = sim.run()
        assert r.read_throughput() <= 425 * MB * 1.02


def test_fig5_wrapper_metadata_floor():
    """Paper Fig 5: the sandbox wrapper (3 serialized GPFS metadata ops per
    task) floors small-file throughput at ~21 tasks/s regardless of nodes."""
    sim = _sim(DispatchPolicy.FIRST_AVAILABLE, n_nodes=16, caching=False)
    objs = make_objects("f", 120, 1)   # 1-byte files
    sim.add_objects(objs)
    sim.submit(uniform_tasks(objs, store_metadata_ops=3))
    r = sim.run()
    assert 15 < r.tasks_per_second() < 30


def test_cache_hit_ratio_near_ideal_with_locality():
    """Paper Fig 10: data-aware scheduling gets >=90% of the ideal
    1 - 1/locality cache-hit ratio."""
    locality = 5
    sim = _sim(DispatchPolicy.MAX_COMPUTE_UTIL, n_nodes=8)
    objs = make_objects("f", 60, 20 * MB)
    sim.add_objects(objs)
    sim.submit(uniform_tasks(objs, accesses_per_object=locality))
    r = sim.run()
    ideal = 1 - 1 / locality
    assert r.global_hit_ratio >= 0.9 * ideal


def test_executor_failure_recovers():
    sim = _sim(DispatchPolicy.MAX_COMPUTE_UTIL, n_nodes=4)
    cfg = sim.cfg
    objs = make_objects("f", 40, 50 * MB)
    sim.add_objects(objs)
    sim.warm_caches(objs)
    sim.cfg.fail_at["e1"] = 2.0
    sim.loop.at(2.0, lambda now: sim._fail_node("e1", now))
    sim.submit(uniform_tasks(objs, compute_seconds=0.2))
    r = sim.run()
    assert r.n_completed == 40        # every task still completes
    assert r.n_failed == 0
    assert "e1" not in sim.dispatcher.executors


def test_straggler_speculation_bounds_makespan():
    def run(spec_factor):
        cfg = SimConfig(testbed=ANL_UC, n_nodes=4,
                        policy=DispatchPolicy.FIRST_AVAILABLE,
                        cache_capacity_bytes=10**12,
                        speculation_factor=spec_factor,
                        executor_slowdown={"e3": 50.0})
        sim = DiffusionSim(cfg)
        objs = make_objects("f", 24, 1 * MB)
        sim.add_objects(objs)
        sim.warm_caches(objs, replicas=4)
        sim.submit(uniform_tasks(objs, compute_seconds=1.0))
        # t_last_complete, not loop-drain time: a cancelled original's
        # no-op timer may still sit in the heap long past completion
        return sim.run().t_last_complete
    slow = run(0.0)
    fast = run(2.0)
    assert fast < slow * 0.6          # speculation rescues the straggler


def test_provisioner_scales_up_and_releases():
    prov = DynamicResourceProvisioner(
        min_executors=1, max_executors=8,
        policy=AllocationPolicy.EXPONENTIAL, queue_threshold=1,
        idle_timeout_s=5.0, trigger_cooldown_s=0.5)
    cfg = SimConfig(testbed=ANL_UC, n_nodes=1,
                    policy=DispatchPolicy.FIRST_AVAILABLE,
                    cache_capacity_bytes=10**12, provisioner=prov)
    sim = DiffusionSim(cfg)
    objs = make_objects("f", 64, 1 * MB)
    sim.add_objects(objs)
    sim.warm_caches(objs, replicas=1)
    sim.submit(uniform_tasks(objs, compute_seconds=2.0))
    r = sim.run()
    assert r.n_completed == 64
    assert prov.n_allocated > 0                      # pool grew
    live = sum(1 for n in sim.nodes.values() if n.alive)
    assert live <= prov.min_executors + prov.n_allocated
    assert prov.n_released > 0                       # and shrank when idle


def test_release_rebalance_preserves_cached_data():
    """Paper §6 future work, answered: 'rebalance' migrates a released
    executor's cache to peers so subsequent tasks still avoid the store;
    'discard' (the paper's default assumption) loses it."""
    def run(policy_name):
        cfg = SimConfig(testbed=ANL_UC, n_nodes=4,
                        policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                        cache_capacity_bytes=10**12,
                        release_policy=policy_name)
        sim = DiffusionSim(cfg)
        objs = make_objects("f", 16, 10 * MB)
        sim.add_objects(objs)
        sim.warm_caches(objs)               # spread over all 4 nodes
        sim.loop.at(0.5, lambda now: sim._release_node("e3", now))
        sim.loop.at(1.0, lambda now: sim.submit(uniform_tasks(objs)))
        r = sim.run()
        assert r.n_completed == 16
        return r.store_reads
    discarded = run("discard")
    rebalanced = run("rebalance")
    assert rebalanced == 0          # e3's objects were migrated, not lost
    assert discarded >= 3           # ~1/4 of the working set re-read


def test_loose_index_coherence_costs_performance_not_correctness():
    """§3.2.1: the index is only loosely coherent.  With a large update
    interval the scheduler works from stale locations -- more store reads,
    identical results."""
    def run(interval):
        import random as _random
        cfg = SimConfig(testbed=ANL_UC, n_nodes=4,
                        policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                        cache_capacity_bytes=10**12,
                        index_update_interval_s=interval)
        sim = DiffusionSim(cfg)
        objs = make_objects("f", 24, 10 * MB)
        sim.add_objects(objs)
        # per-round shuffles: without them, FIFO placement accidentally
        # re-aligns each round onto the same nodes and hides the staleness
        tasks = []
        for rnd in range(3):
            order = list(objs)
            _random.Random(rnd).shuffle(order)
            tasks += [Task(inputs=(ob.oid,), compute_seconds=0.05)
                      for ob in order]
        sim.submit(tasks)
        r = sim.run()
        assert r.n_completed == 72                 # correctness: always
        return r.global_hit_ratio
    tight = run(0.0)
    loose = run(30.0)                              # updates arrive too late
    assert tight > loose                           # staleness costs hits

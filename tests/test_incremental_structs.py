"""Unit coverage for the incremental-engine data structures:

  * TaskQueue -- tombstone deque with O(1) removal and stable positions;
  * Dispatcher's inverted executor->score map staying coherent under
    index updates, executor loss, and enqueue/dequeue churn;
  * ExecutorCache LFU victim selection via the lazily-pruned heap
    (must match the reference "min over (freq, order)" rule exactly);
  * ShardedIndex aggregate op counters.
"""
import random

from repro.core.cache import EvictionPolicy, ExecutorCache
from repro.core.index import IndexUpdate, LocationIndex, ShardedIndex
from repro.core.objects import DataObject, Task
from repro.core.policies import DispatchPolicy
from repro.core.scheduler import Dispatcher, TaskQueue


# ---------------- TaskQueue -------------------------------------------------

def test_taskqueue_fifo_and_removal():
    q = TaskQueue()
    ts = [Task(inputs=()) for _ in range(5)]
    for t in ts:
        q.append(t)
    assert len(q) == 5 and ts[0].tid in q
    assert q.remove(ts[2].tid) and not q.remove(ts[2].tid)
    assert [t.tid for t in q] == [ts[i].tid for i in (0, 1, 3, 4)]
    assert q.popleft() is ts[0]
    q.appendleft(ts[2])                      # re-enqueue after removal
    assert q.popleft() is ts[2]
    assert [t.tid for t in q.first_live(10)] == [ts[1].tid, ts[3].tid, ts[4].tid]
    # positions give the FIFO total order without walking the deque
    assert q.position(ts[1].tid) < q.position(ts[3].tid) < q.position(ts[4].tid)


def test_taskqueue_compaction_keeps_order():
    q = TaskQueue()
    ts = [Task(inputs=()) for _ in range(300)]
    for t in ts:
        q.append(t)
    rng = random.Random(0)
    removed = set(rng.sample(range(300), 200))
    for i in removed:
        q.remove(ts[i].tid)                  # triggers compaction internally
    expect = [ts[i].tid for i in range(300) if i not in removed]
    assert [t.tid for t in q] == expect
    out = [q.popleft().tid for _ in range(len(q))]
    assert out == expect
    assert len(q) == 0 and not q


# ---------------- inverted score map ---------------------------------------

def _mcu(n_exec=3):
    d = Dispatcher(DispatchPolicy.MAX_COMPUTE_UTIL)
    for i in range(n_exec):
        d.executor_joined(f"e{i}", now=0.0)
    return d


def _scores_reference(d: Dispatcher, eid: str) -> dict[str, int]:
    """What the inverted map must equal: fresh index lookups per queued task."""
    out = {}
    for t in d.queue:
        score = 0
        for oid in t.inputs:
            if eid in d.index.lookup(oid):
                score += d.sizes.get(oid, 1)
        if score > 0:
            out[t.tid] = score
    return out


def test_exec_scores_follow_index_updates():
    d = _mcu()
    d.sizes.update({"a": 100, "b": 30, "c": 7})
    d.index.insert("a", "e0")
    t1, t2 = Task(inputs=("a", "b")), Task(inputs=("b", "c"))
    d.submit([t1, t2], 0.0)
    assert d._exec_scores.get("e0", {}) == {t1.tid: 100}
    # a cache insertion lands on e1 -> both waiters rescored
    d.apply_index_updates([IndexUpdate("e1", added=("b",))])
    assert d._exec_scores.get("e1", {}) == {t1.tid: 30, t2.tid: 30}
    # eviction removes it again
    d.apply_index_updates([IndexUpdate("e1", removed=("b",))])
    assert d._exec_scores.get("e1", {}) == {}
    for eid in ("e0", "e1", "e2"):
        assert d._exec_scores.get(eid, {}) == _scores_reference(d, eid)


def test_exec_scores_purged_on_executor_loss_and_dispatch():
    d = _mcu()
    d.sizes["a"] = 50
    d.index.insert("a", "e1")
    t = Task(inputs=("a",))
    blockers = [Task(inputs=()) for _ in range(3)]
    d.submit(blockers, 0.0)
    d.next_dispatches(0.0)                   # all executors now busy
    d.submit([t], 0.0)
    assert d._exec_scores["e1"] == {t.tid: 50}
    d.executor_left("e1", 1.0, failed=True)
    assert "e1" not in d._exec_scores
    assert d._hint_cache[t.tid] == {}        # e1 scrubbed from hints
    d.task_finished(blockers[0], 1.0)
    out = d.next_dispatches(1.0)
    # e1's requeued blocker went to the queue front; t follows once the
    # next executor frees up
    assert [o.task.tid for o in out] == [blockers[1].tid]
    d.task_finished(blockers[1], 2.0)
    out = d.next_dispatches(2.0)
    assert [o.task.tid for o in out] == [t.tid]
    assert t.tid not in d._hint_cache        # dequeued -> forgotten


def test_mcu_dispatch_equals_reference_scan():
    """Random churn: the incremental MCU picks the same executor/task pairs
    a fresh window-rescan implementation would."""
    rng = random.Random(3)
    d = _mcu(n_exec=4)
    oids = [f"o{i}" for i in range(20)]
    for oid in oids:
        d.sizes[oid] = rng.randrange(1, 100)
        for eid in rng.sample(["e0", "e1", "e2", "e3"], rng.randrange(0, 3)):
            d.index.insert(oid, eid)
    tasks = [Task(inputs=tuple(rng.sample(oids, rng.randrange(1, 3))))
             for _ in range(30)]
    d.submit(tasks, 0.0)
    done = []
    now = 0.0
    while len(done) < len(tasks):
        # reference expectation for the next dispatch round
        out = d.next_dispatches(now)
        assert out, "dispatcher stalled"
        for disp in out:
            # executor must be the window-max for its position in avail order
            done.append(disp.task)
            # churn the index between rounds
            if rng.random() < 0.5:
                oid = rng.choice(oids)
                d.apply_index_updates([IndexUpdate(
                    rng.choice(["e0", "e1", "e2", "e3"]),
                    added=(oid,) if rng.random() < 0.7 else (),
                    removed=(oid,) if rng.random() >= 0.7 else ())])
        for disp in out:
            d.task_finished(disp.task, now + 1.0)
        now += 1.0
        for eid in ("e0", "e1", "e2", "e3"):
            assert d._exec_scores.get(eid, {}) == _scores_reference(d, eid)
    assert len(d.completed) == 30


def test_cancelled_queued_twin_is_dequeued_not_executed():
    """If the original finishes while its speculative twin is still waiting
    in the queue, the twin must be removed, not run to completion later."""
    d = Dispatcher(DispatchPolicy.FIRST_AVAILABLE, speculation_factor=2.0,
                   min_completions_for_speculation=1)
    d.executor_joined("e0", 0.0)
    slow = Task(inputs=())
    d.submit([slow], 0.0)
    d.next_dispatches(0.0)                   # e0 busy with the original
    twin = d.make_twin(slow, 5.0)            # twin queued, no free executor
    assert twin.tid in d.queue
    cancel = d.task_finished(slow, 6.0)      # original wins
    assert cancel == twin.tid
    assert twin.tid not in d.queue           # dequeued, not left to run
    assert d.next_dispatches(6.0) == []
    assert len(d.completed) == 1             # counted exactly once


def test_twin_reverse_map():
    d = Dispatcher(DispatchPolicy.FIRST_AVAILABLE, speculation_factor=2.0,
                   min_completions_for_speculation=1)
    d.executor_joined("e0", 0.0)
    d.executor_joined("e1", 0.0)
    slow = Task(inputs=())
    d.submit([slow], 0.0)
    d.next_dispatches(0.0)
    twin = d.make_twin(slow, 5.0)
    assert d.twin_of(slow.tid) == twin.tid
    d.next_dispatches(5.0)
    cancel = d.task_finished(slow, 6.0)      # original wins
    assert cancel == twin.tid
    assert d.twin_of(slow.tid) is None


# ---------------- LFU heap -------------------------------------------------

def _reference_lfu_victim(cache: ExecutorCache):
    cands = [o for o in cache._entries if o not in cache._pinned]
    if not cands:
        return None
    return min(cands, key=lambda o: (cache._freq.get(o, 0), cache._order[o]))


def test_lfu_heap_matches_reference_under_churn():
    rng = random.Random(1)
    cache = ExecutorCache(10_000, EvictionPolicy.LFU)
    for step in range(2000):
        r = rng.random()
        if r < 0.5:
            oid = f"x{rng.randrange(60)}"
            if oid in cache:
                cache.get(oid)               # bump freq
            else:
                # check the victim the heap WOULD pick before inserting
                if cache.used_bytes + 500 > cache.capacity_bytes:
                    assert cache._pick_victim() == _reference_lfu_victim(cache)
                cache.put(DataObject(oid, 500))
        elif r < 0.6 and len(cache):
            oid = rng.choice(list(cache.contents()))
            cache.pin(oid)
        elif r < 0.7:
            for oid in list(cache._pinned):
                cache.unpin(oid)
        elif len(cache):
            assert cache._pick_victim() == _reference_lfu_victim(cache)
    assert cache.used_bytes <= cache.capacity_bytes
    assert cache.used_bytes == sum(cache._entries.values())


def test_random_eviction_only_unpinned_and_bounded():
    cache = ExecutorCache(1000, EvictionPolicy.RANDOM, seed=5)
    for i in range(10):
        cache.put(DataObject(f"r{i}", 100))
    cache.pin("r3")
    cache.pin("r7")
    for i in range(10, 40):
        cache.put(DataObject(f"r{i}", 100))
        assert "r3" in cache and "r7" in cache      # pinned survive
        assert cache.used_bytes <= cache.capacity_bytes


def test_random_eviction_all_pinned_rejects():
    cache = ExecutorCache(300, EvictionPolicy.RANDOM)
    for i in range(3):
        cache.put(DataObject(f"p{i}", 100))
        cache.pin(f"p{i}")
    before = cache.contents()
    assert cache.put(DataObject("q", 100)) == []
    assert cache.contents() == before and cache.stats.rejected == 1


# ---------------- ShardedIndex counters ------------------------------------

def test_sharded_index_counters_aggregate():
    si = ShardedIndex(n_shards=4)
    li = LocationIndex()
    for i in range(100):
        si.insert(f"o{i}", f"e{i % 3}")
        li.insert(f"o{i}", f"e{i % 3}")
    for i in range(50):
        si.lookup(f"o{i}")
        li.lookup(f"o{i}")
    for i in range(20):
        si.remove(f"o{i}", f"e{i % 3}")
        li.remove(f"o{i}", f"e{i % 3}")
    assert (si.n_inserts, si.n_lookups, si.n_removes) == \
           (li.n_inserts, li.n_lookups, li.n_removes) == (100, 50, 20)
    t = si.time_ops(2000)
    assert t["insert_s"] > 0 and t["lookup_s"] > 0

"""Diffusion data pipeline + train loop + checkpoint fault tolerance."""
import pathlib

import jax
import numpy as np
import pytest

from repro.core.policies import DispatchPolicy
from repro.data.dataset import ShardSpec
from repro.data.pipeline import DiffusionDataPipeline, PipelineConfig
from repro.models.config import LayerSpec, ModelConfig
from repro.train import CheckpointManager, adamw, train

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=256,
                   head_dim=8)


def _pipeline(n_steps_worth=8, seed=0):
    cfg = PipelineConfig(global_batch=4, seq_len=32, n_hosts=3,
                         policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                         host_cache_bytes=1 << 24, seed=seed)
    spec = ShardSpec(n_shards=4, tokens_per_shard=4096, vocab_size=256,
                     seed=seed)
    return DiffusionDataPipeline(cfg, spec)


def test_pipeline_shapes_and_determinism():
    p1, p2 = _pipeline(seed=3), _pipeline(seed=3)
    try:
        b1 = [b for _, b in p1.batches(0, 4)]
        b2 = [b for _, b in p2.batches(0, 4)]
        for a, b in zip(b1, b2):
            assert a.shape == (4, 33)
            np.testing.assert_array_equal(a, b)   # bitwise-replayable
    finally:
        p1.close(); p2.close()


def test_pipeline_second_epoch_hits_caches():
    """The paper's locality economics in the training pipeline: epoch 2
    re-reads come from executor caches, not the store."""
    p = _pipeline()
    try:
        for _ in p.batches(0, 8):      # 2 epochs over 4 shards
            pass
        s = p.stats()
        assert s["store_reads"] <= 4 + 1          # ~one cold read per shard
        assert s["global_hit_ratio"] >= 0.4       # epoch 2 fully cached
    finally:
        p.close()


def test_train_loss_decreases_and_ledger_populated():
    p = _pipeline()
    try:
        from repro.train import adamw
        res = train(TINY, p, n_steps=20, ckpt_dir=None, log=lambda s: None,
                    optimizer=adamw(5e-3, warmup=2, total=20))
    finally:
        p.close()
    assert res.steps_run == 20
    import numpy as _np
    # window means: single-step losses are noisy at batch 4
    assert _np.mean(res.losses[-5:]) < _np.mean(res.losses[:5])
    assert res.pipeline_stats["bytes_store"] > 0


def test_checkpoint_restart_reproduces_uninterrupted_run(tmp_path):
    """Kill-and-restart fault tolerance: losses after resume match the
    uninterrupted run bitwise (schedule is a pure function of step)."""
    def run(steps, ckpt):
        p = _pipeline(seed=1)
        try:
            return train(TINY, p, n_steps=steps, ckpt_dir=str(ckpt),
                         ckpt_every=4, seed=7, log=lambda s: None)
        finally:
            p.close()

    full = run(8, tmp_path / "a")
    part = run(4, tmp_path / "b")        # "crash" after 4 (checkpointed)
    resumed = run(8, tmp_path / "b")     # restart picks up at step 4
    assert resumed.resumed_from == 4
    np.testing.assert_allclose(resumed.losses, full.losses[4:], rtol=1e-5)


def test_checkpoint_atomicity_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": np.arange(4.0), "b": {"c": np.ones((2, 2), np.float32)}}
    for s in (1, 2, 3):
        mgr.save(s, tree)
    assert mgr.steps() == [2, 3]                    # retention
    # a torn save (tmp dir without manifest rename) must be invisible
    (tmp_path / "step_9.tmp").mkdir()
    assert mgr.steps() == [2, 3]
    step, restored = mgr.restore_latest(tree)
    assert step == 3
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_elastic_pipeline_host_failure_mid_training():
    """Remove a pipeline host mid-run: training continues, no data lost."""
    p = _pipeline()
    try:
        got = []
        it = p.batches(0, 6)
        for i, (step, b) in enumerate(it):
            got.append(b)
            if i == 1:
                p.rt.remove_executor("w0", failed=True)
        assert len(got) == 6
        assert all(b.shape == (4, 33) for b in got)
    finally:
        p.close()

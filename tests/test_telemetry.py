"""repro.obs telemetry plane (DESIGN.md §13): registry/histogram math,
snapshot merge algebra, health rules, sink round-trips, sim virtual-time
sampling, and fleet stats-frame parity.

The two locked contracts:

* **merge == union** -- folding two registries' snapshots is EXACTLY the
  snapshot of one registry that observed both streams (counters, gauges,
  and histogram buckets all add), which is what makes per-host stats
  frames foldable into one cluster view;
* **free when off** -- with ``observe.metrics`` unset the engines hold
  ``metrics = None`` and the run's scheduling outcome is identical to a
  metrics-on run (sim clocks may extend to the last sample tick, exactly
  like provisioner ticks, so clock-derived fields are excluded).
"""
from __future__ import annotations

import dataclasses
import importlib.util
import json
import math
import random
import threading
from bisect import bisect_left
from pathlib import Path

import pytest

from repro.core import DataObject
from repro.experiments import (ClusterSpec, ExperimentSpec, ObserveSpec,
                               RunReport, RuntimeEngine, SimEngine,
                               WorkloadSpec)
from repro.obs import (ClusterView, HealthMonitor, MetricsRegistry,
                       Telemetry, TelemetryServer, fetch_telemetry,
                       merge_snapshots, quantile, read_metrics)
from repro.obs.metrics import LATENCY_BOUNDS_S
from repro.workloads import TaskEvent, Workload

# --------------------------------------------------------------------------
# histogram bucket math
# --------------------------------------------------------------------------

class TestHistogram:
    def test_bucket_placement_boundaries_inclusive_upper(self):
        """counts[i] holds bounds[i-1] < v <= bounds[i]; trailing bucket
        is overflow."""
        r = MetricsRegistry()
        for v in (0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 9.0):
            r.observe("h", v, bounds=(1.0, 2.0, 4.0))
        h = r.snapshot()["histograms"]["h"]
        assert h["bounds"] == [1.0, 2.0, 4.0]
        assert h["counts"] == [2, 2, 2, 1]   # (.5,1] x2, (1,2] x2, ...
        assert h["count"] == 7
        assert h["sum"] == pytest.approx(0.5 + 1.0 + 1.5 + 2.0 + 3.0
                                         + 4.0 + 9.0)

    def test_invalid_bounds_rejected(self):
        r = MetricsRegistry()
        with pytest.raises(ValueError, match="sorted"):
            r.observe("h", 1.0, bounds=(2.0, 1.0))
        with pytest.raises(ValueError, match="non-empty"):
            r.observe("h", 1.0, bounds=())

    def test_quantile_edges(self):
        r = MetricsRegistry()
        for v in (0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 9.0):
            r.observe("h", v, bounds=(1.0, 2.0, 4.0))
        h = r.snapshot()["histograms"]["h"]
        assert quantile(h, 0.5) == 2.0
        assert quantile(h, 1.0) == 4.0       # overflow clamps to top bound
        assert quantile({"bounds": [1.0], "counts": [0, 0],
                         "sum": 0.0, "count": 0}, 0.5) == 0.0
        with pytest.raises(ValueError, match="q must be"):
            quantile(h, 1.5)

    def test_quantile_within_bucket_resolution(self):
        """For any q, the estimate is the upper bound of the bucket holding
        the true q-quantile value: prev_bound < v_true <= estimate."""
        rng = random.Random(0)
        vals = [rng.uniform(1e-5, 0.9) for _ in range(500)]
        r = MetricsRegistry()
        for v in vals:
            r.observe("lat", v)              # default LATENCY_BOUNDS_S
        h = r.snapshot()["histograms"]["lat"]
        svals = sorted(vals)
        for q in (0.01, 0.1, 0.5, 0.9, 0.99):
            est = quantile(h, q)
            v_true = svals[max(math.ceil(q * len(svals)) - 1, 0)]
            i = list(h["bounds"]).index(est)
            lo = h["bounds"][i - 1] if i else 0.0
            assert lo < v_true <= est, (q, v_true, est)


# --------------------------------------------------------------------------
# registry + merge algebra
# --------------------------------------------------------------------------

class TestRegistry:
    def test_counters_and_gauges(self):
        r = MetricsRegistry()
        r.inc("c")
        r.inc("c", 4)
        r.gauge_set("g", 2.5)
        r.gauge_set("g", 7.0)                # last write wins
        assert r.counter("c") == 5
        assert r.gauge("g") == 7.0
        assert r.counter("absent") == 0 and r.gauge("absent") == 0.0

    def test_snapshot_is_independent(self):
        r = MetricsRegistry()
        r.inc("c")
        snap = r.snapshot()
        r.inc("c", 9)
        assert snap["counters"]["c"] == 1    # not a live view

    def test_merge_equals_observing_union(self):
        """The fleet-fold contract: merging per-source snapshots == one
        registry that observed every stream (gauges are absolute
        per-source totals, so they add too)."""
        rng = random.Random(2)
        ra, rb, runion = (MetricsRegistry() for _ in range(3))
        for i in range(200):
            reg = ra if i % 2 else rb
            reg.inc("tasks")
            runion.inc("tasks")
            v = rng.uniform(1e-5, 0.5)
            reg.observe("lat", v)
            runion.observe("lat", v)
        ra.gauge_set("cache.bytes", 300)
        rb.gauge_set("cache.bytes", 500)
        runion.gauge_set("cache.bytes", 800)
        merged = merge_snapshots(ra.snapshot(), rb.snapshot())
        assert merged == runion.snapshot()

    def test_merge_rejects_bounds_mismatch(self):
        ra, rb = MetricsRegistry(), MetricsRegistry()
        ra.observe("h", 0.5, bounds=(1.0, 2.0))
        rb.observe("h", 0.5, bounds=(1.0, 4.0))
        with pytest.raises(ValueError, match="bounds mismatch"):
            merge_snapshots(ra.snapshot(), rb.snapshot())

    def test_counters_monotone_under_concurrent_emit(self):
        r = MetricsRegistry()
        seen: list[int] = []
        stop = threading.Event()

        def reader():
            while not stop.is_set():
                seen.append(r.counter("c"))

        def writer():
            for _ in range(4000):
                r.inc("c")
                r.observe("lat", 1e-4)

        rt = threading.Thread(target=reader)
        ws = [threading.Thread(target=writer) for _ in range(4)]
        rt.start()
        for w in ws:
            w.start()
        for w in ws:
            w.join()
        stop.set()
        rt.join()
        assert r.counter("c") == 16000       # no lost increments
        assert r.snapshot()["histograms"]["lat"]["count"] == 16000
        assert seen == sorted(seen)          # monotone from any reader


# --------------------------------------------------------------------------
# Telemetry bundle: series, sink round-trip, merged_last
# --------------------------------------------------------------------------

class TestTelemetryBundle:
    def test_sink_roundtrip(self, tmp_path):
        path = tmp_path / "metrics.jsonl"
        tel = Telemetry(interval_s=0.5, sink_path=str(path))
        tel.registry.inc("sched.tasks_completed", 3)
        tel.record_sample(0.5)
        tel.registry.inc("sched.tasks_completed", 2)
        tel.record_sample(1.0, per_host={
            "h0": {"metrics": {"gauges": {"cache.bytes": 11}}, "age_s": 0.1}})
        tel.close()
        header, samples, health = read_metrics(path)
        assert header["interval_s"] == 0.5
        assert [s["t"] for s in samples] == [0.5, 1.0]
        assert samples == list(tel.series)
        assert health == []
        merged = tel.merged_last()
        assert merged["counters"]["sched.tasks_completed"] == 5
        assert merged["gauges"]["cache.bytes"] == 11

    def test_read_metrics_rejects_foreign_files(self, tmp_path):
        p = tmp_path / "x.jsonl"
        p.write_text(json.dumps({"kind": "header"}) + "\n")
        with pytest.raises(ValueError, match="not a metrics sink"):
            read_metrics(p)
        p.write_text("")
        with pytest.raises(ValueError, match="empty file"):
            read_metrics(p)

    def test_series_capacity_bounds_memory(self):
        tel = Telemetry(interval_s=0.1, series_capacity=3)
        for i in range(10):
            tel.record_sample(float(i))
        assert [s["t"] for s in tel.series] == [7.0, 8.0, 9.0]

    def test_interval_validated(self):
        with pytest.raises(ValueError, match="interval_s"):
            Telemetry(interval_s=0.0)


class TestClusterView:
    def test_update_merge_drop(self):
        cv = ClusterView()
        cv.update("h0", {"metrics": {"counters": {}, "histograms": {},
                                     "gauges": {"cache.bytes": 10}}})
        cv.update("h1", {"metrics": {"counters": {}, "histograms": {},
                                     "gauges": {"cache.bytes": 32}}})
        assert cv.merged()["gauges"]["cache.bytes"] == 42
        seqs = cv.seqs()
        assert seqs["h1"] > seqs["h0"] > 0    # strictly ordered arrivals
        per = cv.per_host()
        assert set(per) == {"h0", "h1"}
        assert all(d["age_s"] >= 0 for d in per.values())
        cv.drop("h0")
        assert set(cv.seqs()) == {"h1"}

    def test_update_advances_seq_for_barrier(self):
        cv = ClusterView()
        cv.update("h0", {"metrics": {}})
        before = cv.seqs()["h0"]
        cv.update("h0", {"metrics": {}})
        assert cv.seqs()["h0"] > before       # request_stats' wait condition


# --------------------------------------------------------------------------
# health rules (edge-triggered)
# --------------------------------------------------------------------------

def _sample(t, depth=0, readmits=0, dropped=0, hosts=None):
    rec = {"kind": "metrics", "t": t,
           "metrics": {"counters": {}, "histograms": {},
                       "gauges": {"sched.queue_depth": depth,
                                  "cache.readmits": readmits,
                                  "obs.recorder_dropped": dropped}}}
    if hosts is not None:
        rec["hosts"] = hosts
    return rec


class TestHealthMonitor:
    def test_backlog_growth_fires_once_then_rearms(self):
        hm = HealthMonitor(window=3, backlog_min=8)
        assert hm.observe(_sample(0.0, depth=1)) == []
        assert hm.observe(_sample(0.5, depth=5)) == []
        evs = hm.observe(_sample(1.0, depth=10))   # strictly rising, >= 8
        assert [e["rule"] for e in evs] == ["backlog_growth"]
        assert evs[0]["severity"] == "warn" and evs[0]["t"] == 1.0
        # still rising: suppressed while active
        assert hm.observe(_sample(1.5, depth=12)) == []
        # clears (flat), then a fresh strict rise re-fires
        assert hm.observe(_sample(2.0, depth=12)) == []
        for t, d in ((2.5, 13), (3.0, 14)):
            evs = hm.observe(_sample(t, depth=d))
        assert [e["rule"] for e in evs] == ["backlog_growth"]

    def test_backlog_needs_minimum_depth(self):
        hm = HealthMonitor(window=3, backlog_min=8)
        for t, d in ((0.0, 1), (0.5, 2), (1.0, 3)):
            assert hm.observe(_sample(t, depth=d)) == []   # rising but tiny

    def test_cache_thrash_window_delta(self):
        hm = HealthMonitor(window=2, thrash_min=4)
        assert hm.observe(_sample(0.0, readmits=0)) == []
        evs = hm.observe(_sample(0.5, readmits=5))
        assert [e["rule"] for e in evs] == ["cache_thrash"]
        assert "5 re-admissions" in evs[0]["detail"]

    def test_recorder_drops_is_an_error(self):
        hm = HealthMonitor(window=2)
        hm.observe(_sample(0.0, dropped=0))
        evs = hm.observe(_sample(0.5, dropped=7))
        assert [(e["rule"], e["severity"]) for e in evs] == [
            ("recorder_drops", "error")]

    def test_stale_heartbeat_per_host(self):
        hm = HealthMonitor(window=2, stale_after_s=2.0)
        fresh = {"h0": {"metrics": {}, "age_s": 0.1}}
        stale = {"h0": {"metrics": {}, "age_s": 3.5}}
        assert hm.observe(_sample(0.0, hosts=fresh)) == []
        evs = hm.observe(_sample(0.5, hosts=stale))
        assert [(e["rule"], e["host"]) for e in evs] == [
            ("stale_heartbeat", "h0")]
        assert hm.observe(_sample(1.0, hosts=stale)) == []   # suppressed
        assert hm.observe(_sample(1.5, hosts=fresh)) == []   # re-armed
        assert [e["host"] for e in hm.observe(_sample(2.0, hosts=stale))] \
            == ["h0"]

    def test_health_events_reach_the_sink(self, tmp_path):
        path = tmp_path / "m.jsonl"
        tel = Telemetry(interval_s=0.1, sink_path=str(path),
                        health=HealthMonitor(window=2, thrash_min=1))
        tel.registry.gauge_set("cache.readmits", 0)
        tel.record_sample(0.1)
        tel.registry.gauge_set("cache.readmits", 3)
        tel.record_sample(0.2)
        tel.close()
        _, samples, health = read_metrics(path)
        assert len(samples) == 2
        assert [e["rule"] for e in health] == ["cache_thrash"]
        assert health == tel.health_events


# --------------------------------------------------------------------------
# ObserveSpec knobs
# --------------------------------------------------------------------------

class TestObserveSpecMetrics:
    def test_roundtrip(self):
        spec = ExperimentSpec(
            name="t", workload=_wspec(),
            observe=ObserveSpec(metrics=True, metrics_interval_s=0.1,
                                metrics_port=0))
        back = ExperimentSpec.from_dict(spec.to_dict())
        assert back == spec and back.observe.metrics_interval_s == 0.1

    def test_validation(self):
        with pytest.raises(ValueError, match="metrics_interval_s"):
            ObserveSpec(metrics=True, metrics_interval_s=0.0)
        with pytest.raises(ValueError, match="metrics_sink_path requires"):
            ObserveSpec(metrics_sink_path="/tmp/m.jsonl")
        with pytest.raises(ValueError, match="metrics_port requires"):
            ObserveSpec(metrics_port=0)


# --------------------------------------------------------------------------
# engine integration: sim virtual time, free-when-off, fleet parity
# --------------------------------------------------------------------------

def _wspec(n_tasks=30):
    return WorkloadSpec(
        name="tel",
        arrivals={"kind": "BatchArrivals", "at_s": 0.0},
        popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 2,
                    "corr": 1.0},
        n_tasks=n_tasks, n_objects=12, object_bytes=10_000, seed=7)


def _serial_workload(n_tasks=30):
    """Arrivals 1 s apart vs ~0 service: every placement decision is made
    against an all-idle pool, the regime where engines agree exactly."""
    rng = random.Random(7)
    objs = [DataObject(f"p.o{i}", 10_000) for i in range(12)]
    events = [TaskEvent(t=float(i), tid=f"p-{i}",
                        inputs=tuple(o.oid for o in rng.sample(objs, 2)),
                        outputs=(), compute_seconds=0.0,
                        store_metadata_ops=0)
              for i in range(n_tasks)]
    return Workload("tel", objs, events, spec=None)


def _spec(hosts, tph, *, metrics=True, interval=0.25, sink=None):
    return ExperimentSpec(
        name="telemetry-par",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=4),
        policy="max-compute-util",
        workload=_wspec(),
        observe=ObserveSpec(metrics=metrics, metrics_interval_s=interval,
                            metrics_sink_path=sink),
        seed=3, hosts=hosts, threads_per_host=tph)


#: scheduling-determined report fields: identical between metrics-on and
#: metrics-off runs of one spec.  Clock-derived fields (makespan, rates,
#: efficiency, executor_seconds) legitimately move when the sim's sampling
#: tick extends loop time, exactly like provisioner ticks do.
SCHED_FIELDS = ("n_tasks", "n_completed", "n_failed", "local_hits",
                "peer_hits", "store_reads", "local_hit_ratio",
                "cache_hit_ratio", "full_hit_tasks", "partial_hit_tasks",
                "zero_hit_tasks", "bytes_by_kind", "mean_inputs_per_task",
                "peak_executors")


class TestSimTelemetry:
    def test_virtual_time_sampling_and_final_snapshot(self):
        eng = SimEngine()
        try:
            eng.prepare(_spec(0, 1), workload=_serial_workload())
            rep = eng.run()
            series = list(eng.telemetry.series)
        finally:
            eng.shutdown()
        tel = rep.telemetry
        assert tel["n_samples"] == len(series) >= 2
        # every periodic tick lands on a multiple of the virtual interval
        for s in series[:-1]:
            ratio = s["t"] / 0.25
            assert abs(ratio - round(ratio)) < 1e-6, s["t"]
        final = tel["metrics"]
        assert final["counters"]["sched.tasks_submitted"] == 30
        assert final["counters"]["sched.tasks_completed"] == 30
        assert final["counters"]["sched.dispatches"] == 30
        assert final["gauges"]["sched.queue_depth"] == 0
        # byte gauges reconcile exactly with the report's ledger
        bk = rep.bytes_by_kind
        assert final["gauges"]["bw.bytes_local"] == bk.get("local", 0)
        assert final["gauges"]["bw.bytes_c2c"] == bk.get("c2c", 0)
        assert final["gauges"]["bw.bytes_store"] == bk.get("store_read", 0)
        assert (rep.local_hits + rep.peer_hits
                + rep.store_reads) == 60      # 30 tasks x 2 inputs
        assert tel["merged"] == final         # no hosts on the sim engine

    def test_metrics_off_is_free_and_identical(self):
        reps = {}
        for label, metrics in (("off", False), ("on", True)):
            eng = SimEngine()
            try:
                eng.prepare(_spec(0, 1, metrics=metrics),
                            workload=_serial_workload())
                reps[label] = eng.run()
                if not metrics:
                    assert eng.telemetry is None
                    assert eng.sim.metrics is None
                    assert eng.sim.dispatcher.metrics is None
            finally:
                eng.shutdown()
        assert reps["off"].telemetry == {}
        for f in SCHED_FIELDS:
            assert getattr(reps["off"], f) == getattr(reps["on"], f), f

    def test_sink_written_by_engine_run(self, tmp_path):
        sink = tmp_path / "sim.metrics.jsonl"
        eng = SimEngine()
        try:
            eng.prepare(_spec(0, 1, sink=str(sink)),
                        workload=_serial_workload(n_tasks=5))
            rep = eng.run()
        finally:
            eng.shutdown()
        header, samples, _ = read_metrics(sink)
        assert header["interval_s"] == 0.25
        assert len(samples) == rep.telemetry["n_samples"]
        assert samples[-1]["metrics"]["counters"][
            "sched.tasks_completed"] == 5


class TestFleetTelemetryParity:
    @pytest.fixture(scope="class")
    def runs(self):
        """Barrier-replay of one workload on the in-process runtime and a
        2-host fleet, metrics on both."""
        out = {}
        for label, hosts, tph in (("runtime", 0, 1), ("fleet", 2, 2)):
            eng = RuntimeEngine()
            try:
                eng.prepare(_spec(hosts, tph),
                            workload=_serial_workload())
                out[label] = eng.run(barrier_every=1, timeout=180.0)
            finally:
                eng.shutdown()
        return out

    def test_stats_frames_merged_match_single_process(self, runs):
        """The tentpole parity claim: per-host registries shipped as
        ``{"t":"stats"}`` frames and folded centrally read EXACTLY like the
        single-process registry observing the same (barrier-deterministic)
        run -- cache economics and byte totals, gauge for gauge."""
        rt = runs["runtime"].telemetry["metrics"]["gauges"]
        fl = runs["fleet"].telemetry["merged"]["gauges"]
        for g in ("cache.hits", "cache.misses", "cache.insertions",
                  "cache.bytes", "cache.evictions", "cache.readmits"):
            assert fl.get(g, 0) == rt.get(g, 0), g
        assert fl["host.tasks_done"] == 30

    def test_fleet_bytes_reconcile_with_ledger(self, runs):
        """Summed per-host bandwidth gauges == the run ledger's
        bytes_by_kind, exactly (the bench gate's 5%-window canary holds
        with zero gap under barrier replay)."""
        rep = runs["fleet"]
        fl = rep.telemetry["merged"]["gauges"]
        bk = rep.bytes_by_kind
        assert fl.get("bw.bytes_local", 0) == bk.get("local", 0)
        assert fl.get("bw.bytes_c2c", 0) == bk.get("c2c", 0)
        assert fl.get("bw.bytes_store", 0) == bk.get("store_read", 0)

    def test_fleet_summary_shape(self, runs):
        tel = runs["fleet"].telemetry
        assert set(tel["hosts"]) == {"h0", "h1"}
        for d in tel["hosts"].values():
            assert d["age_s"] >= 0.0
            assert d["metrics"]["gauges"]["host.executors"] == 2
        assert tel["n_samples"] >= 1
        c = tel["metrics"]["counters"]
        assert c["sched.tasks_completed"] == 30
        assert c.get("wire.leases", 0) >= 0   # serial replay: likely 0

    def test_scheduling_parity_with_metrics_on(self, runs):
        for f in SCHED_FIELDS:
            assert getattr(runs["runtime"], f) == getattr(runs["fleet"], f), f


# --------------------------------------------------------------------------
# RunReport surface + endpoint + monitor
# --------------------------------------------------------------------------

def test_report_telemetry_roundtrips_and_diff_ignores():
    eng = SimEngine()
    try:
        eng.prepare(_spec(0, 1), workload=_serial_workload(n_tasks=5))
        rep = eng.run()
    finally:
        eng.shutdown()
    assert rep.telemetry["n_samples"] >= 1
    assert RunReport.from_dict(json.loads(
        json.dumps(rep.as_dict()))) == rep
    stripped = dataclasses.replace(rep, telemetry={})
    assert rep.diff(stripped) == {}           # telemetry never breaks diffs
    d = rep.as_dict()
    del d["telemetry"]                        # pre-PR-10 files stay readable
    assert RunReport.from_dict(d).telemetry == {}


def test_telemetry_server_roundtrip():
    tel = Telemetry(interval_s=0.1)
    srv = TelemetryServer(tel, port=0)
    try:
        rec = fetch_telemetry("127.0.0.1", srv.port)
        assert rec == {"kind": "telemetry", "sample": None, "health": []}
        tel.registry.inc("sched.tasks_completed", 4)
        tel.record_sample(1.0)
        rec = fetch_telemetry("127.0.0.1", srv.port)
        assert rec["sample"]["metrics"]["counters"][
            "sched.tasks_completed"] == 4
    finally:
        srv.close()


def _load_monitor():
    path = Path(__file__).resolve().parents[1] / "tools" / "monitor.py"
    mspec = importlib.util.spec_from_file_location("monitor", path)
    mod = importlib.util.module_from_spec(mspec)
    mspec.loader.exec_module(mod)
    return mod


def test_monitor_render_smoke():
    mon = _load_monitor()
    prev = {"t": 1.0, "metrics": {"gauges": {}}, "hosts": {
        "h0": {"metrics": {"gauges": {"bw.bytes_local": 0}}, "age_s": 0.0}}}
    sample = {
        "t": 2.0,
        "metrics": {"counters": {"sched.tasks_submitted": 9,
                                 "sched.tasks_completed": 7},
                    "gauges": {"sched.queue_depth": 2, "pool.size": 4}},
        "hosts": {"h0": {"metrics": {"gauges": {
            "cache.bytes": 2_000_000, "host.tasks_done": 7,
            "bw.bytes_local": 5_000_000}}, "age_s": 0.12}},
    }
    health = [{"kind": "health", "t": 1.5, "rule": "backlog_growth",
               "severity": "warn", "host": None, "detail": "q 1 -> 9"}]
    frame = mon.render(sample, health, prev)
    assert "queue=     2" in frame
    assert "h0" in frame and "TOTAL" in frame
    assert "5.0" in frame                     # 5 MB over 1 s
    assert "backlog_growth" in frame
    # no hosts: falls back to central cache/bw gauges
    solo = mon.render({"t": 2.0, "metrics": sample["metrics"]}, [])
    assert "cache_MB=" in frame or "cache_MB=" in solo

"""DAG workloads end to end (PR 8, DESIGN.md §11): generators and
validation, trace v4, the dispatcher's ready-set, producer-placement
scoring, engine execution, obs dep-wait spans, and fleet failure
semantics mid-pipeline.

The load-bearing contracts:

  * Workload validation rejects malformed DAGs (duplicate produced oids,
    catalog collisions, unknown/self/cyclic deps) at construction;
  * dep-free workloads stay bit-identical everywhere: record() still
    writes v2, the score_outputs knob is inert, and both slowdown bases
    equal the classic avg_slowdown;
  * held tasks are invisible to every dispatch path until their last
    producer completes, and a producer's terminal failure cascades to
    its (transitive) dependents exactly once;
  * producer placement: a released task's score includes its producers'
    output bytes, so it lands where those outputs were just written;
  * SIGKILLing a fleet host that is executing a producer re-queues the
    producer, keeps its downstream tasks held (never dispatched with
    unmet deps, never lost or doubled), and conserves the ledger.
"""
from __future__ import annotations

import io
import json
import time

import pytest

from repro.core import DataObject, DiffusionRuntime, Task
from repro.core.objects import TaskState
from repro.core.policies import DispatchPolicy
from repro.core.scheduler import Dispatcher
from repro.core import ANL_UC
from repro.core.simulator import DiffusionSim, SimConfig
from repro.experiments import (ClusterSpec, ExperimentSpec, ObserveSpec,
                               RuntimeEngine, SimEngine, WorkloadSpec,
                               run_experiment)
from repro.fleet import FleetRuntime
from repro.workloads import (MetricsCollector, PoissonArrivals, TaskEvent,
                             Workload, ZipfPopularity, all_pairs, build_dag,
                             events_fingerprint, generate, record, record_v3,
                             reduce_tree, replay, stacking_pyramid)


# --------------------------------------------------------------------------
# generators
# --------------------------------------------------------------------------

class TestGenerators:
    def test_all_pairs_shape(self):
        wl = all_pairs("ap", n_objects=3, dt=0.5)
        assert len(wl) == 3 + 9 and wl.has_deps()
        by_tid = {e.tid: e for e in wl.events}
        # off-diagonal pair reads both features, depends on both extracts
        p = by_tid["ap-p0x2"]
        assert p.inputs == ("ap.f0", "ap.f2")
        assert p.deps == ("ap-ext0", "ap-ext2")
        # diagonal pair reads ONE feature once (no double-counted input)
        d = by_tid["ap-p1x1"]
        assert d.inputs == ("ap.f1",) and d.deps == ("ap-ext1",)
        # topological arrival order with dt spacing
        ts = [e.t for e in wl.events]
        assert ts == sorted(ts) and ts[1] - ts[0] == 0.5

    def test_reduce_tree_shape(self):
        wl = reduce_tree("rt", n_leaves=5, fanin=2)
        # 5 leaves -> 3 -> 2 -> 1: 11 tasks, root reads the level-2 partials
        assert len(wl) == 11
        root = wl.events[-1]
        assert root.tid == "rt-r3.0"
        assert root.inputs == ("rt.r2.0", "rt.r2.1")
        assert root.deps == ("rt-r2.0", "rt-r2.1")
        assert not wl.events[0].deps          # leaves read the catalog

    def test_stacking_pyramid_shape(self):
        wl = stacking_pyramid("sp", n_groups=3, group_size=2)
        assert len(wl) == 4 and len(wl.objects) == 6
        mosaic = wl.events[-1]
        assert mosaic.inputs == ("sp.stack0", "sp.stack1", "sp.stack2")
        assert mosaic.deps == ("sp-stack0", "sp-stack1", "sp-stack2")

    def test_spec_round_trips_as_binding(self):
        wl = all_pairs("ap", n_objects=4, feature_bytes=123, dt=0.25)
        again = build_dag(wl.spec)
        assert events_fingerprint(again) == events_fingerprint(wl)
        renamed = build_dag(wl.spec, name="zz")     # overrides win
        assert renamed.events[0].tid == "zz-ext0"
        with pytest.raises(ValueError, match="unknown dag kind"):
            build_dag({"kind": "nope"})


# --------------------------------------------------------------------------
# workload validation (satellite: produced-oid collisions)
# --------------------------------------------------------------------------

def _ev(tid, inputs=(), outputs=(), deps=(), t=0.0):
    return TaskEvent(t=t, tid=tid, inputs=tuple(inputs),
                     outputs=tuple(outputs), deps=tuple(deps))


class TestValidation:
    CAT = (DataObject("a", 10),)

    def test_duplicate_produced_oid_rejected(self):
        evs = [_ev("t0", outputs=(("x", 1),)), _ev("t1", outputs=(("x", 1),))]
        with pytest.raises(ValueError, match="both produce 'x'"):
            Workload("w", self.CAT, evs)

    def test_catalog_collision_rejected(self):
        with pytest.raises(ValueError, match="collides with a catalog"):
            Workload("w", self.CAT, [_ev("t0", outputs=(("a", 1),))])

    def test_duplicate_tid_rejected(self):
        with pytest.raises(ValueError, match="duplicate task id"):
            Workload("w", self.CAT, [_ev("t0"), _ev("t0")])

    def test_unknown_dep_rejected(self):
        with pytest.raises(ValueError, match="unknown task 'ghost'"):
            Workload("w", self.CAT, [_ev("t0", deps=("ghost",))])

    def test_self_dep_rejected(self):
        with pytest.raises(ValueError, match="depends on itself"):
            Workload("w", self.CAT, [_ev("t0", deps=("t0",))])

    def test_cycle_rejected(self):
        evs = [_ev("t0", deps=("t1",)), _ev("t1", deps=("t0",))]
        with pytest.raises(ValueError, match="dependency cycle"):
            Workload("w", self.CAT, evs)

    def test_produced_oid_is_a_known_input(self):
        # reading another task's output is legal; reading nothing isn't
        evs = [_ev("t0", outputs=(("x", 1),)),
               _ev("t1", inputs=("x",), deps=("t0",))]
        Workload("w", self.CAT, evs)
        with pytest.raises(ValueError, match="unknown objects"):
            Workload("w", self.CAT, [_ev("t0", inputs=("y",))])


# --------------------------------------------------------------------------
# trace v4
# --------------------------------------------------------------------------

class TestTraceV4:
    def test_dep_free_record_stays_v2(self):
        wl = generate("flat", PoissonArrivals(10.0), ZipfPopularity(),
                      n_tasks=20, n_objects=8, object_bytes=100, seed=3)
        buf = io.StringIO()
        record(wl, buf)
        lines = [json.loads(ln) for ln in buf.getvalue().splitlines()]
        assert lines[0]["version"] == 2 and "n_outcomes" not in lines[0]
        assert all("deps" not in r for r in lines if r["kind"] == "task")
        buf.seek(0)
        assert events_fingerprint(replay(buf)) == events_fingerprint(wl)

    def test_dag_records_v4_and_round_trips(self):
        wl = all_pairs("ap", n_objects=3, dt=0.125)
        buf = io.StringIO()
        record(wl, buf)
        lines = [json.loads(ln) for ln in buf.getvalue().splitlines()]
        assert lines[0]["version"] == 4 and lines[0]["n_outcomes"] == 0
        tasks = [r for r in lines if r["kind"] == "task"]
        assert tasks[-1]["deps"] == ["ap-ext2"]     # p2x2's single dep
        # produced-feature inputs carry the PRODUCING row's size
        pair_inputs = dict(tasks[-1]["inputs"])
        assert pair_inputs["ap.f2"] == wl.events[2].outputs[0][1]
        buf.seek(0)
        again = replay(buf)
        assert events_fingerprint(again) == events_fingerprint(wl)
        assert again.has_deps()

    def test_record_v3_with_deps_writes_v4(self):
        wl = reduce_tree("rt", n_leaves=2)
        buf = io.StringIO()
        record_v3(wl, buf, outcomes=[])
        header = json.loads(buf.getvalue().splitlines()[0])
        assert header["version"] == 4 and header["n_outcomes"] == 0
        buf.seek(0)
        assert events_fingerprint(replay(buf)) == events_fingerprint(wl)


# --------------------------------------------------------------------------
# dispatcher ready-set
# --------------------------------------------------------------------------

def _mkdisp(policy=DispatchPolicy.FIRST_AVAILABLE, n_exec=2):
    d = Dispatcher(policy)
    for i in range(n_exec):
        d.executor_joined(f"e{i}", now=0.0)
    return d


def _pipeline(n=1):
    """n producers (each with one output) + one consumer depending on all."""
    prods = [Task(tid=f"p{i}", inputs=(),
                  outputs=(DataObject(f"x{i}", 10),)) for i in range(n)]
    cons = Task(tid="c", inputs=tuple(f"x{i}" for i in range(n)),
                deps=tuple(f"p{i}" for i in range(n)))
    return prods, cons


class TestReadySet:
    def test_hold_then_release_stamps_ready_time(self):
        d = _mkdisp(n_exec=1)
        (p,), c = _pipeline()
        d.submit([p, c], now=0.0)
        assert d.held_len == 1 and d.queue_len == 1   # c is NOT demand
        out = d.next_dispatches(0.0)
        assert [o.task.tid for o in out] == ["p0"]
        assert d.next_dispatches(0.0) == []           # c still unreachable
        d.task_finished(p, now=2.5)
        assert d.held_len == 0 and c.ready_time == 2.5
        assert p.ready_time == p.submit_time == 0.0   # dep-free: == submit
        nxt = d.next_dispatches(2.5)
        assert [o.task.tid for o in nxt] == ["c"]

    def test_release_waits_for_all_deps(self):
        d = _mkdisp(n_exec=2)
        prods, c = _pipeline(n=2)
        d.submit(prods + [c], now=0.0)
        for o in d.next_dispatches(0.0):
            pass
        d.task_finished(prods[0], 1.0)
        assert d.held_len == 1                         # one dep still unmet
        d.task_finished(prods[1], 2.0)
        assert d.held_len == 0 and c.ready_time == 2.0

    def test_submit_after_producer_done_is_not_held(self):
        d = _mkdisp(n_exec=1)
        (p,), c = _pipeline()
        d.submit([p], 0.0)
        d.next_dispatches(0.0)
        d.task_finished(p, 1.0)
        d.submit([c], 2.0)
        assert d.held_len == 0 and c.ready_time == 2.0
        assert [o.task.tid for o in d.next_dispatches(2.0)] == ["c"]

    def test_producer_failure_cascades_transitively_once(self):
        d = _mkdisp(n_exec=1)
        p = Task(tid="p", inputs=(), outputs=(DataObject("x", 10),),
                 max_attempts=1)
        mid = Task(tid="m", inputs=("x",), deps=("p",),
                   outputs=(DataObject("y", 10),))
        leaf = Task(tid="z", inputs=("y",), deps=("m",))
        d.submit([p, mid, leaf], 0.0)
        d.next_dispatches(0.0)
        d.task_finished(p, 1.0, ok=False)
        assert p.state is TaskState.FAILED
        dead = d.drain_dep_failed()
        assert [t.tid for t in dead] == ["m", "z"]     # transitive, in order
        assert d.drain_dep_failed() == []              # exactly once
        assert d.held_len == 0
        assert {t.tid for t in d.failed} == {"p", "m", "z"}
        # a late arrival depending on the corpse fails on submission
        late = Task(tid="late", inputs=(), deps=("p",))
        d.submit([late], 2.0)
        assert [t.tid for t in d.drain_dep_failed()] == ["late"]
        assert late.state is TaskState.FAILED

    def test_executor_death_requeues_producer_and_keeps_holds(self):
        d = _mkdisp(n_exec=2)
        (p,), c = _pipeline()
        d.submit([p, c], 0.0)
        out = d.next_dispatches(0.0)
        eid = out[0].executor
        requeued = d.executor_left(eid, 1.0, failed=True)
        assert p in requeued and p.attempts == 1
        assert d.held_len == 1 and c.state is TaskState.SUBMITTED
        nxt = d.next_dispatches(1.0)
        assert nxt[0].task is p and nxt[0].executor != eid
        d.task_finished(p, 2.0)
        assert d.held_len == 0 and c.ready_time == 2.0

    def test_producer_placement_scoring(self):
        d = _mkdisp(DispatchPolicy.MAX_COMPUTE_UTIL, n_exec=2)
        p = Task(tid="p", inputs=(), outputs=(DataObject("f", 100),))
        c = Task(tid="c", inputs=("f",), deps=("p",))
        d.submit([p, c], 0.0)
        out = d.next_dispatches(0.0)
        peid = out[0].executor
        d.index.insert("f", peid)          # engine admits output pre-finish
        d.task_finished(p, 1.0)
        # score_oids folds dep-produced outputs in (even when not an input)
        other = Task(tid="o", inputs=("a",), deps=("p",))
        d.tasks[other.tid] = other
        assert d.score_oids(other) == ("a", "f")
        assert d.score_oids(p) == ()       # dep-free: inputs as-is
        nxt = d.next_dispatches(1.0)
        assert nxt[0].task is c and nxt[0].executor == peid
        assert c.location_hints == {"f": (peid,)}
        assert d.scores_match_reference()

    def test_outputs_ignored_baseline_sees_no_produced_hints(self):
        d = _mkdisp(DispatchPolicy.MAX_COMPUTE_UTIL, n_exec=2)
        d.score_outputs = False
        p = Task(tid="p", inputs=(), outputs=(DataObject("f", 100),))
        c = Task(tid="c", inputs=("f",), deps=("p",))
        d.submit([p, c], 0.0)
        out = d.next_dispatches(0.0)
        d.index.insert("f", out[0].executor)
        d.task_finished(p, 1.0)
        nxt = d.next_dispatches(1.0)
        assert nxt[0].task is c and nxt[0].hints == {}
        assert d.scores_match_reference()


# --------------------------------------------------------------------------
# engines
# --------------------------------------------------------------------------

def _sim_run(wl, n_nodes=4, score_outputs=True):
    cfg = SimConfig(testbed=ANL_UC, n_nodes=n_nodes,
                    policy=DispatchPolicy.MAX_COMPUTE_UTIL, seed=0)
    sim = DiffusionSim(cfg)
    sim.dispatcher.score_outputs = score_outputs
    sim.submit_workload(wl)
    r = sim.run()
    ends = {t.tid: t.end_time for t in sim.dispatcher.completed}
    m = MetricsCollector(ANL_UC).collect(r, n_submitted=sim.n_submitted)
    return m, ends, sim


class TestSimEngine:
    def test_all_pairs_completes_and_orders(self):
        wl = all_pairs("ap", n_objects=4)
        m, ends, sim = _sim_run(wl)
        assert m.n_completed == len(wl) and m.n_failed == 0
        starts = {t.tid: t.dispatch_time
                  for t in sim.dispatcher.completed}
        for e in wl.events:
            for dep in e.deps:
                assert starts[e.tid] >= ends[dep], (e.tid, dep)
        # dep-wait excluded: ready-based slowdown can only be tighter
        assert m.slowdown_from_ready <= m.slowdown_from_arrival
        assert m.slowdown_from_arrival == m.avg_slowdown

    def test_reduce_tree_transitive_release(self):
        wl = reduce_tree("rt", n_leaves=9, fanin=3)
        m, ends, _ = _sim_run(wl)
        assert m.n_completed == len(wl) == 13
        assert max(ends, key=ends.get) == "rt-r2.0"    # root finishes last

    def test_dep_free_slowdown_bases_identical(self):
        wl = generate("flat", PoissonArrivals(20.0), ZipfPopularity(),
                      n_tasks=60, n_objects=16, object_bytes=10**6,
                      compute_seconds=0.05, seed=5)
        m_on, _, _ = _sim_run(wl, score_outputs=True)
        m_off, _, _ = _sim_run(wl, score_outputs=False)
        assert m_on == m_off                           # knob fully inert
        assert m_on.slowdown_from_arrival == m_on.slowdown_from_ready \
            == m_on.avg_slowdown


class TestRuntimeEngine:
    def test_dag_executes_with_payloads_from_cache(self):
        spec = ExperimentSpec(
            name="dag-rt",
            cluster=ClusterSpec(testbed="anl_uc", n_nodes=2),
            policy="max-compute-util",
            workload=WorkloadSpec(
                name="sp",
                dag={"kind": "stacking_pyramid", "n_groups": 2,
                     "group_size": 2, "object_bytes": 64,
                     "stack_bytes": 32, "mosaic_bytes": 32}),
            seed=0)
        eng = RuntimeEngine().prepare(spec)
        try:
            rep = eng.run(task_fn=lambda inputs: b"".join(inputs.values()),
                          payload_factory=lambda ob: b"ab",
                          time_scale=0.0, timeout=60.0)
            assert rep.n_completed == 3 and rep.n_failed == 0
            done = {t.tid: t for t in eng.runtime.dispatcher.completed}
            # real payloads flowed stage to stage
            assert done["sp-mosaic"].result == b"abab" * 2
            # deps guarantee produced stacks are CACHE-resident when the
            # mosaic runs: only the 4 catalog reads may touch the store
            assert rep.store_reads == 4
            assert rep.slowdown_from_ready <= rep.slowdown_from_arrival
        finally:
            eng.shutdown()

    def test_dep_failure_does_not_leak_wait(self):
        def boom(inputs):
            raise RuntimeError("producer down")

        rt = DiffusionRuntime(n_executors=1)
        try:
            p = Task(tid="p", inputs=(), outputs=(DataObject("x", 8),),
                     fn=boom, max_attempts=1)
            c = Task(tid="c", inputs=("x",), deps=("p",))
            rt.submit([p, c])
            assert rt.wait(20), "dep-failed consumer leaked wait()"
            d = rt.dispatcher
            assert {t.tid for t in d.failed} == {"p", "c"}
            assert not d.completed and d.held_len == 0
        finally:
            rt.shutdown()


class TestExperimentBinding:
    def test_spec_dag_binding_runs_through_sim_engine(self):
        spec = ExperimentSpec(
            name="ap-sim",
            cluster=ClusterSpec(testbed="anl_uc", n_nodes=4),
            policy="max-compute-util",
            workload=WorkloadSpec(name="ap",
                                  dag={"kind": "all_pairs", "n_objects": 4}),
            seed=0)
        rep = run_experiment(spec, engine="sim")
        assert rep.n_completed == 4 + 16

    def test_dag_plus_generator_fields_rejected(self):
        with pytest.raises(ValueError, match="EXACTLY ONE"):
            WorkloadSpec(name="w", dag={"kind": "all_pairs"},
                         arrivals={"kind": "PoissonArrivals"})
        with pytest.raises(ValueError, match="silently ignored"):
            WorkloadSpec(name="w", dag={"kind": "all_pairs"}, n_tasks=5)
        with pytest.raises(ValueError, match="unknown dag kind"):
            WorkloadSpec(name="w", dag={"kind": "nope"})


# --------------------------------------------------------------------------
# obs: dep-wait is visible and distinct from queue-wait
# --------------------------------------------------------------------------

def test_obs_emits_held_ready_and_dep_wait_spans(tmp_path):
    wl = all_pairs("ap", n_objects=2)      # 2 extracts + 4 held pairs
    spec = ExperimentSpec(
        name="obs-dag",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=2),
        policy="max-compute-util",
        workload=WorkloadSpec(name="ap",
                              dag={"kind": "all_pairs", "n_objects": 2}),
        observe=ObserveSpec(events=True),
        seed=0)
    eng = SimEngine()
    try:
        eng.prepare(spec, workload=wl)
        rep = eng.run()
        events = eng.recorder.events()
    finally:
        eng.shutdown()
    assert rep.n_completed == 6
    held = [e["tid"] for e in events if e["kind"] == "task_held"]
    ready = [e["tid"] for e in events if e["kind"] == "task_ready"]
    assert sorted(held) == sorted(ready) \
        == ["ap-p0x0", "ap-p0x1", "ap-p1x0", "ap-p1x1"]
    from repro.obs import chrome_trace
    spans = [e for e in chrome_trace(events)["traceEvents"]
             if e["ph"] == "X"]
    dep_spans = [e for e in spans if e["cat"] == "dep_wait"]
    assert sorted(e["name"] for e in dep_spans) == sorted(held)
    queue_spans = [e for e in spans if e["cat"] == "queue_wait"]
    assert len(queue_spans) == 6           # every task queues exactly once


# --------------------------------------------------------------------------
# fleet: SIGKILL mid-pipeline (satellite: DAG conservation under failure)
# --------------------------------------------------------------------------

def _fleet_conservation(rt):
    lg, d = rt.ledger, rt.dispatcher
    sums = [0] * 6
    for t in d.completed:
        sums[0] += t.bytes_local
        sums[1] += t.bytes_cache_to_cache
        sums[2] += t.bytes_store
        sums[3] += t.cache_hits
        sums[4] += t.peer_hits
        sums[5] += t.cache_misses - t.peer_hits
    assert sums == [lg.bytes_local, lg.bytes_c2c, lg.bytes_store,
                    lg.local_hits, lg.peer_hits, lg.store_reads]


def test_fleet_sigkill_mid_pipeline_requeues_and_conserves(monkeypatch):
    """Kill a host while it executes a producer: the producer re-queues,
    its downstream tasks stay held (never dispatched with unmet deps,
    never lost or doubled), the run drains, and the global ledger equals
    the sum of completed-task ledgers exactly."""
    # slow the simulated disk so producers dwell ~2s: the kill lands while
    # every first-wave producer is still EXECUTING, deterministically
    monkeypatch.setenv("REPRO_BENCH_DISK_BW", "1000")
    rt = FleetRuntime(hosts=3, threads_per_host=1,
                      task_fn_name="repro.fleet.runtime:io_dwell_task",
                      heartbeat_timeout_s=2.0)
    try:
        n_prod = 4
        for i in range(n_prod):
            rt.put_object(DataObject(f"g{i}", 2000), b"x" * 2000)
        prods = [Task(tid=f"prod{i}", inputs=(f"g{i}",),
                      outputs=(DataObject(f"p{i}", 100),))
                 for i in range(n_prod)]
        cons = [Task(tid=f"cons{i}", inputs=(f"p{i}",), deps=(f"prod{i}",),
                     outputs=(DataObject(f"c{i}", 40),))
                for i in range(n_prod)]
        root = Task(tid="root", inputs=tuple(f"c{i}" for i in range(n_prod)),
                    deps=tuple(f"cons{i}" for i in range(n_prod)))
        rt.submit(prods + cons + [root])
        time.sleep(0.4)               # producers dispatched, none done (2s)
        d = rt.dispatcher
        assert d.held_len == n_prod + 1 and not d.completed
        victim_eids = set(rt.manager.handles["h1"].eids)
        victim_tids = {tid for eid in victim_eids
                       for tid in d.executors[eid].running}
        assert victim_tids and victim_tids <= {t.tid for t in prods}
        rt.manager.kill_host("h1")
        assert rt.wait(60), "wait() leaked after mid-pipeline SIGKILL"
        assert not d.failed and d.held_len == 0
        tids = [t.tid for t in d.completed]
        assert len(tids) == 2 * n_prod + 1            # never lost...
        assert len(set(tids)) == len(tids)            # ...never doubled
        done = {t.tid: t for t in d.completed}
        # the killed host's executing producers re-queued and re-ran on a
        # survivor (one attempt charged by executor_left)
        for tid in victim_tids:
            assert done[tid].attempts == 1
            assert done[tid].executor not in victim_eids
        # no dependent ever dispatched with an unmet dep
        for t in cons + [root]:
            for dep in t.deps:
                assert done[t.tid].dispatch_time >= done[dep].end_time
                assert done[t.tid].ready_time >= done[dep].end_time
        _fleet_conservation(rt)
    finally:
        rt.shutdown()

"""Benchmark driver: one module per paper table/figure + the roofline.

  PYTHONPATH=src python -m benchmarks.run            # quick (scaled) pass
  PYTHONPATH=src python -m benchmarks.run --full     # paper-size workloads
  PYTHONPATH=src python -m benchmarks.run --only fig3

Prints ``bench,name,value,unit,paper,note`` CSV rows (the scaffold's
name,us_per_call,derived contract, extended with the paper anchor)."""
from __future__ import annotations

import argparse
import csv
import io
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized workloads (slow on 1 CPU)")
    ap.add_argument("--only", default=None,
                    help="substring filter on bench module name")
    ap.add_argument("--out", default=None, help="also write CSV here")
    ap.add_argument("--gate", action="store_true",
                    help="also run tools/bench_gate.py against the committed "
                         "BENCH_engine.json + BENCH_workloads.json + "
                         "BENCH_joins.json + BENCH_policies.json + "
                         "BENCH_fleet.json + BENCH_dispatch.json + "
                         "BENCH_obs.json + BENCH_dags.json + "
                         "BENCH_serve.json + BENCH_telemetry.json baselines "
                         "(fails on >25%% "
                         "wall-clock regression or a correctness-canary "
                         "miss)")
    args = ap.parse_args(argv)

    from . import (bench_dags, bench_dispatch, bench_engine, bench_fleet,
                   bench_index, bench_joins, bench_microbench, bench_obs,
                   bench_policies, bench_roofline, bench_scheduler,
                   bench_serve, bench_stacking, bench_telemetry,
                   bench_workloads)

    modules = [
        ("index", bench_index, 1.0 if args.full else 0.5),
        ("microbench", bench_microbench, 1.0 if args.full else 0.3),
        ("stacking", bench_stacking, 0.2 if args.full else 0.02),
        ("scheduler", bench_scheduler, 1.0 if args.full else 0.25),
        ("engine", bench_engine, 1.0 if args.full else 0.25),
        ("workloads", bench_workloads, 1.0 if args.full else 0.25),
        ("joins", bench_joins, 1.0 if args.full else 0.25),
        ("policies", bench_policies, 1.0 if args.full else 0.25),
        ("fleet", bench_fleet, 1.0 if args.full else 0.5),
        ("dispatch", bench_dispatch, 1.0 if args.full else 0.5),
        ("obs", bench_obs, 1.0 if args.full else 0.5),
        ("dags", bench_dags, 1.0 if args.full else 0.5),
        ("serve", bench_serve, 1.0 if args.full else 0.05),
        ("telemetry", bench_telemetry, 1.0 if args.full else 0.5),
        ("roofline", bench_roofline, 1.0),
    ]
    rows = []
    for name, mod, scale in modules:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows.extend(mod.run(scale=scale))
            status = f"ok ({time.time() - t0:.1f}s)"
        except Exception as e:  # noqa: BLE001
            status = f"FAILED: {type(e).__name__}: {e}"
            rows.append({"bench": name, "name": "ERROR", "value": 0,
                         "unit": "", "paper": None, "note": str(e)[:200]})
        print(f"# {name}: {status}", file=sys.stderr)

    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=["bench", "name", "value", "unit",
                                        "paper", "note"])
    w.writeheader()
    for r in rows:
        w.writerow(r)
    print(buf.getvalue())
    if args.out:
        with open(args.out, "w") as f:
            f.write(buf.getvalue())
    bad = [r for r in rows if r["name"] == "ERROR"]
    rc = 1 if bad else 0
    if args.gate:
        import pathlib
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                               / "tools"))
        import bench_gate
        rc = max(rc, bench_gate.main([]))
    return rc


if __name__ == "__main__":
    sys.exit(main())

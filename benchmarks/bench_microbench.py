"""Figures 3, 4, 5 -- the §4.3 micro-benchmarks (read / read+write / sizes).

Eight configurations x node counts x file sizes, as in the paper; the
Model(local)/Model(GPFS) lines are the analytic testbed envelopes.  Paper
anchors asserted in EXPERIMENTS.md:
  Fig3: 61.7 Gb/s (~94% ideal) for max-compute-util@100%; GPFS caps 3.4 Gb/s
  Fig4: 22.7 Gb/s (~96% ideal) read+write; GPFS ~1.1 Gb/s
  Fig5: wrapper floors small files at ~21 tasks/s
"""
from __future__ import annotations

from repro.core import ANL_UC, DispatchPolicy
from .common import Gb, MB, microbench_sim, row

P = DispatchPolicy


def run(scale: float = 1.0) -> list[dict]:
    rows = []
    nodes_sweep = (1, 2, 4, 8, 16, 32, 64)
    files_per_node = max(int(10 * scale), 2)

    # ---------------- Figure 3: read, 100MB files --------------------------
    for n in nodes_sweep:
        nf = files_per_node * n
        rows.append(row("fig3_read", f"model_local_{n}n",
                        ANL_UC.ideal_read_bw(n) / Gb, "Gb/s"))
        rows.append(row("fig3_read", f"model_gpfs_{n}n",
                        min(n * ANL_UC.nic_in_bw, ANL_UC.store_read_bw) / Gb,
                        "Gb/s"))
        r = microbench_sim(P.FIRST_AVAILABLE, n, nf, 100 * MB, caching=False)
        rows.append(row("fig3_read", f"first_available_{n}n",
                        r.read_throughput() / Gb, "Gb/s",
                        paper=3.1 if n == 64 else None))
        r = microbench_sim(P.FIRST_CACHE_AVAILABLE, n, nf, 100 * MB, warm=True)
        rows.append(row("fig3_read", f"first_cache_avail_100pct_{n}n",
                        r.read_throughput() / Gb, "Gb/s",
                        paper=5.7 if n == 64 else None))
        r = microbench_sim(P.MAX_COMPUTE_UTIL, n, nf, 100 * MB)
        rows.append(row("fig3_read", f"max_compute_util_0pct_{n}n",
                        r.read_throughput() / Gb, "Gb/s"))
        r = microbench_sim(P.MAX_COMPUTE_UTIL, n, nf, 100 * MB, warm=True)
        rows.append(row("fig3_read", f"max_compute_util_100pct_{n}n",
                        r.read_throughput() / Gb, "Gb/s",
                        paper=61.7 if n == 64 else None,
                        note="paper: ~94% of ideal at 64 nodes"))

    # ---------------- Figure 4: read+write, 100MB --------------------------
    for n in (8, 32, 64):
        nf = files_per_node * n
        rows.append(row("fig4_rw", f"model_local_rw_{n}n",
                        ANL_UC.ideal_readwrite_bw(n) / Gb, "Gb/s"))
        r = microbench_sim(P.MAX_COMPUTE_UTIL, n, nf, 100 * MB, warm=True,
                           read_write=True)
        rows.append(row("fig4_rw", f"max_compute_util_100pct_rw_{n}n",
                        r.moved_throughput() / Gb, "Gb/s",
                        paper=22.7 if n == 64 else None))
        r = microbench_sim(P.FIRST_AVAILABLE, n, nf, 100 * MB, caching=False,
                           read_write=True)
        rows.append(row("fig4_rw", f"gpfs_rw_{n}n",
                        r.throughput_of(["store_read", "store_write"]) / Gb,
                        "Gb/s", paper=1.1 if n == 64 else None))

    # ---------------- Figure 5: file-size sweep on 64 nodes ----------------
    for size, label in ((1, "1B"), (10**3, "1KB"), (10**5, "100KB"),
                        (MB, "1MB"), (10 * MB, "10MB"), (100 * MB, "100MB")):
        nf = max(int(256 * scale), 64)
        r = microbench_sim(P.FIRST_AVAILABLE, 64, nf, size, caching=False)
        rows.append(row("fig5_sizes", f"gpfs_{label}",
                        r.read_throughput() / Gb, "Gb/s"))
        rows.append(row("fig5_sizes", f"gpfs_{label}_tasks",
                        r.tasks_per_second(), "tasks/s"))
        rw = microbench_sim(P.FIRST_AVAILABLE, 64, nf, size, caching=False,
                            wrapper=True)
        rows.append(row("fig5_sizes", f"gpfs_wrapper_{label}_tasks",
                        rw.tasks_per_second(), "tasks/s",
                        paper=21.0 if size <= MB else None,
                        note="paper: ~21 tasks/s wrapper floor"))
        dd = microbench_sim(P.MAX_COMPUTE_UTIL, 64, nf, size, warm=True)
        rows.append(row("fig5_sizes", f"diffusion_100pct_{label}",
                        dd.read_throughput() / Gb, "Gb/s"))
    return rows

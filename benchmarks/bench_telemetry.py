"""Telemetry benchmark: metrics-plane overhead + identity + reconciliation.

`repro.obs.metrics` promises (DESIGN.md §13) that the live telemetry plane
is free when off -- every hot-path hook is one attribute read and a branch
-- and cheap when on: one short critical section on the registry's own
leaf lock, never held across the dispatcher lock, never doing I/O.  This
bench is the measurement side, three canaries:

  overhead    the bench_dispatch completion STORM (real framed sockets,
              scripted hosts, instant completions -- the worst case for
              per-task fixed costs) run metrics-OFF and metrics-ON with a
              live `Telemetry` bundle AND a concurrent sampler snapshotting
              the registry at the default interval; best-of-N **central-
              loop CPU** metrics-on must stay within 10% of metrics-off;
  identity    a metrics-ON runtime run must match a metrics-OFF run of the
              same spec EXACTLY on scheduling-determined RunReport fields
              (the §8 parity surface): telemetry observes scheduling, it
              must never steer it;
  reconcile   a real 4-host fleet run with metrics on: the merged per-host
              cumulative bandwidth gauges (`bw.bytes_*`, accumulated
              host-side from done-frame ledgers and shipped as stats
              frames) must sum to within 5% of the run ledger's
              `bytes_by_kind` totals.  The final settled stats frame makes
              this exact in practice; 5% is the live-sampling allowance.

CLI (writes the committed baseline consumed by tools/bench_gate.py):

    PYTHONPATH=src python -m benchmarks.bench_telemetry \
        --out BENCH_telemetry.json
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time

from repro.experiments import (CacheSpec, ClusterSpec, ExperimentSpec,
                               ObserveSpec, WorkloadSpec, run_experiment)
from repro.fleet import reports_scheduling_equal
from repro.obs import Telemetry

from . import bench_dispatch
from .common import row

#: fixed configuration tools/bench_gate.py replays against the baseline.
GATE_NODES = bench_dispatch.GATE_NODES     # storm pool (4 hosts x 48)
GATE_TASKS = 1200                          # storm tasks per overhead cell
IDENTITY_TASKS = 120                       # metrics-on/off parity cell
RECONCILE_TASKS = 60                       # 4-host bandwidth-reconcile cell
RECONCILE_HOSTS = 4
STORM_WIRE_BATCH = 64
SAMPLE_INTERVAL_S = 0.05                   # storm sampler cadence


# --------------------------------------------------------------------------
# overhead: metrics-off vs metrics-on on the same storm
# --------------------------------------------------------------------------

def _sampled_storm(n_tasks: int) -> dict:
    """One metrics-ON storm: the registry hooks fire on every submit/
    dispatch/complete/pump, and a live sampler thread snapshots the
    registry concurrently -- the full cost a monitored run pays."""
    tel = Telemetry(interval_s=SAMPLE_INTERVAL_S)
    stop = threading.Event()
    t0 = time.monotonic()

    def _sampler() -> None:
        while not stop.wait(tel.interval_s):
            tel.record_sample(time.monotonic() - t0)

    thr = threading.Thread(target=_sampler, daemon=True,
                           name="bench-telemetry-sampler")
    thr.start()
    try:
        out = bench_dispatch.measure_storm(STORM_WIRE_BATCH, n_tasks,
                                           metrics=tel)
    finally:
        stop.set()
        thr.join(timeout=10.0)
    out["n_samples"] = len(tel.series)
    out["tasks_completed_counter"] = tel.registry.counter(
        "sched.tasks_completed")
    return out


def measure_overhead(n_tasks: int = GATE_TASKS, repeats: int = 3) -> dict:
    """Best-of-N central-loop CPU with and without the metrics plane on
    identical scripted storms.  Wall clock on a 1-core box mostly measures
    the scripted hosts; central CPU is what the guarded hooks could tax."""
    best_off = best_on = None
    for _ in range(repeats):
        off = bench_dispatch.measure_storm(STORM_WIRE_BATCH, n_tasks)
        on = _sampled_storm(n_tasks)
        if best_off is None or off["central_cpu_s"] < best_off["central_cpu_s"]:
            best_off = off
        if best_on is None or on["central_cpu_s"] < best_on["central_cpu_s"]:
            best_on = on
    return {
        "n_tasks": n_tasks,
        "n_completed": best_on["n_completed"],
        "wall_s": best_on["wall_s"],
        "central_cpu_off_s": best_off["central_cpu_s"],
        "central_cpu_on_s": best_on["central_cpu_s"],
        "overhead_ratio": round(best_on["central_cpu_s"]
                                / max(best_off["central_cpu_s"], 1e-9), 3),
        "n_samples": best_on["n_samples"],
        "counter_matches_completions": (best_on["tasks_completed_counter"]
                                        == best_on["n_completed"]),
    }


# --------------------------------------------------------------------------
# identity: metrics-on run == metrics-off run, scheduling-wise
# --------------------------------------------------------------------------

def _spec(n_tasks: int, *, hosts: int, tph: int, metrics: bool,
          seed: int = 7) -> ExperimentSpec:
    return ExperimentSpec(
        name="telemetry-bench",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=4),
        cache=CacheSpec(capacity_bytes=10**12),       # eviction-free
        policy="max-compute-util",
        workload=WorkloadSpec(
            name="tel",
            arrivals={"kind": "PoissonArrivals", "rate_per_s": 100.0},
            popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 2,
                        "corr": 0.8},
            n_tasks=n_tasks, n_objects=32, object_bytes=50_000, seed=seed),
        observe=ObserveSpec(metrics=metrics, metrics_interval_s=0.05),
        seed=3, hosts=hosts, threads_per_host=tph)


def measure_off_identity(n_tasks: int = IDENTITY_TASKS) -> dict:
    """Batch-synchronous replay of one spec, metrics on vs off: the
    scheduling-determined report fields must be IDENTICAL -- telemetry
    reads the run, it must never write to it."""
    r_off = run_experiment(_spec(n_tasks, hosts=0, tph=1, metrics=False),
                           engine="runtime", barrier_every=4, timeout=300.0)
    r_on = run_experiment(_spec(n_tasks, hosts=0, tph=1, metrics=True),
                          engine="runtime", barrier_every=4, timeout=300.0)
    diff = reports_scheduling_equal(r_off, r_on)
    return {
        "n_tasks": n_tasks,
        "n_completed": r_on.n_completed,
        "identical": not diff and r_on.n_completed == n_tasks,
        "diff_fields": sorted(diff),
        "off_telemetry_empty": r_off.telemetry == {},
        "on_n_samples": r_on.telemetry.get("n_samples", 0),
    }


# --------------------------------------------------------------------------
# reconcile: 4-host merged bandwidth gauges vs the run ledger
# --------------------------------------------------------------------------

def measure_bw_reconcile(n_tasks: int = RECONCILE_TASKS) -> dict:
    """A real 4-host fleet with metrics on: fold the final per-host stats
    frames and compare the summed cumulative `bw.*` gauges against the run
    ledger's `bytes_by_kind` -- the merge algebra's end-to-end check."""
    rep = run_experiment(
        _spec(n_tasks, hosts=RECONCILE_HOSTS, tph=1, metrics=True),
        engine="runtime", barrier_every=4, timeout=300.0)
    g = rep.telemetry.get("merged", {}).get("gauges", {})
    bk = rep.bytes_by_kind
    gauge_total = (g.get("bw.bytes_local", 0) + g.get("bw.bytes_c2c", 0)
                   + g.get("bw.bytes_store", 0))
    ledger_total = (bk.get("local", 0) + bk.get("c2c", 0)
                    + bk.get("store_read", 0))
    gap = abs(gauge_total - ledger_total) / max(ledger_total, 1)
    return {
        "n_tasks": n_tasks,
        "hosts": RECONCILE_HOSTS,
        "n_completed": rep.n_completed,
        "n_hosts_reporting": len(rep.telemetry.get("hosts", {})),
        "gauge_bytes": {"local": g.get("bw.bytes_local", 0),
                        "c2c": g.get("bw.bytes_c2c", 0),
                        "store": g.get("bw.bytes_store", 0)},
        "ledger_bytes": {"local": bk.get("local", 0),
                         "c2c": bk.get("c2c", 0),
                         "store": bk.get("store_read", 0)},
        "bw_gap": round(gap, 6),
    }


# --------------------------------------------------------------------------
# gate / CSV entry points
# --------------------------------------------------------------------------

def gate_measure(repeats: int = 3) -> dict:
    """The fixed shape bench_gate.py replays.  The gated wall is the
    metrics-on storm (best-of-N); the canaries are the overhead ratio, the
    metrics-off scheduling identity, and the bandwidth reconciliation."""
    # the on/off CPU ratio divides two ~100 ms measurements on a shared
    # box; the best-of-N floor needs more samples than the wall gate does
    ov = measure_overhead(GATE_TASKS, repeats=max(repeats, 5))
    ident = measure_off_identity(IDENTITY_TASKS)
    rec = measure_bw_reconcile(RECONCILE_TASKS)
    return {
        "n_nodes": GATE_NODES, "n_tasks": GATE_TASKS,
        "wall_s": ov["wall_s"],
        "n_completed": ov["n_completed"],
        "central_cpu_off_s": ov["central_cpu_off_s"],
        "central_cpu_on_s": ov["central_cpu_on_s"],
        "overhead_ratio": ov["overhead_ratio"],
        "counter_matches_completions": ov["counter_matches_completions"],
        "metrics_off_identical": ident["identical"],
        "off_telemetry_empty": ident["off_telemetry_empty"],
        "bw_gap": rec["bw_gap"],
        "reconcile_hosts_reporting": rec["n_hosts_reporting"],
    }


def run(scale: float = 1.0) -> list[dict]:
    """benchmarks.run contract: overhead + identity + reconcile rows."""
    n_tasks = max(int(GATE_TASKS * scale), 100)
    ov = measure_overhead(n_tasks, repeats=1)
    rows = [
        row("telemetry", "metrics_on_overhead_ratio", ov["overhead_ratio"],
            "x", note=f"central-loop CPU, storm of {n_tasks}, on/off, "
                      f"{ov['n_samples']} live samples"),
    ]
    ident = measure_off_identity(max(int(IDENTITY_TASKS * scale), 40))
    rows.append(row("telemetry", "metrics_off_identical",
                    1.0 if ident["identical"] else 0.0, "bool",
                    note="metrics-on == metrics-off on scheduling-"
                         "determined report fields"))
    rec = measure_bw_reconcile(max(int(RECONCILE_TASKS * scale), 30))
    rows.append(row("telemetry", "fleet_bw_gauge_ledger_gap",
                    rec["bw_gap"], "ratio",
                    note=f"{rec['hosts']}-host merged bw gauges vs ledger "
                         f"({rec['n_hosts_reporting']} hosts reporting)"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=GATE_TASKS)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="BENCH_telemetry.json")
    args = ap.parse_args(argv)

    ov = measure_overhead(args.tasks, repeats=args.repeats)
    print(f"# overhead: on {ov['central_cpu_on_s'] * 1e3:.1f} ms vs off "
          f"{ov['central_cpu_off_s'] * 1e3:.1f} ms central CPU "
          f"({ov['overhead_ratio']:.3f}x), {ov['n_samples']} samples",
          file=sys.stderr)
    ident = measure_off_identity()
    print(f"# identity: {ident['identical']} "
          f"(diff fields {ident['diff_fields']})", file=sys.stderr)
    rec = measure_bw_reconcile()
    print(f"# reconcile: gap {rec['bw_gap']:.4f} over "
          f"{rec['n_hosts_reporting']} hosts", file=sys.stderr)
    out = {"overhead": ov, "off_identity": ident, "reconcile": rec,
           "gate": gate_measure(repeats=args.repeats)}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Serving benchmark: KV-cache diffusion through the serve engine
(DESIGN.md §12), with the PR's acceptance checks built in as canaries:

  kv_gap    the same 200-session x 3-turn chat workload run on a fixed
            4-replica pool under max-cache-hit vs first-available (sim
            engine, seed-paired): prefix-aware dispatch must WIN on
            reused-KV bytes -- the paper's cache-hit economics applied
            to prefill reuse;
  drp       diurnal session arrivals over an elastic 1..8 replica pool
            (exponential allocation): the provisioner must both GROW and
            SHRINK -- autoscaling driven by demand, not configuration;
  events    one serve-engine workload run twice under barrier replay,
            lifecycle events on vs off: the scheduling-determined
            RunReport fields (repro.fleet.SCHEDULING_DETERMINED_FIELDS)
            must be bit-identical -- observation must not perturb
            placement;
  scale     the sim binding at bench scale with ``model=``-derived KV
            page sizes (kv_bytes_per_token over a real ModelConfig);
            ``--full`` / ``main()`` run the acceptance-size 10^5-session
            point recorded in the committed baseline.

CLI (writes the committed baseline consumed by tools/bench_gate.py):

    PYTHONPATH=src python -m benchmarks.bench_serve --out BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.experiments import ObserveSpec, run_experiment
from repro.experiments.spec import ProvisionerSpec
from repro.fleet import reports_scheduling_equal
from repro.serve.diffusion import kv_summary, session_spec

from .common import row

MB = 10**6

#: the small fixed configuration tools/bench_gate.py replays against the
#: committed baseline: 200 sessions x 3 turns on a fixed 4-replica pool
GATE_NODES = 4
GATE_SESSIONS = 200
GATE_TURNS = 3
GATE_TASKS = GATE_SESSIONS * GATE_TURNS
#: the acceptance-size sim-binding point main() records in the baseline
SCALE_SESSIONS = 100_000


def _chat_binding(n_sessions: int, turns: int) -> dict:
    """The seed-paired policy-gap workload: Zipf-shared system prompts,
    think-time-paced turns, open-loop Poisson session arrivals."""
    return {"kind": "chat", "n_sessions": n_sessions,
            "turns_per_session": turns, "n_system_prompts": 8,
            "kv_bytes_per_token": 1024, "block": 32,
            "think_time_s": 2.0, "turn_seconds": 0.05,
            "arrivals": {"kind": "PoissonArrivals", "rate_per_s": 10.0}}


def measure_kv_gap(n_replicas: int = GATE_NODES,
                   n_sessions: int = GATE_SESSIONS,
                   turns: int = GATE_TURNS, seed: int = 0) -> dict:
    """Prefix-aware dispatch vs first-available on reused-KV bytes."""
    binding = _chat_binding(n_sessions, turns)
    t0 = time.perf_counter()
    mch = run_experiment(session_spec("kvgap", binding, seed=seed,
                                      n_replicas=n_replicas,
                                      policy="max-cache-hit"), engine="sim")
    fa = run_experiment(session_spec("kvgap", binding, seed=seed,
                                     n_replicas=n_replicas,
                                     policy="first-available"), engine="sim")
    wall = time.perf_counter() - t0
    s_mch, s_fa = kv_summary(mch), kv_summary(fa)
    return {
        "scenario": "kv_gap", "n_nodes": n_replicas,
        "n_tasks": n_sessions * turns,
        "wall_s": round(wall, 4),
        "n_completed": mch.n_completed + fa.n_completed,
        "mch_reused_kv_mb": round(s_mch["reused_kv_bytes"] / MB, 3),
        "fa_reused_kv_mb": round(s_fa["reused_kv_bytes"] / MB, 3),
        "reused_kv_gap": round(s_mch["reused_kv_bytes"]
                               - s_fa["reused_kv_bytes"], 1),
        "mch_reused_token_fraction": round(s_mch["reused_token_fraction"], 4),
        "fa_reused_token_fraction": round(s_fa["reused_token_fraction"], 4),
    }


def measure_drp(seed: int = 0) -> dict:
    """Diurnal sessions over an elastic pool: grow AND shrink demanded."""
    binding = {"kind": "chat", "n_sessions": 400, "turns_per_session": 2,
               "kv_bytes_per_token": 1024, "block": 32,
               "think_time_s": 5.0, "turn_seconds": 1.0,
               "arrivals": {"kind": "DiurnalArrivals", "peak_rate": 8.0,
                            "trough_rate": 0.5, "day_s": 60.0}}
    spec = session_spec(
        "servedrp", binding, n_replicas=1, seed=seed,
        provisioner=ProvisionerSpec(
            policy="exponential", min_executors=1, max_executors=8,
            queue_threshold=2, idle_timeout_s=5.0, trigger_cooldown_s=1.0))
    t0 = time.perf_counter()
    rep = run_experiment(spec, engine="sim")
    return {
        "scenario": "drp", "n_tasks": rep.n_tasks,
        "wall_s": round(time.perf_counter() - t0, 4),
        "n_completed": rep.n_completed,
        "n_allocated": rep.n_allocated,
        "n_released": rep.n_released,
        "peak_executors": rep.peak_executors,
        "low_executors": rep.low_executors,
    }


def measure_events_parity(seed: int = 3) -> dict:
    """Serve engine under barrier replay, lifecycle events on vs off:
    scheduling-determined report fields must be bit-identical."""
    binding = {"kind": "chat", "n_sessions": 60, "turns_per_session": 3,
               "kv_bytes_per_token": 256, "block": 16,
               "think_time_s": 0.0, "turn_seconds": 0.0,
               "arrivals": {"kind": "BatchArrivals", "at_s": 0.0}}
    t0 = time.perf_counter()
    on = run_experiment(
        session_spec("servepar", binding, n_replicas=GATE_NODES, seed=seed,
                     observe=ObserveSpec(events=True)),
        engine="serve", barrier_every=1, timeout=120)
    off = run_experiment(
        session_spec("servepar", binding, n_replicas=GATE_NODES, seed=seed,
                     observe=ObserveSpec(events=False)),
        engine="serve", barrier_every=1, timeout=120)
    diff = reports_scheduling_equal(on, off)
    return {
        "scenario": "events", "n_tasks": on.n_tasks,
        "wall_s": round(time.perf_counter() - t0, 4),
        "n_completed": on.n_completed + off.n_completed,
        "events_identical": not diff and on.n_completed == on.n_tasks,
        "events_diff_fields": sorted(diff),
    }


def measure_scale(n_sessions: int, seed: int = 0) -> dict:
    """The sim binding at scale, KV pages sized from a real ModelConfig."""
    binding = {"kind": "chat", "n_sessions": n_sessions,
               "turns_per_session": 1, "n_system_prompts": 16,
               "system_prompt_blocks": 2, "turn_blocks": 1, "block": 16,
               "model": "whisper-base",
               "think_time_s": 0.0, "turn_seconds": 0.02,
               "arrivals": {"kind": "PoissonArrivals", "rate_per_s": 400.0}}
    spec = session_spec("servescale", binding, n_replicas=8, seed=seed)
    t0 = time.perf_counter()
    rep = run_experiment(spec, engine="sim")
    wall = time.perf_counter() - t0
    s = kv_summary(rep)
    return {
        "scenario": "scale", "n_sessions": n_sessions,
        "n_tasks": rep.n_tasks, "wall_s": round(wall, 2),
        "n_completed": rep.n_completed,
        "all_completed": rep.n_completed == rep.n_tasks,
        "host_tasks_per_s": round(rep.n_completed / wall, 1),
        "reused_token_fraction": round(s["reused_token_fraction"], 4),
        "model": binding["model"],
    }


def gate_measure(repeats: int = 3) -> dict:
    """The small fixed run bench_gate.py replays; best-of-N wall clock."""
    best = None
    for _ in range(repeats):
        g = measure_kv_gap()
        d = measure_drp()
        e = measure_events_parity()
        m = {
            "n_nodes": GATE_NODES, "n_tasks": GATE_TASKS,
            "wall_s": round(g["wall_s"] + d["wall_s"] + e["wall_s"], 4),
            "n_completed": (g["n_completed"] + d["n_completed"]
                            + e["n_completed"]),
            "mch_reused_kv_mb": g["mch_reused_kv_mb"],
            "fa_reused_kv_mb": g["fa_reused_kv_mb"],
            "reused_kv_gap": g["reused_kv_gap"],
            "drp_allocated": d["n_allocated"],
            "drp_released": d["n_released"],
            "events_identical": e["events_identical"],
        }
        if best is None or m["wall_s"] < best["wall_s"]:
            best = m
    return best


def run(scale: float = 1.0) -> list[dict]:
    """benchmarks.run contract: serving scenarios as CSV rows."""
    g = measure_kv_gap()
    d = measure_drp()
    e = measure_events_parity()
    n = max(int(SCALE_SESSIONS * scale), 2000)
    s = measure_scale(n)
    return [
        row("serve", "kv_gap_wall_s", g["wall_s"], "s",
            note=f"{GATE_NODES} replicas, {GATE_SESSIONS} sessions x "
                 f"{GATE_TURNS} turns x 2 policies"),
        row("serve", "mch_reused_kv_mb", g["mch_reused_kv_mb"], "MB",
            note="max-cache-hit reused-KV bytes (prefix-aware dispatch)"),
        row("serve", "fa_reused_kv_mb", g["fa_reused_kv_mb"], "MB",
            note="first-available baseline (must lose)"),
        row("serve", "drp_grow_shrink",
            1.0 if d["n_allocated"] > 0 and d["n_released"] > 0 else 0.0,
            "bool", note=f"diurnal sessions: +{d['n_allocated']} "
                         f"-{d['n_released']} replicas, peak "
                         f"{d['peak_executors']} low {d['low_executors']}"),
        row("serve", "events_identical",
            1.0 if e["events_identical"] else 0.0, "bool",
            note="events on vs off bit-identical on scheduling-determined "
                 "fields under barrier replay"),
        row("serve", "scale_sessions", s["n_sessions"], "sessions",
            note=f"sim binding, model={s['model']} KV sizing, "
                 f"all_completed={s['all_completed']}"),
        row("serve", "scale_host_tasks_per_s", s["host_tasks_per_s"],
            "tasks/s", note="sim-engine throughput on the session binding"),
        row("serve", "scale_reused_token_fraction",
            s["reused_token_fraction"], "ratio",
            note="byte fraction == token fraction (uniform pages)"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale-sessions", type=int, default=SCALE_SESSIONS,
                    help="session count for the scale row (acceptance size)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    g = measure_kv_gap()
    d = measure_drp()
    e = measure_events_parity()
    print(f"# kv_gap: mch {g['mch_reused_kv_mb']}MB vs fa "
          f"{g['fa_reused_kv_mb']}MB reused, wall {g['wall_s']}s",
          file=sys.stderr)
    print(f"# drp: +{d['n_allocated']} -{d['n_released']} replicas "
          f"(peak {d['peak_executors']}, low {d['low_executors']})",
          file=sys.stderr)
    print(f"# events: identical={e['events_identical']}", file=sys.stderr)
    s = measure_scale(args.scale_sessions)
    print(f"# scale: {s['n_sessions']} sessions in {s['wall_s']}s "
          f"({s['host_tasks_per_s']} tasks/s), reuse "
          f"{s['reused_token_fraction']}", file=sys.stderr)
    out = {"kv_gap": g, "drp": d, "events": e, "scale": s,
           "gate": gate_measure()}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

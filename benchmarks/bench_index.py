"""Figure 2 / §3.2.3: central hash-table index vs P-RLS (modeled).

Measures THIS implementation's insert/lookup latency + derived aggregate
throughput, against the paper's anchors (1-3 us insert, 0.25-1 us lookup,
4.18M lookups/s; data-aware decision budget 2.1 ms at 3800 tasks/s) and the
P-RLS log-fit the paper compares with."""
from __future__ import annotations

from repro.core import LocationIndex, prls_aggregate_throughput
from repro.core.index import ShardedIndex
from .common import row


def run(scale: float = 1.0) -> list[dict]:
    n = max(int(200_000 * scale), 20_000)
    rows = []
    t = LocationIndex().time_ops(n)
    rows.append(row("fig2_index", "insert_us", t["insert_s"] * 1e6, "us",
                    paper=2.0, note="paper: 1-3us (Java 1.5, 2008)"))
    rows.append(row("fig2_index", "lookup_us", t["lookup_s"] * 1e6, "us",
                    paper=0.6, note="paper: 0.25-1us"))
    thr = 1.0 / t["lookup_s"]
    rows.append(row("fig2_index", "central_lookups_per_s", thr, "1/s",
                    paper=4.18e6))
    # decisions/sec budget: a data-aware decision = ~1 lookup per input file
    rows.append(row("fig2_index", "lookups_per_2.1ms_budget",
                    2.1e-3 / t["lookup_s"], "lookups", paper=8700.0,
                    note="paper: >8700 lookups fit the 2.1ms decision budget"))
    # P-RLS comparison (model, as in the paper)
    for nodes in (1, 15, 1000, 32_000, 1_000_000):
        rows.append(row("fig2_prls", f"prls_agg_lookups_{nodes}nodes",
                        prls_aggregate_throughput(nodes), "1/s",
                        note="log-fit extrapolation of Chervenak et al."))
    crossover = 32_000
    rows.append(row("fig2_prls", "prls_nodes_to_match_central",
                    crossover, "nodes", paper=32_000,
                    note="paper: >32K P-RLS nodes to match the hash table"))
    # sharded variant: same observable contract (time_ops + op counters)
    sharded = ShardedIndex(n_shards=8)
    ts = sharded.time_ops(n)
    rows.append(row("fig2_index", "sharded8_insert_us", ts["insert_s"] * 1e6,
                    "us", note="hash-sharded variant, 8 shards"))
    rows.append(row("fig2_index", "sharded8_lookup_us", ts["lookup_s"] * 1e6,
                    "us"))
    rows.append(row("fig2_index", "sharded8_ops_counted",
                    sharded.n_inserts + sharded.n_lookups + sharded.n_removes,
                    "ops", note="aggregate n_inserts+n_lookups+n_removes"))
    return rows

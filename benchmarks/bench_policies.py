"""Provisioner policy study (the ROADMAP item; 0808.3535 Figures 4-6):
one-at-a-time / additive / exponential / all-at-once allocation under the
bursty and diurnal demand curves, run as ONE seed-paired sweep through the
experiment API -- every cell sees the identical arrival sequence and object
draws, so policy differences are pure provisioning effects.

The committed BENCH_policies.json carries, per (curve x policy) cell, the
responsiveness (avg/p95 slowdown), the resource bill (executor-seconds,
performance index) and the grow/shrink counts, plus a ``gate`` entry
tools/bench_gate.py replays with two correctness canaries:

  ordering        exponential allocation must respond at least as well as
                  one-at-a-time under bursty arrivals (avg slowdown <=) --
                  the flash-crowd claim the DRP's exponential ramp exists
                  for;
  schema parity   a small spec run on BOTH engines must yield RunReports
                  with the identical field schema (the experiment API's
                  core contract);
  rebalance       under aggressive idle release, ``release_policy=
                  "rebalance"`` (migrate a released executor's cache to
                  live peers) must hold a cache-hit ratio at least as high
                  as ``"discard"`` on the identical workload -- the §6
                  future-work claim the release-policy knob exists for.

The rebalance study itself (the remaining ROADMAP policy axis) sweeps
``provisioner.idle_timeout_s`` x cache-refill cost (``workload.
object_bytes`` -- bytes the store must re-serve per object lost at
release) x release policy under a two-day diurnal curve, so the pool
shrinks at each trough and the second day's demand finds -- or does not
find -- the first day's cached bytes still in the pool.

CLI (writes the committed baseline consumed by tools/bench_gate.py):

    PYTHONPATH=src python -m benchmarks.bench_policies \
        --out BENCH_policies.json --primed

``--primed`` first runs one joins-gate measurement to warm the process
heap: tools/bench_gate.py executes all gates in ONE process with policies
last, and the heap state left by the earlier (larger) gates systematically
adds ~30% to this sweep's small-object-heavy wall clock.  A baseline
measured cold would therefore flag a phantom regression on every full
gate run; measure the baseline in the context the gate replays it in.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.experiments import (CacheSpec, ClusterSpec, ExperimentSpec,
                               ProvisionerSpec, RunReport, Sweep,
                               WorkloadSpec, run_experiment)

from .common import row

MB = 10**6

#: the small fixed configuration tools/bench_gate.py replays against the
#: committed baseline (n_tasks is PER CURVE; the sweep is 4 policies x 2
#: curves = 8 cells)
GATE_NODES = 32
GATE_TASKS = 800

ALLOCATION_POLICIES = ("one-at-a-time", "additive", "exponential",
                       "all-at-once")


def demand_curves(n_nodes: int) -> dict[str, dict]:
    """Arrival bindings sized so the peak wants roughly the whole pool at
    1 task-second of compute and the trough nearly none."""
    return {
        "bursty": {"kind": "BurstyArrivals", "base_rate": 2.0,
                   "burst_rate": float(n_nodes), "burst_every_s": 40.0,
                   "burst_len_s": 10.0},
        "diurnal": {"kind": "DiurnalArrivals", "peak_rate": float(n_nodes),
                    "trough_rate": 1.0, "day_s": 120.0},
    }


def base_spec(n_nodes: int, n_tasks: int, seed: int = 0) -> ExperimentSpec:
    """One declarative base; the sweep overrides provisioner.policy and
    workload.arrivals."""
    return ExperimentSpec(
        name="policies",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=1),
        cache=CacheSpec(capacity_bytes=10**12),
        policy="max-compute-util",
        provisioner=ProvisionerSpec(
            policy="one-at-a-time", min_executors=1, max_executors=n_nodes,
            additive_k=4, queue_threshold=2, idle_timeout_s=5.0,
            trigger_cooldown_s=1.0),
        workload=WorkloadSpec(
            name="policies",
            arrivals=demand_curves(n_nodes)["bursty"],
            popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 1,
                        "corr": 1.0},
            n_tasks=n_tasks, n_objects=max(n_tasks // 10, 32),
            object_bytes=10 * MB, compute_seconds=1.0, seed=seed),
        seed=seed)


def measure_policy_sweep(n_nodes: int, n_tasks: int, seed: int = 0,
                         out_dir: str | None = None) -> list[dict]:
    """Run the 4x2 seed-paired sweep; one summary dict per cell."""
    curves = demand_curves(n_nodes)
    sw = Sweep(base_spec(n_nodes, n_tasks, seed), {
        "workload.arrivals": [curves["bursty"], curves["diurnal"]],
        "provisioner.policy": list(ALLOCATION_POLICIES),
    }, name="provisioner-policies")
    cells = []
    for cell, rep in sw.run(out_dir=out_dir):
        curve = ("bursty" if cell.overrides["workload.arrivals"]["kind"]
                 == "BurstyArrivals" else "diurnal")
        cells.append({
            "curve": curve,
            "allocation_policy": cell.overrides["provisioner.policy"],
            "n_nodes": n_nodes, "n_tasks": n_tasks, "seed": seed,
            "wall_s": round(rep.wall_s, 4),
            "sim_makespan_s": rep.makespan_s,
            "n_completed": rep.n_completed,
            "n_allocated": rep.n_allocated,
            "n_released": rep.n_released,
            "peak_executors": rep.peak_executors,
            "avg_slowdown": rep.avg_slowdown,
            "p95_slowdown": rep.p95_slowdown,
            "performance_index": rep.performance_index,
            "executor_seconds": rep.executor_seconds,
            "cache_hit_ratio": rep.cache_hit_ratio,
        })
    return cells


def measure_schema_parity() -> bool:
    """The experiment-API contract, checked with teeth: one tiny spec on
    BOTH engines must yield reports that (a) share the full RunReport field
    schema with every field populated, and (b) *agree on every
    engine-independent quantity* -- both drained all n tasks, both account
    exactly one ledger access per input, both carry a pool history and a
    positive executor-seconds integral.  (Key-set equality alone would be
    tautological: both dicts come from the same dataclass.)"""
    n = 40
    spec = ExperimentSpec(
        name="parity",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=4),
        cache=CacheSpec(capacity_bytes=10**9),
        policy="max-compute-util",
        workload=WorkloadSpec(
            name="parity",
            arrivals={"kind": "PoissonArrivals", "rate_per_s": 50.0},
            popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 1,
                        "corr": 1.0},
            n_tasks=n, n_objects=16, object_bytes=MB,
            compute_seconds=0.001, seed=0),
        seed=0)
    d_sim = run_experiment(spec, engine="sim").as_dict()
    d_rt = run_experiment(spec, engine="runtime", timeout=60.0).as_dict()

    def accesses(d: dict) -> int:
        return d["local_hits"] + d["peer_hits"] + d["store_reads"]

    return all((
        tuple(d_sim) == RunReport.schema() == tuple(d_rt),
        all(v is not None for v in d_sim.values()),
        all(v is not None for v in d_rt.values()),
        d_sim["n_completed"] == n and d_rt["n_completed"] == n,
        accesses(d_sim) == n and accesses(d_rt) == n,   # 1 input per task
        len(d_sim["pool_log"]) >= 1 and len(d_rt["pool_log"]) >= 1,
        d_sim["executor_seconds"] > 0 and d_rt["executor_seconds"] > 0,
    ))


#: rebalance-study grid (kept small: 2 x 2 x 2 deterministic sim cells)
REBALANCE_NODES = 16
REBALANCE_TASKS = 1_000
REBALANCE_IDLE_TIMEOUTS = (2.0, 10.0)
REBALANCE_OBJECT_BYTES = (1 * MB, 50 * MB)


def rebalance_base_spec(n_nodes: int = REBALANCE_NODES,
                        n_tasks: int = REBALANCE_TASKS,
                        seed: int = 0) -> ExperimentSpec:
    """Two diurnal days over an elastic pool: each trough releases idle
    executors, each new day re-reads yesterday's working set."""
    return ExperimentSpec(
        name="rebalance",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=1),
        cache=CacheSpec(capacity_bytes=10**12),
        policy="max-compute-util",
        provisioner=ProvisionerSpec(
            policy="exponential", min_executors=1, max_executors=n_nodes,
            queue_threshold=2, idle_timeout_s=5.0, trigger_cooldown_s=1.0),
        workload=WorkloadSpec(
            name="rebalance",
            arrivals={"kind": "DiurnalArrivals", "peak_rate": float(n_nodes),
                      "trough_rate": 0.5, "day_s": 60.0},
            popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 1,
                        "corr": 1.0},
            n_tasks=n_tasks, n_objects=100, object_bytes=10 * MB,
            compute_seconds=1.0, seed=seed),
        seed=seed)


def measure_rebalance_sweep(n_nodes: int = REBALANCE_NODES,
                            n_tasks: int = REBALANCE_TASKS,
                            seed: int = 0,
                            out_dir: str | None = None) -> list[dict]:
    """The ROADMAP's remaining policy axis: idle_timeout x refill cost x
    release policy, one seed-paired grid (deterministic on the sim)."""
    sw = Sweep(rebalance_base_spec(n_nodes, n_tasks, seed), {
        "release_policy": ["discard", "rebalance"],
        "provisioner.idle_timeout_s": list(REBALANCE_IDLE_TIMEOUTS),
        "workload.object_bytes": list(REBALANCE_OBJECT_BYTES),
    }, name="release-rebalance")
    cells = []
    for cell, rep in sw.run(out_dir=out_dir):
        cells.append({
            "release_policy": cell.overrides["release_policy"],
            "idle_timeout_s": cell.overrides["provisioner.idle_timeout_s"],
            "object_bytes": cell.overrides["workload.object_bytes"],
            "n_nodes": n_nodes, "n_tasks": n_tasks, "seed": seed,
            "wall_s": round(rep.wall_s, 4),
            "n_completed": rep.n_completed,
            "n_released": rep.n_released,
            "cache_hit_ratio": rep.cache_hit_ratio,
            "store_reads": rep.store_reads,
            "bytes_store": rep.bytes_by_kind.get("store_read", 0.0),
            "avg_slowdown": rep.avg_slowdown,
            "performance_index": rep.performance_index,
        })
    return cells


def _rebalance_pair(cells: list[dict]) -> tuple[dict, dict]:
    """The aggressive cell pair the canary compares: shortest idle timeout
    (most cache lost to releases), smallest refill cost (fast store reads
    keep the pool churning, so releases actually bite mid-run)."""
    idle, ob = min(REBALANCE_IDLE_TIMEOUTS), min(REBALANCE_OBJECT_BYTES)
    pick = lambda pol: next(  # noqa: E731
        c for c in cells if c["release_policy"] == pol
        and c["idle_timeout_s"] == idle and c["object_bytes"] == ob)
    return pick("discard"), pick("rebalance")


def measure_rebalance_canary() -> dict:
    """Just the canary pair (2 sim runs, deterministic): rebalance must
    not lose cache-hit ratio vs discard under aggressive idle release."""
    base = rebalance_base_spec()
    overrides = {"provisioner.idle_timeout_s": min(REBALANCE_IDLE_TIMEOUTS),
                 "workload.object_bytes": min(REBALANCE_OBJECT_BYTES)}
    from repro.experiments import with_overrides
    reps = {}
    for pol in ("discard", "rebalance"):
        spec = with_overrides(base, dict(overrides, release_policy=pol))
        reps[pol] = run_experiment(spec, engine="sim")
    return {
        "rebalance_hit_advantage": round(
            reps["rebalance"].cache_hit_ratio
            - reps["discard"].cache_hit_ratio, 6),
        "store_bytes_saved": (reps["discard"].bytes_by_kind["store_read"]
                              - reps["rebalance"].bytes_by_kind["store_read"]),
        "n_released": reps["rebalance"].n_released,
    }


def _cell(cells: list[dict], curve: str, policy: str) -> dict:
    return next(c for c in cells
                if c["curve"] == curve and c["allocation_policy"] == policy)


def gate_measure(repeats: int = 3) -> dict:
    """The small fixed sweep bench_gate.py replays; best-of-N wall clock.
    Correctness canaries (policy ordering, schema parity, rebalance
    advantage) ride along -- the deterministic ones run once."""
    parity = measure_schema_parity()   # deterministic; once, not per repeat
    reb = measure_rebalance_canary()   # deterministic sim pair; once
    best = None
    for _ in range(repeats):
        cells = measure_policy_sweep(GATE_NODES, GATE_TASKS)
        exp = _cell(cells, "bursty", "exponential")
        one = _cell(cells, "bursty", "one-at-a-time")
        m = {
            "n_nodes": GATE_NODES, "n_tasks": GATE_TASKS,
            "wall_s": round(sum(c["wall_s"] for c in cells), 4),
            "n_completed": sum(c["n_completed"] for c in cells),
            "bursty_exp_avg_slowdown": exp["avg_slowdown"],
            "bursty_one_avg_slowdown": one["avg_slowdown"],
            "schema_parity": parity,
            "rebalance_hit_advantage": reb["rebalance_hit_advantage"],
            "rebalance_store_bytes_saved": reb["store_bytes_saved"],
        }
        if best is None or m["wall_s"] < best["wall_s"]:
            best = m
    return best


def run(scale: float = 1.0) -> list[dict]:
    """benchmarks.run contract: scaled-down policy study as CSV rows."""
    n_tasks = max(int(GATE_TASKS * scale), 200)
    cells = measure_policy_sweep(GATE_NODES, n_tasks)
    rows = [row("policies", "sweep_wall_s",
                round(sum(c["wall_s"] for c in cells), 4), "s",
                note=f"{GATE_NODES} nodes / {n_tasks} tasks x 8 cells "
                     f"(4 policies x 2 curves, seed-paired)")]
    for c in cells:
        key = f"{c['curve']}_{c['allocation_policy']}"
        rows.append(row("policies", f"{key}_avg_slowdown",
                        c["avg_slowdown"], "x",
                        note=f"+{c['n_allocated']}/-{c['n_released']} "
                             f"executors, PI {c['performance_index']:.3f}"))
    exp = _cell(cells, "bursty", "exponential")
    one = _cell(cells, "bursty", "one-at-a-time")
    rows.append(row("policies", "bursty_exp_beats_one_at_a_time",
                    1.0 if exp["avg_slowdown"] <= one["avg_slowdown"]
                    else 0.0, "bool",
                    note="0808.3535 flash-crowd ordering"))
    rows.append(row("policies", "schema_parity",
                    1.0 if measure_schema_parity() else 0.0, "bool",
                    note="sim + runtime RunReport field schemas identical"))
    reb_cells = measure_rebalance_sweep(
        REBALANCE_NODES, max(int(REBALANCE_TASKS * scale), 300))
    d, r = _rebalance_pair(reb_cells)
    rows.append(row("policies", "rebalance_hit_advantage",
                    round(r["cache_hit_ratio"] - d["cache_hit_ratio"], 4),
                    "ratio",
                    note=f"aggressive idle release: rebalance "
                         f"{r['cache_hit_ratio']:.3f} vs discard "
                         f"{d['cache_hit_ratio']:.3f} hit"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=GATE_NODES)
    ap.add_argument("--tasks", type=int, default=GATE_TASKS)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_policies.json")
    ap.add_argument("--sweep-dir", default=None,
                    help="also write the sweep manifest/results JSONL here")
    ap.add_argument("--primed", action="store_true",
                    help="warm the process heap with one joins-gate run "
                         "first (measure the baseline in the same process "
                         "state the full bench_gate run replays it in)")
    args = ap.parse_args(argv)

    if args.primed:
        from . import bench_joins
        bench_joins.gate_measure(repeats=1)
        print("# primed: one joins-gate pass ran first", file=sys.stderr)
    cells = measure_policy_sweep(args.nodes, args.tasks, args.seed,
                                 out_dir=args.sweep_dir)
    for c in cells:
        print(f"# {c['curve']:8s} {c['allocation_policy']:14s} "
              f"slowdown {c['avg_slowdown']:8.2f}x  "
              f"PI {c['performance_index']:.3f}  "
              f"+{c['n_allocated']}/-{c['n_released']} executors  "
              f"peak {c['peak_executors']}", file=sys.stderr)
    reb_cells = measure_rebalance_sweep(seed=args.seed)
    for c in reb_cells:
        print(f"# release={c['release_policy']:9s} "
              f"idle {c['idle_timeout_s']:4.1f}s  "
              f"refill {c['object_bytes'] // MB:3d}MB  "
              f"hit {c['cache_hit_ratio']:.4f}  "
              f"store {c['store_reads']:4d}  -{c['n_released']} released",
              file=sys.stderr)
    out = {"cells": cells, "rebalance_cells": reb_cells,
           "seed_paired": True, "gate": gate_measure()}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Shared helpers for the paper-figure benchmarks.

Every bench_* module exposes ``run(scale: float) -> list[dict]`` returning
rows with at least {bench, name, value, unit, paper} so run.py can emit one
CSV and EXPERIMENTS.md can cite paper-vs-measured side by side.
``scale`` shrinks workload sizes (task counts) -- the *rates* being measured
are scale-free once the system reaches steady state.
"""
from __future__ import annotations

import time
from typing import Optional

from repro.core import ANL_UC, DataObject, DispatchPolicy, Task, make_objects
from repro.core.cache import EvictionPolicy
from repro.core.simulator import DiffusionSim, SimConfig

MB = 10**6
Gb = 1e9 / 8.0   # gigabit in bytes


def row(bench: str, name: str, value: float, unit: str,
        paper: Optional[float] = None, note: str = "") -> dict:
    return {"bench": bench, "name": name, "value": round(value, 4),
            "unit": unit, "paper": paper, "note": note}


def microbench_sim(
    policy: DispatchPolicy,
    n_nodes: int,
    n_files: int,
    file_bytes: int,
    *,
    warm: bool = False,
    caching: bool = True,
    read_write: bool = False,
    repeats: int = 1,
    wrapper: bool = False,
    cache_gb: float = 400.0,
    seed: int = 0,
):
    """One §4.3 micro-benchmark configuration; returns SimResult."""
    cfg = SimConfig(
        testbed=ANL_UC, n_nodes=n_nodes, policy=policy,
        cache_capacity_bytes=int(cache_gb * 1e9),
        caching_enabled=caching,
        write_outputs_to="local" if caching else "store",
        seed=seed)
    sim = DiffusionSim(cfg)
    objs = make_objects("f", n_files, file_bytes)
    sim.add_objects(objs)
    if warm:
        sim.warm_caches(objs)
    tasks = []
    for r in range(repeats):
        for ob in objs:
            outs = ((DataObject(f"{ob.oid}.out{r}", file_bytes),)
                    if read_write else ())
            tasks.append(Task(inputs=(ob.oid,), outputs=outs,
                              store_metadata_ops=3 if wrapper else 0))
    sim.submit(tasks)
    return sim.run()


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0

"""§5 astronomy image stacking: Figures 8-13.

Replays the SDSS stacking workloads (Table 2 localities, GZ 2MB / FIT 6MB
files, §5.2-profiled per-task compute) through the diffusion simulator on
the ANL/UC testbed with 128 CPUs (64 dual-CPU nodes, as in the paper), for
both data diffusion (max-compute-util + caching) and the GPFS baseline
(next-available, no caching).

Outputs per (locality, mode, format): time-per-stack-per-CPU (Figs 8/9/11),
cache-hit ratio vs ideal 1-1/locality (Fig 10), aggregate and per-source
I/O throughput (Fig 12), and per-stack data movement (Fig 13)."""
from __future__ import annotations

from repro.configs.astro_stacking import (GZ_BYTES, WORKLOADS, workload)
from repro.core import ANL_UC, DispatchPolicy, Task, make_objects
from repro.core.simulator import DiffusionSim, SimConfig
from .common import Gb, MB, row


def _run_stacking(locality: float, diffusion: bool, compressed: bool,
                  scale: float, n_nodes: int = 64, cpus: int = 2):
    wl = workload(locality, compressed=compressed, scale=scale)
    cfg = SimConfig(
        testbed=ANL_UC, n_nodes=n_nodes, cpus_per_node=cpus,
        policy=(DispatchPolicy.MAX_COMPUTE_UTIL if diffusion
                else DispatchPolicy.NEXT_AVAILABLE),
        cache_capacity_bytes=50 * 10**9,
        caching_enabled=diffusion,
        write_outputs_to="none",
        seed=1)
    sim = DiffusionSim(cfg)
    objs = make_objects("img", wl.n_files, wl.file_bytes)
    sim.add_objects(objs)
    # one task per object; objects map onto files round-robin => each file
    # is accessed ~locality times (Table 2's structure)
    tasks = []
    for i in range(wl.n_objects):
        f = objs[i % wl.n_files]
        tasks.append(Task(inputs=(f.oid,),
                          compute_seconds=wl.compute_seconds))
    sim.submit(tasks)
    r = sim.run()
    n_cpus = n_nodes * cpus
    time_per_stack_per_cpu = r.busy_span * n_cpus / max(r.n_completed, 1)
    return r, wl, time_per_stack_per_cpu


def run(scale: float = 0.05) -> list[dict]:
    rows = []
    # ------- Fig 8/9: time per stack vs CPUs at locality 1.38 and 30 -------
    for locality, fig in ((1.38, "fig8"), (30, "fig9")):
        for n_nodes in (2, 8, 32, 64):
            for diffusion in (True, False):
                r, wl, tps = _run_stacking(locality, diffusion, True,
                                           scale, n_nodes=n_nodes)
                mode = "diffusion" if diffusion else "gpfs"
                rows.append(row(fig, f"{mode}_GZ_loc{locality}_{n_nodes * 2}cpu",
                                tps, "s/stack/cpu"))
    # ------- Fig 10/11/12/13: locality sweep at 128 CPUs -------------------
    for locality in (1, 2, 5, 10, 20, 30):
        r, wl, tps = _run_stacking(locality, True, True, scale)
        ideal = wl.ideal_cache_hit_ratio
        rows.append(row("fig10_hits", f"hit_ratio_loc{locality}",
                        r.global_hit_ratio, "ratio", paper=ideal,
                        note="paper: >=90% of ideal 1-1/locality"))
        rows.append(row("fig10_hits", f"hit_ratio_frac_of_ideal_loc{locality}",
                        r.global_hit_ratio / ideal if ideal else 1.0, "frac"))
        rows.append(row("fig11_time", f"diffusion_GZ_loc{locality}",
                        tps, "s/stack/cpu"))
        # Fig 12: I/O throughput split by source
        rows.append(row("fig12_io", f"local_Gbps_loc{locality}",
                        r.throughput_of(["local"]) / Gb, "Gb/s"))
        rows.append(row("fig12_io", f"c2c_Gbps_loc{locality}",
                        r.throughput_of(["c2c"]) / Gb, "Gb/s"))
        rows.append(row("fig12_io", f"gpfs_Gbps_loc{locality}",
                        r.throughput_of(["store_read"]) / Gb, "Gb/s"))
        rows.append(row("fig12_io", f"aggregate_Gbps_loc{locality}",
                        r.read_throughput() / Gb, "Gb/s",
                        paper=39.0 if locality == 30 else None))
        # Fig 13: data movement per stacking
        n = max(r.n_completed, 1)
        rows.append(row("fig13_move", f"gpfs_MB_per_stack_loc{locality}",
                        r.bytes_by_kind.get("store_read", 0) / n / MB, "MB",
                        paper=2.0 if locality == 1 else
                        (0.066 if locality == 30 else None)))
        rows.append(row("fig13_move", f"c2c_MB_per_stack_loc{locality}",
                        r.bytes_by_kind.get("c2c", 0) / n / MB, "MB",
                        paper=0.421 if locality == 30 else None))
        r2, _, tps2 = _run_stacking(locality, False, True, scale)
        rows.append(row("fig11_time", f"gpfs_GZ_loc{locality}", tps2,
                        "s/stack/cpu"))
        rows.append(row("fig12_io", f"gpfs_only_aggregate_Gbps_loc{locality}",
                        r2.read_throughput() / Gb, "Gb/s",
                        paper=4.0 if locality == 30 else None))
    # ------- Fig 7 crossover: GZ beats FIT at scale, loses at 1 CPU --------
    rf_1, _, tps_fit1 = _run_stacking(5, True, False, scale, n_nodes=1, cpus=1)
    rg_1, _, tps_gz1 = _run_stacking(5, True, True, scale, n_nodes=1, cpus=1)
    rf_n, _, tps_fitn = _run_stacking(5, False, False, scale)
    rg_n, _, tps_gzn = _run_stacking(5, False, True, scale)
    rows.append(row("fig7_profile", "single_cpu_gz_over_fit",
                    tps_gz1 / tps_fit1, "ratio",
                    note="paper: GZ slower on 1 CPU (decompress cost)"))
    rows.append(row("fig7_profile", "gpfs128_fit_over_gz",
                    tps_fitn / tps_gzn, "ratio",
                    note="paper: GZ faster at scale (3x fewer shared-FS bytes)"))
    return rows

"""Workload-subsystem benchmark: open-loop elasticity + trace replay cost.

Two scenarios, both through the full engine (DiffusionSim + provisioner +
repro.workloads):

  sine      the companion paper's (arXiv 0808.3535) sine-wave demand ramp at
            up to --nodes executors: measures the grow/shrink cycle
            (allocations, releases, performance index, avg slowdown) and the
            engine's wall-clock cost of heap-scheduled ARRIVAL events;
  zipf      a Zipf(1.1) replay: generates the workload, records it to JSONL,
            replays it, runs the replay, and asserts the replayed run's
            metrics fingerprint matches the direct run -- so the committed
            baseline also guards trace-format stability.

CLI (writes the committed baseline consumed by tools/bench_gate.py):

    PYTHONPATH=src python -m benchmarks.bench_workloads \
        --nodes 256 --tasks 20000 --out BENCH_workloads.json
"""
from __future__ import annotations

import argparse
import io
import json
import sys
import time

from repro.core import ANL_UC, DispatchPolicy, DynamicResourceProvisioner
from repro.core.provisioner import AllocationPolicy
from repro.core.simulator import DiffusionSim, SimConfig
from repro.workloads import (MetricsCollector, SineWaveArrivals,
                             ZipfPopularity, generate, record, replay)

from .common import row

MB = 10**6

#: the small fixed configuration tools/bench_gate.py replays against the
#: committed baseline (kept tiny so the gate costs seconds, not minutes)
GATE_NODES = 32
GATE_TASKS = 2_000


def _sine_workload(n_tasks: int, n_nodes: int, seed: int):
    # demand sized so the peak wants roughly the full pool and the trough
    # nearly none: mean = nodes/2 tasks/s at 1 s/task, 95% amplitude.
    mean = max(n_nodes / 2.0, 1.0)
    return generate(
        "sine", SineWaveArrivals(mean_rate=mean, amplitude=0.95 * mean,
                                 period_s=120.0),
        ZipfPopularity(1.1), n_tasks=n_tasks,
        n_objects=max(n_tasks // 20, 16), object_bytes=10 * MB,
        compute_seconds=1.0, seed=seed)


def _provisioner(n_nodes: int) -> DynamicResourceProvisioner:
    return DynamicResourceProvisioner(
        min_executors=1, max_executors=n_nodes,
        policy=AllocationPolicy.EXPONENTIAL, queue_threshold=2,
        idle_timeout_s=5.0, trigger_cooldown_s=1.0)


def _run(wl, n_nodes: int, provisioner=None, seed: int = 0):
    cfg = SimConfig(
        testbed=ANL_UC, n_nodes=1 if provisioner else n_nodes,
        policy=DispatchPolicy.MAX_COMPUTE_UTIL,
        cache_capacity_bytes=10**13, provisioner=provisioner, seed=seed)
    sim = DiffusionSim(cfg)
    sim.submit_workload(wl)
    t0 = time.perf_counter()
    r = sim.run()
    wall = time.perf_counter() - t0
    m = MetricsCollector(ANL_UC).collect(r, n_submitted=sim.n_submitted)
    return r, m, wall


def measure_sine(n_nodes: int, n_tasks: int, seed: int = 0) -> dict:
    """Elastic sine-wave run; the provisioner must grow AND shrink."""
    wl = _sine_workload(n_tasks, n_nodes, seed)
    prov = _provisioner(n_nodes)
    _, m, wall = _run(wl, n_nodes, provisioner=prov, seed=seed)
    return {
        "scenario": "sine", "n_nodes": n_nodes, "n_tasks": n_tasks,
        "wall_s": round(wall, 4),
        "sim_makespan_s": m.makespan_s,
        "n_completed": m.n_completed,
        "n_allocated": prov.n_allocated,
        "n_released": prov.n_released,
        "peak_executors": m.peak_executors,
        "low_executors": m.low_executors,
        "cache_hit_ratio": m.cache_hit_ratio,
        "avg_slowdown": m.avg_slowdown,
        "performance_index": m.performance_index,
        "tasks_per_wall_s": round(n_tasks / max(wall, 1e-9), 1),
    }


def measure_zipf_replay(n_nodes: int, n_tasks: int, seed: int = 0) -> dict:
    """Zipf workload: direct run vs JSONL-replayed run, identity-checked."""
    wl = generate(
        "zipf", SineWaveArrivals(mean_rate=max(n_nodes / 2.0, 1.0),
                                 amplitude=0.0, period_s=60.0),
        ZipfPopularity(1.1), n_tasks=n_tasks,
        n_objects=max(n_tasks // 10, 16), object_bytes=10 * MB,
        compute_seconds=0.2, seed=seed)
    buf = io.StringIO()
    t0 = time.perf_counter()
    record(wl, buf)
    record_s = time.perf_counter() - t0
    buf.seek(0)
    t0 = time.perf_counter()
    wl2 = replay(buf)
    replay_s = time.perf_counter() - t0
    _, m_direct, _ = _run(wl, n_nodes, seed=seed)
    _, m_replayed, wall = _run(wl2, n_nodes, seed=seed)
    return {
        "scenario": "zipf_replay", "n_nodes": n_nodes, "n_tasks": n_tasks,
        "wall_s": round(wall, 4),
        "record_s": round(record_s, 4),
        "replay_s": round(replay_s, 4),
        "sim_makespan_s": m_replayed.makespan_s,
        "n_completed": m_replayed.n_completed,
        "cache_hit_ratio": m_replayed.cache_hit_ratio,
        "avg_slowdown": m_replayed.avg_slowdown,
        "replay_identical": m_direct == m_replayed,
        "tasks_per_wall_s": round(n_tasks / max(wall, 1e-9), 1),
    }


def gate_measure(repeats: int = 3) -> dict:
    """The small fixed run bench_gate.py replays; best-of-N wall clock.

    Sums the sine + zipf-replay walls so the gate covers both the ARRIVAL
    path and the trace-replay path; the correctness canaries (completions,
    grow/shrink, replay identity) ride along.
    """
    best = None
    for _ in range(repeats):
        s = measure_sine(GATE_NODES, GATE_TASKS)
        z = measure_zipf_replay(GATE_NODES, GATE_TASKS)
        m = {
            "n_nodes": GATE_NODES, "n_tasks": GATE_TASKS,
            "wall_s": round(s["wall_s"] + z["wall_s"], 4),
            "n_completed": s["n_completed"] + z["n_completed"],
            "n_allocated": s["n_allocated"],
            "n_released": s["n_released"],
            "replay_identical": z["replay_identical"],
        }
        if best is None or m["wall_s"] < best["wall_s"]:
            best = m
    return best


def run(scale: float = 1.0) -> list[dict]:
    """benchmarks.run contract: scaled-down workload scenarios as CSV rows."""
    n_tasks = max(int(8_000 * scale), 800)
    s = measure_sine(GATE_NODES, n_tasks)
    z = measure_zipf_replay(GATE_NODES, n_tasks)
    return [
        row("workloads", "sine_wall_s", s["wall_s"], "s",
            note=f"{GATE_NODES} nodes / {n_tasks} tasks, elastic pool"),
        row("workloads", "sine_allocated", s["n_allocated"], "executors"),
        row("workloads", "sine_released", s["n_released"], "executors"),
        row("workloads", "sine_performance_index", s["performance_index"],
            "ratio", note="ideal core-s / allocated core-s (0808.3535 PI)"),
        row("workloads", "sine_avg_slowdown", s["avg_slowdown"], "x"),
        row("workloads", "zipf_replay_wall_s", z["wall_s"], "s"),
        row("workloads", "zipf_cache_hit_ratio", z["cache_hit_ratio"],
            "ratio"),
        row("workloads", "replay_identical",
            1.0 if z["replay_identical"] else 0.0, "bool",
            note="JSONL-replayed run metrics == direct run"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=256)
    ap.add_argument("--tasks", type=int, default=20_000)
    ap.add_argument("--out", default="BENCH_workloads.json")
    args = ap.parse_args(argv)

    sine = measure_sine(args.nodes, args.tasks)
    zipf = measure_zipf_replay(args.nodes, args.tasks)
    print(f"# sine: +{sine['n_allocated']}/-{sine['n_released']} executors, "
          f"PI {sine['performance_index']:.3f}, wall {sine['wall_s']}s",
          file=sys.stderr)
    print(f"# zipf replay: identical={zipf['replay_identical']}, "
          f"hit {zipf['cache_hit_ratio']:.3f}, wall {zipf['wall_s']}s",
          file=sys.stderr)
    out = {"sine": sine, "zipf_replay": zipf, "gate": gate_measure()}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

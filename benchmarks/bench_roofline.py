"""§Roofline: three-term roofline per (arch x shape) from the dry-run JSONs.

  compute    = HLO_FLOPs / (chips * 197 TFLOP/s bf16)
  memory     = HLO_bytes / (chips * 819 GB/s HBM)
  collective = collective_bytes / (chips * 50 GB/s ICI link)

HLO_FLOPs/HLO_bytes are chips * the per-device cost-analysis numbers
(loop-corrected, see launch/cellrun.py); collective_bytes likewise
chips * per-device HLO collective bytes, so every term reduces to
per-device work over per-device bandwidth.  MODEL_FLOPS = 6*N*D (dense) /
6*N_active*D (MoE); decode shapes process D = global_batch tokens/step."""
from __future__ import annotations

import json
import pathlib

from repro.configs import REGISTRY, SHAPES
from .common import row

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)


def model_flops(arch: str, shape_name: str) -> float:
    cfg = REGISTRY[arch]
    shape = SHAPES[shape_name]
    n = cfg.param_count(active_only=cfg.n_experts > 0)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch        # decode: one token per seq


def analyse(rec: dict) -> dict:
    chips = rec["n_devices"]
    flops = rec["per_device_flops"]            # per device
    bytes_ = rec["per_device_bytes"]
    coll = sum(rec.get("collective_per_device", {}).values())
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops * chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "step_time_bound_s": max(terms.values()),
        "mfu_bound": (mf / chips / PEAK_FLOPS) / max(terms.values())
        if max(terms.values()) > 0 else 0.0,
        "peak_gb": rec.get("peak_bytes_per_device", 0) / 1e9,
    }


def run(scale: float = 1.0, dryrun_dir: str = "results/dryrun") -> list[dict]:
    rows = []
    d = pathlib.Path(dryrun_dir)
    if not d.exists():
        rows.append(row("roofline", "missing_dryrun_results", 0, "n/a",
                        note="run: python -m repro.launch.dryrun --all"))
        return rows
    for f in sorted(d.glob("*__single_pod_16x16.json")):
        rec = json.loads(f.read_text())
        if not rec.get("ok"):
            continue
        a = analyse(rec)
        tag = f"{a['arch']}__{a['shape']}"
        rows.append(row("roofline", f"{tag}__dominant_{a['dominant']}",
                        a["step_time_bound_s"], "s",
                        note=f"mfu_bound={a['mfu_bound']:.3f} "
                             f"useful={a['useful_ratio']:.2f} "
                             f"peak={a['peak_gb']:.1f}GB"))
    return rows

"""DAG-workload benchmark: ready-set dispatch + producer-output placement,
with the PR's acceptance checks built in as canaries:

  all_pairs    N=24 extracts -> 576 pair comparisons over PRODUCED features
               on 16 nodes under max-compute-util, run twice: producer-
               placement scoring (``score_outputs=True``, the default) vs
               the outputs-ignored baseline (``score_outputs=False``, every
               produced-feature read unhinted).  Producer placement must
               WIN on global cache-hit ratio -- the reason §11's scoring
               folds dep-produced outputs into the cached-byte score;
  scores       the producer-placement run probed per dispatch round: the
               incremental executor->score maps (now covering produced
               oids) must bit-match ``reference_scores()``;
  reduce_tree  a 64-leaf fanin-4 reduction pyramid: transitive release
               through four levels, all tasks complete, makespan recorded;
  dep_free     a fixed flat Zipf workload run under BOTH score_outputs
               settings: RunMetrics must be bit-identical (the knob -- and
               the whole DAG layer -- is inert on dep-free workloads), and
               their fingerprint must match the committed baseline's
               (bit-parity with the pre-DAG dispatcher).

CLI (writes the committed baseline consumed by tools/bench_gate.py):

    PYTHONPATH=src python -m benchmarks.bench_dags --out BENCH_dags.json
"""
from __future__ import annotations

import argparse
import hashlib
import json
import sys
import time

from repro.core import ANL_UC, DispatchPolicy
from repro.core.simulator import DiffusionSim, SimConfig
from repro.workloads import (MetricsCollector, PoissonArrivals,
                             ZipfPopularity, all_pairs, generate, reduce_tree)

from .common import row

MB = 10**6

#: the small fixed configuration tools/bench_gate.py replays against the
#: committed baseline: N=24 all-pairs (24 extracts + 576 pairs) on 16 nodes
GATE_NODES = 16
GATE_N = 24
GATE_TASKS = GATE_N + GATE_N * GATE_N
#: dispatch rounds probed for incremental-vs-reference score equality
SCORE_PROBES = 250


def _ap_workload(n: int):
    # big catalog images, small hot features: pair tasks read ONLY produced
    # features, so placement of producer outputs decides the hit ratio
    return all_pairs("apbench", n_objects=n, object_bytes=10 * MB,
                     feature_bytes=2 * MB, extract_seconds=0.1,
                     pair_seconds=0.02)


def _rt_workload():
    return reduce_tree("rtbench", n_leaves=64, fanin=4,
                       object_bytes=10 * MB, partial_bytes=2 * MB,
                       leaf_seconds=0.1, reduce_seconds=0.05)


def _dep_free_workload():
    return generate(
        "dfbench", PoissonArrivals(8.0), ZipfPopularity(alpha=1.1),
        n_tasks=400, n_objects=64, object_bytes=10 * MB,
        compute_seconds=0.1, seed=11)


def _run(wl, n_nodes: int, seed: int = 0, score_outputs: bool = True,
         probe_scores: bool = False):
    cfg = SimConfig(testbed=ANL_UC, n_nodes=n_nodes,
                    policy=DispatchPolicy.MAX_COMPUTE_UTIL,
                    cache_capacity_bytes=10**12, seed=seed)
    sim = DiffusionSim(cfg)
    sim.dispatcher.score_outputs = score_outputs
    checks = {"probed": 0, "ok": True}
    if probe_scores:
        orig = sim.dispatcher.next_dispatches

        def checked(now):
            if checks["probed"] < SCORE_PROBES:
                checks["probed"] += 1
                if not sim.dispatcher.scores_match_reference():
                    checks["ok"] = False
            return orig(now)

        sim.dispatcher.next_dispatches = checked
    sim.submit_workload(wl)
    t0 = time.perf_counter()
    r = sim.run()
    wall = time.perf_counter() - t0
    m = MetricsCollector(ANL_UC).collect(r, n_submitted=sim.n_submitted)
    return m, wall, checks


def _fingerprint(m) -> str:
    """Stable content hash of a RunMetrics (bit-parity comparisons)."""
    return hashlib.sha256(
        json.dumps(m.as_dict(), sort_keys=True).encode()).hexdigest()[:16]


def measure_all_pairs(n_nodes: int, n: int, seed: int = 0) -> dict:
    """Producer-placement scoring vs the outputs-ignored baseline."""
    wl = _ap_workload(n)
    pp, wall_pp, checks = _run(wl, n_nodes, seed, score_outputs=True,
                               probe_scores=True)
    ign, wall_ign, _ = _run(wl, n_nodes, seed, score_outputs=False)
    return {
        "scenario": "all_pairs", "n_nodes": n_nodes, "n": n,
        "n_tasks": len(wl),
        "wall_s": round(wall_pp + wall_ign, 4),
        "n_completed": pp.n_completed + ign.n_completed,
        "pp_cache_hit_ratio": pp.cache_hit_ratio,
        "ignored_cache_hit_ratio": ign.cache_hit_ratio,
        "hit_delta": pp.cache_hit_ratio - ign.cache_hit_ratio,
        "pp_slowdown_from_ready": pp.slowdown_from_ready,
        "pp_slowdown_from_arrival": pp.slowdown_from_arrival,
        "scores_match_reference": bool(checks["ok"] and checks["probed"] > 0),
        "score_probes": checks["probed"],
    }


def measure_reduce_tree(n_nodes: int, seed: int = 0) -> dict:
    """Transitive release through a 4-level pyramid; makespan recorded."""
    wl = _rt_workload()
    m, wall, _ = _run(wl, n_nodes, seed)
    return {
        "scenario": "reduce_tree", "n_nodes": n_nodes, "n_tasks": len(wl),
        "wall_s": round(wall, 4),
        "n_completed": m.n_completed,
        "n_failed": m.n_failed,
        "all_completed": m.n_completed == len(wl),
        "makespan_s": m.makespan_s,
        "cache_hit_ratio": m.cache_hit_ratio,
    }


def measure_dep_free(n_nodes: int, seed: int = 0) -> dict:
    """Dep-free bit-identity: the score_outputs knob (and the whole DAG
    layer) must be inert on a flat workload."""
    wl = _dep_free_workload()
    m_on, wall, _ = _run(wl, n_nodes, seed, score_outputs=True)
    m_off, _, _ = _run(wl, n_nodes, seed, score_outputs=False)
    return {
        "scenario": "dep_free", "n_nodes": n_nodes, "n_tasks": len(wl),
        "wall_s": round(wall, 4),
        "n_completed": m_on.n_completed,
        "knob_inert": m_on == m_off,
        "fingerprint": _fingerprint(m_on),
    }


def gate_measure(repeats: int = 3) -> dict:
    """The small fixed run bench_gate.py replays; best-of-N wall clock."""
    best = None
    for _ in range(repeats):
        a = measure_all_pairs(GATE_NODES, GATE_N)
        t = measure_reduce_tree(GATE_NODES)
        d = measure_dep_free(GATE_NODES)
        m = {
            "n_nodes": GATE_NODES, "n_tasks": GATE_TASKS,
            "wall_s": round(a["wall_s"] + t["wall_s"] + d["wall_s"], 4),
            "n_completed": (a["n_completed"] + t["n_completed"]
                            + d["n_completed"]),
            "pp_cache_hit_ratio": a["pp_cache_hit_ratio"],
            "ignored_cache_hit_ratio": a["ignored_cache_hit_ratio"],
            "hit_delta": a["hit_delta"],
            "scores_match_reference": a["scores_match_reference"],
            "tree_all_completed": t["all_completed"],
            "tree_makespan_s": t["makespan_s"],
            "dep_free_knob_inert": d["knob_inert"],
            "dep_free_fingerprint": d["fingerprint"],
        }
        if best is None or m["wall_s"] < best["wall_s"]:
            best = m
    return best


def run(scale: float = 1.0) -> list[dict]:
    """benchmarks.run contract: DAG scenarios as CSV rows."""
    n = max(int(GATE_N * max(scale, 0.25)), 8)
    a = measure_all_pairs(GATE_NODES, n)
    t = measure_reduce_tree(GATE_NODES)
    d = measure_dep_free(GATE_NODES)
    return [
        row("dags", "all_pairs_wall_s", a["wall_s"], "s",
            note=f"{GATE_NODES} nodes, N={n} ({a['n_tasks']} tasks) x 2 "
                 f"scoring modes"),
        row("dags", "pp_cache_hit_ratio", a["pp_cache_hit_ratio"], "ratio",
            note="producer-placement scoring (score_outputs=True)"),
        row("dags", "ignored_cache_hit_ratio", a["ignored_cache_hit_ratio"],
            "ratio", note="outputs-ignored baseline"),
        row("dags", "hit_delta", a["hit_delta"], "ratio",
            note="producer-placement minus outputs-ignored (must be > 0)"),
        row("dags", "scores_match_reference",
            1.0 if a["scores_match_reference"] else 0.0, "bool",
            note=f"incremental == brute force over {a['score_probes']} "
                 f"dispatch rounds, produced oids included"),
        row("dags", "reduce_tree_makespan_s", t["makespan_s"], "sim-s",
            note="64 leaves, fanin 4, all levels released and drained"),
        row("dags", "dep_free_knob_inert", 1.0 if d["knob_inert"] else 0.0,
            "bool", note="flat workload bit-identical under both scoring "
                         "modes"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=GATE_NODES)
    ap.add_argument("--n", type=int, default=GATE_N)
    ap.add_argument("--out", default="BENCH_dags.json")
    args = ap.parse_args(argv)

    a = measure_all_pairs(args.nodes, args.n)
    t = measure_reduce_tree(args.nodes)
    d = measure_dep_free(args.nodes)
    print(f"# all_pairs: pp {a['pp_cache_hit_ratio']:.3f} vs ignored "
          f"{a['ignored_cache_hit_ratio']:.3f} (+{a['hit_delta']:.3f}), "
          f"scores_match={a['scores_match_reference']}, wall {a['wall_s']}s",
          file=sys.stderr)
    print(f"# reduce_tree: completed {t['n_completed']}/{t['n_tasks']}, "
          f"makespan {t['makespan_s']:.1f} sim-s", file=sys.stderr)
    print(f"# dep_free: knob_inert={d['knob_inert']} "
          f"fingerprint={d['fingerprint']}", file=sys.stderr)
    out = {"all_pairs": a, "reduce_tree": t, "dep_free": d,
           "gate": gate_measure()}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

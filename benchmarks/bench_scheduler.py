"""Falkon dispatcher throughput (§3.1/§3.2.3 anchors).

Measures the REAL Dispatcher's decision throughput (not the simulator):
non-data-aware dispatch (paper: 3800 tasks/s on 2008's 8-core box) and
data-aware dispatch with window matching (budget: 2.1 ms/decision)."""
from __future__ import annotations

import time

from repro.core import DispatchPolicy, LocationIndex, Task
from repro.core.scheduler import Dispatcher
from .common import row


def _throughput(policy: DispatchPolicy, n_tasks: int, n_exec: int = 64,
                with_index: bool = True) -> float:
    d = Dispatcher(policy)
    for i in range(n_exec):
        d.executor_joined(f"e{i}", 0.0)
    if with_index:
        for i in range(n_tasks):
            d.index.insert(f"o{i}", f"e{i % n_exec}")
            d.sizes[f"o{i}"] = 1000
    tasks = [Task(inputs=(f"o{i}",)) for i in range(n_tasks)]
    d.submit(tasks, 0.0)
    t0 = time.perf_counter()
    done = 0
    now = 0.0
    while done < n_tasks:
        out = d.next_dispatches(now)
        if not out:
            break
        for disp in out:
            d.task_finished(disp.task, now)
            done += 1
        now += 1.0
    dt = time.perf_counter() - t0
    return done / dt


def run(scale: float = 1.0) -> list[dict]:
    n = max(int(20_000 * scale), 2_000)
    rows = []
    fa = _throughput(DispatchPolicy.FIRST_AVAILABLE, n, with_index=False)
    rows.append(row("falkon_dispatch", "first_available_tasks_per_s", fa,
                    "tasks/s", paper=3800.0,
                    note="paper: 3800/s on 8-core 2008 Xeon; 1 core here"))
    mcu = _throughput(DispatchPolicy.MAX_COMPUTE_UTIL, n)
    rows.append(row("falkon_dispatch", "max_compute_util_tasks_per_s", mcu,
                    "tasks/s"))
    rows.append(row("falkon_dispatch", "data_aware_decision_ms",
                    1e3 / max(mcu, 1e-9), "ms", paper=2.1,
                    note="paper budget: 2.1 ms/decision"))
    return rows

"""Join-workload benchmark: k-input tasks + partial-overlap data-aware
dispatch, with the PR's three acceptance checks built in as canaries:

  overlap   k=3 correlated Zipf joins on >= 64 executors, run under
            max-cache-hit (partial-overlap scoring) AND first-available:
            data-aware dispatch must WIN on cache_hit_ratio -- the
            0808.3535 claim this layer exists to reproduce;
  scores    the same workload under max-compute-util with a per-dispatch
            probe: the dispatcher's incremental executor->score maps must
            bit-match its brute-force ``reference_scores()`` before every
            sampled dispatch round;
  v1        the committed single-input v1 trace (tests/data/trace_v1.jsonl)
            replayed through the v2 reader must run to RunMetrics
            bit-identical to regenerating the same workload from its seed.

CLI (writes the committed baseline consumed by tools/bench_gate.py):

    PYTHONPATH=src python -m benchmarks.bench_joins --out BENCH_joins.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.core import ANL_UC, DispatchPolicy
from repro.core.simulator import DiffusionSim, SimConfig
from repro.workloads import (MetricsCollector, PoissonArrivals,
                             ZipfPopularity, generate, replay)

from .common import row

MB = 10**6
V1_FIXTURE = Path(__file__).resolve().parents[1] / "tests/data/trace_v1.jsonl"

#: the small fixed configuration tools/bench_gate.py replays against the
#: committed baseline (>= 64 executors per the acceptance criteria)
GATE_NODES = 64
GATE_TASKS = 3_000
#: dispatch rounds probed for incremental-vs-reference score equality
SCORE_PROBES = 250


def _join_workload(n_tasks: int, n_nodes: int, seed: int):
    # k=3 correlated Zipf joins; catalog sized so caches must churn a bit
    # and arrival rate sized so the pool stays busy without unbounded queue
    return generate(
        "joins", PoissonArrivals(max(n_nodes / 2.0, 4.0)),
        ZipfPopularity(alpha=1.1, k=3, corr=0.8),
        n_tasks=n_tasks, n_objects=max(n_tasks // 10, 64),
        object_bytes=10 * MB, compute_seconds=0.2, seed=seed)


def _run(wl, n_nodes: int, policy: DispatchPolicy, seed: int = 0,
         probe_scores: bool = False):
    cfg = SimConfig(testbed=ANL_UC, n_nodes=n_nodes, policy=policy,
                    cache_capacity_bytes=10**12, seed=seed)
    sim = DiffusionSim(cfg)
    checks = {"probed": 0, "ok": True}
    if probe_scores:
        orig = sim.dispatcher.next_dispatches

        def checked(now):
            if checks["probed"] < SCORE_PROBES:
                checks["probed"] += 1
                if not sim.dispatcher.scores_match_reference():
                    checks["ok"] = False
            return orig(now)

        sim.dispatcher.next_dispatches = checked
    sim.submit_workload(wl)
    t0 = time.perf_counter()
    r = sim.run()
    wall = time.perf_counter() - t0
    m = MetricsCollector(ANL_UC).collect(r, n_submitted=sim.n_submitted)
    return m, wall, checks


def measure_overlap(n_nodes: int, n_tasks: int, seed: int = 0) -> dict:
    """max-cache-hit (partial-overlap scoring) vs first-available."""
    wl = _join_workload(n_tasks, n_nodes, seed)
    mch, wall_mch, _ = _run(wl, n_nodes, DispatchPolicy.MAX_CACHE_HIT, seed)
    fa, wall_fa, _ = _run(wl, n_nodes, DispatchPolicy.FIRST_AVAILABLE, seed)
    mcu, wall_mcu, checks = _run(wl, n_nodes, DispatchPolicy.MAX_COMPUTE_UTIL,
                                 seed, probe_scores=True)
    return {
        "scenario": "joins_overlap", "n_nodes": n_nodes, "n_tasks": n_tasks,
        "k": 3, "corr": 0.8,
        "wall_s": round(wall_mch + wall_fa + wall_mcu, 4),
        "n_completed": mch.n_completed + fa.n_completed + mcu.n_completed,
        "mean_inputs_per_task": mch.mean_inputs_per_task,
        "mch_cache_hit_ratio": mch.cache_hit_ratio,
        "fa_cache_hit_ratio": fa.cache_hit_ratio,
        "mcu_cache_hit_ratio": mcu.cache_hit_ratio,
        "hit_advantage": mch.cache_hit_ratio - fa.cache_hit_ratio,
        "mch_partial_hit_tasks": mch.partial_hit_tasks,
        "mch_full_hit_tasks": mch.full_hit_tasks,
        "scores_match_reference": bool(checks["ok"] and checks["probed"] > 0),
        "score_probes": checks["probed"],
        "tasks_per_wall_s": round(3 * n_tasks / max(
            wall_mch + wall_fa + wall_mcu, 1e-9), 1),
    }


def v1_equivalent_workload():
    """THE generation recipe tests/data/trace_v1.jsonl was recorded from.

    Single source of truth -- tests/test_workload_trace.py imports this, so
    the fixture, the test and the gate canary can never drift apart.  If
    the fixture is ever regenerated, change only this function."""
    return generate(
        "v1fix", PoissonArrivals(6.0), ZipfPopularity(alpha=1.0),
        n_tasks=60, n_objects=12, object_bytes=3 * MB,
        compute_seconds=0.02, output_bytes=MB,
        store_metadata_ops=1, seed=13)


def measure_v1_replay(n_nodes: int = 8, seed: int = 0) -> dict:
    """Committed v1 trace -> v2 reader -> bit-identical RunMetrics."""
    wl_replayed = replay(V1_FIXTURE)
    wl_direct = v1_equivalent_workload()
    m_rep, wall, _ = _run(wl_replayed, n_nodes,
                          DispatchPolicy.MAX_COMPUTE_UTIL, seed)
    m_dir, _, _ = _run(wl_direct, n_nodes,
                       DispatchPolicy.MAX_COMPUTE_UTIL, seed)
    return {
        "scenario": "v1_replay", "n_nodes": n_nodes,
        "wall_s": round(wall, 4),
        "n_completed": m_rep.n_completed,
        "v1_replay_identical": m_rep == m_dir,
    }


def gate_measure(repeats: int = 3) -> dict:
    """The small fixed run bench_gate.py replays; best-of-N wall clock."""
    best = None
    for _ in range(repeats):
        o = measure_overlap(GATE_NODES, GATE_TASKS)
        v = measure_v1_replay()
        m = {
            "n_nodes": GATE_NODES, "n_tasks": GATE_TASKS,
            "wall_s": round(o["wall_s"] + v["wall_s"], 4),
            "n_completed": o["n_completed"] + v["n_completed"],
            "mch_cache_hit_ratio": o["mch_cache_hit_ratio"],
            "fa_cache_hit_ratio": o["fa_cache_hit_ratio"],
            "hit_advantage": o["hit_advantage"],
            "scores_match_reference": o["scores_match_reference"],
            "v1_replay_identical": v["v1_replay_identical"],
        }
        if best is None or m["wall_s"] < best["wall_s"]:
            best = m
    return best


def run(scale: float = 1.0) -> list[dict]:
    """benchmarks.run contract: scaled-down join scenarios as CSV rows."""
    n_tasks = max(int(GATE_TASKS * scale), 500)
    o = measure_overlap(GATE_NODES, n_tasks)
    v = measure_v1_replay()
    return [
        row("joins", "overlap_wall_s", o["wall_s"], "s",
            note=f"{GATE_NODES} nodes / {n_tasks} k=3 tasks x 3 policies"),
        row("joins", "mch_cache_hit_ratio", o["mch_cache_hit_ratio"],
            "ratio", note="max-cache-hit, partial-overlap scoring"),
        row("joins", "fa_cache_hit_ratio", o["fa_cache_hit_ratio"], "ratio",
            note="first-available baseline"),
        row("joins", "hit_advantage", o["hit_advantage"], "ratio",
            note="data-aware minus data-unaware (must be > 0)"),
        row("joins", "scores_match_reference",
            1.0 if o["scores_match_reference"] else 0.0, "bool",
            note=f"incremental == brute force over {o['score_probes']} "
                 f"dispatch rounds"),
        row("joins", "v1_replay_identical",
            1.0 if v["v1_replay_identical"] else 0.0, "bool",
            note="v1 JSONL fixture -> bit-identical RunMetrics"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=GATE_NODES)
    ap.add_argument("--tasks", type=int, default=GATE_TASKS)
    ap.add_argument("--out", default="BENCH_joins.json")
    args = ap.parse_args(argv)

    o = measure_overlap(args.nodes, args.tasks)
    v = measure_v1_replay()
    print(f"# overlap: mch {o['mch_cache_hit_ratio']:.3f} vs fa "
          f"{o['fa_cache_hit_ratio']:.3f} (+{o['hit_advantage']:.3f}), "
          f"scores_match={o['scores_match_reference']}, wall {o['wall_s']}s",
          file=sys.stderr)
    print(f"# v1 replay: identical={v['v1_replay_identical']}",
          file=sys.stderr)
    out = {"overlap": o, "v1_replay": v, "gate": gate_measure()}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Sim-engine scaling: incremental flow solver + indexed dispatch vs naive.

Not a paper figure -- this measures OUR discrete-event engine, because the
paper's headline claim (aggregate throughput scales linearly with cache-node
count) can only be demonstrated if the simulator itself stays tractable at
10^5 tasks x 10^2 nodes.  The naive reference solver reprices every live
flow and re-pushes every ETA event on every flow start/finish (O(F^2) event
storm); the incremental solver reprices only flows sharing a dirty resource
and skips re-pushes when a rate is unchanged (DESIGN.md §3).  Both produce
bit-identical results (tests/test_flow_equivalence.py), so the comparison
is pure engine cost.

CLI (writes the committed baseline consumed by tools/bench_gate.py):

    PYTHONPATH=src python -m benchmarks.bench_engine \
        --nodes 256 --tasks 50000 --out BENCH_engine.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core import ANL_UC, DispatchPolicy, make_objects, uniform_tasks
from repro.core.simulator import DiffusionSim, SimConfig

from .common import row

MB = 10**6

#: the small fixed configuration tools/bench_gate.py replays against the
#: committed baseline (kept tiny so the gate costs seconds, not minutes)
GATE_NODES = 32
GATE_TASKS = 2_000


def measure(n_nodes: int, n_tasks: int, solver: str, *,
            locality: int = 4, file_mb: int = 10,
            compute_seconds: float = 0.05, seed: int = 0) -> dict:
    """One engine run; returns wall-clock + event-count observables."""
    n_objs = max(n_tasks // locality, 1)
    cfg = SimConfig(
        testbed=ANL_UC, n_nodes=n_nodes,
        policy=DispatchPolicy.MAX_COMPUTE_UTIL,
        cache_capacity_bytes=10**13,
        flow_solver=solver, seed=seed)
    sim = DiffusionSim(cfg)
    objs = make_objects("f", n_objs, file_mb * MB)
    sim.add_objects(objs)
    sim.warm_caches(objs)
    tasks = uniform_tasks(objs, accesses_per_object=locality,
                          compute_seconds=compute_seconds)[:n_tasks]
    t0 = time.perf_counter()
    sim.submit(tasks)
    r = sim.run()
    wall = time.perf_counter() - t0
    return {
        "solver": solver,
        "n_nodes": n_nodes,
        "n_tasks": n_tasks,
        "wall_s": round(wall, 4),
        "sim_makespan_s": r.makespan,
        "n_completed": r.n_completed,
        "loop_events_scheduled": sim.loop.n_scheduled,
        "flow_events_scheduled": sim.net.n_events_scheduled,
        "flow_event_skips": sim.net.n_event_skips,
        "rate_recomputes": sim.net.n_rate_recomputes,
        "rebalances": sim.net.n_rebalances,
        "bytes_by_kind": {k: v for k, v in sorted(r.bytes_by_kind.items())},
        "local_hits": r.local_hits,
        "peer_hits": r.peer_hits,
        "store_reads": r.store_reads,
        "tasks_per_wall_s": round(n_tasks / max(wall, 1e-9), 1),
    }


def _result_fingerprint(m: dict) -> tuple:
    return (m["sim_makespan_s"], m["n_completed"],
            tuple(sorted(m["bytes_by_kind"].items())),
            m["local_hits"], m["peer_hits"], m["store_reads"])


def compare(n_nodes: int, n_tasks: int, **kw) -> dict:
    inc = measure(n_nodes, n_tasks, "incremental", **kw)
    nai = measure(n_nodes, n_tasks, "naive", **kw)
    return {
        "config": {"n_nodes": n_nodes, "n_tasks": n_tasks,
                   "testbed": ANL_UC.name, "policy": "max-compute-util",
                   "locality": kw.get("locality", 4),
                   "file_mb": kw.get("file_mb", 10)},
        "incremental": inc,
        "naive": nai,
        "speedup_wall": round(nai["wall_s"] / max(inc["wall_s"], 1e-9), 2),
        "flow_event_ratio": round(nai["flow_events_scheduled"]
                                  / max(inc["flow_events_scheduled"], 1), 2),
        "loop_event_ratio": round(nai["loop_events_scheduled"]
                                  / max(inc["loop_events_scheduled"], 1), 2),
        "results_identical": _result_fingerprint(inc) == _result_fingerprint(nai),
    }


def gate_measure(repeats: int = 3) -> dict:
    """The small fixed run bench_gate.py replays; best-of-N wall clock."""
    best = None
    for _ in range(repeats):
        m = measure(GATE_NODES, GATE_TASKS, "incremental")
        if best is None or m["wall_s"] < best["wall_s"]:
            best = m
    return best


def run(scale: float = 1.0) -> list[dict]:
    """benchmarks.run contract: scaled-down engine comparison as CSV rows."""
    n_tasks = max(int(8_000 * scale), 800)
    c = compare(GATE_NODES, n_tasks)
    rows = [
        row("engine", "incremental_wall_s", c["incremental"]["wall_s"], "s",
            note=f"{GATE_NODES} nodes / {n_tasks} tasks"),
        row("engine", "naive_wall_s", c["naive"]["wall_s"], "s"),
        row("engine", "speedup_wall", c["speedup_wall"], "x"),
        row("engine", "flow_event_ratio", c["flow_event_ratio"], "x",
            note="naive/incremental scheduled flow-ETA events"),
        row("engine", "results_identical", 1.0 if c["results_identical"] else 0.0,
            "bool", note="bit-identical SimResult across solvers"),
    ]
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nodes", type=int, default=256)
    ap.add_argument("--tasks", type=int, default=50_000)
    ap.add_argument("--out", default="BENCH_engine.json")
    ap.add_argument("--skip-naive", action="store_true",
                    help="only measure the incremental solver (quick look)")
    args = ap.parse_args(argv)

    if args.skip_naive:
        out = {"incremental": measure(args.nodes, args.tasks, "incremental")}
    else:
        out = compare(args.nodes, args.tasks)
        print(f"# speedup {out['speedup_wall']}x wall, "
              f"{out['flow_event_ratio']}x fewer flow events, "
              f"identical={out['results_identical']}", file=sys.stderr)
    out["gate"] = gate_measure()
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

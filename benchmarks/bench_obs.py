"""Observability benchmark: recording overhead + sim<->fleet agreement.

`repro.obs` promises (DESIGN.md §10) that recording is (a) free when off
-- every hot-path hook is one attribute read and a branch -- and cheap
when on: one short critical section on the recorder's own leaf lock,
never held across the dispatcher lock, never doing I/O.  This bench is
the measurement side, three canaries:

  overhead    the bench_dispatch completion STORM (real framed sockets,
              scripted hosts, instant completions -- the worst case for
              per-task fixed costs) run events-OFF and events-ON with
              the central recorder at default ring capacity; best-of-N
              **central-loop CPU** events-on must stay within 10% of
              events-off;
  drops       the events-on storm must lose nothing: ``dropped == 0``
              at DEFAULT_RING_CAPACITY (an under-provisioned ring
              degrades to a truncated trace by design, but the default
              must absorb a full storm);
  agreement   a serial replay (arrivals spaced >> service time,
              ``barrier_every=1``: every dispatch decision sees an
              all-idle pool) recorded on a real 2-host x 2-thread fleet,
              diffed per task against the sim twin's prediction --
              placement agreement must be >= 99% (it is exactly 100% in
              this regime; see §10 for why contended replay diverges).

CLI (writes the committed baseline consumed by tools/bench_gate.py):

    PYTHONPATH=src python -m benchmarks.bench_obs --out BENCH_obs.json
"""
from __future__ import annotations

import argparse
import json
import random
import sys

from repro.core import DataObject
from repro.experiments import (ClusterSpec, ExperimentSpec, ObserveSpec,
                               RuntimeEngine, SimEngine, WorkloadSpec)
from repro.obs import Recorder, diff_outcomes, lifecycle_fingerprints
from repro.obs.recorder import DEFAULT_RING_CAPACITY
from repro.workloads import TaskEvent, Workload

from . import bench_dispatch
from .common import row

#: fixed configuration tools/bench_gate.py replays against the baseline.
GATE_NODES = bench_dispatch.GATE_NODES     # storm pool (4 hosts x 48)
GATE_TASKS = 1200                          # storm tasks per overhead cell
PARITY_TASKS = 40                          # serial-replay agreement cell
STORM_WIRE_BATCH = 64


# --------------------------------------------------------------------------
# overhead: events-off vs events-on on the same storm
# --------------------------------------------------------------------------

def measure_overhead(n_tasks: int = GATE_TASKS, repeats: int = 3) -> dict:
    """Best-of-N central-loop CPU with and without a central recorder on
    identical scripted storms.  Wall clock on a 1-core box mostly measures
    the scripted hosts; central CPU is what the guarded hooks could tax."""
    best_off = best_on = None
    drops = emitted = 0
    for _ in range(repeats):
        off = bench_dispatch.measure_storm(STORM_WIRE_BATCH, n_tasks)
        rec = Recorder(DEFAULT_RING_CAPACITY)
        on = bench_dispatch.measure_storm(STORM_WIRE_BATCH, n_tasks,
                                          recorder=rec)
        if best_off is None or off["central_cpu_s"] < best_off["central_cpu_s"]:
            best_off = off
        if best_on is None or on["central_cpu_s"] < best_on["central_cpu_s"]:
            best_on = on
            drops, emitted = rec.dropped, rec.emitted
    return {
        "n_tasks": n_tasks,
        "n_completed": best_on["n_completed"],
        "wall_s": best_on["wall_s"],
        "central_cpu_off_s": best_off["central_cpu_s"],
        "central_cpu_on_s": best_on["central_cpu_s"],
        "overhead_ratio": round(best_on["central_cpu_s"]
                                / max(best_off["central_cpu_s"], 1e-9), 3),
        "events_emitted": emitted,
        "dropped": drops,
    }


# --------------------------------------------------------------------------
# agreement: serial replay, sim twin vs real fleet
# --------------------------------------------------------------------------

def serial_workload(n_tasks: int = PARITY_TASKS, seed: int = 7) -> Workload:
    """Arrivals 1 s apart vs ~50 ms anl_uc service time: every dispatch
    decision on every engine is made against an all-idle pool."""
    rng = random.Random(seed)
    objs = [DataObject(f"p.o{i}", 10_000) for i in range(12)]
    events = [TaskEvent(t=float(i), tid=f"p-{i}",
                        inputs=tuple(o.oid for o in rng.sample(objs, 2)),
                        outputs=(), compute_seconds=0.0,
                        store_metadata_ops=0)
              for i in range(n_tasks)]
    return Workload("obs-parity", objs, events, spec=None)


def _parity_spec(hosts: int, tph: int, n_tasks: int) -> ExperimentSpec:
    return ExperimentSpec(
        name="obs-agreement",
        cluster=ClusterSpec(testbed="anl_uc", n_nodes=4),
        policy="max-compute-util",
        workload=WorkloadSpec(
            name="obs",
            arrivals={"kind": "BatchArrivals", "at_s": 0.0},
            popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 2,
                        "corr": 1.0},
            n_tasks=n_tasks, n_objects=12, object_bytes=10_000, seed=7),
        observe=ObserveSpec(events=True),
        seed=3, hosts=hosts, threads_per_host=tph)


def measure_agreement(n_tasks: int = PARITY_TASKS) -> dict:
    """Sim twin vs a real 2x2 fleet on the serial workload: per-task
    placement agreement from `repro.obs.diff`, plus full lifecycle-
    fingerprint identity (kinds, placement, per-input source/bytes)."""
    wl = serial_workload(n_tasks)
    eng = SimEngine()
    try:
        eng.prepare(_parity_spec(0, 1, n_tasks), workload=wl)
        eng.run()
        sim_out, sim_fp = (eng.last_outcomes,
                           lifecycle_fingerprints(eng.recorder.events()))
    finally:
        eng.shutdown()
    eng = RuntimeEngine()
    try:
        eng.prepare(_parity_spec(2, 2, n_tasks), workload=wl)
        rep = eng.run(barrier_every=1, timeout=300.0)
        fleet_out, fleet_fp = (eng.last_outcomes,
                               lifecycle_fingerprints(eng.recorder.events()))
        fleet_dropped = eng.recorder.dropped
    finally:
        eng.shutdown()
    div = diff_outcomes(fleet_out, sim_out)
    return {
        "n_tasks": n_tasks,
        "n_completed": rep.n_completed,
        "n_matched": div["n_matched"],
        "placement_agreement": div["placement_agreement"],
        "bytes_agreement": div["bytes_agreement"],
        "fingerprints_identical": sim_fp == fleet_fp,
        "fleet_dropped": fleet_dropped,
    }


# --------------------------------------------------------------------------
# gate / CSV entry points
# --------------------------------------------------------------------------

def gate_measure(repeats: int = 3) -> dict:
    """The fixed shape bench_gate.py replays.  The gated wall is the
    events-on storm (best-of-N); the canaries are the overhead ratio, the
    drop count, and the sim<->fleet placement agreement."""
    # the on/off CPU ratio divides two ~100 ms measurements on a shared
    # box; the best-of-N floor needs more samples than the wall gate does
    ov = measure_overhead(GATE_TASKS, repeats=max(repeats, 5))
    ag = measure_agreement(PARITY_TASKS)
    return {
        "n_nodes": GATE_NODES, "n_tasks": GATE_TASKS,
        "wall_s": ov["wall_s"],
        "n_completed": ov["n_completed"],
        "central_cpu_off_s": ov["central_cpu_off_s"],
        "central_cpu_on_s": ov["central_cpu_on_s"],
        "overhead_ratio": ov["overhead_ratio"],
        "events_emitted": ov["events_emitted"],
        "dropped": ov["dropped"] + ag["fleet_dropped"],
        "placement_agreement": ag["placement_agreement"],
        "bytes_agreement": ag["bytes_agreement"],
        "fingerprints_identical": ag["fingerprints_identical"],
    }


def run(scale: float = 1.0) -> list[dict]:
    """benchmarks.run contract: overhead + agreement as CSV rows."""
    n_tasks = max(int(GATE_TASKS * scale), 100)
    ov = measure_overhead(n_tasks, repeats=1)
    rows = [
        row("obs", "events_on_overhead_ratio", ov["overhead_ratio"], "x",
            note=f"central-loop CPU, storm of {n_tasks}, on/off"),
        row("obs", "events_emitted", ov["events_emitted"], "events",
            note=f"dropped {ov['dropped']} at default ring "
                 f"({DEFAULT_RING_CAPACITY})"),
    ]
    ag = measure_agreement()
    rows.append(row("obs", "sim_fleet_placement_agreement",
                    ag["placement_agreement"], "ratio",
                    note=f"serial replay, {ag['n_matched']} tasks joined, "
                         f"fingerprints identical: "
                         f"{ag['fingerprints_identical']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=GATE_TASKS)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args(argv)

    ov = measure_overhead(args.tasks, repeats=args.repeats)
    print(f"# overhead: on {ov['central_cpu_on_s'] * 1e3:.1f} ms vs off "
          f"{ov['central_cpu_off_s'] * 1e3:.1f} ms central CPU "
          f"({ov['overhead_ratio']:.3f}x), {ov['events_emitted']} events, "
          f"{ov['dropped']} dropped", file=sys.stderr)
    ag = measure_agreement()
    print(f"# agreement: placement {ag['placement_agreement']:.3f}, bytes "
          f"{ag['bytes_agreement']:.3f}, fingerprints identical "
          f"{ag['fingerprints_identical']}", file=sys.stderr)
    out = {"overhead": ov, "agreement": ag,
           "gate": gate_measure(repeats=args.repeats)}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Fleet benchmark: aggregate cache bandwidth vs. data-cache node count.

The paper's headline scaling claim is that aggregate I/O bandwidth grows
with the number of data cache *nodes*.  This bench replays one fixed Zipf
trace (recorded + replayed through the JSONL layer, so the wire between
generator and engines is the committed trace format) on 1 / 2 / 4 host
processes of ``GATE_TPH`` executors each and records, per host count:

  cache_bw_bps   (local + cache-to-cache bytes) / drain wall  -- the
                 aggregate bandwidth served from caches (Figure 3's axis);
  peer_bw_bps    cache-to-cache bytes / wall (real socket transfers);
  tasks_per_s    drained throughput.

Tasks run `repro.fleet.runtime.io_dwell_task`: service time = input bytes
at ``BENCH_DISK_BW`` per node, so delivered bandwidth is bounded by how
many nodes serve concurrently -- the quantity under test -- while the
fleet layer's own dispatch/wire/peer overhead is exactly what separates
the measured curve from ideal.  The committed BENCH_fleet.json must show
cache_bw_bps increasing monotonically 1 -> 2 -> 4 hosts.

The gate also carries the *trace-replay parity canary*: the same recorded
trace replayed batch-synchronously (``barrier_every``) on the in-process
runtime and on a 2x2 fleet must produce IDENTICAL scheduling-determined
RunReport fields (drained counts, hit/peer/store split, byte ledger --
`repro.fleet.SCHEDULING_DETERMINED_FIELDS`).

CLI (writes the committed baseline consumed by tools/bench_gate.py):

    PYTHONPATH=src python -m benchmarks.bench_fleet --out BENCH_fleet.json
"""
from __future__ import annotations

import argparse
import io
import json
import sys
import time

from repro.experiments import (CacheSpec, ClusterSpec, ExperimentSpec,
                               WorkloadSpec, run_experiment)
from repro.fleet import FleetRuntime, reports_scheduling_equal
from repro.workloads import PoissonArrivals, ZipfPopularity, generate, record, replay

from .common import row

KB = 1000

#: fixed configuration tools/bench_gate.py replays against the baseline.
#: GATE_NODES is the largest cell's executor count (hosts * GATE_TPH).
GATE_HOSTS = (1, 2, 4)
GATE_TPH = 4
GATE_NODES = max(GATE_HOSTS) * GATE_TPH
GATE_TASKS = 400
OBJECT_BYTES = 768 * KB
N_OBJECTS = 64
#: per-executor cache: 4 caches hold ~half the catalog (eviction pressure
#: at 1 host), 16 caches hold ~2x of it -- more nodes = more cache, the
#: second axis of the paper's claim.
CACHE_CAPACITY = 6_000 * KB


def fleet_trace(n_tasks: int, seed: int = 0):
    """The fixed Zipf trace, round-tripped through JSONL record/replay so
    the bench drives the committed trace format, not just the generator."""
    wl = generate("fleet", PoissonArrivals(rate_per_s=100_000.0),
                  ZipfPopularity(1.1), n_tasks=n_tasks,
                  n_objects=N_OBJECTS, object_bytes=OBJECT_BYTES, seed=seed)
    buf = io.StringIO()
    record(wl, buf)
    buf.seek(0)
    return replay(buf)


def measure_scaling(hosts: int, wl, tph: int = GATE_TPH) -> dict:
    """One fleet cell: spawn, replay the trace free-running, drain.
    ``wall_s`` covers submit->drain only (spawn/teardown are setup)."""
    rt = FleetRuntime(hosts=hosts, threads_per_host=tph,
                      cache_capacity_bytes=CACHE_CAPACITY,
                      task_fn_name="repro.fleet.runtime:io_dwell_task")
    try:
        for ob in wl.objects:
            rt.put_object(ob, b"x" * ob.size_bytes)
        t0 = time.perf_counter()
        th = rt.submit_workload(wl, time_scale=0.0)
        th.join(600)
        drained = (not th.is_alive()) and rt.wait(600)
        wall = time.perf_counter() - t0
        lg = rt.ledger
        n = len(rt.dispatcher.completed)
        cache_bytes = lg.bytes_local + lg.bytes_c2c
        return {
            "hosts": hosts, "threads_per_host": tph,
            "executors": hosts * tph,
            "n_tasks": len(wl), "n_completed": n, "drained": drained,
            "wall_s": round(wall, 4),
            "cache_hit_ratio": round(lg.global_hit_ratio, 4),
            "local_hits": lg.local_hits, "peer_hits": lg.peer_hits,
            "store_reads": lg.store_reads,
            "cache_bw_bps": round(cache_bytes / wall, 1),
            "peer_bw_bps": round(lg.bytes_c2c / wall, 1),
            "tasks_per_s": round(n / wall, 1),
        }
    finally:
        rt.shutdown()


def measure_parity(n_tasks: int = 150, seed: int = 7) -> dict:
    """Trace-replay parity: one recorded trace, replayed batch-
    synchronously on the in-process runtime (hosts=0) and a 2x2 fleet;
    scheduling-determined RunReport fields must agree EXACTLY."""
    def spec(hosts, tph, n_nodes):
        return ExperimentSpec(
            name="fleet-parity",
            cluster=ClusterSpec(testbed="anl_uc", n_nodes=n_nodes),
            cache=CacheSpec(capacity_bytes=10**12),   # eviction-free
            policy="max-compute-util",
            workload=WorkloadSpec(
                name="fp",
                arrivals={"kind": "PoissonArrivals", "rate_per_s": 100.0},
                popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 2,
                            "corr": 0.8},
                n_tasks=n_tasks, n_objects=32, object_bytes=50 * KB,
                seed=seed),
            seed=3, hosts=hosts, threads_per_host=tph)

    wl = None   # each engine builds from the identical binding
    r_single = run_experiment(spec(0, 1, 4), engine="runtime", workload=wl,
                              barrier_every=4, timeout=300.0)
    r_fleet = run_experiment(spec(2, 2, 4), engine="runtime", workload=wl,
                             barrier_every=4, timeout=300.0)
    diff = reports_scheduling_equal(r_single, r_fleet)
    return {
        "parity": not diff and r_single.n_completed == n_tasks,
        "n_completed": r_single.n_completed,
        "diff_fields": sorted(diff),
        "hit_split": [r_fleet.local_hits, r_fleet.peer_hits,
                      r_fleet.store_reads],
    }


def _monotonic(cells: list[dict], key: str) -> bool:
    vals = [c[key] for c in sorted(cells, key=lambda c: c["hosts"])]
    return all(b > a for a, b in zip(vals, vals[1:]))


def gate_measure(repeats: int = 3) -> dict:
    """The fixed 1/2/4-host sweep bench_gate.py replays; best-of-N total
    drain wall.  Parity is deterministic and measured once."""
    par = measure_parity()
    wl = fleet_trace(GATE_TASKS)
    best = None
    for _ in range(repeats):
        cells = [measure_scaling(h, wl) for h in GATE_HOSTS]
        by_hosts = {c["hosts"]: c for c in cells}
        m = {
            "n_nodes": GATE_NODES, "n_tasks": GATE_TASKS,
            "wall_s": round(sum(c["wall_s"] for c in cells), 4),
            "n_completed": sum(c["n_completed"] for c in cells),
            "all_drained": all(c["drained"] for c in cells),
            "cache_bw_1host": by_hosts[1]["cache_bw_bps"],
            "cache_bw_4host": by_hosts[4]["cache_bw_bps"],
            "bw_monotonic": _monotonic(cells, "cache_bw_bps"),
            "parity": par["parity"],
        }
        if best is None or m["wall_s"] < best["wall_s"]:
            best = m
    return best


def run(scale: float = 1.0) -> list[dict]:
    """benchmarks.run contract: the scaling curve + parity as CSV rows."""
    n_tasks = max(int(GATE_TASKS * scale), 100)
    wl = fleet_trace(n_tasks)
    cells = [measure_scaling(h, wl) for h in GATE_HOSTS]
    rows = []
    for c in cells:
        rows.append(row(
            "fleet", f"cache_bw_{c['hosts']}hosts_mbps",
            round(c["cache_bw_bps"] / 1e6, 1), "MB/s",
            paper="Fig 3",
            note=f"{c['executors']} executors, hit {c['cache_hit_ratio']}, "
                 f"peer {round(c['peer_bw_bps'] / 1e6, 2)} MB/s, "
                 f"{c['tasks_per_s']} tasks/s"))
    rows.append(row("fleet", "cache_bw_monotonic_1_2_4",
                    1.0 if _monotonic(cells, "cache_bw_bps") else 0.0,
                    "bool", note="aggregate cache bandwidth grows with "
                                 "host count"))
    par = measure_parity()
    rows.append(row("fleet", "trace_replay_parity",
                    1.0 if par["parity"] else 0.0, "bool",
                    note="fleet == single-process on scheduling-determined "
                         "RunReport fields"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=GATE_TASKS)
    ap.add_argument("--tph", type=int, default=GATE_TPH)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args(argv)

    wl = fleet_trace(args.tasks, args.seed)
    cells = [measure_scaling(h, wl, args.tph) for h in GATE_HOSTS]
    for c in cells:
        print(f"# {c['hosts']} host(s) x {c['threads_per_host']}: "
              f"cache {c['cache_bw_bps'] / 1e6:7.1f} MB/s  "
              f"peer {c['peer_bw_bps'] / 1e6:5.2f} MB/s  "
              f"{c['tasks_per_s']:6.1f} tasks/s  "
              f"hit {c['cache_hit_ratio']:.3f}", file=sys.stderr)
    par = measure_parity()
    print(f"# parity: {par['parity']} (split {par['hit_split']})",
          file=sys.stderr)
    out = {"cells": cells, "parity": par, "gate": gate_measure()}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

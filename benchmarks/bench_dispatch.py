"""Dispatch benchmark: central-loop throughput, wire batching, hierarchy.

The paper's dispatcher tops out near ~1k tasks/s because every task costs
the central process a fixed slice of lock + decision + wire work (§3.1);
PR 6 attacks that wall two ways -- bounded batch frames on the wire and
hierarchical per-host dispatch -- and this bench is the measurement side:

  dispatcher  a pure `Dispatcher` loop (submit / next_dispatches /
              apply_index_updates / task_finished, no threads, no wire):
              the ceiling any transport can reach;
  storm       a synthetic completion storm at 4 hosts x GATE_TPH driven
              through the REAL wire: framed socket frames into the real
              per-host receiver threads (`manager._receive` ->
              `FleetRuntime._on_remote_batch`), but with *scripted* host
              threads instead of processes -- every completion is instant,
              so the wall clock is the central loop plus the wire, the
              two things batching changes.  Run at ``wire_batch=1``
              (exactly the unbatched one-frame-per-message wire) and
              ``wire_batch=64``; the committed baseline must show
              ``batched_speedup >= 3``;
  curve       a real fleet (1 / 2 / 4 host processes x GATE_TPH) in
              hierarchical mode (``local_dispatch=True``) running
              `io_dwell_task`; drained tasks/s must rise strictly
              monotonically with host count;
  parity      the recorded-trace replay canary of bench_fleet, but with
              hierarchical dispatch + batching ON for the fleet side:
              batch-synchronous replay must still match the single-process
              runtime EXACTLY on scheduling-determined RunReport fields
              (leases never engage when the pool drains each chunk --
              DESIGN.md §9).

CLI (writes the committed baseline consumed by tools/bench_gate.py):

    PYTHONPATH=src python -m benchmarks.bench_dispatch --out BENCH_dispatch.json
"""
from __future__ import annotations

import argparse
import json
import os
import random
import socket
import sys
import threading
import time

from repro.core.objects import Task
from repro.core.policies import DispatchPolicy
from repro.core.scheduler import Dispatcher
from repro.core.index import IndexUpdate
from repro.experiments import (CacheSpec, ClusterSpec, ExperimentSpec,
                               WorkloadSpec, run_experiment)
from repro.fleet import FleetRuntime, reports_scheduling_equal
from repro.fleet.manager import HostHandle
from repro.fleet.runtime import _RemoteExecutor
from repro.fleet.wire import (PeerGone, SocketChannel, _resolve_codec,
                              recv_msg, send_msg)
from repro.workloads import PoissonArrivals, ZipfPopularity, generate

from .common import row

KB = 1000

#: fixed configuration tools/bench_gate.py replays against the baseline.
GATE_HOSTS = (1, 2, 4)
GATE_TPH = 4
STORM_TPH = 48            # storm pool: 4 hosts x 48 threads (deep pool =>
                          # the per-completion pump pass dominates)
GATE_NODES = max(GATE_HOSTS) * STORM_TPH
GATE_TASKS = 1200         # storm tasks (the gated wall)
CURVE_TASKS = 240         # real-fleet curve tasks
K_INPUTS = 3              # storm join width
N_OBJECTS = 64            # curve catalogue (objects carry real payloads)
STORM_OBJECTS = 1024      # storm catalogue (ids only; wide key space)
OBJECT_BYTES = 128 * KB   # storm object size (ids + sizes only)
CURVE_OBJECT_BYTES = 96 * KB    # curve payloads: small enough to ship
CURVE_DISK_BW = 2 * 10**6       # ...but dwell = 48 ms at the overridden
                          # disk bandwidth, so cells stay sleep-bound (not
                          # codec/CPU-bound) on a 1-core CI box and
                          # tasks/s scales with serving executors
SIM_CACHE_OBJS = 8        # scripted per-executor cache: constant eviction
                          # churn => an update frame per completion


# --------------------------------------------------------------------------
# pure dispatcher loop
# --------------------------------------------------------------------------

def measure_dispatcher_loop(n_tasks: int, seed: int = 0) -> dict:
    """Central decision loop with zero transport: submit once, then
    dispatch / complete / apply-updates until drained.  ops/s here is the
    ceiling; the storm below shows how much of it each wire keeps."""
    rng = random.Random(seed)
    d = Dispatcher(DispatchPolicy.MAX_COMPUTE_UTIL)
    now = 0.0
    for i in range(GATE_NODES):
        d.executor_joined(f"w{i}", now)
    oids = [f"o{i}" for i in range(STORM_OBJECTS)]
    for oid in oids:
        d.sizes[oid] = OBJECT_BYTES
    tasks = [Task(inputs=tuple(rng.sample(oids, K_INPUTS)))
             for _ in range(n_tasks)]
    t0 = time.perf_counter()
    d.submit(tasks, now)
    while len(d.completed) < n_tasks:
        now += 1.0
        dispatches = d.next_dispatches(now)
        if not dispatches:
            break
        for disp in dispatches:
            d.apply_index_updates(
                [IndexUpdate(disp.executor, added=disp.task.inputs)])
            d.task_finished(disp.task, now + 0.5, ok=True)
    wall = time.perf_counter() - t0
    return {"n_completed": len(d.completed), "wall_s": round(wall, 4),
            "tasks_per_s": round(n_tasks / wall, 1),
            "decisions": d.n_decisions}


# --------------------------------------------------------------------------
# synthetic completion storm over the real central receive path
# --------------------------------------------------------------------------

class _ScriptProc:
    """Process stand-in for a scripted (in-process) storm host, so the
    real HostManager monitor/reap paths work unchanged."""

    pid = 0

    def __init__(self) -> None:
        self.alive = True

    def is_alive(self) -> bool:
        return self.alive

    def terminate(self) -> None:
        self.alive = False

    def join(self, timeout=None) -> None:
        self.alive = False


def _storm_host_main(sock: socket.socket, codec: str,
                     wire_batch: int) -> None:
    """Scripted host: answer every task frame instantly with cache-churn
    update frames + a done frame, batched at ``wire_batch`` -- the same
    traffic shape a real host emits, minus the execution time."""
    caches: dict[str, list[str]] = {}
    try:
        while True:
            msg = recv_msg(sock, codec)
            msgs = msg["msgs"] if msg.get("t") == "batch" else [msg]
            replies: list[dict] = []
            for m in msgs:
                kind = m["t"]
                if kind == "task":
                    replies.extend(_scripted_attempt(m, caches))
                elif kind == "shutdown":
                    return
                # put/spawn/index/peers/lease frames need no reply
            for i in range(0, len(replies), wire_batch):
                chunk = replies[i:i + wire_batch]
                send_msg(sock, chunk[0] if len(chunk) == 1
                         else {"t": "batch", "msgs": chunk}, codec)
    except (PeerGone, OSError):
        return
    finally:
        try:
            sock.close()
        except OSError:
            pass


def measure_storm(wire_batch: int, n_tasks: int, seed: int = 0,
                  codec: str = "auto", recorder=None, metrics=None) -> dict:
    """One storm run at 4 scripted hosts: real framed sockets, the real
    per-host receiver threads and the real batched pump, but completions
    are instant.  ``wire_batch=1`` is bit-for-bit the unbatched
    one-frame-per-message wire.

    The gated metric is **central-loop CPU**: ``time.thread_time()``
    accumulated inside the per-host receiver threads, i.e. the seconds the
    central process's serialized loop (recv syscalls + codec decode + lock
    + dispatch decisions + pump + ledger accounting) is busy per storm.
    On a single-core CI box the wall clock is dominated by the scripted
    hosts sharing the CPU with the central loop, so wall understates the
    batching win badly; central-loop occupancy is the resource batching
    actually relieves and is what bounds tasks/s at scale-out."""
    codec = _resolve_codec(codec)
    rng = random.Random(seed)
    rt = FleetRuntime(hosts=0, threads_per_host=STORM_TPH,
                      wire_batch=wire_batch, heartbeat_timeout_s=60.0,
                      recorder=recorder,   # bench_obs overhead canary
                      metrics=metrics)     # bench_telemetry overhead canary
    central_cpu: list[float] = []
    recv_threads: list[threading.Thread] = []

    def _timed_receive(handle: HostHandle) -> None:
        t0 = time.thread_time()
        try:
            rt.manager._receive(handle)
        finally:
            central_cpu.append(time.thread_time() - t0)

    try:
        for h in range(max(GATE_HOSTS)):
            c_sock, h_sock = socket.socketpair()
            handle = HostHandle(f"h{h}", _ScriptProc(),
                                SocketChannel(c_sock, codec),
                                peer_host="127.0.0.1", peer_port=0)
            with rt._lock:
                for _ in range(rt.threads_per_host):
                    eid = f"w{rt._next_worker_id}"
                    rt._next_worker_id += 1
                    rt.workers[eid] = _RemoteExecutor(eid, handle, rt)
                    handle.eids.append(eid)
                    rt.dispatcher.executor_joined(eid, time.monotonic())
            rt.manager.handles[handle.host_id] = handle
            threading.Thread(target=_storm_host_main,
                             args=(h_sock, codec, wire_batch),
                             daemon=True, name=f"storm-host-{h}").start()
            rthr = threading.Thread(target=_timed_receive, args=(handle,),
                                    daemon=True, name=f"storm-recv-{h}")
            rthr.start()
            recv_threads.append(rthr)
        with rt._lock:
            for i in range(STORM_OBJECTS):
                rt.dispatcher.sizes[f"o{i}"] = OBJECT_BYTES
        oids = [f"o{i}" for i in range(STORM_OBJECTS)]
        tasks = [Task(inputs=tuple(rng.sample(oids, K_INPUTS)))
                 for _ in range(n_tasks)]
        t0 = time.perf_counter()
        rt.submit(tasks)
        drained = rt.wait(timeout=300.0)
        wall = time.perf_counter() - t0
        st = rt.dispatch_stats()
        n = len(rt.dispatcher.completed)
        # Shut the fleet down NOW so the receiver threads exit and report
        # their accumulated thread CPU (the central-loop occupancy).
        rt.shutdown()
        for thr in recv_threads:
            thr.join(timeout=30.0)
        cpu = sum(central_cpu)
        return {"wire_batch": wire_batch, "n_tasks": n_tasks,
                "n_completed": n, "drained": drained,
                "wall_s": round(wall, 4),
                "tasks_per_s": round(n / wall, 1),
                "central_cpu_s": round(cpu, 4),
                "central_tasks_per_cpu_s": round(n / max(cpu, 1e-9), 1),
                "pump_calls": st["pump_calls"],
                "max_dispatch_batch": st["max_dispatch_batch"],
                "lock_hold_ms": round(st["lock_hold_s"] * 1e3, 2),
                "frames_recv": st["frames_recv"],
                "msgs_recv": st["msgs_recv"],
                "frames_sent": st["frames_sent"],
                "msgs_sent": st["msgs_sent"]}
    finally:
        rt.shutdown()


def _scripted_attempt(m: dict, caches: dict[str, list[str]]) -> list[dict]:
    """Host-side behaviour for one task msg: admit each input into a tiny
    LRU (churn), then one coalesced updates frame for the whole attempt's
    cache delta and the done frame -- updates strictly before done, the
    §8 ordering contract."""
    eid = m["eid"]
    cache = caches.setdefault(eid, [])
    before = set(cache)
    led = {"bytes_local": 0, "bytes_cache_to_cache": 0, "bytes_store": 0,
           "cache_hits": 0, "peer_hits": 0, "cache_misses": 0}
    for oid, size in m["inputs"]:
        if oid in cache:
            cache.remove(oid)
            cache.append(oid)
            led["cache_hits"] += 1
            led["bytes_local"] += size
            continue
        led["cache_misses"] += 1
        led["bytes_store"] += size
        cache.append(oid)
        while len(cache) > SIM_CACHE_OBJS:
            cache.pop(0)
    # one coalesced NET cache delta per attempt (an oid evicted then
    # re-admitted within the attempt appears in neither list)
    added = [o for o in cache if o not in before]
    removed = sorted(before - set(cache))
    replies: list[dict] = []
    if added or removed:
        replies.append({"t": "updates", "eid": eid,
                        "added": added, "removed": removed})
    replies.append({"t": "done", "eid": eid, "tid": m["tid"],
                    "ok": True, "ledger": led})
    return replies


# --------------------------------------------------------------------------
# real-fleet hierarchical curve + replay parity
# --------------------------------------------------------------------------

def curve_trace(n_tasks: int, seed: int = 0):
    return generate("dispatch", PoissonArrivals(rate_per_s=100_000.0),
                    ZipfPopularity(1.1), n_tasks=n_tasks,
                    n_objects=N_OBJECTS, object_bytes=CURVE_OBJECT_BYTES,
                    seed=seed)


def measure_curve_cell(hosts: int, wl, tph: int = GATE_TPH) -> dict:
    """One hierarchical cell: free-running replay keeps a backlog, so
    leases engage and hosts claim locally; drained tasks/s is the axis.

    The spawned hosts inherit ``REPRO_BENCH_DISK_BW`` (a slow simulated
    disk): dwell per input is deep (48 ms) while payloads stay small, so
    the cells are sleep-bound, not codec/CPU-bound, and tasks/s scales
    with serving executors even on a 1-core CI box."""
    os.environ["REPRO_BENCH_DISK_BW"] = str(CURVE_DISK_BW)
    rt = FleetRuntime(hosts=hosts, threads_per_host=tph,
                      local_dispatch=True,
                      task_fn_name="repro.fleet.runtime:io_dwell_task")
    try:
        for ob in wl.objects:
            rt.put_object(ob, b"x" * ob.size_bytes)
        t0 = time.perf_counter()
        th = rt.submit_workload(wl, time_scale=0.0)
        th.join(600)
        drained = (not th.is_alive()) and rt.wait(600)
        wall = time.perf_counter() - t0
        st = rt.dispatch_stats()
        n = len(rt.dispatcher.completed)
        return {"hosts": hosts, "executors": hosts * tph,
                "n_tasks": len(wl), "n_completed": n, "drained": drained,
                "wall_s": round(wall, 4),
                "tasks_per_s": round(n / wall, 1),
                "leases": st["leases"], "claims": st["claims"],
                "claim_conflicts": st["claim_conflicts"]}
    finally:
        rt.shutdown()
        os.environ.pop("REPRO_BENCH_DISK_BW", None)


def measure_parity(n_tasks: int = 150, seed: int = 7) -> dict:
    """Hierarchical replay parity: batch-synchronous replay (B <= pool) on
    a 2x2 fleet with local_dispatch + batching ON must match the single-
    process runtime exactly -- leases only engage on backlog, and barrier
    replay never has one (DESIGN.md §9)."""
    def spec(hosts, tph, n_nodes, local):
        return ExperimentSpec(
            name="dispatch-parity",
            cluster=ClusterSpec(testbed="anl_uc", n_nodes=n_nodes),
            cache=CacheSpec(capacity_bytes=10**12),   # eviction-free
            policy="max-compute-util",
            workload=WorkloadSpec(
                name="dp",
                arrivals={"kind": "PoissonArrivals", "rate_per_s": 100.0},
                popularity={"kind": "ZipfPopularity", "alpha": 1.1, "k": 2,
                            "corr": 0.8},
                n_tasks=n_tasks, n_objects=32, object_bytes=50 * KB,
                seed=seed),
            seed=3, hosts=hosts, threads_per_host=tph,
            local_dispatch=local)

    r_single = run_experiment(spec(0, 1, 4, False), engine="runtime",
                              barrier_every=4, timeout=300.0)
    r_fleet = run_experiment(spec(2, 2, 4, True), engine="runtime",
                             barrier_every=4, timeout=300.0)
    diff = reports_scheduling_equal(r_single, r_fleet)
    return {
        "parity": not diff and r_single.n_completed == n_tasks,
        "n_completed": r_single.n_completed,
        "diff_fields": sorted(diff),
        "fleet_leases": r_fleet.dispatch_stats.get("leases", -1),
        "fleet_claims": r_fleet.dispatch_stats.get("claims", -1),
    }


def _monotonic(cells: list[dict], key: str) -> bool:
    vals = [c[key] for c in sorted(cells, key=lambda c: c["hosts"])]
    return all(b > a for a, b in zip(vals, vals[1:]))


# --------------------------------------------------------------------------
# gate / CSV entry points
# --------------------------------------------------------------------------

def gate_measure(repeats: int = 3) -> dict:
    """The fixed shape bench_gate.py replays.  The gated wall is the
    batched storm (best-of-N); the speedup compares best-of-N
    **central-loop CPU** of the two wire modes on identical scripted
    traffic (wall clock on a 1-core CI box mostly measures the scripted
    hosts, not the central loop -- see :func:`measure_storm`).  Curve +
    parity are run once (process spawns dominate; canaries are boolean)."""
    best1 = best64 = None
    for _ in range(repeats):
        s1 = measure_storm(1, GATE_TASKS)
        s64 = measure_storm(64, GATE_TASKS)
        if best1 is None or s1["central_cpu_s"] < best1["central_cpu_s"]:
            best1 = s1
        if best64 is None or s64["central_cpu_s"] < best64["central_cpu_s"]:
            best64 = s64
    wl = curve_trace(CURVE_TASKS)
    cells = [measure_curve_cell(h, wl) for h in GATE_HOSTS]
    par = measure_parity()
    loop = measure_dispatcher_loop(GATE_TASKS)
    return {
        "n_nodes": GATE_NODES, "n_tasks": GATE_TASKS,
        "wall_s": best64["wall_s"],
        "n_completed": best64["n_completed"],
        "unbatched_wall_s": best1["wall_s"],
        "central_cpu_s": best64["central_cpu_s"],
        "unbatched_central_cpu_s": best1["central_cpu_s"],
        "batched_speedup": round(best1["central_cpu_s"]
                                 / max(best64["central_cpu_s"], 1e-9), 2),
        "dispatcher_tasks_per_s": loop["tasks_per_s"],
        "curve_tasks_per_s": {str(c["hosts"]): c["tasks_per_s"]
                              for c in cells},
        "curve_drained": all(c["drained"] for c in cells),
        "curve_monotonic": _monotonic(cells, "tasks_per_s"),
        "curve_claims": sum(c["claims"] for c in cells),
        "parity": par["parity"],
        "parity_leases": par["fleet_leases"],
    }


def run(scale: float = 1.0) -> list[dict]:
    """benchmarks.run contract: storm + curve + parity as CSV rows."""
    n_tasks = max(int(GATE_TASKS * scale), 100)
    loop = measure_dispatcher_loop(n_tasks)
    rows = [row("dispatch", "dispatcher_loop_ktasks_per_s",
                loop["tasks_per_s"] / 1e3, "k/s",
                note=f"pure Dispatcher loop, {GATE_NODES} executors, "
                     f"k={K_INPUTS} inputs")]
    s1 = measure_storm(1, n_tasks)
    s64 = measure_storm(64, n_tasks)
    rows.append(row("dispatch", "storm_tasks_per_s_unbatched",
                    s1["tasks_per_s"], "tasks/s", paper=1000,
                    note=f"{s1['frames_recv']} frames up, pump x"
                         f"{s1['pump_calls']}"))
    rows.append(row("dispatch", "storm_tasks_per_s_batched",
                    s64["tasks_per_s"], "tasks/s", paper=1000,
                    note=f"{s64['frames_recv']} frames up, pump x"
                         f"{s64['pump_calls']}"))
    rows.append(row("dispatch", "wire_batching_speedup",
                    s1["central_cpu_s"] / max(s64["central_cpu_s"], 1e-9),
                    "x", note="central-loop CPU, same storm, "
                              "wire_batch 1 vs 64"))
    wl = curve_trace(max(int(CURVE_TASKS * scale), 96))
    cells = [measure_curve_cell(h, wl) for h in GATE_HOSTS]
    for c in cells:
        rows.append(row("dispatch", f"hier_tasks_per_s_{c['hosts']}hosts",
                        c["tasks_per_s"], "tasks/s",
                        note=f"{c['executors']} executors, local claims "
                             f"{c['claims']}, conflicts "
                             f"{c['claim_conflicts']}"))
    rows.append(row("dispatch", "hier_tasks_per_s_monotonic_1_2_4",
                    1.0 if _monotonic(cells, "tasks_per_s") else 0.0,
                    "bool", note="hierarchical throughput grows with "
                                 "host count"))
    par = measure_parity()
    rows.append(row("dispatch", "hier_replay_parity",
                    1.0 if par["parity"] else 0.0, "bool",
                    note="hierarchical+batched replay == single-process "
                         "on scheduling-determined fields"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tasks", type=int, default=GATE_TASKS)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", default="BENCH_dispatch.json")
    args = ap.parse_args(argv)

    loop = measure_dispatcher_loop(args.tasks)
    print(f"# dispatcher loop: {loop['tasks_per_s']:.0f} tasks/s",
          file=sys.stderr)
    storms = {wb: measure_storm(wb, args.tasks) for wb in (1, 8, 64)}
    for wb, s in storms.items():
        print(f"# storm wire_batch={wb:3d}: {s['tasks_per_s']:8.1f} tasks/s  "
              f"central cpu {s['central_cpu_s'] * 1e3:7.1f} ms  "
              f"{s['frames_recv']:6d} frames  pump x{s['pump_calls']}",
              file=sys.stderr)
    wl = curve_trace(CURVE_TASKS)
    cells = [measure_curve_cell(h, wl) for h in GATE_HOSTS]
    for c in cells:
        print(f"# hier {c['hosts']} host(s): {c['tasks_per_s']:7.1f} tasks/s  "
              f"claims {c['claims']}  conflicts {c['claim_conflicts']}",
              file=sys.stderr)
    par = measure_parity()
    print(f"# parity: {par['parity']} (leases {par['fleet_leases']})",
          file=sys.stderr)
    out = {"dispatcher_loop": loop,
           "storms": {str(k): v for k, v in storms.items()},
           "curve": cells, "parity": par,
           "gate": gate_measure(repeats=args.repeats)}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Versioned task-lifecycle event schema.

An event is a plain dict -- ``{"t": <seconds>, "kind": <str>}`` plus optional
``tid`` (task id), ``eid`` (executor id) and kind-specific fields.  Both
engines and the fleet hosts emit the SAME kinds at the same lifecycle points,
so a batch-synchronous replay produces identical per-task event sequences on
the simulator and on a 4-host fleet (tests/test_obs.py asserts this).

Clocks differ by emitter (sim time for DiffusionSim, process-relative
monotonic for the runtime and each fleet host); comparisons that must be
exact therefore go through :func:`lifecycle_fingerprints`, which drops
timestamps and normalizes executor naming (sim ``e3`` vs runtime ``w3``).
"""
from __future__ import annotations

import re

#: v1 = the PR-7 lifecycle kinds; v2 adds the DAG ready-set kinds
#: ``task_held`` / ``task_ready`` (DESIGN.md §11).  load_events hard-errors
#: on sinks written by any other version.
EVENT_SCHEMA_VERSION = 2

# -- lifecycle kinds (per task) ---------------------------------------------
TASK_ARRIVED = "task_arrived"        # Dispatcher.submit
TASK_HELD = "task_held"              # submitted with unmet deps (ready-set)
TASK_READY = "task_ready"            # last dep completed; about to enqueue
TASK_QUEUED = "task_queued"          # entered the wait queue (front=retry/requeue)
TASK_LEASED = "task_leased"          # queue-head slice leased to a host
TASK_CLAIMED = "task_claimed"        # host claim reconciled against the lease pool
TASK_DISPATCHED = "task_dispatched"  # bound to an executor
INPUT = "input"                      # one input resolved: oid, source, bytes
EXEC_START = "exec_start"            # task function begins
EXEC_END = "exec_end"                # task function returned
TASK_DONE = "task_done"
TASK_FAILED = "task_failed"          # terminal failure (attempts exhausted / dep_failed)
TASK_REQUEUED = "task_requeued"      # retry / lease return / executor loss

# -- aggregate kinds --------------------------------------------------------
PUMP = "pump"                        # one dispatch pass: n bound, queue depth
POOL = "pool"                        # executor pool transition: size, delta
PROVISION = "provision"              # DRP decision: allocate, release

LIFECYCLE_KINDS = (
    TASK_ARRIVED, TASK_HELD, TASK_READY, TASK_QUEUED, TASK_LEASED,
    TASK_CLAIMED, TASK_DISPATCHED,
    INPUT, EXEC_START, EXEC_END, TASK_DONE, TASK_FAILED, TASK_REQUEUED,
)
EVENT_KINDS = frozenset(LIFECYCLE_KINDS) | {PUMP, POOL, PROVISION}

# Input sources (the ``source`` field of INPUT events).
SOURCE_LOCAL = "local"
SOURCE_PEER = "peer"
SOURCE_STORE = "store"

# Required keys of a measured per-task outcome record (trace v3 rows).
OUTCOME_FIELDS = (
    "tid", "executor", "attempts",
    "queue_s", "exec_s", "turnaround_s",
    "bytes_local", "bytes_peer", "bytes_store",
    "cache_hits", "peer_hits", "cache_misses",
)

_EXEC_RE = re.compile(r"(\d+)$")


def exec_index(eid):
    """Normalize an executor id to its numeric index (sim names nodes
    ``e{i}``, the runtime and fleet name them ``w{i}``; the index is the
    scheduling-determined part)."""
    if eid is None:
        return None
    m = _EXEC_RE.search(str(eid))
    return int(m.group(1)) if m else str(eid)


def outcome_record(task, base=0.0):
    """Measured per-task outcome dict built from a completed Task.

    ``base`` rebases the absolute clock fields (the runtime stamps tasks with
    raw ``time.monotonic()``; the sim already starts at 0).  Latency fields
    are clock-base independent.
    """
    sub = task.submit_time
    dis = task.dispatch_time if task.dispatch_time is not None else sub
    st = task.start_time if task.start_time is not None else dis
    en = task.end_time if task.end_time is not None else st
    return {
        "tid": task.tid,
        "executor": task.executor,
        "attempts": task.attempts,
        "t_submit": sub - base,
        "t_dispatch": dis - base,
        "t_start": st - base,
        "t_end": en - base,
        "queue_s": dis - sub,
        "exec_s": en - st,
        "turnaround_s": en - sub,
        "bytes_local": task.bytes_local,
        "bytes_peer": task.bytes_cache_to_cache,
        "bytes_store": task.bytes_store,
        "cache_hits": task.cache_hits,
        "peer_hits": task.peer_hits,
        "cache_misses": task.cache_misses,
    }


def lifecycle_fingerprints(events):
    """Collapse an event stream into per-task, clock-free fingerprints.

    Returns ``{tid: (kinds, exec_idx, inputs)}`` where ``kinds`` is the tuple
    of lifecycle kinds in emission order, ``exec_idx`` the normalized index
    of the executor that ran the task, and ``inputs`` the sorted tuple of
    ``(oid, source, bytes)`` triples.  Two engines replaying the same trace
    batch-synchronously must produce EQUAL fingerprint maps even though their
    clocks (and the interleaving across tasks) differ.
    """
    kinds: dict = {}
    execs: dict = {}
    inputs: dict = {}
    for e in events:
        tid = e.get("tid")
        if tid is None or e["kind"] not in EVENT_KINDS:
            continue
        k = e["kind"]
        if k == INPUT:
            inputs.setdefault(tid, []).append(
                (e["oid"], e["source"], e["bytes"]))
        else:
            kinds.setdefault(tid, []).append(k)
        if k == EXEC_START:
            execs[tid] = exec_index(e.get("eid"))
    return {
        tid: (
            tuple(ks),
            execs.get(tid),
            tuple(sorted(inputs.get(tid, ()))),
        )
        for tid, ks in kinds.items()
    }

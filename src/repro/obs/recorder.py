"""Bounded, lock-cheap event ring buffer with a JSONL sink.

The Recorder is the only mutable state the observability layer adds to the
hot paths.  Emission is one short critical section on the recorder's OWN
lock (append to a deque + a couple of counter bumps) -- it never takes and
is never held across the dispatcher/runtime lock, and it never does I/O.
When the ring is full the OLDEST event is dropped and counted, so an
under-provisioned ring degrades to a truncated trace, never to backpressure.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque

from .events import EVENT_KINDS, EVENT_SCHEMA_VERSION

DEFAULT_RING_CAPACITY = 65536

_HEADER_KIND = "events_header"


class Recorder:
    """Bounded event ring.  ``clock`` is a zero-arg callable stamping new
    events; the default is process-relative monotonic seconds (the sim engine
    swaps in its virtual clock, each fleet host builds its own)."""

    __slots__ = ("capacity", "clock", "_buf", "_lock", "emitted", "dropped")

    def __init__(self, capacity: int = DEFAULT_RING_CAPACITY, clock=None):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = capacity
        if clock is None:
            t0 = time.monotonic()
            clock = lambda: time.monotonic() - t0  # noqa: E731
        self.clock = clock
        self._buf: deque = deque()
        self._lock = threading.Lock()
        self.emitted = 0
        self.dropped = 0

    # -- hot path -----------------------------------------------------------
    def emit(self, kind: str, t: float | None = None, tid=None, eid=None,
             **data) -> None:
        # the kwargs dict doubles as the event record (one allocation)
        data["t"] = self.clock() if t is None else t
        data["kind"] = kind
        if tid is not None:
            data["tid"] = tid
        if eid is not None:
            data["eid"] = eid
        with self._lock:
            if len(self._buf) >= self.capacity:
                self._buf.popleft()
                self.dropped += 1
            self._buf.append(data)
            self.emitted += 1

    def ingest(self, events) -> None:
        """Append pre-stamped events (fleet hosts forward their rings
        upstream; the central recorder ingests the frames verbatim)."""
        with self._lock:
            for ev in events:
                if len(self._buf) >= self.capacity:
                    self._buf.popleft()
                    self.dropped += 1
                self._buf.append(ev)
                self.emitted += 1

    # -- cold path ----------------------------------------------------------
    def drain(self) -> list:
        """Remove and return all buffered events (wire forwarding)."""
        with self._lock:
            evs = list(self._buf)
            self._buf.clear()
        return evs

    def events(self) -> list:
        """Non-destructive snapshot of the buffered events."""
        with self._lock:
            return list(self._buf)

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    def dump(self, path) -> int:
        """Write the buffered events as JSONL (one header line with schema
        version + drop accounting, then one event per line).  Returns the
        number of event lines written."""
        evs = self.events()
        with open(path, "w", encoding="utf-8") as fh:
            fh.write(json.dumps({
                "kind": _HEADER_KIND,
                "schema_version": EVENT_SCHEMA_VERSION,
                "n_events": len(evs),
                "emitted": self.emitted,
                "dropped": self.dropped,
            }, sort_keys=True) + "\n")
            for ev in evs:
                fh.write(json.dumps(ev, sort_keys=True) + "\n")
        return len(evs)


def load_events(path):
    """Read a Recorder JSONL sink back: ``(header, events)``.  Hard-errors on
    unknown header kinds/versions, on unknown event kinds, and on truncated
    files -- a half-understood sink silently skews everything downstream."""
    with open(path, "r", encoding="utf-8") as fh:
        header = json.loads(fh.readline())
        if header.get("kind") != _HEADER_KIND:
            raise ValueError(f"not an events sink: header kind "
                             f"{header.get('kind')!r}")
        if header.get("schema_version") != EVENT_SCHEMA_VERSION:
            raise ValueError(
                f"unsupported event schema version "
                f"{header.get('schema_version')!r} "
                f"(supported: {EVENT_SCHEMA_VERSION})")
        events = [json.loads(line) for line in fh if line.strip()]
    if len(events) != header["n_events"]:
        raise ValueError(f"truncated events sink: header says "
                         f"{header['n_events']} events, found {len(events)}")
    for i, ev in enumerate(events):
        if ev.get("kind") not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {ev.get('kind')!r} "
                             f"at event {i} (schema v{EVENT_SCHEMA_VERSION})")
    return header, events

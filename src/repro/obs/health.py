"""Health evaluator: window rules over the telemetry sample stream
(DESIGN.md §13).

`HealthMonitor.observe(sample)` is called once per recorded sample (the
`Telemetry` bundle wires it in) and returns the health events that FIRED on
this sample.  Rules are edge-triggered: an event is emitted when a rule's
condition transitions inactive -> active, suppressed while it stays active,
and re-armed when the condition clears -- so a sustained backlog produces
one event, not one per tick.

Built-in rules (each keyed (rule, host) in the active set):

  backlog_growth    the merged ``sched.queue_depth`` gauge rose strictly
                    across the whole window and the newest reading is at
                    least ``backlog_min`` -- the queue is growing faster
                    than the pool drains it;
  stale_heartbeat   a host's stats frame is older than ``stale_after_s``
                    (only meaningful on engines that attach receive ages,
                    i.e. fleets);
  cache_thrash      the merged ``cache.readmits`` total (re-admissions of
                    objects previously pressure-evicted) grew by at least
                    ``thrash_min`` across the window -- the working set no
                    longer fits and the cache is churning;
  recorder_drops    the merged ``obs.recorder_dropped`` total increased:
                    the lifecycle ring is saturated and the trace (hence
                    any divergence join) is silently truncated.

Events are plain dicts: ``{"kind": "health", "t", "rule", "severity",
"host", "detail"}`` -- JSONL-ready, appended to the telemetry sink right
after the sample that triggered them.
"""
from __future__ import annotations

from collections import deque
from typing import Optional


def merged_value(sample: dict, name: str) -> float:
    """A metric's cluster-wide value in one sample: central counter+gauge
    reading plus the same reading from every attached per-host snapshot
    (gauges in this plane are absolute per-source totals, so sum)."""
    def _one(snap: dict) -> float:
        return (snap.get("counters", {}).get(name, 0)
                + snap.get("gauges", {}).get(name, 0))
    total = _one(sample.get("metrics", {}))
    for d in sample.get("hosts", {}).values():
        total += _one(d.get("metrics", {}))
    return total


class HealthMonitor:
    def __init__(self, window: int = 5, backlog_min: int = 8,
                 stale_after_s: float = 2.0, thrash_min: int = 16):
        if window < 2:
            raise ValueError(f"window must be >= 2, got {window}")
        self.window = window
        self.backlog_min = backlog_min
        self.stale_after_s = stale_after_s
        self.thrash_min = thrash_min
        self._samples: deque = deque(maxlen=window)
        self._active: set[tuple] = set()

    # -- rule conditions ----------------------------------------------------
    def _backlog_growth(self) -> Optional[str]:
        if len(self._samples) < self.window:
            return None
        depths = [merged_value(s, "sched.queue_depth")
                  for s in self._samples]
        if depths[-1] < self.backlog_min:
            return None
        if all(b > a for a, b in zip(depths, depths[1:])):
            return (f"queue depth rose {depths[0]:.0f} -> {depths[-1]:.0f} "
                    f"over {len(depths)} samples")
        return None

    def _cache_thrash(self) -> Optional[str]:
        if len(self._samples) < self.window:
            return None
        delta = (merged_value(self._samples[-1], "cache.readmits")
                 - merged_value(self._samples[0], "cache.readmits"))
        if delta >= self.thrash_min:
            return (f"{delta:.0f} re-admissions of evicted objects over "
                    f"{len(self._samples)} samples")
        return None

    def _recorder_drops(self) -> Optional[str]:
        if len(self._samples) < 2:
            return None
        cur = merged_value(self._samples[-1], "obs.recorder_dropped")
        prev = merged_value(self._samples[-2], "obs.recorder_dropped")
        if cur > prev:
            return (f"lifecycle ring dropped {cur:.0f} events total "
                    f"(+{cur - prev:.0f}); trace is truncated")
        return None

    def _stale_hosts(self, sample: dict) -> dict[str, str]:
        out = {}
        for host, d in sample.get("hosts", {}).items():
            age = d.get("age_s", 0.0)
            if age > self.stale_after_s:
                out[host] = f"last stats frame {age:.1f}s ago"
        return out

    # -- driver -------------------------------------------------------------
    def observe(self, sample: dict) -> list[dict]:
        """Feed one sample; returns newly-fired events (edge-triggered)."""
        self._samples.append(sample)
        t = sample.get("t", 0.0)
        fired: list[dict] = []

        def edge(rule: str, host, severity: str, detail: Optional[str]):
            key = (rule, host)
            if detail is None:
                self._active.discard(key)
                return
            if key in self._active:
                return
            self._active.add(key)
            fired.append({"kind": "health", "t": t, "rule": rule,
                          "severity": severity, "host": host,
                          "detail": detail})

        edge("backlog_growth", None, "warn", self._backlog_growth())
        edge("cache_thrash", None, "warn", self._cache_thrash())
        edge("recorder_drops", None, "error", self._recorder_drops())
        stale = self._stale_hosts(sample)
        for host in list(sample.get("hosts", {})) or []:
            edge("stale_heartbeat", host, "error", stale.get(host))
        return fired

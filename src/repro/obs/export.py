"""Chrome-trace (chrome://tracing / Perfetto) JSON export.

One track per executor (``ph:"X"`` complete events spanning exec_start ->
exec_end, named by task id), two wait tracks separating *dep-wait* (held on
unmet producers: task_held -> task_ready) from *queue-wait* (runnable but
unplaced: ready/queued -> task_dispatched), plus counter tracks (``ph:"C"``)
for executor pool size, dispatcher queue depth, and cumulative
cache-admitted bytes.  Timestamps are rebased so the trace starts at ts=0
regardless of the emitters' clock bases.

When telemetry ``samples`` (the `repro.obs.metrics.Telemetry` series, or
rows loaded by ``read_metrics``) are passed alongside, the export adds
sampled counter tracks from the live plane: ``sampled_queue_depth``,
``sampled_pool_size``, and cache bytes per host (``sampled_cache_bytes:h0``
on fleets, a single ``sampled_cache_bytes`` track otherwise).  Samples and
events share one rebased timebase, so the sampled curves overlay the
per-task spans.
"""
from __future__ import annotations

import json

from .events import (
    EXEC_END,
    EXEC_START,
    INPUT,
    POOL,
    PUMP,
    SOURCE_LOCAL,
    TASK_DISPATCHED,
    TASK_HELD,
    TASK_QUEUED,
    TASK_READY,
    exec_index,
)

_PID = 0
_COUNTER_TID = 0  # counter tracks render per-process; tid is cosmetic


def chrome_trace(events, path=None, samples=None):
    """Build a Chrome-trace dict from an event stream (plus optional
    telemetry ``samples``); optionally write it to ``path``.  Returns the
    trace dict (``{"traceEvents": [...]}``)."""
    events = sorted(events, key=lambda e: e.get("t", 0.0))
    samples = sorted(samples or [], key=lambda s: s.get("t", 0.0))
    starts = ([events[0]["t"]] if events else []) \
        + ([samples[0]["t"]] if samples else [])
    t0 = min(starts) if starts else 0.0

    def us(t):
        return round((t - t0) * 1e6, 3)

    trace = []
    # Executor tracks, ordered by normalized index.
    eids = sorted({e["eid"] for e in events if e.get("eid") is not None},
                  key=lambda x: (isinstance(exec_index(x), str),
                                 exec_index(x)))
    tid_of = {eid: i + 1 for i, eid in enumerate(eids)}
    for eid, track in tid_of.items():
        trace.append({"ph": "M", "pid": _PID, "tid": track,
                      "name": "thread_name", "args": {"name": eid}})
    dep_track = len(eids) + 1
    queue_track = len(eids) + 2
    trace.append({"ph": "M", "pid": _PID, "tid": dep_track,
                  "name": "thread_name", "args": {"name": "dep_wait"}})
    trace.append({"ph": "M", "pid": _PID, "tid": queue_track,
                  "name": "thread_name", "args": {"name": "queue_wait"}})

    open_execs: dict = {}
    held_at: dict = {}    # tid -> t of task_held (dep-wait span start)
    queue_at: dict = {}   # tid -> t runnable (ready or first queued)
    cache_bytes = 0
    for e in events:
        k = e["kind"]
        if k == EXEC_START:
            open_execs[e["tid"]] = e
        elif k == EXEC_END:
            s = open_execs.pop(e["tid"], None)
            if s is None:
                continue
            eid = e.get("eid") or s.get("eid")
            trace.append({
                "ph": "X", "pid": _PID, "tid": tid_of.get(eid, 0),
                "name": e["tid"], "cat": "task",
                "ts": us(s["t"]), "dur": max(us(e["t"]) - us(s["t"]), 0.0),
                "args": {"executor": eid},
            })
        elif k == TASK_HELD:
            held_at[e["tid"]] = e["t"]
        elif k == TASK_READY:
            s = held_at.pop(e["tid"], None)
            if s is not None:
                trace.append({
                    "ph": "X", "pid": _PID, "tid": dep_track,
                    "name": e["tid"], "cat": "dep_wait",
                    "ts": us(s), "dur": max(us(e["t"]) - us(s), 0.0),
                })
            queue_at.setdefault(e["tid"], e["t"])
        elif k == TASK_QUEUED:
            queue_at.setdefault(e["tid"], e["t"])
        elif k == TASK_DISPATCHED:
            s = queue_at.pop(e["tid"], None)
            if s is not None:
                trace.append({
                    "ph": "X", "pid": _PID, "tid": queue_track,
                    "name": e["tid"], "cat": "queue_wait",
                    "ts": us(s), "dur": max(us(e["t"]) - us(s), 0.0),
                })
        elif k == POOL:
            trace.append({"ph": "C", "pid": _PID, "tid": _COUNTER_TID,
                          "name": "pool_size", "ts": us(e["t"]),
                          "args": {"executors": e["size"]}})
        elif k == PUMP:
            trace.append({"ph": "C", "pid": _PID, "tid": _COUNTER_TID,
                          "name": "queue_depth", "ts": us(e["t"]),
                          "args": {"tasks": e["queue"]}})
        elif k == INPUT and e.get("source") != SOURCE_LOCAL:
            # Cumulative bytes admitted into caches (peer + store reads both
            # end in a cache admit; local hits move nothing).
            cache_bytes += e.get("bytes", 0)
            trace.append({"ph": "C", "pid": _PID, "tid": _COUNTER_TID,
                          "name": "cache_bytes", "ts": us(e["t"]),
                          "args": {"bytes": cache_bytes}})

    for s in samples:
        ts = us(s.get("t", 0.0))
        g = s.get("metrics", {}).get("gauges", {})
        trace.append({"ph": "C", "pid": _PID, "tid": _COUNTER_TID,
                      "name": "sampled_queue_depth", "ts": ts,
                      "args": {"tasks": g.get("sched.queue_depth", 0)}})
        trace.append({"ph": "C", "pid": _PID, "tid": _COUNTER_TID,
                      "name": "sampled_pool_size", "ts": ts,
                      "args": {"executors": g.get("pool.size", 0)}})
        hosts = s.get("hosts", {})
        if hosts:
            for h in sorted(hosts):
                hg = hosts[h].get("metrics", {}).get("gauges", {})
                trace.append({"ph": "C", "pid": _PID, "tid": _COUNTER_TID,
                              "name": f"sampled_cache_bytes:{h}", "ts": ts,
                              "args": {"bytes": hg.get("cache.bytes", 0)}})
        else:
            trace.append({"ph": "C", "pid": _PID, "tid": _COUNTER_TID,
                          "name": "sampled_cache_bytes", "ts": ts,
                          "args": {"bytes": g.get("cache.bytes", 0)}})

    out = {"traceEvents": trace, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(out, fh)
    return out

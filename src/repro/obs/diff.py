"""Per-task sim<->real divergence diff.

`RunReport.diff` compares two runs in aggregate; this module answers *which
tasks* diverged and *where in their lifecycle*: join measured outcome
records (trace v3 rows from a real run) against the simulator's predicted
outcomes for the same arrival trace, by task id, and report divergence
distributions -- placement agreement, byte-split agreement, and absolute
latency-error quantiles.  The result dict is what `RunReport.
task_divergence` carries and what ``tools/run_experiment.py diff`` prints.

This is the measurement half of the ROADMAP's calibration loop: the fit
half (tools/hillclimb.py over testbed parameters, minimising these
distributions) builds on it.
"""
from __future__ import annotations

import dataclasses

from repro.workloads.metrics import latency_quantiles

from .events import exec_index, outcome_record

#: latency fields diffed between measured and predicted outcome records
LATENCY_FIELDS = ("queue_s", "exec_s", "turnaround_s")
_BYTE_FIELDS = ("bytes_local", "bytes_peer", "bytes_store")


def sim_twin_spec(spec, trace_path=None):
    """The simulator-runnable twin of a (possibly fleet/runtime) spec: same
    pool size, policy, cache and seed, but hosts=0, strict index coherence,
    and -- when ``trace_path`` is given -- the workload re-bound to the
    recorded arrival trace.  Observation is disabled on the twin (the diff
    consumes its dispatcher state directly)."""
    from repro.experiments.spec import ObserveSpec, WorkloadSpec

    kw = dict(hosts=0, threads_per_host=1, wire_batch=64,
              local_dispatch=False, index_update_batch=1,
              observe=ObserveSpec())
    if trace_path is not None:
        kw["workload"] = WorkloadSpec(name=spec.workload.name,
                                      trace_path=str(trace_path))
    return dataclasses.replace(spec, **kw)


def sim_replay_outcomes(spec, trace_path=None, until=float("inf")):
    """Run the sim twin of ``spec`` (optionally re-bound to ``trace_path``)
    and return its predicted per-task outcome records."""
    from repro.experiments.engines import SimEngine

    eng = SimEngine().prepare(sim_twin_spec(spec, trace_path))
    eng.run(until=until)
    return [outcome_record(t) for t in eng.result.dispatcher.completed]


def diff_outcomes(measured, predicted) -> dict:
    """Join measured vs. predicted outcome records by task id and summarise
    the per-task divergence.  Executor names are compared by normalized
    index (sim ``e3`` == runtime ``w3``)."""
    m = {r["tid"]: r for r in measured}
    p = {r["tid"]: r for r in predicted}
    matched = sorted(set(m) & set(p))
    n = len(matched)
    place_ok = sum(
        1 for t in matched
        if exec_index(m[t]["executor"]) == exec_index(p[t]["executor"]))
    bytes_ok = sum(
        1 for t in matched
        if all(m[t][f] == p[t][f] for f in _BYTE_FIELDS))
    return {
        "n_measured": len(m),
        "n_predicted": len(p),
        "n_matched": n,
        "n_only_measured": len(m) - n,
        "n_only_predicted": len(p) - n,
        "placement_agreement": (place_ok / n) if n else 0.0,
        "bytes_agreement": (bytes_ok / n) if n else 0.0,
        "latency_error_s": {
            f: latency_quantiles([abs(m[t][f] - p[t][f]) for t in matched])
            for f in LATENCY_FIELDS
        },
    }


def format_divergence(div: dict, latencies: bool = True) -> str:
    """Human-readable divergence summary.  ``latencies=False`` omits the
    wall-clock-dependent quantiles (reproducible-stdout callers)."""
    lines = [
        f"matched {div['n_matched']} task(s) "
        f"(measured-only {div['n_only_measured']}, "
        f"predicted-only {div['n_only_predicted']})",
        f"placement agreement  {div['placement_agreement']:.1%}",
        f"byte-split agreement {div['bytes_agreement']:.1%}",
    ]
    if latencies:
        for f in LATENCY_FIELDS:
            q = div["latency_error_s"][f]
            lines.append(
                f"|{f} error|  p50 {q['p50']:.4f}s  p90 {q['p90']:.4f}s  "
                f"p99 {q['p99']:.4f}s  mean {q['mean']:.4f}s")
    return "\n".join(lines)

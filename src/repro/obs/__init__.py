"""repro.obs -- low-overhead observability for both engines and the fleet.

Per-task lifecycle events (arrived -> queued -> leased/claimed -> dispatched
-> per-input resolve -> exec start/end -> done/failed/requeued, plus pool and
pump transitions) recorded into a bounded ring buffer (`Recorder`), exported
as Chrome-trace JSON (`export.chrome_trace`) and diffed task-by-task between
a measured run and its simulator replay (`diff.diff_outcomes`).

Recording is off by default and free when off: every hot-path hook is a
``if recorder is not None`` guard.  See DESIGN.md section 10.
"""
from .events import (
    EVENT_SCHEMA_VERSION,
    EVENT_KINDS,
    LIFECYCLE_KINDS,
    OUTCOME_FIELDS,
    exec_index,
    lifecycle_fingerprints,
    outcome_record,
)
from .recorder import Recorder, load_events
from .export import chrome_trace
from .diff import (diff_outcomes, format_divergence, sim_replay_outcomes,
                   sim_twin_spec)

__all__ = [
    "EVENT_SCHEMA_VERSION",
    "EVENT_KINDS",
    "LIFECYCLE_KINDS",
    "OUTCOME_FIELDS",
    "Recorder",
    "chrome_trace",
    "diff_outcomes",
    "exec_index",
    "format_divergence",
    "lifecycle_fingerprints",
    "load_events",
    "outcome_record",
    "sim_replay_outcomes",
    "sim_twin_spec",
]

"""repro.obs -- low-overhead observability for both engines and the fleet.

Per-task lifecycle events (arrived -> queued -> leased/claimed -> dispatched
-> per-input resolve -> exec start/end -> done/failed/requeued, plus pool and
pump transitions) recorded into a bounded ring buffer (`Recorder`), exported
as Chrome-trace JSON (`export.chrome_trace`) and diffed task-by-task between
a measured run and its simulator replay (`diff.diff_outcomes`).

Recording is off by default and free when off: every hot-path hook is a
``if recorder is not None`` guard.  See DESIGN.md section 10.

The live telemetry plane (DESIGN.md section 13) rides alongside: a
lock-cheap `MetricsRegistry` (counters / gauges / fixed-bucket histograms
with mergeable snapshots), the per-run `Telemetry` bundle with its JSONL
time series, the `ClusterView` merged from fleet ``{"t": "stats"}`` frames,
a `HealthMonitor` evaluating window rules over the stream, and the
`TelemetryServer` endpoint that tools/monitor.py attaches to.  Same
free-when-off contract: ``metrics is None`` unless the spec asks.
"""
from .events import (
    EVENT_SCHEMA_VERSION,
    EVENT_KINDS,
    LIFECYCLE_KINDS,
    OUTCOME_FIELDS,
    exec_index,
    lifecycle_fingerprints,
    outcome_record,
)
from .recorder import Recorder, load_events
from .export import chrome_trace
from .diff import (diff_outcomes, format_divergence, sim_replay_outcomes,
                   sim_twin_spec)
from .metrics import (METRICS_SCHEMA_VERSION, ClusterView, MetricsRegistry,
                      Telemetry, TelemetryServer, fetch_telemetry,
                      merge_snapshots, quantile, read_metrics)
from .health import HealthMonitor

__all__ = [
    "EVENT_SCHEMA_VERSION",
    "EVENT_KINDS",
    "LIFECYCLE_KINDS",
    "METRICS_SCHEMA_VERSION",
    "OUTCOME_FIELDS",
    "ClusterView",
    "HealthMonitor",
    "MetricsRegistry",
    "Recorder",
    "Telemetry",
    "TelemetryServer",
    "chrome_trace",
    "diff_outcomes",
    "exec_index",
    "fetch_telemetry",
    "format_divergence",
    "lifecycle_fingerprints",
    "load_events",
    "merge_snapshots",
    "outcome_record",
    "quantile",
    "read_metrics",
    "sim_replay_outcomes",
    "sim_twin_spec",
]

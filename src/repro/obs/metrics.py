"""Live telemetry plane: lock-cheap metrics registry, mergeable snapshots,
periodic JSONL time-series, merged cluster view, and a status endpoint
(DESIGN.md §13).

The registry is the counterpart to `recorder.Recorder`: where the recorder
keeps *per-task lifecycle events* for post-hoc analysis, the registry keeps
*aggregates you can read while the run is alive* -- monotonic counters,
last-write-wins gauges, and fixed-bucket histograms.  The same free-when-off
contract applies: engines hold ``metrics = None`` when the spec doesn't ask
for telemetry, and every hot-path hook is one attribute read plus a branch.
When on, every mutation is one short critical section on the registry's own
leaf lock -- never held across the dispatcher lock, never doing I/O.

Snapshots are plain dicts (JSON-ready) and MERGE: fleet hosts sample their
own registry and ship the snapshot upstream in ``{"t": "stats"}`` frames;
``merge_snapshots`` folds any number of them into a cluster view.  Counters
and histogram buckets add; gauges ALSO add, because every gauge in this
plane is an absolute per-source total (bytes cached on *this* host, tasks
done by *this* host) -- the cluster-wide value of such a gauge is the sum
over sources, never the max or last.
"""
from __future__ import annotations

import json
import socket
import threading
import time
from bisect import bisect_left
from collections import deque
from typing import Iterable, Optional

METRICS_SCHEMA_VERSION = 1

#: default histogram bounds (seconds): log-ish 10us .. 1s + overflow bucket.
#: Tuned for pump/dispatch latencies; callers with other units pass bounds.
LATENCY_BOUNDS_S = (1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3,
                    1e-2, 3e-2, 1e-1, 3e-1, 1.0)


class _Hist:
    """Fixed-bucket histogram: ``counts[i]`` holds observations v with
    ``bounds[i-1] < v <= bounds[i]``; the trailing bucket is overflow."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: tuple):
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError("histogram bounds must be a sorted, "
                             "non-empty sequence")
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def snapshot(self) -> dict:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "sum": self.sum, "count": self.count}


class MetricsRegistry:
    """Counters + gauges + histograms behind ONE leaf lock.

    The lock covers single dict updates only; contention is negligible next
    to the dispatcher lock every instrumented path already holds or just
    released.  ``snapshot()`` returns an independent JSON-ready dict."""

    __slots__ = ("_lock", "_counters", "_gauges", "_hists")

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, _Hist] = {}

    # -- write side (hot paths) ---------------------------------------------
    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def gauge_set(self, name: str, v: float) -> None:
        with self._lock:
            self._gauges[name] = v

    def observe(self, name: str, v: float,
                bounds: tuple = LATENCY_BOUNDS_S) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = _Hist(bounds)
            h.observe(v)

    # -- read side ----------------------------------------------------------
    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def gauge(self, name: str) -> float:
        with self._lock:
            return self._gauges.get(name, 0.0)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: h.snapshot()
                               for k, h in self._hists.items()},
            }


# --------------------------------------------------------------------------
# snapshot algebra
# --------------------------------------------------------------------------

def _empty_snapshot() -> dict:
    return {"counters": {}, "gauges": {}, "histograms": {}}


def merge_snapshots(a: dict, b: dict) -> dict:
    """Fold two registry snapshots: counters add, gauges add (they are
    absolute per-source totals -- see module docstring), histogram bucket
    counts/sum/count add.  Merging disjoint observation sets is EXACTLY
    observing their union (test-locked).  Histogram bounds must agree."""
    out = _empty_snapshot()
    for src in (a, b):
        for k, v in src.get("counters", {}).items():
            out["counters"][k] = out["counters"].get(k, 0) + v
        for k, v in src.get("gauges", {}).items():
            out["gauges"][k] = out["gauges"].get(k, 0) + v
        for k, h in src.get("histograms", {}).items():
            cur = out["histograms"].get(k)
            if cur is None:
                out["histograms"][k] = {"bounds": list(h["bounds"]),
                                        "counts": list(h["counts"]),
                                        "sum": h["sum"],
                                        "count": h["count"]}
                continue
            if list(cur["bounds"]) != list(h["bounds"]):
                raise ValueError(f"histogram {k!r}: bounds mismatch, "
                                 f"cannot merge")
            cur["counts"] = [x + y for x, y in zip(cur["counts"],
                                                   h["counts"])]
            cur["sum"] += h["sum"]
            cur["count"] += h["count"]
    return out


def quantile(hist_snap: dict, q: float) -> float:
    """Bucket-resolution quantile estimate: the upper bound of the bucket
    where the cumulative count crosses ``q * count``.  For any observed
    value v the estimate e satisfies prev_bound < v <= e, i.e. the error
    is bounded by one bucket width.  Overflow clamps to the top bound."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"q must be in [0, 1], got {q}")
    total = hist_snap["count"]
    if total == 0:
        return 0.0
    target = q * total
    cum = 0
    bounds = hist_snap["bounds"]
    for i, c in enumerate(hist_snap["counts"]):
        cum += c
        if cum >= target and c:
            return float(bounds[min(i, len(bounds) - 1)])
    return float(bounds[-1])


# --------------------------------------------------------------------------
# the per-run telemetry bundle
# --------------------------------------------------------------------------

class Telemetry:
    """Everything one observed run carries: the central registry, the
    sampling interval, an optional JSONL sink, the in-memory time series
    (bounded), health events, and an optional `HealthMonitor`.

    Engines store the bundle and hand ``registry`` to the hot paths;
    samplers call :meth:`record_sample` at each tick with the engine's
    clock (virtual time in the sim, wall-rebased time elsewhere)."""

    def __init__(self, interval_s: float = 0.25,
                 sink_path: Optional[str] = None,
                 series_capacity: int = 4096,
                 health=None,
                 registry: Optional[MetricsRegistry] = None):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.registry = registry if registry is not None else MetricsRegistry()
        self.interval_s = float(interval_s)
        self.sink_path = sink_path
        self.series: deque = deque(maxlen=series_capacity)
        self.health = health
        self.health_events: list[dict] = []
        self._sink = None
        self._io_lock = threading.Lock()

    def record_sample(self, t: float, per_host: Optional[dict] = None) -> dict:
        """Snapshot the registry, append to the series, evaluate health
        rules, and (if a sink is configured) append JSONL lines.  Returns
        the sample record."""
        rec = {"kind": "metrics", "t": round(float(t), 6),
               "metrics": self.registry.snapshot()}
        if per_host:
            rec["hosts"] = {h: {"metrics": d.get("metrics", {}),
                                "age_s": d.get("age_s", 0.0)}
                            for h, d in per_host.items()}
        self.series.append(rec)
        events: list[dict] = []
        if self.health is not None:
            events = self.health.observe(rec)
            self.health_events.extend(events)
        if self.sink_path is not None:
            self._write(rec, events)
        return rec

    def merged_last(self) -> dict:
        """Cluster-wide fold of the newest sample: central registry plus
        every per-host snapshot it carried."""
        if not self.series:
            return _empty_snapshot()
        rec = self.series[-1]
        out = merge_snapshots(_empty_snapshot(), rec["metrics"])
        for d in rec.get("hosts", {}).values():
            out = merge_snapshots(out, d.get("metrics", {}))
        return out

    def _write(self, rec: dict, events: Iterable[dict]) -> None:
        with self._io_lock:
            if self._sink is None:
                self._sink = open(self.sink_path, "w")
                header = {"kind": "metrics_header",
                          "schema_version": METRICS_SCHEMA_VERSION,
                          "interval_s": self.interval_s}
                self._sink.write(json.dumps(header) + "\n")
            self._sink.write(json.dumps(rec) + "\n")
            for ev in events:
                self._sink.write(json.dumps(ev) + "\n")
            self._sink.flush()          # monitors tail this file live

    def close(self) -> None:
        with self._io_lock:
            if self._sink is not None:
                self._sink.close()
                self._sink = None


def read_metrics(path) -> tuple[dict, list[dict], list[dict]]:
    """Load a telemetry sink: (header, samples, health events).  Strict on
    the header the same way `recorder.load_events` is."""
    with open(path) as f:
        first = f.readline()
        if not first:
            raise ValueError(f"{path}: empty file, not a metrics sink")
        header = json.loads(first)
        if header.get("kind") != "metrics_header":
            raise ValueError(f"{path}: not a metrics sink")
        if header.get("schema_version") != METRICS_SCHEMA_VERSION:
            raise ValueError(f"{path}: unsupported metrics schema "
                             f"{header.get('schema_version')!r}")
        samples, health = [], []
        for line in f:
            if not line.strip():
                continue
            rec = json.loads(line)
            (samples if rec.get("kind") == "metrics" else health).append(rec)
    return header, samples, health


# --------------------------------------------------------------------------
# merged cluster view (central side of the {"t": "stats"} frames)
# --------------------------------------------------------------------------

class ClusterView:
    """Latest per-host registry snapshot, stamped with a receive clock and
    a monotonically increasing sequence number.  The sequence numbers give
    `FleetRuntime.request_stats` its barrier: broadcast a stats request,
    then wait for every live host's seq to advance past the pre-request
    reading -- the frames that arrive after that are post-request samples."""

    def __init__(self):
        self._lock = threading.Lock()
        self._hosts: dict[str, dict] = {}
        self._seq = 0

    def update(self, host_id: str, msg: dict) -> None:
        with self._lock:
            self._seq += 1
            self._hosts[host_id] = {"metrics": msg.get("metrics", {}),
                                    "seq": self._seq,
                                    "recv_clock": time.monotonic()}

    def drop(self, host_id: str) -> None:
        with self._lock:
            self._hosts.pop(host_id, None)

    def seqs(self) -> dict[str, int]:
        with self._lock:
            return {h: d["seq"] for h, d in self._hosts.items()}

    def per_host(self) -> dict[str, dict]:
        now = time.monotonic()
        with self._lock:
            return {h: {"metrics": d["metrics"],
                        "age_s": round(now - d["recv_clock"], 3)}
                    for h, d in self._hosts.items()}

    def merged(self) -> dict:
        with self._lock:
            snaps = [d["metrics"] for d in self._hosts.values()]
        out = _empty_snapshot()
        for s in snaps:
            out = merge_snapshots(out, s)
        return out


# --------------------------------------------------------------------------
# status endpoint (tools/monitor.py --attach)
# --------------------------------------------------------------------------

class TelemetryServer:
    """One-shot TCP status endpoint: each connection receives a single JSON
    line -- the newest sample plus the health-event tail -- and is closed.
    Read-only and stateless per connection, so a monitor polling it can
    never perturb the run beyond one registry snapshot per poll."""

    def __init__(self, telemetry: Telemetry, host: str = "127.0.0.1",
                 port: int = 0):
        self.telemetry = telemetry
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(8)
        self.addr = self._sock.getsockname()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._serve, daemon=True,
                                        name="telemetry-server")
        self._thread.start()

    @property
    def port(self) -> int:
        return self.addr[1]

    def _payload(self) -> bytes:
        tel = self.telemetry
        rec = {"kind": "telemetry",
               "sample": tel.series[-1] if tel.series else None,
               "health": tel.health_events[-20:]}
        return (json.dumps(rec) + "\n").encode()

    def _serve(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            try:
                conn.sendall(self._payload())
            except OSError:
                pass
            finally:
                try:
                    conn.close()
                except OSError:
                    pass

    def close(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        self._thread.join(timeout=5.0)


def fetch_telemetry(host: str, port: int, timeout: float = 2.0) -> dict:
    """Client half of `TelemetryServer`: one connect, one JSON line."""
    with socket.create_connection((host, port), timeout=timeout) as s:
        s.settimeout(timeout)
        buf = b""
        while not buf.endswith(b"\n"):
            chunk = s.recv(65536)
            if not chunk:
                break
            buf += chunk
    return json.loads(buf.decode())

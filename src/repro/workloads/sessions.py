"""Multi-turn chat sessions as diffusion workloads (the serving binding).

A serving request is a task whose inputs are the block-aligned prefix-chain
oids of its prompt (repro.serve.kvcache) -- a correlated k-input join, so
the trace schema has carried it since v2.  A *session* is the correlation
structure that makes KV diffusion interesting:

  * every turn re-reads the session's system prompt pages (Zipf-shared
    across sessions: a handful of hot system prompts dominate, exactly the
    paper's hot-object skew);
  * turn j+1's prompt extends turn j's verbatim, so its chain is turn j's
    chain plus ``turn_blocks`` new pages -- the monotone prefix property
    the tests lock;
  * turns are spaced ``think_time_s`` apart on the session's own clock
    while sessions arrive open-loop (diurnal by default), which is what
    drives the DRP's grow-AND-shrink story.

Sizing: one chain oid == one KV *page* of ``block * kv_bytes_per_token``
bytes (see repro.serve.router's sizing note); ``model=`` derives
kv_bytes_per_token from a real ModelConfig via
``repro.serve.kvcache.kv_bytes_per_token(get_config(model))``.

``SESSIONS`` / :func:`build_sessions` mirror the DAGS registry so
``WorkloadSpec.sessions = {"kind": "chat", ...}`` and ``mk_workload
--sessions`` share one construction path.
"""
from __future__ import annotations

import random
from bisect import bisect_left
from dataclasses import dataclass, field
from typing import Optional

from repro.core.objects import DataObject
from repro.serve.kvcache import prefix_chain

from .arrivals import ARRIVALS
from .workload import TaskEvent, Workload

#: default open-loop demand: a compressed day, ~10x peak/trough swing
DEFAULT_ARRIVALS = {"kind": "DiurnalArrivals", "peak_rate": 2.0,
                    "trough_rate": 0.2, "day_s": 240.0}

_VOCAB = 32_000


@dataclass
class SessionModel:
    """Deterministic generator of multi-turn session workloads.

    Every token, arrival time and Zipf draw is a pure function of ``seed``
    (string-seeded ``random.Random`` streams, PYTHONHASHSEED-independent),
    so two ``generate()`` calls are bit-identical -- the property trace
    record/replay and the bench canaries rely on.
    """

    name: str = "sess"
    n_sessions: int = 64
    turns_per_session: int = 3
    n_system_prompts: int = 8
    zipf_s: float = 1.1              # Zipf skew over system prompts
    system_prompt_blocks: int = 4    # blocks in each system prompt
    turn_blocks: int = 2             # new blocks appended per turn
    block: int = 64                  # tokens per KV page
    model: Optional[str] = None      # arch id -> kv_bytes_per_token(cfg)
    kv_bytes_per_token: int = 4096   # used when model is None
    think_time_s: float = 4.0        # gap between a session's turns
    turn_seconds: float = 0.05       # compute per turn (decode proxy)
    arrivals: dict = field(default_factory=lambda: dict(DEFAULT_ARRIVALS))
    seed: int = 0

    def __post_init__(self) -> None:
        if self.n_sessions < 1:
            raise ValueError("sessions: need n_sessions >= 1")
        if self.turns_per_session < 1:
            raise ValueError("sessions: need turns_per_session >= 1")
        if self.n_system_prompts < 1:
            raise ValueError("sessions: need n_system_prompts >= 1")
        if self.system_prompt_blocks < 1 or self.turn_blocks < 1:
            raise ValueError("sessions: need >= 1 block per prompt and turn")
        if self.block < 1:
            raise ValueError("sessions: need block >= 1")
        if self.zipf_s < 0:
            raise ValueError("sessions: need zipf_s >= 0")
        kind = self.arrivals.get("kind")
        if kind not in ARRIVALS:
            raise ValueError(f"sessions: unknown arrivals kind {kind!r} "
                             f"(known: {sorted(ARRIVALS)})")

    # ------------------------------------------------------------------
    @property
    def kv_bpt(self) -> int:
        if self.model is not None:
            from repro.configs import get_config
            from repro.serve.kvcache import kv_bytes_per_token
            return max(kv_bytes_per_token(get_config(self.model)), 1)
        return self.kv_bytes_per_token

    @property
    def page_bytes(self) -> int:
        return self.block * self.kv_bpt

    def _system_prompt(self, p: int) -> list[int]:
        rng = random.Random(f"{self.seed}:sys:{p}")
        n = self.system_prompt_blocks * self.block
        return [rng.randrange(_VOCAB) for _ in range(n)]

    def _conversation(self, sid: int) -> list[int]:
        rng = random.Random(f"{self.seed}:conv:{sid}")
        n = self.turns_per_session * self.turn_blocks * self.block
        return [rng.randrange(_VOCAB) for _ in range(n)]

    def _zipf_cdf(self) -> list[float]:
        weights = [1.0 / (r ** self.zipf_s)
                   for r in range(1, self.n_system_prompts + 1)]
        total = sum(weights)
        acc, cdf = 0.0, []
        for w in weights:
            acc += w / total
            cdf.append(acc)
        cdf[-1] = 1.0
        return cdf

    # ------------------------------------------------------------------
    def generate(self) -> Workload:
        binding = self.arrivals
        proc = ARRIVALS[binding["kind"]](
            **{k: v for k, v in binding.items() if k != "kind"})
        starts = list(proc.times(self.n_sessions, self.seed))
        cdf = self._zipf_cdf()
        zrng = random.Random(f"{self.seed}:zipf")
        sys_prompts = [self._system_prompt(p)
                       for p in range(self.n_system_prompts)]

        page = self.page_bytes
        objects: dict[str, DataObject] = {}
        events: list[tuple[tuple, TaskEvent]] = []
        for sid, start in enumerate(starts):
            p = bisect_left(cdf, zrng.random())
            full = sys_prompts[p] + self._conversation(sid)
            # ONE chain over the session's final prompt; turn j's prompt is
            # a block-aligned prefix of it, so turn j's chain is exactly the
            # first (system_prompt_blocks + j*turn_blocks) entries.
            chain = prefix_chain(full, self.block)
            for oid in chain:
                if oid not in objects:
                    objects[oid] = DataObject(oid, page)
            for j in range(1, self.turns_per_session + 1):
                n_pages = self.system_prompt_blocks + j * self.turn_blocks
                events.append((
                    (start + (j - 1) * self.think_time_s, sid, j),
                    TaskEvent(
                        t=start + (j - 1) * self.think_time_s,
                        tid=f"{self.name}-s{sid}.t{j}",
                        inputs=tuple(chain[:n_pages]),
                        compute_seconds=self.turn_seconds)))
        events.sort(key=lambda e: e[0])
        return Workload(name=self.name,
                        objects=list(objects.values()),
                        events=[ev for _, ev in events],
                        spec=self.spec())

    def spec(self) -> dict:
        """Round-trippable binding: build_sessions(spec()) regenerates the
        identical workload."""
        return {
            "kind": "chat", "name": self.name,
            "n_sessions": self.n_sessions,
            "turns_per_session": self.turns_per_session,
            "n_system_prompts": self.n_system_prompts,
            "zipf_s": self.zipf_s,
            "system_prompt_blocks": self.system_prompt_blocks,
            "turn_blocks": self.turn_blocks,
            "block": self.block,
            "model": self.model,
            "kv_bytes_per_token": self.kv_bytes_per_token,
            "think_time_s": self.think_time_s,
            "turn_seconds": self.turn_seconds,
            "arrivals": dict(self.arrivals),
            "seed": self.seed,
        }


def chat_sessions(name: str = "sess", **kw) -> Workload:
    """Functional entry point (the SESSIONS registry target)."""
    return SessionModel(name=name, **kw).generate()


#: registry for the experiment-spec binding (WorkloadSpec.sessions =
#: {"kind": "chat", ...}), mirroring DAGS / ARRIVALS / POPULARITY
SESSIONS = {"chat": chat_sessions}


def build_sessions(binding: dict, **overrides) -> Workload:
    """Materialise a ``{"kind": ..., ...kwargs}`` session binding;
    ``overrides`` win (the spec's workload name, typically)."""
    kind = binding.get("kind")
    if kind not in SESSIONS:
        raise ValueError(
            f"unknown sessions kind {kind!r} (known: {sorted(SESSIONS)})")
    kw = {k: v for k, v in binding.items() if k != "kind"}
    kw.update(overrides)
    return SESSIONS[kind](**kw)

"""Workload generation, trace record/replay, and run metrics.

The open-loop layer the paper's elasticity story needs: arrival processes
(arrivals.py) x object-popularity models (popularity.py) compose into a
:class:`Workload` of timed tasks (workload.py); workloads serialise to a
versioned JSONL trace and replay bit-identically (trace.py); finished runs
reduce to the papers' headline numbers (metrics.py).  Engines consume
workloads via ``DiffusionSim.submit_workload`` (heap-scheduled ARRIVAL
events) and ``DiffusionRuntime.submit_workload`` (paced submitter thread).
"""
from .arrivals import (ARRIVALS, ArrivalProcess, BatchArrivals,
                       BurstyArrivals, DiurnalArrivals, PoissonArrivals,
                       SineWaveArrivals)
from .dags import DAGS, all_pairs, build_dag, reduce_tree, stacking_pyramid
from .metrics import MetricsCollector, RunMetrics
from .popularity import (POPULARITY, PopularityModel, ShiftingWorkingSet,
                         StackingTrace, UniformScan, ZipfPopularity)
from .sessions import SESSIONS, SessionModel, build_sessions, chat_sessions
from .trace import (SUPPORTED_VERSIONS, TRACE_VERSION, TRACE_VERSION_V3,
                    TRACE_VERSION_V4, events_fingerprint, read_outcomes,
                    record, record_v3, replay)
from .workload import TaskEvent, Workload, generate

__all__ = [
    "ARRIVALS",
    "ArrivalProcess",
    "BatchArrivals",
    "BurstyArrivals",
    "DAGS",
    "DiurnalArrivals",
    "MetricsCollector",
    "POPULARITY",
    "PoissonArrivals",
    "PopularityModel",
    "RunMetrics",
    "SESSIONS",
    "SUPPORTED_VERSIONS",
    "SessionModel",
    "ShiftingWorkingSet",
    "SineWaveArrivals",
    "StackingTrace",
    "TRACE_VERSION",
    "TRACE_VERSION_V3",
    "TRACE_VERSION_V4",
    "TaskEvent",
    "UniformScan",
    "Workload",
    "ZipfPopularity",
    "all_pairs",
    "build_dag",
    "build_sessions",
    "chat_sessions",
    "events_fingerprint",
    "generate",
    "read_outcomes",
    "record",
    "record_v3",
    "reduce_tree",
    "replay",
    "stacking_pyramid",
]

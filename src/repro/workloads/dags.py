"""Structured DAG workload generators (PR 8).

The classic generators (``workload.generate``) emit flat bags: every task is
runnable on arrival and reads only catalog objects.  The paper's flagship
applications are *pipelines* -- astronomy stacking feeds per-group stacks
into a mosaic, all-pairs feeds per-object feature extraction into N^2
comparisons -- where most reads target objects another task PRODUCED.  The
generators here emit those shapes as plain :class:`Workload` values: tasks
carry ``deps`` (producer tids) and inputs may name produced oids, so the
dispatcher's ready-set holds downstream tasks until their producers finish
and its producer-placement scoring can route them at the freshly written
outputs (DESIGN.md §11).

Three shapes, each a pure function of its arguments:

  all_pairs         N extract tasks (catalog object -> feature), then the
                    full N x N comparison grid: each pair task reads two
                    produced features and depends on both extracts.  The
                    canonical "does producer placement matter" workload --
                    every feature is read ~2N times.
  reduce_tree       N leaf tasks over the catalog, then ``fanin``-way
                    reduction levels over produced partials up to a single
                    root.  Deep chains; exercises transitive release (and
                    transitive dep-failure).
  stacking_pyramid  the astronomy §4.3 shape: ``n_groups`` stack tasks,
                    each folding ``group_size`` catalog images into one
                    produced stack, then ONE mosaic task reading every
                    stack.  Two levels, maximal fan-in at the top.

``DAGS`` registers them by kind for the experiment-spec binding
(``WorkloadSpec.dag = {"kind": ..., ...ctor kwargs}``), mirroring the
ARRIVALS / POPULARITY registries; :func:`build_dag` is the dispatch helper
``build_workload`` and ``tools/mk_workload.py`` share.

Arrivals: tasks arrive in topological generation order, ``dt`` seconds
apart (default 0.0 = everything arrives at t=0 and the ready-set alone
sequences the stages).  Dependents arriving before -- or with -- their
producers is the point: the dispatcher must hold them, not the generator.
"""
from __future__ import annotations

from repro.core.objects import DataObject

from .workload import TaskEvent, Workload


def all_pairs(name: str = "ap", *, n_objects: int = 8,
              object_bytes: int = 10 * 1024**2,
              feature_bytes: int = 1024**2,
              extract_seconds: float = 0.05,
              pair_seconds: float = 0.01,
              dt: float = 0.0, seed: int = 0) -> Workload:
    """N extracts -> N x N pair comparisons over the produced features.

    Pair task (i, j) reads features f_i and f_j (the diagonal reads just
    f_i) and depends on both extracts, so no pair is runnable until its
    producers finish and every feature byte it reads was placed by the
    scheduler's own output admission.
    """
    if n_objects < 1:
        raise ValueError("all_pairs needs n_objects >= 1")
    objects = [DataObject(f"{name}.o{i}", object_bytes)
               for i in range(n_objects)]
    feat = [f"{name}.f{i}" for i in range(n_objects)]
    events: list[TaskEvent] = []
    t = 0.0
    for i in range(n_objects):
        events.append(TaskEvent(
            t=t, tid=f"{name}-ext{i}",
            inputs=(objects[i].oid,),
            outputs=((feat[i], feature_bytes),),
            compute_seconds=extract_seconds))
        t += dt
    for i in range(n_objects):
        for j in range(n_objects):
            inputs = (feat[i],) if i == j else (feat[i], feat[j])
            deps = (f"{name}-ext{i}",) if i == j \
                else (f"{name}-ext{i}", f"{name}-ext{j}")
            events.append(TaskEvent(
                t=t, tid=f"{name}-p{i}x{j}",
                inputs=inputs,
                compute_seconds=pair_seconds,
                deps=deps))
            t += dt
    spec = {"kind": "all_pairs", "name": name, "n_objects": n_objects,
            "object_bytes": object_bytes, "feature_bytes": feature_bytes,
            "extract_seconds": extract_seconds, "pair_seconds": pair_seconds,
            "dt": dt, "seed": seed}
    return Workload(name, objects, events, spec)


def reduce_tree(name: str = "rt", *, n_leaves: int = 8, fanin: int = 2,
                object_bytes: int = 10 * 1024**2,
                partial_bytes: int = 1024**2,
                leaf_seconds: float = 0.05,
                reduce_seconds: float = 0.02,
                dt: float = 0.0, seed: int = 0) -> Workload:
    """``fanin``-way reduction tree: N leaves over the catalog, then
    levels of reduce tasks over produced partials, down to one root."""
    if n_leaves < 1:
        raise ValueError("reduce_tree needs n_leaves >= 1")
    if fanin < 2:
        raise ValueError("reduce_tree needs fanin >= 2")
    objects = [DataObject(f"{name}.o{i}", object_bytes)
               for i in range(n_leaves)]
    events: list[TaskEvent] = []
    t = 0.0
    # level 0: leaves read the catalog, produce partials
    level: list[tuple[str, str]] = []          # (tid, produced oid)
    for i in range(n_leaves):
        tid, oid = f"{name}-l{i}", f"{name}.r0.{i}"
        events.append(TaskEvent(
            t=t, tid=tid, inputs=(objects[i].oid,),
            outputs=((oid, partial_bytes),),
            compute_seconds=leaf_seconds))
        level.append((tid, oid))
        t += dt
    depth = 1
    while len(level) > 1:
        nxt: list[tuple[str, str]] = []
        for k in range(0, len(level), fanin):
            children = level[k:k + fanin]
            tid, oid = f"{name}-r{depth}.{k // fanin}", \
                f"{name}.r{depth}.{k // fanin}"
            events.append(TaskEvent(
                t=t, tid=tid,
                inputs=tuple(o for _, o in children),
                outputs=((oid, partial_bytes),),
                compute_seconds=reduce_seconds,
                deps=tuple(c for c, _ in children)))
            nxt.append((tid, oid))
            t += dt
        level = nxt
        depth += 1
    spec = {"kind": "reduce_tree", "name": name, "n_leaves": n_leaves,
            "fanin": fanin, "object_bytes": object_bytes,
            "partial_bytes": partial_bytes, "leaf_seconds": leaf_seconds,
            "reduce_seconds": reduce_seconds, "dt": dt, "seed": seed}
    return Workload(name, objects, events, spec)


def stacking_pyramid(name: str = "sp", *, n_groups: int = 4,
                     group_size: int = 4,
                     object_bytes: int = 10 * 1024**2,
                     stack_bytes: int = 10 * 1024**2,
                     mosaic_bytes: int = 20 * 1024**2,
                     stack_seconds: float = 0.05,
                     mosaic_seconds: float = 0.1,
                     dt: float = 0.0, seed: int = 0) -> Workload:
    """Two-level astronomy shape: per-group stacks, then one mosaic that
    reads every produced stack (maximal fan-in at the top)."""
    if n_groups < 1 or group_size < 1:
        raise ValueError("stacking_pyramid needs n_groups, group_size >= 1")
    objects = [DataObject(f"{name}.g{g}.o{k}", object_bytes)
               for g in range(n_groups) for k in range(group_size)]
    events: list[TaskEvent] = []
    t = 0.0
    stacks: list[tuple[str, str]] = []
    for g in range(n_groups):
        tid, oid = f"{name}-stack{g}", f"{name}.stack{g}"
        events.append(TaskEvent(
            t=t, tid=tid,
            inputs=tuple(f"{name}.g{g}.o{k}" for k in range(group_size)),
            outputs=((oid, stack_bytes),),
            compute_seconds=stack_seconds))
        stacks.append((tid, oid))
        t += dt
    events.append(TaskEvent(
        t=t, tid=f"{name}-mosaic",
        inputs=tuple(o for _, o in stacks),
        outputs=((f"{name}.mosaic", mosaic_bytes),),
        compute_seconds=mosaic_seconds,
        deps=tuple(c for c, _ in stacks)))
    spec = {"kind": "stacking_pyramid", "name": name, "n_groups": n_groups,
            "group_size": group_size, "object_bytes": object_bytes,
            "stack_bytes": stack_bytes, "mosaic_bytes": mosaic_bytes,
            "stack_seconds": stack_seconds, "mosaic_seconds": mosaic_seconds,
            "dt": dt, "seed": seed}
    return Workload(name, objects, events, spec)


#: kind -> generator, mirroring ARRIVALS / POPULARITY: the binding dicts
#: ARE constructor kwargs (and each Workload.spec round-trips as a binding).
DAGS = {
    "all_pairs": all_pairs,
    "reduce_tree": reduce_tree,
    "stacking_pyramid": stacking_pyramid,
}


def build_dag(binding: dict, **overrides) -> Workload:
    """Materialise a ``{"kind": ..., ...kwargs}`` DAG binding (the
    experiment layer's ``WorkloadSpec.dag`` / mk_workload's ``--dag``).
    ``overrides`` win over the binding (the spec's name, typically)."""
    kind = binding.get("kind")
    if kind not in DAGS:
        raise ValueError(f"unknown dag kind {kind!r} (known: {sorted(DAGS)})")
    kw = {k: v for k, v in binding.items() if k != "kind"}
    kw.update(overrides)
    return DAGS[kind](**kw)

"""Open-loop arrival processes (when tasks *arrive*, not when slots free up).

The paper's microbenchmarks are closed-loop: a fixed batch is submitted at
t=0 and the system drains it.  The elasticity claim (§3.1 / the companion
paper arXiv 0808.3535) is about *open-loop* demand: tasks arrive on their
own clock regardless of system state, the wait queue grows when the pool is
too small, and the DynamicResourceProvisioner reacts.  Every process here is
a deterministic function of its seed: the same ``ArrivalProcess`` + seed
yields bit-identical arrival times, which is what makes trace record/replay
(trace.py) and the regression benchmarks reproducible.

Non-homogeneous processes (sine, bursty, diurnal) are sampled by Lewis &
Shedler thinning against ``max_rate``: propose exponential gaps at the peak
rate, accept a proposal at time t with probability rate(t)/max_rate.  Both
draws come from the same ``random.Random(seed)`` stream, so acceptance
history -- and therefore every arrival time -- is reproducible.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Iterator


class ArrivalProcess:
    """Base: a (possibly time-varying) rate function sampled by thinning."""

    #: subclasses must set the instantaneous-rate ceiling used for thinning
    max_rate: float = 1.0

    def rate(self, t: float) -> float:  # pragma: no cover - abstract
        raise NotImplementedError

    def times(self, n: int, seed: int) -> Iterator[float]:
        """Yield ``n`` arrival times (non-decreasing), deterministic in seed."""
        if self.max_rate <= 0:
            raise ValueError(f"{type(self).__name__}: max_rate must be > 0")
        rng = random.Random(seed)
        t = 0.0
        emitted = 0
        while emitted < n:
            t += rng.expovariate(self.max_rate)
            if rng.random() * self.max_rate <= self.rate(t):
                yield t
                emitted += 1

    def spec(self) -> dict:
        """JSON-able description for the trace header."""
        d = {k: v for k, v in vars(self).items()
             if not k.startswith("_") and k != "max_rate"}
        d["kind"] = type(self).__name__
        return d


@dataclass(init=False)
class BatchArrivals(ArrivalProcess):
    """Every task arrives at ``at_s`` -- the closed-loop batch the repo's
    microbenchmarks used to hard-code via ``sim.submit(tasks)``."""

    at_s: float

    def __init__(self, at_s: float = 0.0) -> None:
        self.at_s = at_s
        self.max_rate = float("inf")

    def rate(self, t: float) -> float:
        return 0.0

    def times(self, n: int, seed: int) -> Iterator[float]:
        for _ in range(n):
            yield self.at_s


@dataclass(init=False)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson arrivals at ``rate_per_s``."""

    rate_per_s: float

    def __init__(self, rate_per_s: float) -> None:
        if rate_per_s <= 0:
            raise ValueError("rate_per_s must be > 0")
        self.rate_per_s = rate_per_s
        self.max_rate = rate_per_s

    def rate(self, t: float) -> float:
        return self.rate_per_s


@dataclass(init=False)
class SineWaveArrivals(ArrivalProcess):
    """The companion paper's sine-wave ramp (arXiv 0808.3535 §4): demand
    oscillates around ``mean_rate`` with the given amplitude and period, so
    a provisioned pool must grow on the upswing and release on the trough.

    rate(t) = mean_rate + amplitude * sin(2*pi*(t/period) + phase)
    (clamped at 0; amplitude may equal mean_rate for a full-depth trough).
    """

    mean_rate: float
    amplitude: float
    period_s: float
    phase: float

    def __init__(self, mean_rate: float, amplitude: float, period_s: float,
                 phase: float = 0.0) -> None:
        if mean_rate <= 0 or period_s <= 0:
            raise ValueError("mean_rate and period_s must be > 0")
        if not 0 <= amplitude <= mean_rate:
            raise ValueError("need 0 <= amplitude <= mean_rate "
                             "(rates must stay non-negative)")
        self.mean_rate = mean_rate
        self.amplitude = amplitude
        self.period_s = period_s
        self.phase = phase
        self.max_rate = mean_rate + amplitude

    def rate(self, t: float) -> float:
        return max(self.mean_rate + self.amplitude
                   * math.sin(2.0 * math.pi * t / self.period_s + self.phase),
                   0.0)


@dataclass(init=False)
class BurstyArrivals(ArrivalProcess):
    """Flash-crowd shape: a low base rate with periodic rectangular bursts
    (every ``burst_every_s`` seconds the rate jumps to ``burst_rate`` for
    ``burst_len_s``) -- the demand curve that punishes slow allocation
    policies and exercises the provisioner's exponential ramp."""

    base_rate: float
    burst_rate: float
    burst_every_s: float
    burst_len_s: float

    def __init__(self, base_rate: float, burst_rate: float,
                 burst_every_s: float, burst_len_s: float) -> None:
        if base_rate <= 0 or burst_rate < base_rate:
            raise ValueError("need 0 < base_rate <= burst_rate")
        if not 0 < burst_len_s <= burst_every_s:
            raise ValueError("need 0 < burst_len_s <= burst_every_s")
        self.base_rate = base_rate
        self.burst_rate = burst_rate
        self.burst_every_s = burst_every_s
        self.burst_len_s = burst_len_s
        self.max_rate = burst_rate

    def rate(self, t: float) -> float:
        return self.burst_rate if (t % self.burst_every_s) < self.burst_len_s \
            else self.base_rate


@dataclass(init=False)
class DiurnalArrivals(ArrivalProcess):
    """Day/night cycle: cosine between ``trough_rate`` (midnight, t=0) and
    ``peak_rate`` (mid-day).  ``day_s`` compresses the 24 h period into a
    tractable simulation horizon (e.g. day_s=240 squeezes a day into 4 min
    of simulated time)."""

    peak_rate: float
    trough_rate: float
    day_s: float

    def __init__(self, peak_rate: float, trough_rate: float,
                 day_s: float = 86_400.0) -> None:
        if not 0 <= trough_rate <= peak_rate or peak_rate <= 0:
            raise ValueError("need 0 <= trough_rate <= peak_rate, peak > 0")
        self.peak_rate = peak_rate
        self.trough_rate = trough_rate
        self.day_s = day_s
        self.max_rate = peak_rate

    def rate(self, t: float) -> float:
        mid = (self.peak_rate + self.trough_rate) / 2.0
        amp = (self.peak_rate - self.trough_rate) / 2.0
        # peak at mid-day (t = day_s/2), trough at t = 0
        return mid - amp * math.cos(2.0 * math.pi * t / self.day_s)


#: registry used by trace replay and the mk_workload CLI
ARRIVALS: dict[str, type[ArrivalProcess]] = {
    cls.__name__: cls
    for cls in (BatchArrivals, PoissonArrivals, SineWaveArrivals,
                BurstyArrivals, DiurnalArrivals)
}

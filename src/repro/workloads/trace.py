"""Versioned JSONL trace format: record a Workload, replay it bit-identically.

Format (one JSON object per line):

  {"kind": "header", "version": 2, "name": ..., "n_objects": ...,
   "n_tasks": ..., "spec": {...}}                       # line 1, required
  {"kind": "object", "oid": ..., "size": ...}           # catalog entries
  {"kind": "task", "t": ..., "tid": ..., "inputs": [[oid, size], ...],
   "outputs": [[oid, size], ...], "compute_s": ..., "meta_ops": ...}

Version history:

  v1  single-input era: ``"inputs": [oid, ...]`` (sizes live only in the
      catalog).  Still read bit-identically -- a v1 trace replays to the
      same TaskEvents (and therefore the same RunMetrics) it always did;
      tests/data/trace_v1.jsonl is the committed regression fixture.
  v2  multi-input (join) era: each input is an ``[oid, size]`` pair, so a
      task line is self-describing (k-input byte totals without a catalog
      join) and size drift between the task lines and the catalog is a
      hard error instead of silent disagreement.
  v3  measured-outcome era (written by :func:`record_v3` only; plain
      :func:`record` still writes v2 -- arrivals-only traces gain nothing
      from the bump).  A v3 trace is a v2 trace plus, after the task rows,
      one ``{"kind": "outcome", ...}`` row per *measured* task completion
      (executor, attempts, per-source byte split, queue/exec/turnaround
      latencies -- the `repro.obs.events.outcome_record` schema), and its
      header carries ``n_outcomes`` so truncation stays a hard error.
      :func:`replay` reads the arrival half of a v3 trace bit-identically
      to v2 (outcome rows don't exist to it beyond the count check);
      :func:`read_outcomes` reads the measured half.  One file therefore
      carries both what a run was ASKED to do and what a real fleet
      MEASURED doing it -- the sim twin replays the former, repro.obs.diff
      joins the latter against the sim's prediction per task.
  v4  DAG era: task rows gain ``"deps": [tid, ...]`` (producer tasks that
      must complete first) and input pairs may name *produced* oids, whose
      sizes come from the producing row's outputs rather than the catalog.
      Written only when the workload actually carries dep edges --
      :func:`record` / :func:`record_v3` keep emitting byte-identical
      v2 / v3 for dep-free workloads, so every committed v1-v3 fixture and
      parity surface replays unchanged.  A v4 header always carries
      ``n_outcomes`` (0 when recorded without a measured half) and v4 may
      carry outcome rows exactly as v3 does.

Round-trip guarantee: ``replay(record(wl))`` reproduces the *exact* event
sequence -- same tids, arrival times, input/output sets and sizes -- because
Python's json emits shortest-round-trip float reprs and the reader rebuilds
the same frozen TaskEvents.  Running the replayed workload through a
deterministic engine therefore yields bit-identical metrics (enforced by
tests/test_workload_trace.py).

The version field gates schema evolution: readers *hard-error* on versions
they do not understand (anything outside SUPPORTED_VERSIONS) instead of
best-effort parsing -- a half-understood trace silently skews every metric
downstream of it.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import IO, Union

from repro.core.objects import DataObject

from .workload import TaskEvent, Workload

#: version written by :func:`record` for dep-free workloads
TRACE_VERSION = 2
#: version written by :func:`record_v3` (arrivals + measured outcomes)
TRACE_VERSION_V3 = 3
#: version written when the workload carries dependency edges
TRACE_VERSION_V4 = 4
#: versions :func:`replay` understands (v1 = single-input era traces)
SUPPORTED_VERSIONS = (1, 2, 3, 4)


def _open(path_or_file: Union[str, Path, IO[str]], mode: str):
    if hasattr(path_or_file, "write") or hasattr(path_or_file, "read"):
        return path_or_file, False
    return open(path_or_file, mode), True


def _trace_sizes(wl: Workload) -> dict[str, int]:
    """oid -> size for everything a task row may reference: the catalog
    plus every produced output (v4 inputs may name produced oids)."""
    sizes = {ob.oid: ob.size_bytes for ob in wl.objects}
    for e in wl.events:
        for oid, sz in e.outputs:
            sizes[oid] = sz
    return sizes


def _task_row(e: TaskEvent, sizes: dict[str, int], version: int) -> dict:
    row = {
        "kind": "task", "t": e.t, "tid": e.tid,
        "inputs": [[oid, sizes[oid]] for oid in e.inputs],
        "outputs": [[oid, sz] for oid, sz in e.outputs],
        "compute_s": e.compute_seconds,
        "meta_ops": e.store_metadata_ops,
    }
    if version >= TRACE_VERSION_V4:
        row["deps"] = list(e.deps)
    return row


def record(wl: Workload, path_or_file: Union[str, Path, IO[str]]) -> int:
    """Write ``wl`` as JSONL (schema v2, or v4 when ``wl`` carries dep
    edges); returns the task events written."""
    version = TRACE_VERSION_V4 if wl.has_deps() else TRACE_VERSION
    sizes = _trace_sizes(wl)
    f, should_close = _open(path_or_file, "w")
    try:
        header = {
            "kind": "header", "version": version, "name": wl.name,
            "n_objects": len(wl.objects), "n_tasks": len(wl.events),
            "spec": wl.spec,
        }
        if version >= TRACE_VERSION_V4:
            header["n_outcomes"] = 0
        f.write(json.dumps(header, sort_keys=True) + "\n")
        for ob in wl.objects:
            f.write(json.dumps({"kind": "object", "oid": ob.oid,
                                "size": ob.size_bytes}, sort_keys=True) + "\n")
        for e in wl.events:
            f.write(json.dumps(_task_row(e, sizes, version),
                               sort_keys=True) + "\n")
    finally:
        if should_close:
            f.close()
    return len(wl.events)


def record_v3(wl: Workload, path_or_file: Union[str, Path, IO[str]],
              outcomes: list[dict]) -> int:
    """Write ``wl`` plus measured per-task ``outcomes`` as JSONL (schema
    v3, or v4 when ``wl`` carries dep edges).  Every outcome must carry
    at least the
    `repro.obs.events.OUTCOME_FIELDS` keys (extra keys -- e.g. raw
    timestamps -- are preserved); a missing key hard-errors before the
    first byte is written.  Returns the task events written."""
    from repro.obs.events import OUTCOME_FIELDS

    for i, rec in enumerate(outcomes):
        missing = [k for k in OUTCOME_FIELDS if k not in rec]
        if missing:
            raise ValueError(f"outcome {i} (tid={rec.get('tid')!r}) is "
                             f"missing field(s) {missing}")
    version = TRACE_VERSION_V4 if wl.has_deps() else TRACE_VERSION_V3
    sizes = _trace_sizes(wl)
    f, should_close = _open(path_or_file, "w")
    try:
        f.write(json.dumps({
            "kind": "header", "version": version, "name": wl.name,
            "n_objects": len(wl.objects), "n_tasks": len(wl.events),
            "n_outcomes": len(outcomes), "spec": wl.spec,
        }, sort_keys=True) + "\n")
        for ob in wl.objects:
            f.write(json.dumps({"kind": "object", "oid": ob.oid,
                                "size": ob.size_bytes}, sort_keys=True) + "\n")
        for e in wl.events:
            f.write(json.dumps(_task_row(e, sizes, version),
                               sort_keys=True) + "\n")
        for rec in outcomes:
            f.write(json.dumps({"kind": "outcome", **rec},
                               sort_keys=True) + "\n")
    finally:
        if should_close:
            f.close()
    return len(wl.events)


def read_outcomes(path_or_file: Union[str, Path, IO[str]]) -> list[dict]:
    """Read the measured-outcome rows of a v3/v4 trace.  Hard-errors on
    any other version (a v1/v2 trace HAS no measured half -- silently
    returning [] would read as 'the run completed nothing')."""
    f, should_close = _open(path_or_file, "r")
    try:
        lines = (ln for ln in f if ln.strip())
        try:
            header = json.loads(next(lines))
        except StopIteration:
            raise ValueError("empty trace file") from None
        if header.get("kind") != "header":
            raise ValueError("trace must start with a header line")
        if header.get("version") not in (TRACE_VERSION_V3, TRACE_VERSION_V4):
            raise ValueError(
                f"trace version {header.get('version')!r} carries no "
                f"measured outcomes (need v{TRACE_VERSION_V3}+)")
        out = []
        for ln in lines:
            rec = json.loads(ln)
            if rec.get("kind") == "outcome":
                rec.pop("kind")
                out.append(rec)
    finally:
        if should_close:
            f.close()
    if len(out) != header.get("n_outcomes"):
        raise ValueError(
            f"truncated trace: header promises {header.get('n_outcomes')} "
            f"outcomes, found {len(out)}")
    return out


def _parse_inputs(rec: dict, version: int, sizes: dict[str, int]) -> tuple[str, ...]:
    if version == 1:
        return tuple(rec["inputs"])
    inputs = []
    for oid, sz in rec["inputs"]:
        known = sizes.get(oid)
        if known is not None and known != sz:
            raise ValueError(
                f"task {rec.get('tid')!r} input {oid!r} size {sz} "
                f"disagrees with catalog size {known}")
        inputs.append(oid)
    return tuple(inputs)


def replay(path_or_file: Union[str, Path, IO[str]]) -> Workload:
    """Read a JSONL trace back into a Workload (event-identical)."""
    f, should_close = _open(path_or_file, "r")
    try:
        lines = (ln for ln in f if ln.strip())
        try:
            header = json.loads(next(lines))
        except StopIteration:
            raise ValueError("empty trace file") from None
        if header.get("kind") != "header":
            raise ValueError("trace must start with a header line")
        version = header.get("version")
        if version not in SUPPORTED_VERSIONS:
            raise ValueError(
                f"unsupported trace version {version!r} "
                f"(this reader understands {SUPPORTED_VERSIONS})")
        objects: list[DataObject] = []
        sizes: dict[str, int] = {}
        events: list[TaskEvent] = []
        n_outcomes = 0
        for ln in lines:
            rec = json.loads(ln)
            kind = rec.get("kind")
            if kind == "object":
                objects.append(DataObject(rec["oid"], rec["size"]))
                sizes[rec["oid"]] = rec["size"]
            elif kind == "task":
                events.append(TaskEvent(
                    t=rec["t"], tid=rec["tid"],
                    inputs=_parse_inputs(rec, version, sizes),
                    outputs=tuple((oid, sz) for oid, sz in rec["outputs"]),
                    compute_seconds=rec["compute_s"],
                    store_metadata_ops=rec["meta_ops"],
                    deps=tuple(rec.get("deps", ()))
                    if version >= TRACE_VERSION_V4 else (),
                ))
                for oid, sz in rec["outputs"]:
                    sizes.setdefault(oid, sz)
            elif kind == "outcome" and version >= 3:
                # measured half of a v3 trace: not this reader's business
                # (read_outcomes consumes it), but still truncation-checked
                n_outcomes += 1
            else:
                raise ValueError(f"unknown trace record kind {kind!r}")
    finally:
        if should_close:
            f.close()
    if len(objects) != header.get("n_objects") \
            or len(events) != header.get("n_tasks"):
        raise ValueError(
            f"truncated trace: header promises {header.get('n_objects')} "
            f"objects / {header.get('n_tasks')} tasks, "
            f"found {len(objects)} / {len(events)}")
    if version >= 3 and n_outcomes != header.get("n_outcomes"):
        raise ValueError(
            f"truncated trace: header promises {header.get('n_outcomes')} "
            f"outcomes, found {n_outcomes}")
    return Workload(header.get("name", "trace"), objects, events,
                    spec=header.get("spec"))


def events_fingerprint(wl: Workload) -> tuple:
    """Hashable identity of a workload's full event sequence (for tests)."""
    return (wl.name, tuple(wl.objects),
            tuple((e.t, e.tid, e.inputs, e.outputs, e.compute_seconds,
                   e.store_metadata_ops, e.deps) for e in wl.events))

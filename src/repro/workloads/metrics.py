"""Per-run headline metrics -- the numbers the papers actually report.

Given a finished simulator run (SimResult + the pool log the simulator keeps
for elastic runs), :class:`MetricsCollector` computes:

  cache_hit_ratio       any access served without touching the persistent
                        store (paper Figure 10's metric; local + peer hits).
                        Accounting is *per input*, not per task, so the
                        ratio stays meaningful for k-input joins: a task
                        that hits 2 of its 3 stacked files contributes
                        2 hits + 1 store read, not one blended outcome;
  join split            the per-task view of the same ledger: how many
                        completed tasks had ALL inputs served cache-side
                        (full_hit_tasks), a strict subset (partial_hit_
                        tasks), or none (zero_hit_tasks), plus the mean
                        join width (mean_inputs_per_task);
  read_bandwidth_bps /  aggregate I/O bandwidth: task-input consumption and
  moved_bandwidth_bps   total bytes moved per second of busy span (Fig 3/4);
  efficiency            delivered read bandwidth / the testbed's ideal for
                        the *peak* live pool (Figure 3's "fraction of ideal");
  avg_slowdown          arXiv 0808.3535's per-task metric: turnaround time
                        (completion - arrival) divided by the task's ideal
                        duration on an otherwise-idle executor with a warm
                        cache (compute + overhead + local-disk I/O).  1.0 is
                        perfect; queueing, cold caches and store contention
                        push it up;
  performance_index     0808.3535's resource-normalised score: ideal
                        core-seconds of completed work divided by allocated
                        executor core-seconds (the integral of the live pool
                        over the run).  High PI = the provisioner bought
                        only the resources the demand curve needed.

All inputs come from engine observables; the collector never re-runs
anything, so collecting metrics is free and bit-deterministic: identical
runs (e.g. a trace replayed from JSONL) produce identical RunMetrics.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Optional, Sequence

from repro.core.testbeds import TestbedSpec


@dataclass(frozen=True)
class RunMetrics:
    n_tasks: int
    n_completed: int
    n_failed: int
    makespan_s: float
    busy_span_s: float
    tasks_per_second: float
    # cache economics
    local_hits: int
    peer_hits: int
    store_reads: int
    local_hit_ratio: float
    cache_hit_ratio: float            # global: (local + peer) / all accesses
    # join (multi-input) split, over completed tasks with >= 1 input
    mean_inputs_per_task: float
    full_hit_tasks: int               # every input local/peer-served
    partial_hit_tasks: int            # some inputs cache-side, some store
    zero_hit_tasks: int               # every input read from the store
    # aggregate I/O
    read_bandwidth_bps: float
    moved_bandwidth_bps: float
    efficiency: float                 # delivered read bw / ideal(peak pool)
    # 0808.3535 workload metrics.  avg/p95_slowdown measure from *arrival*
    # (the paper's definition, and what the committed gates canary);
    # slowdown_from_ready measures from the moment the task became runnable
    # (deps met), so dep-wait does not read as scheduler queueing.  Dep-free
    # workloads: slowdown_from_arrival == avg_slowdown == slowdown_from_ready.
    avg_slowdown: float
    p95_slowdown: float
    slowdown_from_arrival: float
    slowdown_from_ready: float
    performance_index: float
    # elasticity
    peak_executors: int
    low_executors: int
    executor_seconds: float

    def as_dict(self) -> dict:
        return asdict(self)


def quantile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation quantile of an (unsorted) sequence; 0.0 when
    empty.  Deterministic -- identical inputs give bit-identical output."""
    if not values:
        return 0.0
    s = sorted(values)
    if len(s) == 1:
        return s[0]
    pos = q * (len(s) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (pos - lo)


def latency_quantiles(values: Sequence[float]) -> dict:
    """Summary distribution for a latency (or latency-error) sample: the
    shape `repro.obs.diff` reports per lifecycle phase."""
    return {
        "p50": quantile(values, 0.50),
        "p90": quantile(values, 0.90),
        "p99": quantile(values, 0.99),
        "mean": sum(values) / len(values) if values else 0.0,
        "max": max(values) if values else 0.0,
        "n": len(values),
    }


def _ideal_task_seconds(task, sizes: dict[str, int], tb: TestbedSpec) -> float:
    """Best-case duration: warm local cache, idle node, no queueing."""
    in_bytes = sum(sizes.get(oid, 0) for oid in task.inputs)
    out_bytes = sum(ob.size_bytes for ob in task.outputs)
    return (task.compute_seconds + tb.task_overhead_s
            + tb.store_meta_latency_s * task.store_metadata_ops
            + in_bytes / tb.disk_read_bw
            + out_bytes / tb.disk_write_bw)


def _pool_integral(pool_log: Sequence[tuple[float, int]], t_end: float,
                   initial: int = 0) -> tuple[float, int, int]:
    """Integrate live-executor count over [0, t_end] from (t, live) samples.

    Returns (executor_seconds, peak, low). ``low`` is the minimum AFTER the
    first sample (so a run that only ever grows reports its start size).
    """
    if not pool_log:
        return initial * t_end, initial, initial
    secs = 0.0
    prev_t, prev_n = 0.0, initial
    peak = low = pool_log[0][1]
    for t, n in pool_log:
        secs += prev_n * (max(t, prev_t) - prev_t)
        prev_t, prev_n = max(t, prev_t), n
        peak, low = max(peak, n), min(low, n)
    secs += prev_n * max(t_end - prev_t, 0.0)
    return secs, peak, low


class MetricsCollector:
    """Computes RunMetrics from a simulator run.

    ``collect(result)`` takes the SimResult returned by DiffusionSim.run();
    the pool log and testbed ride along inside the result.
    """

    def __init__(self, testbed: TestbedSpec, cpus_per_node: int = 1) -> None:
        self.testbed = testbed
        self.cpus_per_node = cpus_per_node

    def collect(self, result, n_submitted: Optional[int] = None) -> RunMetrics:
        tb = self.testbed
        d = result.dispatcher
        pool_log = getattr(result, "pool_log", [])
        t_end = result.makespan
        exec_secs, peak, low = _pool_integral(pool_log, t_end)
        exec_secs *= self.cpus_per_node

        slowdowns: list[float] = []
        ready_slowdowns: list[float] = []
        ideal_core_s = 0.0
        n_inputs = full_hit = partial_hit = zero_hit = 0
        for t in d.completed:
            ideal = _ideal_task_seconds(t, d.sizes, tb)
            ideal_core_s += ideal
            turnaround = t.end_time - t.submit_time
            slowdowns.append(max(turnaround, 0.0) / max(ideal, 1e-12))
            # ready_time is stamped at submit for dep-free tasks and at
            # release for dep-waiters; 0.0 (a twin / direct Task) falls
            # back to arrival so both bases agree exactly when dep-free
            ready = t.ready_time if t.ready_time else t.submit_time
            ready_slowdowns.append(
                max(t.end_time - ready, 0.0) / max(ideal, 1e-12))
            n_inputs += len(t.inputs)
            if t.inputs:
                # cache-side inputs = local hits + peer fetches; the rest
                # touched the store (cache_misses counts peer AND store)
                cached = t.cache_hits + t.peer_hits
                if t.cache_misses == t.peer_hits:
                    full_hit += 1
                elif cached == 0:
                    zero_hit += 1
                else:
                    partial_hit += 1
        # both bases sum over SORTED samples: float addition is order-
        # sensitive, and dep-free runs must yield bit-equal values
        slowdowns.sort()
        ready_slowdowns.sort()
        avg_sd = sum(slowdowns) / len(slowdowns) if slowdowns else 0.0
        p95_sd = slowdowns[min(int(0.95 * len(slowdowns)),
                               len(slowdowns) - 1)] if slowdowns else 0.0

        read_bw = result.read_throughput()
        ideal_bw = tb.ideal_read_bw(max(peak, 1))
        accesses = result.local_hits + result.peer_hits + result.store_reads
        return RunMetrics(
            n_tasks=n_submitted if n_submitted is not None else len(d.tasks),
            n_completed=result.n_completed,
            n_failed=result.n_failed,
            makespan_s=result.makespan,
            busy_span_s=result.busy_span,
            tasks_per_second=result.tasks_per_second(),
            local_hits=result.local_hits,
            peer_hits=result.peer_hits,
            store_reads=result.store_reads,
            local_hit_ratio=result.local_hit_ratio if accesses else 0.0,
            cache_hit_ratio=result.global_hit_ratio if accesses else 0.0,
            mean_inputs_per_task=(n_inputs / len(d.completed)
                                  if d.completed else 0.0),
            full_hit_tasks=full_hit,
            partial_hit_tasks=partial_hit,
            zero_hit_tasks=zero_hit,
            read_bandwidth_bps=read_bw,
            moved_bandwidth_bps=result.moved_throughput(),
            efficiency=read_bw / ideal_bw if ideal_bw > 0 else 0.0,
            avg_slowdown=avg_sd,
            p95_slowdown=p95_sd,
            slowdown_from_arrival=avg_sd,
            slowdown_from_ready=(sum(ready_slowdowns) / len(ready_slowdowns)
                                 if ready_slowdowns else 0.0),
            performance_index=(ideal_core_s / exec_secs
                               if exec_secs > 0 else 0.0),
            peak_executors=peak,
            low_executors=low,
            executor_seconds=exec_secs,
        )

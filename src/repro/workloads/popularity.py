"""Object-popularity models: *which* objects each arriving task reads.

Composable with any arrival process (arrivals.py) via workload.generate().
Each model deterministically maps (task index, seeded rng) -> input oids, so
a (model, seed) pair always produces the same access sequence.

  UniformScan        round-robin over the catalog -- the repo's historical
                     ``uniform_tasks`` microbenchmark shape: with
                     n_tasks = locality * n_objects every object is read
                     exactly ``locality`` times.
  ZipfPopularity     rank-skewed draws (web/cache-trace classic): object of
                     rank r drawn with probability ~ 1/r^alpha.
  ShiftingWorkingSet a hot window over the catalog that slides every
                     ``shift_every`` tasks -- defeats pure-LFU caching and
                     exercises eviction + re-diffusion.
  StackingTrace      the astronomy-stacking shape of §4.3/Table 2: each file
                     is read ``locality`` times total, interleaved in a
                     seeded shuffle (the paper's trace has no temporal
                     clustering by file).
"""
from __future__ import annotations

import bisect
import itertools
import random
from dataclasses import dataclass


class PopularityModel:
    """Base: pick the input objects (by index into the catalog) per task."""

    def pick(self, i: int, rng: random.Random, n_objects: int) -> tuple[int, ...]:
        raise NotImplementedError  # pragma: no cover - abstract

    def spec(self) -> dict:
        d = {k: v for k, v in vars(self).items() if not k.startswith("_")}
        d["kind"] = type(self).__name__
        return d


@dataclass(init=False)
class UniformScan(PopularityModel):
    """Task i reads object (i * stride) % n -- a sequential (or strided)
    scan; locality L falls out of submitting L*n tasks."""

    stride: int

    def __init__(self, stride: int = 1) -> None:
        if stride < 1:
            raise ValueError("stride must be >= 1")
        self.stride = stride

    def pick(self, i: int, rng: random.Random, n_objects: int) -> tuple[int, ...]:
        return ((i * self.stride) % n_objects,)


@dataclass(init=False)
class ZipfPopularity(PopularityModel):
    """Zipf(alpha) over object rank; rank r (1-based) has weight r^-alpha.
    Object index == rank-1, so low indices are hot."""

    alpha: float

    def __init__(self, alpha: float = 1.0) -> None:
        if alpha < 0:
            raise ValueError("alpha must be >= 0")
        self.alpha = alpha
        self._cdf: list[float] = []
        self._cdf_n = -1

    def _ensure_cdf(self, n: int) -> None:
        if self._cdf_n == n:
            return
        weights = [1.0 / (r ** self.alpha) for r in range(1, n + 1)]
        total = sum(weights)
        acc, cdf = 0.0, []
        for w in weights:
            acc += w / total
            cdf.append(acc)
        cdf[-1] = 1.0
        self._cdf, self._cdf_n = cdf, n

    def pick(self, i: int, rng: random.Random, n_objects: int) -> tuple[int, ...]:
        self._ensure_cdf(n_objects)
        return (bisect.bisect_left(self._cdf, rng.random()),)


@dataclass(init=False)
class ShiftingWorkingSet(PopularityModel):
    """Uniform draws from a hot window of ``working_set`` objects that
    advances by ``shift_by`` every ``shift_every`` tasks (wrapping)."""

    working_set: int
    shift_every: int
    shift_by: int

    def __init__(self, working_set: int, shift_every: int,
                 shift_by: int = 1) -> None:
        if working_set < 1 or shift_every < 1 or shift_by < 0:
            raise ValueError("working_set/shift_every >= 1, shift_by >= 0")
        self.working_set = working_set
        self.shift_every = shift_every
        self.shift_by = shift_by

    def pick(self, i: int, rng: random.Random, n_objects: int) -> tuple[int, ...]:
        base = (i // self.shift_every) * self.shift_by
        w = min(self.working_set, n_objects)
        return ((base + rng.randrange(w)) % n_objects,)


@dataclass(init=False)
class StackingTrace(PopularityModel):
    """§4.3 stacking-trace shape: every object is accessed exactly
    ``locality`` times and the full access list is shuffled once with
    ``shuffle_seed`` (temporal order uncorrelated with file id, as in the
    paper's SDSS trace).  Submitting more than locality*n tasks wraps the
    shuffled list."""

    locality: int
    shuffle_seed: int

    def __init__(self, locality: int, shuffle_seed: int = 0) -> None:
        if locality < 1:
            raise ValueError("locality must be >= 1")
        self.locality = locality
        self.shuffle_seed = shuffle_seed
        self._order: list[int] = []
        self._order_n = -1

    def _ensure_order(self, n: int) -> None:
        if self._order_n == n:
            return
        order = list(itertools.chain.from_iterable(
            range(n) for _ in range(self.locality)))
        random.Random(self.shuffle_seed).shuffle(order)
        self._order, self._order_n = order, n

    def pick(self, i: int, rng: random.Random, n_objects: int) -> tuple[int, ...]:
        self._ensure_order(n_objects)
        return (self._order[i % len(self._order)],)


#: registry used by trace replay and the mk_workload CLI
POPULARITY: dict[str, type[PopularityModel]] = {
    cls.__name__: cls
    for cls in (UniformScan, ZipfPopularity, ShiftingWorkingSet, StackingTrace)
}

"""Object-popularity models: *which* objects each arriving task reads.

Composable with any arrival process (arrivals.py) via workload.generate().
Each model deterministically maps (task index, seeded rng) -> input oids, so
a (model, seed) pair always produces the same access sequence.

  UniformScan        round-robin over the catalog -- the repo's historical
                     ``uniform_tasks`` microbenchmark shape: with
                     n_tasks = locality * n_objects every object is read
                     exactly ``locality`` times.
  ZipfPopularity     rank-skewed draws (web/cache-trace classic): object of
                     rank r drawn with probability ~ 1/r^alpha.
  ShiftingWorkingSet a hot window over the catalog that slides every
                     ``shift_every`` tasks -- defeats pure-LFU caching and
                     exercises eviction + re-diffusion.
  StackingTrace      the astronomy-stacking shape of §4.3/Table 2: each file
                     is read ``locality`` times total, interleaved in a
                     seeded shuffle (the paper's trace has no temporal
                     clustering by file).

Multi-input ("join") tasks.  The paper's stacking workload (§4.3) reads
*many* image files per request, and 0808.3535's data-aware dispatch argument
hinges on tasks whose input sets partially overlap executor caches.  Every
model therefore takes ``k`` (inputs per task, default 1) and -- where draws
are random -- a ``corr`` knob in [0, 1]:

  corr = 1   the k-1 extra inputs are the primary draw's *neighborhood*
             (Zipf / shifting working set: adjacent ranks; StackingTrace:
             the primary object's stack group of k files), so tasks reading
             nearby primaries share most of their inputs -- the §4.3
             stacked-read shape;
  corr = 0   the extras are independent draws from the same model -- joins
             with little overlap;
  between    each extra input is a neighborhood member with probability
             ``corr``, an independent draw otherwise.

Inputs within one task are always distinct (independent draws that collide
probe linearly to the next free object), and ``k`` is capped at the catalog
(or window) size.  With ``k == 1`` every model consumes *exactly* the same
rng draws as it did before ``k`` existed, so single-input workloads -- and
every committed v1 trace -- are bit-identical.
"""
from __future__ import annotations

import bisect
import itertools
import random
from dataclasses import dataclass


def _probe_distinct(idx: int, chosen: set[int], n: int) -> int:
    """Smallest (idx + j) % n not already chosen -- deterministic dedupe."""
    while idx in chosen:
        idx = (idx + 1) % n
    return idx


def _check_k_corr(k: int, corr: float) -> None:
    if k < 1:
        raise ValueError("k (inputs per task) must be >= 1")
    if not 0.0 <= corr <= 1.0:
        raise ValueError("corr must be in [0, 1]")


class PopularityModel:
    """Base: pick the input objects (by index into the catalog) per task."""

    def pick(self, i: int, rng: random.Random, n_objects: int) -> tuple[int, ...]:
        raise NotImplementedError  # pragma: no cover - abstract

    def spec(self) -> dict:
        d = {k: v for k, v in vars(self).items() if not k.startswith("_")}
        d["kind"] = type(self).__name__
        return d


@dataclass(init=False)
class UniformScan(PopularityModel):
    """Task i reads object (i * stride) % n -- a sequential (or strided)
    scan; locality L falls out of submitting L*n tasks.  With ``k > 1`` each
    task reads the k consecutive strided objects starting there (a sliding
    join window; no rng, so no ``corr`` knob)."""

    stride: int
    k: int

    def __init__(self, stride: int = 1, k: int = 1) -> None:
        if stride < 1:
            raise ValueError("stride must be >= 1")
        _check_k_corr(k, 0.0)
        self.stride = stride
        self.k = k

    def pick(self, i: int, rng: random.Random, n_objects: int) -> tuple[int, ...]:
        if self.k == 1:
            return ((i * self.stride) % n_objects,)
        # strided windows can collide when n divides a stride multiple
        # (e.g. stride=5, n=10): probe to keep the k inputs distinct
        out: list[int] = []
        chosen: set[int] = set()
        for j in range(min(self.k, n_objects)):
            cand = _probe_distinct(((i + j) * self.stride) % n_objects,
                                   chosen, n_objects)
            out.append(cand)
            chosen.add(cand)
        return tuple(out)


@dataclass(init=False)
class ZipfPopularity(PopularityModel):
    """Zipf(alpha) over object rank; rank r (1-based) has weight r^-alpha.
    Object index == rank-1, so low indices are hot.  Extra inputs (``k``)
    are the primary's rank neighborhood (corr) or independent Zipf draws."""

    alpha: float
    k: int
    corr: float

    def __init__(self, alpha: float = 1.0, k: int = 1, corr: float = 1.0) -> None:
        if alpha < 0:
            raise ValueError("alpha must be >= 0")
        _check_k_corr(k, corr)
        self.alpha = alpha
        self.k = k
        self.corr = corr
        self._cdf: list[float] = []
        self._cdf_n = -1

    def _ensure_cdf(self, n: int) -> None:
        if self._cdf_n == n:
            return
        weights = [1.0 / (r ** self.alpha) for r in range(1, n + 1)]
        total = sum(weights)
        acc, cdf = 0.0, []
        for w in weights:
            acc += w / total
            cdf.append(acc)
        cdf[-1] = 1.0
        self._cdf, self._cdf_n = cdf, n

    def _draw(self, rng: random.Random) -> int:
        return bisect.bisect_left(self._cdf, rng.random())

    def pick(self, i: int, rng: random.Random, n_objects: int) -> tuple[int, ...]:
        self._ensure_cdf(n_objects)
        base = self._draw(rng)
        if self.k == 1:
            return (base,)
        out = [base]
        chosen = {base}
        for j in range(1, min(self.k, n_objects)):
            if rng.random() < self.corr:
                cand = (base + j) % n_objects          # co-drawn neighborhood
            else:
                cand = self._draw(rng)                 # independent join leg
            cand = _probe_distinct(cand, chosen, n_objects)
            out.append(cand)
            chosen.add(cand)
        return tuple(out)


@dataclass(init=False)
class ShiftingWorkingSet(PopularityModel):
    """Uniform draws from a hot window of ``working_set`` objects that
    advances by ``shift_by`` every ``shift_every`` tasks (wrapping).  Extra
    inputs stay inside the window: the primary's in-window neighborhood
    (corr) or independent in-window draws."""

    working_set: int
    shift_every: int
    shift_by: int
    k: int
    corr: float

    def __init__(self, working_set: int, shift_every: int,
                 shift_by: int = 1, k: int = 1, corr: float = 1.0) -> None:
        if working_set < 1 or shift_every < 1 or shift_by < 0:
            raise ValueError("working_set/shift_every >= 1, shift_by >= 0")
        _check_k_corr(k, corr)
        self.working_set = working_set
        self.shift_every = shift_every
        self.shift_by = shift_by
        self.k = k
        self.corr = corr

    def pick(self, i: int, rng: random.Random, n_objects: int) -> tuple[int, ...]:
        base = (i // self.shift_every) * self.shift_by
        w = min(self.working_set, n_objects)
        first = rng.randrange(w)
        if self.k == 1:
            return ((base + first) % n_objects,)
        offsets = [first]
        chosen = {first}
        for j in range(1, min(self.k, w)):
            if rng.random() < self.corr:
                cand = (first + j) % w                 # in-window neighborhood
            else:
                cand = rng.randrange(w)                # independent in-window
            cand = _probe_distinct(cand, chosen, w)
            offsets.append(cand)
            chosen.add(cand)
        return tuple((base + o) % n_objects for o in offsets)


@dataclass(init=False)
class StackingTrace(PopularityModel):
    """§4.3 stacking-trace shape: every object is accessed exactly
    ``locality`` times and the full access list is shuffled once with
    ``shuffle_seed`` (temporal order uncorrelated with file id, as in the
    paper's SDSS trace).  Submitting more than locality*n tasks wraps the
    shuffled list.

    With ``k > 1`` the catalog is partitioned into per-object *stack groups*
    of k consecutive files (group(o) = o // k) and each task stacks its
    primary's whole group -- the paper's many-files-per-request reads.  Each
    non-primary group member is used with probability ``corr``, replaced by
    an independent uniform draw otherwise."""

    locality: int
    shuffle_seed: int
    k: int
    corr: float

    def __init__(self, locality: int, shuffle_seed: int = 0,
                 k: int = 1, corr: float = 1.0) -> None:
        if locality < 1:
            raise ValueError("locality must be >= 1")
        _check_k_corr(k, corr)
        self.locality = locality
        self.shuffle_seed = shuffle_seed
        self.k = k
        self.corr = corr
        self._order: list[int] = []
        self._order_n = -1

    def _ensure_order(self, n: int) -> None:
        if self._order_n == n:
            return
        order = list(itertools.chain.from_iterable(
            range(n) for _ in range(self.locality)))
        random.Random(self.shuffle_seed).shuffle(order)
        self._order, self._order_n = order, n

    def pick(self, i: int, rng: random.Random, n_objects: int) -> tuple[int, ...]:
        self._ensure_order(n_objects)
        primary = self._order[i % len(self._order)]
        if self.k == 1:
            return (primary,)
        group_base = (primary // self.k) * self.k
        out = [primary]
        chosen = {primary}
        for j in range(self.k):
            member = group_base + j
            if member == primary:
                continue
            if len(out) >= min(self.k, n_objects):
                break
            # a member past the catalog end (last partial stack group) is
            # replaced by an independent draw, like a corr miss, so tasks
            # keep their full width min(k, n)
            if member < n_objects and rng.random() < self.corr:
                cand = member                          # stack-group co-read
            else:
                cand = rng.randrange(n_objects)        # independent draw
            cand = _probe_distinct(cand, chosen, n_objects)
            out.append(cand)
            chosen.add(cand)
        return tuple(out)


#: registry used by trace replay and the mk_workload CLI
POPULARITY: dict[str, type[PopularityModel]] = {
    cls.__name__: cls
    for cls in (UniformScan, ZipfPopularity, ShiftingWorkingSet, StackingTrace)
}

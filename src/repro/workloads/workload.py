"""Workload = arrival process x popularity model -> timed task events.

A :class:`Workload` is an immutable list of :class:`TaskEvent` (arrival
time + task shape) over an object catalog.  Engines consume it through
``tasks()``, which materialises *fresh* :class:`repro.core.objects.Task`
instances on every call -- the events themselves are never mutated, so one
Workload can be run many times (and across both engines) with identical
inputs.  Task ids are assigned deterministically (``{name}-{i}``), never
from the global task counter, so a recorded trace replays with the same
ids (trace.py round-trips bit-identically).

Invariants (relied on by the simulator's ARRIVAL events, the runtime's
paced submitter, and the trace tests):
  * events are sorted by arrival time (ties keep generation order);
  * every input oid appears in ``objects``;
  * generation is a pure function of (generator specs, seed, n_tasks).

Tasks may read *multiple* inputs (k-input "joins" -- the §4.3 stacked
reads); ``TaskEvent.inputs`` is the ordered tuple of oids and
``mean_inputs_per_task`` exposes the join width.  Single-input workloads
are unchanged.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, Iterator, Optional, Sequence

from repro.core.objects import DataObject, Task

from .arrivals import ArrivalProcess
from .popularity import PopularityModel


@dataclass(frozen=True, slots=True)
class TaskEvent:
    """One open-loop arrival: at time ``t`` a task with this shape arrives."""

    t: float
    tid: str
    inputs: tuple[str, ...]
    outputs: tuple[tuple[str, int], ...] = ()   # (oid, size_bytes)
    compute_seconds: float = 0.0
    store_metadata_ops: int = 0
    # producer tids that must complete before this task becomes ready.
    # () (the default) keeps the classic flat-bag shape.
    deps: tuple[str, ...] = ()

    def make_task(self) -> Task:
        return Task(
            inputs=self.inputs,
            outputs=tuple(DataObject(oid, sz) for oid, sz in self.outputs),
            compute_seconds=self.compute_seconds,
            store_metadata_ops=self.store_metadata_ops,
            tid=self.tid,
            deps=self.deps,
        )


class Workload:
    """An immutable timed-task sequence over an object catalog."""

    def __init__(self, name: str, objects: Sequence[DataObject],
                 events: Sequence[TaskEvent], spec: Optional[dict] = None) -> None:
        ts = [e.t for e in events]
        if any(b < a for a, b in zip(ts, ts[1:])):
            raise ValueError("workload events must be sorted by arrival time")
        tids = set()
        for e in events:
            if e.tid in tids:
                raise ValueError(f"duplicate task id {e.tid!r}")
            tids.add(e.tid)
        # Produced oids must be globally unique AND disjoint from the catalog:
        # a second registration of the same oid would silently clobber the
        # size table / index state for the first (objects are immutable).
        catalog = {ob.oid for ob in objects}
        produced: dict[str, str] = {}   # oid -> producing tid
        for e in events:
            for oid, _sz in e.outputs:
                if oid in catalog:
                    raise ValueError(
                        f"event {e.tid} produces {oid!r}, which collides "
                        f"with a catalog object")
                other = produced.get(oid)
                if other is not None:
                    raise ValueError(
                        f"events {other} and {e.tid} both produce {oid!r} "
                        f"(produced oids must be unique)")
                produced[oid] = e.tid
        # Inputs may read catalog objects or another task's produced outputs
        # (stage-structured pipelines); anything else is unknown.
        known = catalog | set(produced)
        for e in events:
            missing = [oid for oid in e.inputs if oid not in known]
            if missing:
                raise ValueError(f"event {e.tid} reads unknown objects {missing}")
        _validate_deps(events, tids)
        self.name = name
        self.objects: tuple[DataObject, ...] = tuple(objects)
        self.events: tuple[TaskEvent, ...] = tuple(events)
        self.spec = dict(spec or {})

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self) -> Iterator[tuple[float, Task]]:
        for e in self.events:
            yield e.t, e.make_task()

    def tasks(self) -> list[tuple[float, Task]]:
        """Fresh Task objects for one run (engines mutate Task state)."""
        return [(e.t, e.make_task()) for e in self.events]

    @property
    def duration(self) -> float:
        """Arrival span (time of the last arrival)."""
        return self.events[-1].t if self.events else 0.0

    def offered_load(self) -> float:
        """Mean arrival rate over the arrival span (tasks/s)."""
        return len(self.events) / self.duration if self.duration > 0 else 0.0

    def mean_inputs_per_task(self) -> float:
        """Mean join width k (1.0 for classic single-input workloads)."""
        if not self.events:
            return 0.0
        return sum(len(e.inputs) for e in self.events) / len(self.events)

    def has_deps(self) -> bool:
        """True if any task carries dependency edges (a DAG workload)."""
        return any(e.deps for e in self.events)


def _validate_deps(events: Sequence[TaskEvent], tids: set) -> None:
    """Reject unknown-tid deps, self-deps, and dependency cycles."""
    dag = False
    for e in events:
        for d in e.deps:
            if d == e.tid:
                raise ValueError(f"event {e.tid} depends on itself")
            if d not in tids:
                raise ValueError(f"event {e.tid} depends on unknown task {d!r}")
        dag = dag or bool(e.deps)
    if not dag:
        return
    # Kahn's algorithm over the dep edges; leftover nodes => a cycle.
    indeg = {e.tid: len(set(e.deps)) for e in events}
    dependents: dict[str, list[str]] = {}
    for e in events:
        for d in set(e.deps):
            dependents.setdefault(d, []).append(e.tid)
    ready = [tid for tid, n in indeg.items() if n == 0]
    seen = 0
    while ready:
        tid = ready.pop()
        seen += 1
        for dtid in dependents.get(tid, ()):
            indeg[dtid] -= 1
            if indeg[dtid] == 0:
                ready.append(dtid)
    if seen != len(events):
        stuck = sorted(tid for tid, n in indeg.items() if n > 0)[:5]
        raise ValueError(f"dependency cycle among tasks {stuck}")


def generate(
    name: str,
    arrivals: ArrivalProcess,
    popularity: PopularityModel,
    n_tasks: int,
    *,
    objects: Optional[Sequence[DataObject]] = None,
    n_objects: int = 0,
    object_bytes: int = 0,
    compute_seconds: float | Callable[[int, random.Random], float] = 0.0,
    output_bytes: int = 0,
    store_metadata_ops: int = 0,
    seed: int = 0,
) -> Workload:
    """Compose an arrival process and a popularity model into a Workload.

    Pass either an explicit ``objects`` catalog or (``n_objects``,
    ``object_bytes``) to synthesise one.  ``compute_seconds`` may be a
    constant or a callable ``(task_index, rng) -> seconds`` for heavy-tailed
    service times.  Everything is a pure function of ``seed``.
    """
    if objects is None:
        if n_objects <= 0:
            raise ValueError("need objects or n_objects > 0")
        objects = [DataObject(f"{name}.o{i}", object_bytes)
                   for i in range(n_objects)]
    objects = list(objects)
    rng = random.Random(seed ^ 0x9E3779B9)   # decorrelated from arrival draws
    events: list[TaskEvent] = []
    for i, t in enumerate(arrivals.times(n_tasks, seed)):
        idx = popularity.pick(i, rng, len(objects))
        cs = compute_seconds(i, rng) if callable(compute_seconds) \
            else compute_seconds
        outputs = ((f"{name}-{i}.out", output_bytes),) if output_bytes > 0 else ()
        events.append(TaskEvent(
            t=t,
            tid=f"{name}-{i}",
            inputs=tuple(objects[j].oid for j in idx),
            outputs=outputs,
            compute_seconds=cs,
            store_metadata_ops=store_metadata_ops,
        ))
    spec = {
        "name": name,
        "seed": seed,
        "n_tasks": n_tasks,
        "arrivals": arrivals.spec(),
        "popularity": popularity.spec(),
        "object_bytes": object_bytes,
        "output_bytes": output_bytes,
        "store_metadata_ops": store_metadata_ops,
    }
    return Workload(name, objects, events, spec)

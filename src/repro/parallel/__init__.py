from .sharding import LogicalRules, logical_to_spec, shard, make_rules
from .mesh import make_production_mesh, make_local_mesh

__all__ = [
    "LogicalRules",
    "logical_to_spec",
    "make_local_mesh",
    "make_production_mesh",
    "make_rules",
    "shard",
]

"""Logical-axis sharding (MaxText-style) for DP/FSDP/TP/EP/SP.

Every parameter and key activation in repro.models carries a tuple of
*logical* axis names.  A :class:`LogicalRules` maps logical names to physical
mesh axes; models call :func:`shard` to attach constraints and the launcher
builds pjit in/out shardings from the same rules, so changing the parallelism
layout is a rules edit, not a model edit.  This is also the lever the §Perf
hillclimbing turns.

Default layout (see DESIGN.md §5):
  batch    -> ("pod", "data")      data parallel across pods and hosts
  fsdp     -> ("pod", "data")      ZeRO-3 weight sharding on the largest
                                   non-TP dim of every stacked parameter
  tp       -> ("model",)           tensor parallel: heads / mlp / vocab
  expert   -> ("model",)           expert parallel (when E % model == 0)
  seq      -> ("model",)           sequence parallel for long-context
  (anything unmapped replicates)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class LogicalRules:
    """logical axis name -> tuple of mesh axes (or () to replicate)."""

    rules: dict[str, tuple[str, ...]] = field(default_factory=dict)
    mesh: Optional[Mesh] = None

    def spec_for(self, logical: tuple[Optional[str], ...]) -> P:
        phys: list = []
        used: set[str] = set()
        for name in logical:
            if name is None:
                phys.append(None)
                continue
            axes = tuple(a for a in self.rules.get(name, ()) if a not in used)
            used.update(axes)
            if len(axes) == 0:
                phys.append(None)
            elif len(axes) == 1:
                phys.append(axes[0])
            else:
                phys.append(axes)
        while phys and phys[-1] is None:
            phys.pop()
        return P(*phys)

    def spec_for_shape(self, logical: tuple[Optional[str], ...],
                       shape: tuple[int, ...]) -> P:
        """Shape-aware spec: an axis is claimed only if it both (a) is not
        already used by an earlier dim and (b) divides the dim.  Doing the
        dedup and the divisibility check TOGETHER matters: mixtral's
        8-expert dim must not consume the 16-way model axis it cannot use
        (that would leave d_ff unsharded => 85 GB/dev optimizer args,
        measured).  This is the single source of truth for all shardings."""
        if self.mesh is None:
            return P()
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        phys: list = []
        used: set[str] = set()
        for i, name in enumerate(logical):
            if name is None or i >= len(shape):
                phys.append(None)
                continue
            kept: list[str] = []
            denom = 1
            for a in self.rules.get(name, ()):
                if a in used:
                    continue
                if shape[i] % (denom * sizes[a]) == 0:
                    kept.append(a)
                    used.add(a)
                    denom *= sizes[a]
            phys.append(tuple(kept) if len(kept) > 1
                        else (kept[0] if kept else None))
        while phys and phys[-1] is None:
            phys.pop()
        return P(*phys)

    def sharding_for(self, logical: tuple[Optional[str], ...]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec_for(logical))


def make_rules(
    mesh: Optional[Mesh] = None,
    *,
    fsdp: bool = True,
    expert_parallel: bool = True,
    sequence_parallel: bool = False,
    extra: Optional[dict[str, tuple[str, ...]]] = None,
) -> LogicalRules:
    """Build the default rule set for a mesh with axes from
    {("data","model") | ("pod","data","model")} (launch.mesh produces these).
    With mesh=None returns no-op rules (single-device smoke tests)."""
    if mesh is None:
        return LogicalRules({}, None)
    axes = mesh.axis_names
    dp: tuple[str, ...] = tuple(a for a in ("pod", "data") if a in axes)
    tp: tuple[str, ...] = ("model",) if "model" in axes else ()
    rules: dict[str, tuple[str, ...]] = {
        "batch": dp,
        "fsdp": dp if fsdp else (),
        "tp": tp,
        # "prefer TP, fall back to ZeRO": params whose natural shard dim is
        # the TP one (mamba's d_inner) still get sharded when tp is off
        "tp_fsdp": tp + (dp if fsdp else ()),
        "expert": tp if expert_parallel else (),
        "seq": tp if sequence_parallel else (),
        "kv_seq": tp if sequence_parallel else (),
    }
    if extra:
        rules.update(extra)
    return LogicalRules(rules, mesh)


def _is_logical(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def logical_to_spec(rules: LogicalRules, logical_tree):
    """Map a pytree of logical-axis tuples to PartitionSpecs."""
    return jax.tree.map(lambda lg: rules.spec_for(lg), logical_tree,
                        is_leaf=_is_logical)


def _guard_spec(mesh: Mesh, spec: P, shape: tuple[int, ...]) -> P:
    """Drop mesh axes that do not divide their dim (e.g. whisper's 51865
    vocab on a 16-way model axis, or mixtral's 8 experts => automatic
    EP->TP fallback; see DESIGN.md §5)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out: list = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None if entry is None else entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept: list[str] = []
        denom = 1
        for a in axes:
            if shape[i] % (denom * sizes[a]) == 0:
                kept.append(a)
                denom *= sizes[a]
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_shardings(rules: LogicalRules, logical_tree, abstract_tree):
    """NamedShardings for a pytree, with divisibility-guarded specs.
    abstract_tree supplies shapes (arrays or ShapeDtypeStructs)."""
    assert rules.mesh is not None

    def one(lg, ab):
        return NamedSharding(rules.mesh,
                             rules.spec_for_shape(lg, tuple(ab.shape)))

    flat_lg, treedef = jax.tree.flatten(logical_tree, is_leaf=_is_logical)
    flat_ab = treedef.flatten_up_to(abstract_tree)
    return treedef.unflatten([one(lg, ab) for lg, ab in zip(flat_lg, flat_ab)])


def shard_tree(tree, rules: Optional[LogicalRules], logical_tree):
    """with_sharding_constraint over a pytree (guarded).  Used inside the
    layer scan: constraining the per-block param slices pins their sharding
    through the while loop, and the constraint's transpose shards the
    stacked gradient accumulators too (without this, SPMD propagation
    materializes full-size f32 grad/optimizer stacks -- measured)."""
    if rules is None or rules.mesh is None:
        return tree
    flat_lg, treedef = jax.tree.flatten(logical_tree, is_leaf=_is_logical)
    flat_x = treedef.flatten_up_to(tree)
    out = []
    for lg, x in zip(flat_lg, flat_x):
        spec = rules.spec_for_shape(lg, tuple(x.shape))
        out.append(jax.lax.with_sharding_constraint(
            x, NamedSharding(rules.mesh, spec)))
    return treedef.unflatten(out)


def shard(x: jax.Array, rules: Optional[LogicalRules], *logical: Optional[str]):
    """Attach a sharding constraint (no-op without a mesh; divisibility-
    guarded so model code never has to special-case axis sizes)."""
    if rules is None or rules.mesh is None:
        return x
    spec = rules.spec_for_shape(tuple(logical), tuple(x.shape))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))

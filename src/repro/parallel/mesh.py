"""Mesh construction helpers (see also repro.launch.mesh for the production
entry point; this module is importable without touching jax device state)."""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The production mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips.

    NOTE: building this requires 256/512 visible devices.  The dry-run
    launcher sets XLA_FLAGS=--xla_force_host_platform_device_count=512
    *before any jax import*; nothing else in the framework should."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Optional[Mesh]:
    """Best-effort small mesh over the locally visible devices (CPU tests).
    Returns None when the device count does not cover the request."""
    n = len(jax.devices())
    if data * model > n:
        return None
    return jax.make_mesh((data, model), ("data", "model"))

"""Training loop: diffusion data pipeline + jit'd train step + checkpointing.

Fault tolerance exercised here (and in tests/test_train_loop.py):
  * restart-from-latest: the loop always resumes from the newest committed
    checkpoint -- kill the process at any step and rerun;
  * async checkpointing (no step blocks on IO);
  * the data pipeline's shard schedule is a pure function of the step, so
    a restarted run replays the exact same batches (bitwise-reproducible
    losses on CPU);
  * pipeline host failures are handled by the diffusion runtime
    (re-dispatch + index invalidation), invisible here.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DiffusionDataPipeline
from repro.models import make_train_step
from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from .checkpoint import CheckpointManager
from .optimizer import Optimizer, adamw


@dataclass
class TrainResult:
    steps_run: int
    final_step: int
    losses: list[float] = field(default_factory=list)
    pipeline_stats: dict = field(default_factory=dict)
    resumed_from: Optional[int] = None


def train(
    cfg: ModelConfig,
    pipeline: DiffusionDataPipeline,
    n_steps: int,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    optimizer: Optional[Optimizer] = None,
    seed: int = 0,
    log_every: int = 10,
    log: Callable[[str], None] = print,
) -> TrainResult:
    opt = optimizer or adamw(3e-4, warmup=20, total=max(n_steps, 100))
    params = init_params(cfg, jax.random.PRNGKey(seed))
    state = opt.init(params)
    mgr = CheckpointManager(ckpt_dir, async_save=True) if ckpt_dir else None
    start_step = 0
    resumed = None
    if mgr is not None:
        latest, restored = mgr.restore_latest(state)
        if latest is not None:
            state, start_step, resumed = restored, latest, latest
            log(f"[train] resumed from checkpoint step {latest}")

    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    losses: list[float] = []
    t0 = time.time()
    for step, batch_np in pipeline.batches(start_step, n_steps - start_step):
        batch = {"tokens": jnp.asarray(batch_np)}
        if cfg.frontend == "vision":
            batch["image_embeds"] = jnp.zeros(
                (batch_np.shape[0], cfg.num_frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        if cfg.is_encdec:
            batch["frame_embeds"] = jnp.zeros(
                (batch_np.shape[0], batch_np.shape[1], cfg.d_model),
                jnp.dtype(cfg.dtype))
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if (step + 1) % log_every == 0:
            dt = (time.time() - t0) / max(len(losses), 1)
            log(f"[train] step {step + 1}/{n_steps} loss={loss:.4f} "
                f"({dt * 1e3:.0f} ms/step) "
                f"store_hits_avoided={pipeline.ledger.global_hit_ratio:.2f}")
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, state)
    if mgr is not None:
        mgr.save(start_step + len(losses), state)
        mgr.wait()
    return TrainResult(steps_run=len(losses),
                       final_step=start_step + len(losses),
                       losses=losses, pipeline_stats=pipeline.stats(),
                       resumed_from=resumed)

from .optimizer import Optimizer, TrainState, adamw
from .schedule import constant, warmup_cosine

__all__ = ["Optimizer", "TrainState", "adamw", "constant", "warmup_cosine"]
from .checkpoint import CheckpointManager
from .loop import TrainResult, train

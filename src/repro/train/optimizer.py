"""Optimizers built from scratch (no optax): AdamW and a factored-second-
moment Adafactor-style variant for memory-tight very-large configs.

State layout mirrors the param tree so the same logical-axis sharding rules
apply to optimizer state (ZeRO: m/v are sharded exactly like their params).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class TrainState(NamedTuple):
    step: jax.Array          # scalar int32
    params: PyTree
    m: PyTree                # first moment (fp32)
    v: PyTree                # second moment (fp32; factored => tuple leaves)


@dataclass(frozen=True)
class Optimizer:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    factored: bool = False    # Adafactor-style factored v for 2D+ params

    # ------------------------------------------------------------------
    def init(self, params: PyTree) -> TrainState:
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        v = jax.tree.map(self._init_v, params)
        return TrainState(step=jnp.zeros((), jnp.int32), params=params, m=m, v=v)

    def _init_v(self, p):
        if self.factored and p.ndim >= 2:
            return (jnp.zeros(p.shape[:-1], jnp.float32),
                    jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)

    # ------------------------------------------------------------------
    def apply(self, state: TrainState, grads: PyTree) -> TrainState:
        step = state.step + 1
        gnorm = _global_norm(grads)
        scale = jnp.where(gnorm > self.grad_clip,
                          self.grad_clip / (gnorm + 1e-9), 1.0)
        lr = self.lr(step)
        bc1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            if isinstance(v, tuple):
                vr = self.b2 * v[0] + (1 - self.b2) * jnp.mean(g * g, axis=-1)
                vc = self.b2 * v[1] + (1 - self.b2) * jnp.mean(g * g, axis=-2)
                rmean = jnp.mean(vr, axis=-1, keepdims=True)
                vhat = (vr[..., None] * vc[..., None, :]
                        / jnp.maximum(rmean[..., None], 1e-30)) / bc2
                new_v = (vr, vc)
            else:
                new_v = self.b2 * v + (1 - self.b2) * g * g
                vhat = new_v / bc2
            mhat = m / bc1
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m, new_v

        flat_p, tdef = jax.tree.flatten(state.params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.m)
        flat_v = tdef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return TrainState(step=step, params=new_p, m=new_m, v=new_v)

    # ------------------------------------------------------------------
    def state_logical(self, params_logical: PyTree) -> "TrainState":
        """Logical axes for TrainState given the params' logical tree
        (m like params; factored v drops the last / second-to-last axis)."""
        def v_logical(lg):
            if self.factored and len(lg) >= 2:
                return (lg[:-1], lg[:-2] + lg[-1:])
            return lg
        is_lg = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        return TrainState(
            step=(),
            params=params_logical,
            m=params_logical,
            v=jax.tree.map(v_logical, params_logical, is_leaf=is_lg),
        )


def _global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw(peak_lr: float = 3e-4, warmup: int = 100, total: int = 10_000,
          **kw) -> Optimizer:
    from .schedule import warmup_cosine
    return Optimizer(lr=warmup_cosine(peak_lr, warmup, total), **kw)

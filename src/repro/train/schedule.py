"""Learning-rate schedules (pure functions of the step scalar)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / jnp.maximum(warmup, 1)
        t = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)
    return lr


def constant(value: float):
    def lr(step):
        return jnp.full((), value, jnp.float32)
    return lr

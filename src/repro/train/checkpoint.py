"""Sharded checkpointing with atomic commit + restart-from-latest.

Fault-tolerance contract (exercised by tests/test_checkpoint.py and the
train loop's crash-restart test):
  * save is atomic: written to ``step_N.tmp/`` then renamed -- a crash
    mid-save never corrupts the latest checkpoint;
  * every leaf is saved as its own .npy plus a manifest (pytree structure,
    dtypes, step), so restore works process-by-process on a fleet (each
    host reads only its shards; here single-process reads all);
  * ``restore_latest`` picks the newest *committed* step;
  * retention: keep the most recent ``keep`` checkpoints;
  * async mode: device_get + write happen on a background thread, double
    buffered (the train loop never blocks on IO -- the paper's overlap
    discipline applied to checkpointing).
"""
from __future__ import annotations

import json
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_names(tree: PyTree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(_path_str(p) for p in path) or "leaf"
        out.append((name, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3,
                 async_save: bool = False) -> None:
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def save(self, step: int, tree: PyTree) -> pathlib.Path:
        if self.async_save:
            # snapshot to host memory synchronously (cheap), write async
            host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._thread.start()
            return self.dir / f"step_{step}"
        return self._write(step, jax.device_get(tree))

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, tree: PyTree) -> pathlib.Path:
        final = self.dir / f"step_{step}"
        tmp = self.dir / f"step_{step}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves = _flatten_with_names(tree)
        manifest = {"step": step, "leaves": []}
        for i, (name, leaf) in enumerate(leaves):
            arr = np.asarray(leaf)
            fname = f"leaf_{i}.npy"
            dtype_name = str(arr.dtype)
            if "bfloat16" in dtype_name:
                # numpy cannot round-trip ml_dtypes.bfloat16 through .npy;
                # store the raw bits as uint16 and record the logical dtype
                np.save(tmp / fname, arr.view(np.uint16))
            else:
                np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {"name": name, "file": fname, "dtype": dtype_name,
                 "shape": list(arr.shape)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
        with self._lock:
            self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for child in self.dir.iterdir():
            m = _STEP_RE.match(child.name)
            if m and (child / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, step: int, like: PyTree) -> PyTree:
        path = self.dir / f"step_{step}"
        manifest = json.loads((path / "manifest.json").read_text())
        leaves = []
        for entry in manifest["leaves"]:
            arr = np.load(path / entry["file"])
            if "bfloat16" in entry["dtype"]:
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            leaves.append(arr)
        flat, treedef = jax.tree.flatten(like)
        assert len(flat) == len(leaves), \
            f"checkpoint has {len(leaves)} leaves, model expects {len(flat)}"
        cast = [np.asarray(a).astype(b.dtype) if hasattr(b, "dtype") else a
                for a, b in zip(leaves, flat)]
        return jax.tree.unflatten(treedef, cast)

    def restore_latest(self, like: PyTree) -> tuple[Optional[int], PyTree]:
        steps = self.steps()
        if not steps:
            return None, like
        s = steps[-1]
        return s, self.restore(s, like)

"""Serving driver: batched requests through prefix-cache-aware routing.

  PYTHONPATH=src python -m repro.launch.serve --arch whisper-base --reduced \
      --requests 32 --replicas 4 --policy max-compute-util
"""
from __future__ import annotations

import argparse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--policy", default="max-compute-util")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import numpy as np

    from repro.configs import get_config
    from repro.core.policies import DispatchPolicy
    from repro.serve import Request, ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encdec:
        print("[serve] enc-dec serving demo uses the decoder-only path of a "
              "dense arch; pick an LM arch for this driver")
        return 0
    eng = ServeEngine(cfg, n_replicas=args.replicas,
                      policy=DispatchPolicy(args.policy), max_seq=96,
                      seed=args.seed)
    rng = np.random.default_rng(args.seed)
    # shared prompt prefixes => prefix-cache locality (Table 2's "locality"
    # knob, serving edition)
    bases = [list(rng.integers(2, cfg.vocab_size, 32)) for _ in range(4)]
    reqs = []
    for i in range(args.requests):
        base = bases[i % len(bases)]
        reqs.append(Request(rid=i, prompt=base + list(
            rng.integers(2, cfg.vocab_size, 8)), max_new_tokens=args.max_new))
    done = []
    for i in range(0, len(reqs), 8):
        done += eng.generate(reqs[i: i + 8])
    print(f"[serve] served {len(done)} requests on {args.replicas} replicas "
          f"({args.policy})")
    print(f"[serve] prefill tokens computed: {eng.prefill_tokens}, "
          f"reused from prefix caches: {eng.reused_tokens}")
    print(f"[serve] router: {eng.router.stats()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

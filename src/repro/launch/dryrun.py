import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run driver.

Lowers + compiles every (architecture x input-shape) cell against the
production meshes -- 16x16 (single pod, 256 chips) and 2x16x16 (two pods,
512 chips) -- and records cost/memory/collective analysis to JSON for the
roofline (§Roofline) and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-15b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch jamba-1.5-large-398b --mesh multi
"""
import argparse
import json
import pathlib
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="skip the depth-variant cost fit (compile+memory "
                         "proof only; used for the multi-pod pass -- the "
                         "roofline table reads single-pod cells)")
    args = ap.parse_args(argv)

    import jax  # deferred: after XLA_FLAGS
    assert len(jax.devices()) == 512, \
        f"dry-run needs 512 host devices, got {len(jax.devices())}"

    from repro.configs import REGISTRY, SHAPES, cells, skip_reason
    from repro.launch.cellrun import run_cell
    from repro.launch.mesh import make_production_mesh

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.arch or args.shape:
        archs = [REGISTRY[args.arch]] if args.arch else list(REGISTRY.values())
        shapes = [SHAPES[args.shape]] if args.shape else list(SHAPES.values())
        todo = []
        for c in archs:
            for s in shapes:
                todo.append((c, s, skip_reason(c, s)))
    else:
        todo = list(cells(include_skipped=True))

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    n_ok = n_fail = n_skip = 0
    for cfg, shape, reason in todo:
        for mesh_name, mesh in meshes:
            tag = f"{cfg.name}__{shape.name}__{mesh_name}"
            path = outdir / f"{tag}.json"
            if reason is not None:
                n_skip += 1
                path.write_text(json.dumps(
                    {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
                     "ok": False, "skipped": True, "reason": reason}, indent=1))
                print(f"  SKIP {tag}: {reason}")
                continue
            if args.skip_existing and path.exists():
                prev = json.loads(path.read_text())
                if prev.get("ok"):
                    n_ok += 1
                    print(f"  CACHED {tag}")
                    continue
            res = run_cell(cfg, shape, mesh, mesh_name,
                           loop_correct=not args.fast)
            d = res.to_dict()
            d["skipped"] = False
            path.write_text(json.dumps(d, indent=1))
            if res.ok:
                n_ok += 1
            else:
                n_fail += 1
    print(f"dry-run: {n_ok} ok, {n_fail} failed, {n_skip} skipped "
          f"(documented long_500k skips)")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())

"""Lower+compile one (arch x shape x mesh) cell and extract roofline inputs.

Shared by the dry-run driver and the §Perf hillclimb loop.  Never allocates
model-scale arrays: params/caches/batches are ShapeDtypeStructs.
"""
from __future__ import annotations

import re
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.models import (abstract_cache, abstract_params, batch_logical,
                          cache_logical, input_specs, make_prefill,
                          make_serve_step, make_train_step, param_logical)
from repro.models.config import ModelConfig
from repro.parallel.sharding import LogicalRules, make_rules, named_shardings
from repro.train.optimizer import Optimizer, TrainState, adamw

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"(\((?:[^()]|\([^()]*\))*\)|\S+\[[\d,]*\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([\d,]*)\]")
_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
          "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
          "u64": 8}


def f32_shadow_bytes(hlo_text: str, min_bytes: float = 64e6) -> float:
    """Bytes of fp32 'shadow' tensors: fp32 buffers whose exact shape also
    exists as a bf16 buffer.  The CPU backend emulates bf16 arithmetic by
    upconverting to fp32, so big bf16 values (saved-carry stacks, gathered
    weights) get whole-stack fp32 twins that a native-bf16 TPU lowering
    does not materialize.  Subtracted to form the TPU-adjusted peak
    (documented in EXPERIMENTS.md par. Dry-run)."""
    seen_f32: dict[str, float] = {}
    seen_bf16: set[str] = set()
    for m in re.finditer(r"(f32|bf16)\[([\d,]+)\]", hlo_text):
        dims = m.group(2)
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if m.group(1) == "f32":
            if n * 4 >= min_bytes:
                seen_f32[dims] = n * 4.0
        else:
            seen_bf16.add(dims)
    return sum(v for k, v in seen_f32.items() if k in seen_bf16)


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, float]:
    """Per-device bytes by collective kind, from post-SPMD optimized HLO.
    The result-type shapes are per-partition, so these are bytes handled by
    ONE device; multiply by chip count for the global figure."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:  # async pair: count the start only
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_type, kind = m.group(1), m.group(2)
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(result_type):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        out[kind] = out.get(kind, 0.0) + nbytes
    return out


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

@dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool = False
    error: str = ""
    n_devices: int = 0
    lower_s: float = 0.0
    compile_s: float = 0.0
    per_device_flops: float = 0.0
    per_device_bytes: float = 0.0
    collective_per_device: dict[str, float] = field(default_factory=dict)
    peak_bytes_per_device: float = 0.0
    peak_tpu_adjusted: float = 0.0     # peak minus CPU-backend f32 shadows
    argument_bytes: float = 0.0
    output_bytes: float = 0.0
    temp_bytes: float = 0.0
    generated_code_bytes: float = 0.0
    model_params: float = 0.0
    active_params: float = 0.0

    def to_dict(self) -> dict[str, Any]:
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


def default_layout(cfg: ModelConfig) -> str:
    """Baseline parallel layout per family (DESIGN.md §5):

    fsdp_tp_sp -- FSDP over (pod,data) + TP over model + sequence-parallel
        residual stream.  Right when per-layer TP shrinks the big matmuls
        (dense attention archs, qwen3's 128-expert EP).
    dp_zero3 -- batch over EVERY mesh axis + ZeRO-3 over every axis, no TP.
        Right when layers must see the full sequence anyway (mamba's scan)
        or when experts cannot divide the model axis (mixtral's 8 on 16):
        activations shrink by the model-axis width and TP's per-layer
        activation collectives disappear; weights arrive via per-layer
        all-gather (ZeRO-3), sized by the layer not the model.
    """
    if cfg.family in ("ssm", "hybrid"):
        return "dp_zero3"
    if cfg.n_experts and cfg.n_experts % 16 != 0:
        return "dp_zero3"          # mixtral: EP cannot divide the model axis
    return "fsdp_tp_sp"


def default_layout_for(cfg: ModelConfig, mode: str) -> str:
    """dp_zero3 exists to fit TRAIN optimizer state; inference shapes have
    no optimizer state and want sequence/TP sharding (a dp_zero3 mixtral
    prefill keeps full-seq activations per device -- measured 841 GB)."""
    if mode in ("prefill", "decode"):
        return "fsdp_tp_sp"
    return default_layout(cfg)


def rules_for_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
                   *, layout: Optional[str] = None,
                   seq_shard_decode: bool = True) -> LogicalRules:
    """Default (baseline) rules; the §Perf hillclimb overrides."""
    layout = layout or default_layout_for(cfg, shape.mode)
    all_axes = tuple(mesh.axis_names)
    if shape.mode == "decode":
        dp = [a for a in ("pod", "data") if a in mesh.axis_names]
        dp_size = 1
        for a in dp:
            dp_size *= mesh.devices.shape[mesh.axis_names.index(a)]
        batch_ok = shape.global_batch % dp_size == 0
        extra = {}
        if seq_shard_decode:
            extra["kv_seq"] = ("model",) if batch_ok else all_axes
        if not batch_ok:
            extra["batch"] = ()
        rules = make_rules(mesh, fsdp=True, extra=extra)
        if layout == "dp_zero3":
            r = dict(rules.rules)
            r["fsdp"] = all_axes
            r["tp"] = ()
            r["tp_fsdp"] = all_axes
            rules = LogicalRules(r, mesh)
        return rules
    if layout == "dp_zero3":
        return make_rules(mesh, extra={
            "batch": all_axes, "fsdp": all_axes, "tp": (),
            "tp_fsdp": all_axes,
            "act_seq": (), "expert": ("model",) if "model" in all_axes else (),
        })
    # fsdp_tp_sp: sequence-parallel residual stream (the lax.scan carry --
    # what backward must save -- shards over the model axis along seq)
    return make_rules(mesh, fsdp=True, extra={"act_seq": ("model",)})


def _depth_variant(cfg: ModelConfig, k: int) -> ModelConfig:
    """Same arch with k pattern-blocks (and k encoder layers for enc-dec);
    used to fit cost = a + b*n_blocks, correcting XLA cost analysis'
    count-the-loop-body-once behaviour for lax.scan over layers."""
    kw: dict = {"n_layers": cfg.period * k, "scan_unroll": max(k, 1)}
    if cfg.is_encdec:
        kw["enc_layers"] = k
    return cfg.with_(**kw)


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
               rules: Optional[LogicalRules] = None,
               optimizer: Optional[Optimizer] = None):
    """Returns (fn, args_abstract, in_shardings, out_shardings?) for jit."""
    rules = rules or rules_for_cell(cfg, shape, mesh)
    params_ab = abstract_params(cfg)
    params_lg = param_logical(cfg)
    batch_ab = input_specs(cfg, shape.seq_len, shape.global_batch, shape.mode)
    batch_lg = batch_logical(cfg, shape.mode)
    batch_sh = named_shardings(rules, batch_lg, batch_ab)

    if shape.mode == "train":
        opt = optimizer or adamw(3e-4, 100, 10_000)
        state_ab = jax.eval_shape(opt.init, params_ab)
        state_lg = opt.state_logical(params_lg)
        state_sh = named_shardings(rules, state_lg, state_ab)
        fn = make_train_step(cfg, opt, rules)
        # out_shardings matter: without them XLA may materialize the new
        # optimizer state / grads UNSHARDED inside the loop (measured as
        # multi-GB f32 full-size temps on the 42 GB danube lowering).
        metrics_sh = {"loss": named_shardings(rules, (), jax.ShapeDtypeStruct((), jnp.float32)),
                      "grad_norm": named_shardings(rules, (), jax.ShapeDtypeStruct((), jnp.float32)),
                      "step": named_shardings(rules, (), jax.ShapeDtypeStruct((), jnp.int32))}
        return fn, (state_ab, batch_ab), (state_sh, batch_sh), \
            (state_sh, metrics_sh), rules
    if shape.mode == "prefill":
        fn = make_prefill(cfg, rules)
        params_sh = named_shardings(rules, params_lg, params_ab)
        # prefill returns LAST-position logits (B, 1, V)
        logits_ab = jax.ShapeDtypeStruct(
            (shape.global_batch, 1, cfg.vocab_size), jnp.float32)
        logits_sh = named_shardings(rules, ("batch", None, "tp"), logits_ab)
        return fn, (params_ab, batch_ab), (params_sh, batch_sh), \
            logits_sh, rules
    # decode
    cache_ab = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    cache_lg = cache_logical(cfg)
    cache_sh = named_shardings(rules, cache_lg, cache_ab)
    params_sh = named_shardings(rules, params_lg, params_ab)
    fn = make_serve_step(cfg, rules)
    logits_ab = jax.ShapeDtypeStruct(
        (shape.global_batch, 1, cfg.vocab_size), jnp.float32)
    logits_sh = named_shardings(rules, ("batch", None, "tp"), logits_ab)
    return fn, (params_ab, cache_ab, batch_ab), \
        (params_sh, cache_sh, batch_sh), (logits_sh, cache_sh), rules


def _compile_once(cfg: ModelConfig, shape: ShapeSpec, mesh,
                  rules: Optional[LogicalRules], donate: bool):
    fn, args_ab, in_sh, out_sh, rules = build_cell(cfg, shape, mesh, rules)
    donate_argnums = ()
    if donate and shape.mode == "train":
        donate_argnums = (0,)      # donate TrainState
    elif donate and shape.mode == "decode":
        donate_argnums = (1,)      # donate cache
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate_argnums)
    t0 = time.time()
    with mesh:
        lowered = jitted.lower(*args_ab)
    lower_s = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    return compiled, lower_s, compile_s


def _costs(compiled) -> tuple[float, float, dict[str, float]]:
    ca = compiled.cost_analysis() or {}
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            collective_bytes_from_hlo(compiled.as_text()))


def run_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, mesh_name: str,
             rules: Optional[LogicalRules] = None,
             donate: bool = True,
             verbose: bool = True,
             loop_correct: bool = True) -> CellResult:
    res = CellResult(arch=cfg.name, shape=shape.name, mesh=mesh_name,
                     n_devices=mesh.devices.size,
                     model_params=float(cfg.param_count()),
                     active_params=float(cfg.param_count(active_only=True)))
    try:
        # 1) full-depth compile: proves the cell fits + compiles (memory
        #    analysis is exact here; cost analysis counts scan bodies once)
        compiled, res.lower_s, res.compile_s = _compile_once(
            cfg, shape, mesh, rules, donate)
        ma = compiled.memory_analysis()
        if ma is not None:
            res.argument_bytes = float(getattr(ma, "argument_size_in_bytes", 0))
            res.output_bytes = float(getattr(ma, "output_size_in_bytes", 0))
            res.temp_bytes = float(getattr(ma, "temp_size_in_bytes", 0))
            res.generated_code_bytes = float(
                getattr(ma, "generated_code_size_in_bytes", 0))
            alias = float(getattr(ma, "alias_size_in_bytes", 0))
            res.peak_bytes_per_device = (res.argument_bytes + res.output_bytes
                                         + res.temp_bytes - alias)
        res.peak_tpu_adjusted = max(
            res.peak_bytes_per_device - f32_shadow_bytes(compiled.as_text()),
            0.0)
        f_full, b_full, c_full = _costs(compiled)
        if loop_correct and cfg.n_blocks > 2:
            # 2) depth-1 and depth-2 variants -> cost = a + b*n_blocks fit
            #    (XLA cost analysis counts a lax.scan body ONCE regardless of
            #    trip count; the fit restores the true per-step totals).
            c1, *_ = _compile_once(_depth_variant(cfg, 1), shape, mesh,
                                   None if rules is None else rules, donate)
            c2, *_ = _compile_once(_depth_variant(cfg, 2), shape, mesh,
                                   None if rules is None else rules, donate)
            f1, b1, coll1 = _costs(c1)
            f2, b2, coll2 = _costs(c2)
            nb = cfg.n_blocks
            res.per_device_flops = f1 + (f2 - f1) * (nb - 1)
            res.per_device_bytes = b1 + (b2 - b1) * (nb - 1)
            kinds = set(coll1) | set(coll2)
            res.collective_per_device = {
                k: coll1.get(k, 0.0)
                + (coll2.get(k, 0.0) - coll1.get(k, 0.0)) * (nb - 1)
                for k in kinds}
        else:
            res.per_device_flops = f_full
            res.per_device_bytes = b_full
            res.collective_per_device = c_full
        res.ok = True
        if verbose:
            coll = sum(res.collective_per_device.values())
            print(f"  OK {cfg.name} x {shape.name} x {mesh_name}: "
                  f"{res.per_device_flops/1e12:.2f} TF/dev, "
                  f"{res.per_device_bytes/1e9:.2f} GB/dev touched, "
                  f"{coll/1e9:.3f} GB/dev collectives, "
                  f"peak {res.peak_bytes_per_device/1e9:.2f} GB/dev "
                  f"(tpu-adj {res.peak_tpu_adjusted/1e9:.2f}) "
                  f"(lower {res.lower_s:.1f}s compile {res.compile_s:.1f}s)")
    except Exception as e:  # noqa: BLE001 -- cell failures are data
        res.ok = False
        res.error = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"  FAIL {cfg.name} x {shape.name} x {mesh_name}: "
                  f"{res.error[:300]}")
    return res

"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-3-4b \
      --steps 50 --reduced --hosts 4 --policy max-compute-util

``--reduced`` runs the arch's reduced (smoke) config on CPU; the full
configs are for the TPU fleet (and are exercised shape-only by dryrun.py).
The data path ALWAYS flows through data diffusion -- the point of the
framework -- and the driver prints the byte ledger at the end.
"""
from __future__ import annotations

import argparse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced config (CPU-sized)")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--policy", default="max-compute-util")
    ap.add_argument("--cache-mb", type=int, default=64)
    ap.add_argument("--shards", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.core.policies import DispatchPolicy
    from repro.data.dataset import ShardSpec
    from repro.data.pipeline import DiffusionDataPipeline, PipelineConfig
    from repro.train.loop import train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    pipe_cfg = PipelineConfig(
        global_batch=args.global_batch, seq_len=args.seq_len,
        n_hosts=args.hosts,
        policy=DispatchPolicy(args.policy),
        host_cache_bytes=args.cache_mb << 20, seed=args.seed)
    spec = ShardSpec(
        n_shards=args.shards,
        tokens_per_shard=max(pipe_cfg.tokens_per_batch, 1 << 16),
        vocab_size=cfg.vocab_size, seed=args.seed)
    pipeline = DiffusionDataPipeline(pipe_cfg, spec)
    try:
        result = train(cfg, pipeline, args.steps, ckpt_dir=args.ckpt_dir,
                       seed=args.seed)
    finally:
        pipeline.close()
    print(f"[train] done: {result.steps_run} steps, "
          f"final loss {result.losses[-1]:.4f}" if result.losses else "no steps")
    print(f"[train] diffusion ledger: {result.pipeline_stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Production mesh (defined as functions -- importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before any jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips (single pod) or 2x16x16 = 512 chips (two pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)

"""FleetRuntime: the threaded diffusion runtime across OS processes.

Same authoritative scheduling stack as `DiffusionRuntime` -- ONE
`Dispatcher`/`LocationIndex`/policy instance, in this process -- but the
executors live in ``hosts`` separate host processes of
``threads_per_host`` executor threads each (repro.fleet.host), talking
through the two Channel seams:

  dispatch   `_RemoteExecutor.dispatch` serialises each `Dispatch` (task
             shape + input sizes + location hints + peer routes) onto the
             host's socket instead of a thread inbox;
  updates    hosts stream `IndexUpdate`s and attempt completions back; the
             per-host receiver applies them through the SAME `_on_update` /
             `_finish_attempt` code paths the in-process workers use, so
             membership guards, retry accounting and the byte ledger are
             one implementation.

Because placement, hints, retries and accounting never leave this process,
the scheduling behaviour is identical to single-process mode by
construction; `benchmarks/bench_fleet.py` verifies it by replaying a
recorded trace batch-synchronously on both and comparing RunReports
field-for-field on the scheduling-determined numbers.

Failure semantics: a host that SIGKILLs/EOFs/stops heartbeating is
declared dead once; every executor on it goes through the PR 2
``executor_left`` path (in-flight tasks re-queued front-of-line, attempts
bumped, terminally-failed ones accounted so ``wait()`` cannot leak), and
its cached bytes vanish from the index, exactly like a failed thread
worker -- the rest of the fleet re-fetches from peers or the store.

Provisioning is whole-host: the DRP's executor-unit requests are rounded
to ``threads_per_host`` quanta (`DynamicResourceProvisioner.
allocate_quantum`), ``provision_grow`` spawns hosts, and only hosts whose
executors are ALL idle are offered for release.
"""
from __future__ import annotations

import os
import time
from typing import Any, Iterable, Optional

from repro.core.cache import EvictionPolicy
from repro.core.objects import DataObject
from repro.core.policies import DispatchPolicy
from repro.core.runtime import DiffusionRuntime, ObjectStore, _InputLedger
from repro.core.scheduler import Dispatch

from .manager import HostHandle, HostManager


class _RemoteExecutor:
    """Central-side proxy for one executor thread on a host.  Lives in
    ``runtime.workers`` exactly where an `ExecutorWorker` would, so every
    inherited code path (pump, membership guard, removal) works unchanged
    -- identity of this object IS the attempt-validity token."""

    __slots__ = ("eid", "host", "rt")

    def __init__(self, eid: str, host: HostHandle, rt: "FleetRuntime") -> None:
        self.eid = eid
        self.host = host
        self.rt = rt

    def task_msg(self, disp: Dispatch) -> dict:
        """Serialise one Dispatch to its wire message (the pump collects
        these per host and ships them as bounded batch frames)."""
        t = disp.task
        routes: dict[str, list] = {}
        for locs in disp.hints.values():
            for peer in locs:
                if peer in routes:
                    continue
                w = self.rt.workers.get(peer)
                if isinstance(w, _RemoteExecutor) and w.host is not self.host:
                    routes[peer] = [w.host.peer_host, w.host.peer_port]
        sizes = self.rt.dispatcher.sizes
        return {
            "t": "task",
            "eid": self.eid,
            "tid": t.tid,
            "inputs": [[oid, sizes.get(oid, 0)] for oid in t.inputs],
            "outputs": [[ob.oid, ob.size_bytes] for ob in t.outputs],
            "hints": {oid: list(locs) for oid, locs in disp.hints.items()},
            "routes": routes,
        }

    def dispatch(self, disp: Dispatch) -> None:
        self.host.send(self.task_msg(disp))

    def stop(self) -> None:
        """Nothing to join centrally; host teardown stops the thread."""


class FleetRuntime(DiffusionRuntime):
    def __init__(
        self,
        hosts: int,
        threads_per_host: int = 1,
        policy: DispatchPolicy = DispatchPolicy.MAX_COMPUTE_UTIL,
        cache_policy: EvictionPolicy = EvictionPolicy.LRU,
        cache_capacity_bytes: int = 1 << 30,
        store: Optional[ObjectStore] = None,
        seed: int = 0,
        index_update_batch: int = 1,
        task_fn_name: Optional[str] = None,
        codec: str = "auto",
        heartbeat_interval_s: float = 0.25,
        heartbeat_timeout_s: float = 3.0,
        spawn_timeout_s: float = 60.0,
        wire_batch: int = 64,
        local_dispatch: bool = False,
        lease_depth: int = 2,
        bind_host: str = "127.0.0.1",
        recorder=None,
        metrics=None,  # optional repro.obs.metrics.Telemetry
    ) -> None:
        if hosts < 0:
            # hosts=0 builds an empty fleet (unit tests drive the receive
            # path directly; add_host() grows it for real)
            raise ValueError("need hosts >= 0")
        if threads_per_host < 1:
            raise ValueError("need threads_per_host >= 1")
        if wire_batch < 1:
            raise ValueError("need wire_batch >= 1")
        if lease_depth < 1:
            raise ValueError("need lease_depth >= 1")
        self.threads_per_host = threads_per_host
        self.wire_batch = wire_batch
        self.local_dispatch = local_dispatch
        self.lease_depth = lease_depth
        super().__init__(n_executors=0, policy=policy,
                         cache_policy=cache_policy,
                         cache_capacity_bytes=cache_capacity_bytes,
                         store=store, seed=seed,
                         index_update_batch=index_update_batch,
                         recorder=recorder, metrics=metrics)
        #: host_id -> {tid: Task} parked on a lease, awaiting claim/reclaim
        self._leases: dict[str, dict[str, Any]] = {}
        #: applied index updates pending forward to host replicas
        self._fwd_buf: list[list] = []
        self.manager = HostManager(
            self, codec=codec, task_fn_name=task_fn_name,
            hb_interval_s=heartbeat_interval_s,
            hb_timeout_s=heartbeat_timeout_s,
            spawn_timeout_s=spawn_timeout_s,
            bind_host=bind_host, wire_batch=wire_batch,
            local_dispatch=local_dispatch,
            # hosts mirror the central ring's capacity; 0 keeps host-side
            # recording compiled out entirely (no Recorder import there)
            observe_capacity=(recorder.capacity
                              if recorder is not None else 0),
            # hosts sample on the telemetry cadence; 0 keeps host-side
            # registries (and stats frames) compiled out entirely
            metrics_interval_s=(metrics.interval_s
                                if metrics is not None else 0.0))
        try:
            for _ in range(hosts):
                self.add_host()
        except Exception:
            self.manager.shutdown()
            raise
        # collapse the construction ramp into one t=0 sample, like the
        # in-process ctor (RunReport pool integrals start at full strength)
        self.pool_log = [(0.0, len(self.workers))]

    # -- membership (whole hosts) ------------------------------------------
    def add_host(self) -> str:
        """Spawn one host process, replicate the store to it, register its
        ``threads_per_host`` executors.  Spawn messages go on the wire
        BEFORE the dispatcher learns each eid, so a racing pump can never
        dispatch to an executor the host hasn't spawned yet (per-host
        streams are ordered)."""
        handle = self.manager.spawn_host()
        for obj, payload in self.store.items():
            handle.send({"t": "put", "oid": obj.oid, "size": obj.size_bytes,
                         "payload": payload})
        for _ in range(self.threads_per_host):
            with self._lock:
                wid = self._next_worker_id
                self._next_worker_id += 1
            eid = f"w{wid}"
            handle.send({"t": "spawn", "eid": eid,
                         "cap": self._cache_capacity(),
                         "policy": self._cache_policy().value,
                         "seed": self._seed + wid})
            with self._lock:
                self.workers[eid] = _RemoteExecutor(eid, handle, self)
                handle.eids.append(eid)
                self.dispatcher.executor_joined(eid, time.monotonic())
                self.pool_log.append((time.monotonic() - self._t0,
                                      len(self.workers)))
        if self.local_dispatch:
            # every host needs routes to every executor so locally-built
            # hints can resolve to cross-host peer fetches
            with self._lock:
                routes = {eid: [w.host.peer_host, w.host.peer_port]
                          for eid, w in self.workers.items()
                          if isinstance(w, _RemoteExecutor)}
            self.manager.broadcast({"t": "peers", "routes": routes})
        self._pump()
        return handle.host_id

    def remove_host(self, host_id: str) -> None:
        """Graceful release (DRP shrink): deregister every executor, then
        shut the process down.  In-flight work (there should be none for a
        released-idle host, but the path is shared with tests) re-queues
        through executor_left like any removal."""
        with self._lock:
            handle = self.manager.handles.get(host_id)
            if handle is None or handle.dead:
                return
            handle.dead = True
            self._drop_host_locked(handle, failed=False)
        self.manager.reap(handle, graceful=True)
        if self.local_dispatch:
            self.manager.broadcast({"t": "index_drop", "eids": handle.eids})
        self._pump()

    def _drop_host_locked(self, handle: HostHandle, failed: bool) -> None:
        for eid in handle.eids:
            if self.workers.pop(eid, None) is None:
                continue
            self.pool_log.append((time.monotonic() - self._t0,
                                  len(self.workers)))
            self._deregister_locked(eid, failed)
        # unclaimed leases return to the queue front in lease order; any
        # claim frame still in flight from this host will be rejected (the
        # handle is dead) and its eventual done dropped by the membership
        # guard, so the re-queued task runs exactly once
        leased = self._leases.pop(handle.host_id, None)
        if leased:
            self.dispatcher.requeue_leased(leased.values())
        # fold the dying connection's wire counters into the runtime's
        # stats so dispatch_stats() keeps counting retired hosts (dead
        # handles are excluded from the live fold)
        self.stats.frames_sent += handle.frames_sent
        self.stats.msgs_sent += handle.msgs_sent
        self.stats.frames_recv += handle.frames_recv
        self.stats.msgs_recv += handle.msgs_recv

    def _on_host_dead(self, handle: HostHandle) -> None:
        """Receiver-EOF / monitor callback: requeue the dead host's
        in-flight tasks and drop its index entries.  Idempotent -- the
        ``dead`` flag flips under the runtime lock exactly once."""
        with self._lock:
            if handle.dead:
                return
            handle.dead = True
            self._drop_host_locked(handle, failed=True)
        self.manager.reap(handle)
        if self.local_dispatch:
            # surviving replicas must forget the dead executors' entries
            # (a late resurrection there costs a failed peer fetch, not
            # correctness, but the drop keeps local scores honest)
            self.manager.broadcast({"t": "index_drop", "eids": handle.eids})
        self._pump()

    def add_executor(self) -> str:
        raise RuntimeError("a fleet grows by whole hosts; use add_host()")

    def remove_executor(self, eid: str, failed: bool = False) -> None:
        raise RuntimeError("a fleet shrinks by whole hosts; use "
                           "remove_host() or manager.kill_host()")

    def configure_caches(self, capacity_bytes: int,
                         policy: EvictionPolicy) -> None:
        raise RuntimeError("fleet executor caches are fixed at host spawn")

    # -- provisioning hooks (whole-host granularity) ------------------------
    def provision_grow(self, n: int) -> None:
        for _ in range(n // self.threads_per_host):
            self.add_host()

    def provision_release(self, eids: Iterable[str]) -> None:
        by_host: dict[str, set[str]] = {}
        for eid in eids:
            w = self.workers.get(eid)
            if isinstance(w, _RemoteExecutor):
                by_host.setdefault(w.host.host_id, set()).add(eid)
        for host_id, group in by_host.items():
            handle = self.manager.handles.get(host_id)
            if handle is not None and set(handle.eids) <= group:
                self.remove_host(host_id)

    def provision_idle(self, now: float, idle_for_s: float) -> list[str]:
        """Only whole-idle hosts are offered (grouped host-by-host, so a
        quantum-truncated prefix still maps to whole hosts)."""
        idle = set(self.dispatcher.idle_executors(now, idle_for_s))
        out: list[str] = []
        for handle in self.manager.live_handles():
            if handle.eids and set(handle.eids) <= idle:
                out.extend(handle.eids)
        return out

    # -- data ---------------------------------------------------------------
    def put_object(self, obj: DataObject, payload: Any) -> None:
        super().put_object(obj, payload)
        self.manager.broadcast({"t": "put", "oid": obj.oid,
                                "size": obj.size_bytes, "payload": payload})

    # -- central dispatch loop (batched wire) --------------------------------
    def _pump(self) -> None:
        """Fleet pump: one lock pass collects dispatches, lease grants and
        forwarded index updates; outside the lock everything is grouped per
        host and shipped as bounded batch frames (wire_batch=1 degenerates
        to the one-frame-per-message wire)."""
        with self._lock:
            t0 = time.perf_counter()
            now = time.monotonic()
            dispatches = self.dispatcher.next_dispatches(now)
            # leases engage only on backlog: a non-empty queue after
            # next_dispatches means no executor is idle, so under
            # batch-synchronous replay (B <= pool drains each chunk in one
            # pump against an all-idle pool) leases NEVER engage and
            # placement stays bit-identical to central dispatch
            lease_out = (self._lease_locked(now)
                         if self.local_dispatch else [])
            fwd, self._fwd_buf = self._fwd_buf, []
            self._note_pump_locked(len(dispatches), time.perf_counter() - t0)
        per_host: dict[str, tuple[HostHandle, list]] = {}
        if fwd:
            for handle in self.manager.live_handles():
                per_host[handle.host_id] = (
                    handle, [{"t": "index", "updates": fwd}])
        orphans = []
        for d in dispatches:
            w = self.workers.get(d.executor)
            if w is None:
                orphans.append(d)
            elif isinstance(w, _RemoteExecutor):
                ent = per_host.get(w.host.host_id)
                if ent is None:
                    ent = per_host[w.host.host_id] = (w.host, [])
                ent[1].append(w.task_msg(d))
            else:   # pragma: no cover - fleets hold only remote executors
                w.dispatch(d)
        for handle, msg in lease_out:
            ent = per_host.get(handle.host_id)
            if ent is None:
                ent = per_host[handle.host_id] = (handle, [])
            ent[1].append(msg)
        for handle, msgs in per_host.values():
            handle.send_batch(msgs, self.wire_batch)
        for d in orphans:
            with self._lock:
                self.dispatcher.task_finished(d.task, time.monotonic(),
                                              ok=False)

    def _lease_locked(self, now: float) -> list[tuple[HostHandle, list]]:
        """Top up each live host's lease pool from the queue head (up to
        ``lease_depth * threads_per_host`` outstanding per host); returns
        the per-host lease messages to ship."""
        if not self.dispatcher.queue:
            return []
        out: list[tuple[HostHandle, list]] = []
        sizes = self.dispatcher.sizes
        cap = self.lease_depth * self.threads_per_host
        for handle in self.manager.live_handles():
            pool = self._leases.setdefault(handle.host_id, {})
            granted = []
            while len(pool) < cap:
                t = self.dispatcher.lease_next()
                if t is None:
                    break
                pool[t.tid] = t
                self.stats.leases += 1
                if self.metrics is not None:
                    self.metrics.inc("wire.leases")
                granted.append({
                    "tid": t.tid,
                    "inputs": [[oid, sizes.get(oid, 0)] for oid in t.inputs],
                    "outputs": [[ob.oid, ob.size_bytes]
                                for ob in t.outputs]})
            if granted:
                out.append((handle, {"t": "lease", "tasks": granted}))
            if not self.dispatcher.queue:
                break
        return out

    def _on_update_locked(self, upd) -> None:
        super()._on_update_locked(upd)
        if self.local_dispatch:
            # queue the applied update for forwarding to host replicas on
            # the next pump (hosts apply them loosely-coherently, exactly
            # like the central index itself)
            self._fwd_buf.append([upd.executor, list(upd.added),
                                  list(upd.removed)])

    # -- update-channel consumers (called by the per-host receivers) --------
    def _on_remote_batch(self, handle: HostHandle, msgs: list) -> None:
        """Apply one frame's messages in wire order under ONE lock
        acquisition, then pump once if anything completed -- the receive-
        side half of the batching win (the send side cut the frame count;
        this cuts lock acquisitions and pump passes per completion storm)."""
        need_pump = False
        with self._lock:
            for msg in msgs:
                kind = msg["t"]
                if kind == "updates":
                    self._remote_update_locked(handle, msg)
                elif kind == "done":
                    self._remote_done_locked(handle, msg)
                    need_pump = True
                elif kind == "claim":
                    self._remote_claim_locked(handle, msg)
                elif kind == "stats":
                    # latest-wins per-host snapshot; the ClusterView has
                    # its own leaf lock and never calls out, so updating
                    # it under the runtime lock cannot deadlock
                    self.manager.cluster.update(msg["host"], msg)
                elif kind == "events" and self.recorder is not None:
                    # host-recorded lifecycle events ingest in wire order
                    # (the host enqueued them just before the done they
                    # describe, so exec events land before the central's
                    # own task_done).  The recorder has its own lock and
                    # never calls out, so taking it here cannot deadlock.
                    self.recorder.ingest(msg["events"])
                # hb riding in a batch already refreshed handle.last_hb
        if need_pump:
            self._pump()

    def _on_remote_updates(self, handle: HostHandle, msg: dict) -> None:
        self._on_remote_batch(handle, [msg])

    def _on_remote_done(self, handle: HostHandle, msg: dict) -> None:
        self._on_remote_batch(handle, [msg])

    def _remote_update_locked(self, handle: HostHandle, msg: dict) -> None:
        from repro.core.index import IndexUpdate

        w = self.workers.get(msg["eid"])
        if not isinstance(w, _RemoteExecutor) or w.host is not handle:
            # the host was declared dead (or the executor deregistered)
            # while frames were still in flight: its index entries were
            # dropped with it, and a late update must not resurrect
            # locations for an executor that can never rejoin
            return
        self._on_update_locked(IndexUpdate(msg["eid"],
                                           added=tuple(msg["added"]),
                                           removed=tuple(msg["removed"])))

    def _remote_done_locked(self, handle: HostHandle, msg: dict) -> None:
        t = self.dispatcher.tasks.get(msg["tid"])
        w = self.workers.get(msg["eid"])
        if t is None or w is None:
            return   # executor already deregistered; executor_left ruled
        led = msg["ledger"]
        acc = _InputLedger(
            bytes_local=led["bytes_local"],
            bytes_cache_to_cache=led["bytes_cache_to_cache"],
            bytes_store=led["bytes_store"],
            cache_hits=led["cache_hits"],
            peer_hits=led["peer_hits"],
            cache_misses=led["cache_misses"])
        if not msg["ok"]:
            t.result = RuntimeError(msg.get("error") or "remote failure")
        if t.start_time == 0.0:
            # results/payloads stay host-side; the central clock brackets
            # the attempt at dispatch..completion for the report's makespan
            t.start_time = t.dispatch_time
        self._finish_attempt_locked(w, t, acc, msg["ok"])

    def _remote_claim_locked(self, handle: HostHandle, msg: dict) -> None:
        """Reconcile a host's local claim against its lease pool.  Every
        conflict path falls back to central authority: the lease was
        already reclaimed (host declared dead mid-flight) or the claiming
        executor is no longer a member -- in both cases the claim is
        refused here and the attempt's eventual done is dropped by the
        membership guard, while the re-queued task runs centrally."""
        w = self.workers.get(msg["eid"])
        if (handle.dead or not isinstance(w, _RemoteExecutor)
                or w.host is not handle):
            self.stats.claim_conflicts += 1
            if self.metrics is not None:
                self.metrics.inc("wire.claim_conflicts")
            return
        pool = self._leases.get(handle.host_id)
        t = pool.pop(msg["tid"], None) if pool else None
        if t is None:
            self.stats.claim_conflicts += 1
            if self.metrics is not None:
                self.metrics.inc("wire.claim_conflicts")
            return
        self.dispatcher.bind_claim(t, msg["eid"], time.monotonic())
        self.stats.claims += 1
        if self.metrics is not None:
            self.metrics.inc("wire.claims")

    def sample_metrics(self) -> None:
        """On a fleet the per-host stats frames own the bandwidth totals
        (each host accumulates its own done-frame ledgers), so the
        inherited ledger-derived ``bw.*`` gauges are cleared after the base
        refresh -- folding central + hosts must not count bytes twice."""
        super().sample_metrics()
        m = self.metrics
        if m is None:
            return
        m.gauge_set("bw.bytes_local", 0)
        m.gauge_set("bw.bytes_c2c", 0)
        m.gauge_set("bw.bytes_store", 0)

    def request_stats(self, timeout: float = 2.0) -> dict:
        """Stats barrier: broadcast ``stats_req`` and wait until every live
        host's snapshot sequence advances past its pre-request reading --
        every returned snapshot is then a post-request sample.  Hosts that
        die mid-barrier stop being waited on.  Returns the cluster's
        per-host view (`ClusterView.per_host`)."""
        cv = self.manager.cluster
        before = cv.seqs()
        waiting = {h.host_id for h in self.manager.live_handles()}
        self.manager.broadcast({"t": "stats_req"})
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            seqs = cv.seqs()
            live = {h.host_id for h in self.manager.live_handles()}
            if all(seqs.get(h, 0) > before.get(h, 0)
                   for h in waiting & live):
                break
            time.sleep(0.005)
        return cv.per_host()

    def dispatch_stats(self) -> dict:
        """Central counters plus the wire counters of live connections
        (retired hosts were folded into ``stats`` at drop time).

        The live-handle snapshot is taken UNDER the runtime lock: the
        ``dead`` flag flips and the counter fold (`_drop_host_locked`)
        happen under this same lock, so a host retiring concurrently is
        counted exactly once.  (Snapshotting before acquiring the lock --
        the old shape -- let a host die in the gap and be counted twice:
        once from the stale live list, once from the folded stats.)
        Lock order runtime._lock -> manager._lock is safe; the manager
        never calls back into the runtime while holding its own lock."""
        with self._lock:
            d = self.stats.as_dict()
            for h in self.manager.live_handles():
                d["frames_sent"] += h.frames_sent
                d["msgs_sent"] += h.msgs_sent
                d["frames_recv"] += h.frames_recv
                d["msgs_recv"] += h.msgs_recv
        return d

    # -- lifecycle ----------------------------------------------------------
    def shutdown(self) -> None:
        self._stop_pacing.set()
        self.manager.shutdown()


#: single-process vs fleet: the RunReport fields that must agree exactly
#: when the same trace is replayed batch-synchronously on both (wall-clock
#: fields are excluded by construction; identity fields by definition).
SCHEDULING_DETERMINED_FIELDS = (
    "n_tasks", "n_completed", "n_failed",
    "local_hits", "peer_hits", "store_reads",
    "local_hit_ratio", "cache_hit_ratio",
    "mean_inputs_per_task", "full_hit_tasks", "partial_hit_tasks",
    "zero_hit_tasks", "bytes_by_kind",
    "peak_executors", "low_executors",
)


def reports_scheduling_equal(a, b) -> dict:
    """Diff two RunReports on the scheduling-determined fields only;
    empty dict == exact agreement (the fleet parity contract)."""
    out = {}
    for f in SCHEDULING_DETERMINED_FIELDS:
        va, vb = getattr(a, f), getattr(b, f)
        if va != vb:
            out[f] = (va, vb)
    return out


def fleet_task(payloads: dict) -> int:
    """A tiny, importable default task fn (``repro.fleet.runtime:
    fleet_task``): touches every payload byte-lengthwise so payload-bearing
    runs do real (GIL-releasing where numpy) work on the host."""
    total = 0
    for v in payloads.values():
        total += getattr(v, "nbytes", None) or (len(v) if hasattr(v, "__len__") else 0)
    return total


def slow_task(payloads: dict) -> int:
    """`fleet_task` plus a few ms of dwell -- keeps attempts in flight long
    enough for failure-injection tests to catch them mid-execution."""
    import time as _time

    _time.sleep(0.005)
    return fleet_task(payloads)


#: simulated per-node local-I/O bandwidth for `io_dwell_task` (bytes/s);
#: the paper testbed's single-node disk read rate, halved -- a slower
#: simulated disk makes bench runs sleep-dominated, so the measured
#: scaling curve survives this container's CPU-share throttling.
#: ``REPRO_BENCH_DISK_BW`` overrides it (inherited by spawned hosts, so a
#: bench can deepen dwell without shipping proportionally larger payloads).
BENCH_DISK_BW = float(os.environ.get("REPRO_BENCH_DISK_BW") or 16 * 10**6)


def io_dwell_task(payloads: dict) -> int:
    """Service time = input bytes / BENCH_DISK_BW, slept on the executor
    thread.  This reproduces the paper's execution model -- a task's cost
    is dominated by its node-local I/O -- so a bench's aggregate delivered
    bandwidth is bounded by how many *nodes* serve concurrently (the claim
    under test), not by this container's core count; what the fleet layer
    adds or loses on top (dispatch RPCs, wire codec, peer sockets) is
    exactly the overhead the wall clock then exposes."""
    import time as _time

    n = fleet_task(payloads)
    _time.sleep(n / BENCH_DISK_BW)
    return n

"""Fleet host process: N executor threads + a peer-fetch server.

One host = one OS process (its own GIL -- the whole point) running:

  * a dispatch loop: framed messages from the central process, in order
    (``put`` store replicas, ``spawn``/``stop`` executors, ``task``
    dispatches routed to the executor's local channel, ``shutdown``);
  * ``threads_per_host`` executor threads, each an exact structural twin
    of `repro.core.runtime.ExecutorWorker`: ExecutorCache + payload dict +
    dispatch Channel, resolving inputs local-cache -> hinted peers (in hint
    order; peers on this host are an in-process peek, peers on other hosts
    a socket fetch) -> store replica, then running the task fn and caching
    outputs.  Index updates stream upstream *before* the attempt's ``done``
    (the Channel seam ordering contract);
  * a peer server: other hosts fetch cached payloads from a specific
    executor here (the paper's GridFTP-analogue cache-to-cache path);
  * a heartbeat thread.

In central mode the host holds NO scheduling state: placement, hints,
retries, membership and all metrics stay in the central Dispatcher/
LocationIndex stack.  With ``local_dispatch`` (DESIGN.md §9) the host
additionally keeps a loosely-coherent `ShardedIndex` *replica* (fed by
``index`` frames the central forwards) plus a leased slice of the wait
queue: idle executors score leased tasks against the replica, claim the
best match upstream, and run it -- the central Dispatcher stays the only
authority (it reconciles every claim; unclaimed leases of a dead host
re-queue centrally).  Task callables cannot cross the wire; hosts resolve
``task_fn_name`` against the :data:`TASK_FNS` registry at startup
(shape-only tasks need none).

The store "replica" stands in for the paper's shared filesystem (GPFS):
equally reachable from every node, so each host holds a local copy seeded
by ``put`` broadcasts and store reads never touch the central process.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Any, Callable, Optional

from repro.core.cache import EvictionPolicy
from repro.core.channel import BatchingChannel, ChannelClosed
from repro.core.index import ShardedIndex
from repro.core.objects import DataObject
from repro.core.runtime import (SHAPE_ONLY_PAYLOAD, CacheExecutorBase,
                                _wants_kwargs)

from .wire import SocketChannel, recv_msg, send_msg

#: named task callables a host may run (callables don't serialise; a fleet
#: run names one and every host resolves it here).  Keyed registration so
#: tests and benchmarks can install fns before spawning hosts -- the
#: registry is module-level, so under the "spawn" start method the child
#: re-imports this module and the fn must be registered at import time of
#: whatever module ``register_task_fn`` was called from... which a fresh
#: interpreter will NOT replay.  Hosts therefore resolve names via
#: :func:`resolve_task_fn`, which also accepts dotted ``module:attr`` paths
#: importable in the child.
TASK_FNS: dict[str, Callable[..., Any]] = {}


def register_task_fn(name: str, fn: Callable[..., Any]) -> None:
    TASK_FNS[name] = fn


def resolve_task_fn(name: Optional[str]) -> Optional[Callable[..., Any]]:
    """None -> shape-only; registry name -> that fn; ``module:attr`` ->
    imported (works across process boundaries, unlike the registry)."""
    if name is None:
        return None
    if name in TASK_FNS:
        return TASK_FNS[name]
    if ":" in name:
        import importlib

        mod, _, attr = name.partition(":")
        fn = getattr(importlib.import_module(mod), attr)
        register_task_fn(name, fn)
        return fn
    raise KeyError(f"task fn {name!r} not registered on this host "
                   f"(register_task_fn at import time, or use module:attr)")


# --------------------------------------------------------------------------
# peer fetch (host <-> host data plane)
# --------------------------------------------------------------------------

class PeerClient:
    """Pooled framed connections to other hosts' peer servers."""

    def __init__(self, codec: str) -> None:
        self.codec = codec
        self._conns: dict[tuple[str, int], tuple[socket.socket, threading.Lock]] = {}
        self._lock = threading.Lock()
        self.bytes_fetched = 0

    def _conn(self, addr: tuple[str, int]) -> tuple[socket.socket, threading.Lock]:
        with self._lock:
            ent = self._conns.get(addr)
            if ent is None:
                s = socket.create_connection(addr, timeout=10.0)
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                ent = (s, threading.Lock())
                self._conns[addr] = ent
            return ent

    def fetch(self, addr: tuple[str, int], eid: str, oid: str) -> Optional[Any]:
        """One fetch round-trip; any failure is a miss (hint staleness and
        dead peers cost performance, never correctness)."""
        try:
            sock, lock = self._conn(addr)
            with lock:
                send_msg(sock, {"t": "fetch", "eid": eid, "oid": oid},
                         self.codec)
                resp = recv_msg(sock, self.codec, timeout=30.0)
        except Exception:  # noqa: BLE001 - degrade to a store read
            with self._lock:
                ent = self._conns.pop(addr, None)
            if ent is not None:   # close, don't leak, the broken socket
                try:
                    ent[0].close()
                except OSError:
                    pass
            return None
        if not resp.get("ok"):
            return None
        return resp["payload"]

    def close(self) -> None:
        with self._lock:
            for s, _ in self._conns.values():
                try:
                    s.close()
                except OSError:
                    pass
            self._conns.clear()


class PeerServer(threading.Thread):
    """Serves this host's executor caches to other hosts."""

    def __init__(self, host: "FleetHost", codec: str,
                 bind_host: str = "127.0.0.1") -> None:
        super().__init__(daemon=True, name="peer-server")
        self.host = host
        self.codec = codec
        self.sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.sock.bind((bind_host, 0))
        self.sock.listen(16)
        self.port = self.sock.getsockname()[1]
        self._stop = threading.Event()

    def run(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self.sock.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._serve, args=(conn,), daemon=True,
                             name="peer-conn").start()

    def _serve(self, conn: socket.socket) -> None:
        try:
            while True:
                req = recv_msg(conn, self.codec)
                ex = self.host.executors.get(req["eid"])
                payload = ex.cache_peek(req["oid"]) if ex is not None else None
                send_msg(conn, {"ok": payload is not None,
                                "payload": payload}, self.codec)
        except Exception:  # noqa: BLE001 - client went away
            try:
                conn.close()
            except OSError:
                pass

    def stop(self) -> None:
        self._stop.set()
        try:
            self.sock.close()
        except OSError:
            pass


# --------------------------------------------------------------------------
# executor threads (structural twins of ExecutorWorker)
# --------------------------------------------------------------------------

class HostExecutor(CacheExecutorBase):
    """One executor thread on a host: the shared cache/inbox surface from
    `repro.core.runtime.CacheExecutorBase` (one implementation, so host
    and in-process cache semantics cannot drift apart) plus the host-side
    execute/resolve loop."""

    def __init__(self, eid: str, host: "FleetHost", cache_capacity: int,
                 policy: EvictionPolicy, seed: int) -> None:
        super().__init__(eid, cache_capacity, policy, seed)
        self.host = host
        self.thread = threading.Thread(target=self._run, daemon=True,
                                       name=f"executor-{eid}")

    def start(self) -> None:
        self.thread.start()

    # -- task loop ----------------------------------------------------------
    def _run(self) -> None:
        # announce readiness before blocking on the inbox: under
        # local_dispatch an idle executor is what pulls leased work
        self.host.executor_ready(self)
        while self.alive:
            try:
                msg = self.inbox.recv()
            except ChannelClosed:
                return
            self._execute(msg)
            self.host.executor_ready(self)

    def _admit(self, obj: DataObject, payload: Any) -> None:
        added, removed = self.cache_admit(obj, payload)
        self.host.send_update(self.eid, added, removed)

    def _resolve(self, oid: str, size: int, hints: dict[str, list],
                 routes: dict[str, list], led: dict[str, int],
                 tid: str = "") -> Any:
        """Mirror of DiffusionRuntime._resolve: local cache -> hinted peers
        in hint order (local peek for same-host executors, socket fetch for
        remote ones) -> store replica.  Accounting fields match
        core.runtime._InputLedger one-for-one."""
        rec = self.host.recorder
        payload = self.cache_lookup(oid)
        if payload is not None:
            led["cache_hits"] += 1
            led["bytes_local"] += size
            if rec is not None:
                rec.emit("input", tid=tid, eid=self.eid, oid=oid,
                         source="local", bytes=size)
            return payload
        led["cache_misses"] += 1
        for peer_id in hints.get(oid, ()):
            if peer_id == self.eid:
                continue
            local = self.host.executors.get(peer_id)
            if local is not None:
                payload = local.cache_peek(oid)
            elif peer_id in routes:
                h, p = routes[peer_id]
                payload = self.host.peers.fetch((h, int(p)), peer_id, oid)
            else:
                continue
            if payload is not None:
                led["peer_hits"] += 1
                led["bytes_cache_to_cache"] += size
                if rec is not None:
                    rec.emit("input", tid=tid, eid=self.eid, oid=oid,
                             source="peer", bytes=size, peer=peer_id)
                self._admit(DataObject(oid, size), payload)
                return payload
        ent = self.host.store.get(oid)
        if ent is None:
            raise KeyError(oid)   # matches the central store's KeyError
        obj, payload = ent
        led["bytes_store"] += obj.size_bytes
        if rec is not None:
            rec.emit("input", tid=tid, eid=self.eid, oid=oid,
                     source="store", bytes=obj.size_bytes)
        self._admit(obj, payload)
        return payload

    def _execute(self, msg: dict) -> None:
        led = {"bytes_local": 0, "bytes_cache_to_cache": 0, "bytes_store": 0,
               "cache_hits": 0, "peer_hits": 0, "cache_misses": 0}
        hints = msg.get("hints") or {}
        routes = msg.get("routes") or {}
        rec = self.host.recorder
        tid = msg["tid"]
        ok, err, result = True, None, None
        try:
            inputs = {oid: self._resolve(oid, size, hints, routes, led,
                                         tid=tid)
                      for oid, size in msg["inputs"]}
            if rec is not None:
                rec.emit("exec_start", tid=tid, eid=self.eid)
            fn = self.host.task_fn
            if fn is not None:
                result = fn(**inputs) if _wants_kwargs(fn) else fn(inputs)
            for oid, osize in msg["outputs"]:
                # shape-only tasks: admit the wire-stable sentinel (mirrors
                # DiffusionRuntime._execute) so downstream DAG reads of the
                # produced object still count as cache hits
                if fn is None:
                    payload = SHAPE_ONLY_PAYLOAD
                else:
                    payload = result if len(msg["outputs"]) == 1 else result[oid]
                self._admit(DataObject(oid, int(osize)), payload)
        except Exception as e:  # noqa: BLE001 - task failure is data
            ok, err = False, f"{type(e).__name__}: {e}"
        if rec is not None:
            rec.emit("exec_end", tid=tid, eid=self.eid, ok=ok)
        self.host.send_done(self.eid, tid, ok, led, err)


# --------------------------------------------------------------------------
# the host process
# --------------------------------------------------------------------------

class FleetHost:
    def __init__(self, central: tuple[str, int], host_id: str, codec: str,
                 task_fn_name: Optional[str], hb_interval_s: float,
                 bind_host: str = "127.0.0.1", wire_batch: int = 64,
                 local_dispatch: bool = False,
                 observe_capacity: int = 0,
                 metrics_interval_s: float = 0.0) -> None:
        self.host_id = host_id
        self.codec = codec
        self.task_fn = resolve_task_fn(task_fn_name)
        self.hb_interval_s = hb_interval_s
        self.bind_host = bind_host
        self.local_dispatch = local_dispatch
        # host-side event recording (DESIGN.md §10): same Recorder class as
        # the central, drained upstream with each done/heartbeat flush
        if observe_capacity > 0:
            from repro.obs.recorder import Recorder

            self.recorder: Optional[Any] = Recorder(observe_capacity)
        else:
            self.recorder = None
        # host-side telemetry (DESIGN.md §13): an own registry, sampled on
        # the heartbeat cadence and shipped upstream as {"t": "stats"}
        # frames (0 = telemetry off, free -- no registry, no frames)
        self.metrics_interval_s = metrics_interval_s
        if metrics_interval_s > 0:
            from repro.obs.metrics import MetricsRegistry

            self.metrics: Optional[Any] = MetricsRegistry()
        else:
            self.metrics = None
        self._last_stats = 0.0
        self._led_lock = threading.Lock()
        # cumulative attempt-ledger totals (absolute per-host gauges:
        # the cluster-wide value is the sum over hosts)
        self._led_totals = {"bytes_local": 0, "bytes_c2c": 0,
                            "bytes_store": 0, "tasks_done": 0}
        self.store: dict[str, tuple[DataObject, Any]] = {}
        self.executors: dict[str, HostExecutor] = {}
        self.peers = PeerClient(codec)
        self.peer_server = PeerServer(self, codec, bind_host)
        sock = socket.create_connection(central, timeout=30.0)
        # drop the connect timeout: it would otherwise persist on the
        # socket and turn any 30s dispatch lull into a phantom
        # central-death (blocking recv is the correct idle behaviour here)
        sock.settimeout(None)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.up = SocketChannel(sock, codec)   # both directions of the pair
        # all upstream traffic funnels through one batcher so wire order
        # is exactly buffer order: an attempt's updates always precede its
        # done, and a claim always precedes its attempt's first update
        self.out = BatchingChannel(self.up, max_batch=wire_batch)
        self._stop = threading.Event()
        # -- local-dispatch state (DESIGN.md §9) ----------------------------
        self.replica = ShardedIndex()          # forwarded central index view
        self.routes: dict[str, list] = {}      # eid -> [peer_host, peer_port]
        self._sched_lock = threading.Lock()
        self._lease: list[dict] = []           # leased task descriptors
        self._idle: set[str] = set()           # eids waiting for lease work

    # -- upstream (the update channel of the pair) --------------------------
    def send_update(self, eid: str, added, removed) -> None:
        if self.local_dispatch:
            # short-circuit our own admissions into the replica: the central
            # forwards them back eventually, but fresher hints are free here
            # (re-application is idempotent -- the index is set-valued)
            self.replica.apply_wire([[eid, list(added), list(removed)]])
        try:
            # buffered: the matching done (flush=True) bounds the delay
            self.out.send({"t": "updates", "eid": eid,
                           "added": list(added), "removed": list(removed)})
        except ChannelClosed:
            self._stop.set()

    def send_done(self, eid: str, tid: str, ok: bool, led: dict,
                  err: Optional[str]) -> None:
        if self.metrics is not None:
            with self._led_lock:
                tot = self._led_totals
                tot["bytes_local"] += led["bytes_local"]
                tot["bytes_c2c"] += led["bytes_cache_to_cache"]
                tot["bytes_store"] += led["bytes_store"]
                tot["tasks_done"] += 1
        try:
            # drained events ride (buffered) immediately before the flushed
            # done: the attempt's input/exec events arrive in the frame that
            # carries its completion, and the updates-before-done ordering
            # is untouched because everything shares the one outbox buffer
            self._forward_events()
            self.out.send({"t": "done", "eid": eid, "tid": tid, "ok": ok,
                           "ledger": led, "error": err}, flush=True)
        except ChannelClosed:
            self._stop.set()

    def _forward_events(self) -> None:
        """Drain the host recorder into one buffered ``events`` message.
        A no-op with recording off; holds no host scheduling lock (the
        recorder has its own), so it can never reorder the outbox."""
        if self.recorder is None:
            return
        events = self.recorder.drain()
        if events:
            self.out.send({"t": "events", "host": self.host_id,
                           "events": events})

    def _sample_and_send(self, flush: bool = False) -> None:
        """Refresh this host's gauges and ship one ``stats`` frame through
        the shared outbox.  Cache counters are read without the executor
        locks -- racy int reads are fine for telemetry (the final, settled
        sample is exact because the executors are quiescent by then)."""
        m = self.metrics
        if m is None:
            return
        caches = [ex.cache for ex in list(self.executors.values())]
        m.gauge_set("cache.bytes", sum(c.used_bytes for c in caches))
        m.gauge_set("cache.hits", sum(c.stats.hits for c in caches))
        m.gauge_set("cache.misses", sum(c.stats.misses for c in caches))
        m.gauge_set("cache.evictions", sum(c.stats.evictions for c in caches))
        m.gauge_set("cache.insertions",
                    sum(c.stats.insertions for c in caches))
        m.gauge_set("cache.readmits", sum(c.stats.readmits for c in caches))
        with self._led_lock:
            tot = dict(self._led_totals)
        m.gauge_set("bw.bytes_local", tot["bytes_local"])
        m.gauge_set("bw.bytes_c2c", tot["bytes_c2c"])
        m.gauge_set("bw.bytes_store", tot["bytes_store"])
        m.gauge_set("host.tasks_done", tot["tasks_done"])
        m.gauge_set("host.executors", len(caches))
        if self.recorder is not None:
            m.gauge_set("obs.recorder_dropped", self.recorder.dropped)
        self._last_stats = time.monotonic()
        try:
            # same outbox as updates/done: a stats frame sent after a done
            # reflects at least that attempt's ledger (ordering contract)
            self.out.send({"t": "stats", "host": self.host_id,
                           "metrics": m.snapshot()}, flush=flush)
        except ChannelClosed:
            self._stop.set()

    def _heartbeat(self) -> None:
        while not self._stop.wait(self.hb_interval_s):
            try:
                # flushing here bounds buffered-update staleness to one
                # heartbeat interval even on a host with no completions
                # (and bounds recorded-event staleness the same way)
                self._forward_events()
                if (self.metrics is not None
                        and time.monotonic() - self._last_stats
                        >= self.metrics_interval_s):
                    self._sample_and_send()   # buffered; hb flush carries it
                self.out.send({"t": "hb", "host_id": self.host_id},
                              flush=True)
            except ChannelClosed:
                return

    # -- local dispatch (lease pool -> idle executors) ----------------------
    def executor_ready(self, ex: HostExecutor) -> None:
        """Executor-thread callback on start and after every attempt: pull
        the best-matching leased task, or park in the idle set."""
        if not self.local_dispatch:
            return
        with self._sched_lock:
            if not ex.alive or not ex.inbox.empty():
                # centrally-dispatched work is already queued; run it first
                self._idle.discard(ex.eid)
                return
            ent = self._pick_locked(ex.eid)
            if ent is None:
                self._idle.add(ex.eid)
                return
            self._idle.discard(ex.eid)
            msg = self._task_msg(ex.eid, ent)
        # the claim goes upstream through the SAME outbox the attempt's
        # updates/done will use, BEFORE the task enters the inbox: wire
        # order therefore shows claim -> updates -> done, and the central
        # binds the lease before it can see the completion
        try:
            self.out.send({"t": "claim", "eid": ex.eid, "tid": msg["tid"]},
                          flush=True)
        except ChannelClosed:
            self._stop.set()
            return
        try:
            ex.inbox.send(msg)
        except ChannelClosed:
            pass

    def _pick_locked(self, eid: str) -> Optional[dict]:
        """Best lease-pool entry for ``eid`` by replica-cached input bytes
        (the host-local mirror of max-compute-util's byte score); ties break
        toward lease order.  Removes and returns the winner."""
        best_i, best_score = -1, -1
        for i, ent in enumerate(self._lease):
            score = 0
            for oid, size in ent["inputs"]:
                if eid in self.replica.lookup(oid):
                    score += int(size)
            if score > best_score:
                best_i, best_score = i, score
        if best_i < 0:
            return None
        return self._lease.pop(best_i)

    def _task_msg(self, eid: str, ent: dict) -> dict:
        hints: dict[str, list] = {}
        routes: dict[str, list] = {}
        for oid, _size in ent["inputs"]:
            locs = self.replica.lookup(oid)
            if locs:
                hints[oid] = sorted(locs)
                for peer in locs:
                    if peer not in self.executors and peer in self.routes:
                        routes[peer] = self.routes[peer]
        return {"t": "task", "eid": eid, "tid": ent["tid"],
                "inputs": ent["inputs"], "outputs": ent["outputs"],
                "hints": hints, "routes": routes}

    # -- dispatch loop ------------------------------------------------------
    def run(self) -> None:
        import os

        self.peer_server.start()
        self.up.send({"t": "hello", "host_id": self.host_id,
                      "pid": os.getpid(),
                      "peer_host": self.bind_host,
                      "peer_port": self.peer_server.port})
        threading.Thread(target=self._heartbeat, daemon=True,
                         name="heartbeat").start()
        try:
            while not self._stop.is_set():
                try:
                    msg = self.up.recv()
                except ChannelClosed:
                    break   # central went away: the fleet is over
                if not self._handle(msg):
                    break
        finally:
            self._stop.set()
            for ex in self.executors.values():
                ex.stop()
            self.peer_server.stop()
            self.peers.close()
            try:
                self._sample_and_send()  # settled final stats frame
                self._forward_events()   # last events ride the final flush
                self.out.close()   # flush buffered updates, then close up
            except ChannelClosed:
                self.up.close()

    def _handle(self, msg: dict) -> bool:
        kind = msg["t"]
        if kind == "batch":
            for m in msg["msgs"]:
                if not self._handle(m):
                    return False
        elif kind == "task":
            ex = self.executors.get(msg["eid"])
            if ex is not None:
                if self.local_dispatch:
                    with self._sched_lock:
                        self._idle.discard(msg["eid"])
                try:
                    ex.inbox.send(msg)
                except ChannelClosed:
                    pass
        elif kind == "lease":
            with self._sched_lock:
                self._lease.extend(msg["tasks"])
                ready = [self.executors[eid] for eid in sorted(self._idle)
                         if eid in self.executors]
            for ex in ready:
                self.executor_ready(ex)
        elif kind == "index":
            self.replica.apply_wire(msg["updates"])
        elif kind == "index_drop":
            for eid in msg["eids"]:
                self.replica.drop_executor(eid)
                self.routes.pop(eid, None)
        elif kind == "peers":
            self.routes.update(msg["routes"])
        elif kind == "stats_req":
            # the central's stats barrier (request_stats): answer with an
            # immediate, flushed sample
            self._sample_and_send(flush=True)
        elif kind == "put":
            obj = DataObject(msg["oid"], int(msg["size"]))
            self.store[obj.oid] = (obj, msg["payload"])
        elif kind == "spawn":
            ex = HostExecutor(msg["eid"], self, int(msg["cap"]),
                              EvictionPolicy(msg["policy"]), int(msg["seed"]))
            self.executors[msg["eid"]] = ex
            ex.start()
        elif kind == "stop":
            ex = self.executors.pop(msg["eid"], None)
            if ex is not None:
                ex.stop()
        elif kind == "shutdown":
            return False
        return True


def host_main(central_host: str, central_port: int, host_id: str,
              codec: str = "auto", task_fn_name: Optional[str] = None,
              hb_interval_s: float = 0.25, bind_host: str = "127.0.0.1",
              wire_batch: int = 64, local_dispatch: bool = False,
              observe_capacity: int = 0,
              metrics_interval_s: float = 0.0) -> None:
    """Entry point for the spawned host process (see manager.py)."""
    FleetHost((central_host, central_port), host_id, codec,
              task_fn_name, hb_interval_s, bind_host=bind_host,
              wire_batch=wire_batch, local_dispatch=local_dispatch,
              observe_capacity=observe_capacity,
              metrics_interval_s=metrics_interval_s).run()

"""repro.fleet: the multi-process distribution layer over the Channel seams.

`DiffusionRuntime` keeps all scheduling in one process and talks to its
executors only through two Channels (task dispatch, index updates) --
`repro.core.channel`.  This package swaps those channels for a
length-prefixed socket wire protocol (`wire`), runs executors as threads
inside spawned host processes (`host`, managed by `manager.HostManager`),
and exposes the result as `FleetRuntime`: same Dispatcher, same policies,
same byte ledger -- N GILs, real sockets between peer caches.

    rt = FleetRuntime(hosts=4, threads_per_host=2)
    rt.put_object(obj, payload)            # replicated to every host
    rt.submit(tasks); rt.wait()            # identical surface
    rt.manager.kill_host("h0")             # SIGKILL failure injection
    rt.shutdown()

The experiment layer binds it through ``ExperimentSpec(hosts=...,
threads_per_host=...)`` on the runtime engine; benchmarks/bench_fleet.py
measures aggregate cache bandwidth across host counts and holds the
trace-replay parity canary (fleet == single-process on every
scheduling-determined RunReport field).
"""
from .host import TASK_FNS, register_task_fn, resolve_task_fn
from .manager import HostHandle, HostManager
from .runtime import (SCHEDULING_DETERMINED_FIELDS, FleetRuntime, fleet_task,
                      reports_scheduling_equal)
from .wire import (HAVE_MSGPACK, MAX_FRAME, PeerGone, SocketChannel,
                   WireError, decode, encode, recv_msg, send_msg)

__all__ = [
    "FleetRuntime",
    "HAVE_MSGPACK",
    "HostHandle",
    "HostManager",
    "MAX_FRAME",
    "PeerGone",
    "SCHEDULING_DETERMINED_FIELDS",
    "SocketChannel",
    "TASK_FNS",
    "WireError",
    "decode",
    "encode",
    "fleet_task",
    "recv_msg",
    "register_task_fn",
    "reports_scheduling_equal",
    "resolve_task_fn",
    "send_msg",
]

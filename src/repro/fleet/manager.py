"""HostManager: spawn / monitor / reap fleet host processes.

The central process owns a listening socket; each spawned host (a
``multiprocessing`` *spawn*-context process -- fork would duplicate the
central's live threads and locks) connects back, sends ``hello`` with its
peer-server port, and from then on the connection carries the fleet's
channel pair: central->host is the dispatch channel, host->central the
update channel (see wire.SocketChannel).

Liveness: one receiver thread per host drains the update channel (updates,
completions, heartbeats); a SIGKILLed host's socket EOFs, which the
receiver turns into ``runtime._on_host_dead`` immediately.  A monitor
thread additionally sweeps for stale heartbeats and dead PIDs (a wedged
host whose socket stays open).  Both paths are idempotent -- the runtime
marks the handle dead under its own lock before requeueing, so the
receiver/monitor race resolves to exactly one ``executor_left`` pass.

Lock order: runtime._lock may be held when manager state is read
(`live_handles` inside the DRP driver's snapshot), so the manager NEVER
calls back into the runtime while holding its own lock.
"""
from __future__ import annotations

import multiprocessing
import os
import signal
import socket
import threading
import time
from typing import Any, Optional

from repro.obs.metrics import ClusterView

from .host import host_main
from .wire import SocketChannel, _resolve_codec, recv_msg


class HostHandle:
    """Central-side view of one host process."""

    def __init__(self, host_id: str, proc, chan: SocketChannel,
                 peer_host: str, peer_port: int) -> None:
        self.host_id = host_id
        self.proc = proc
        self.chan = chan
        self.peer_host = peer_host
        self.peer_port = peer_port
        self.eids: list[str] = []        # executors spawned on this host
        self.last_hb = time.monotonic()
        self.dead = False                # set under runtime._lock
        self.frames_sent = 0             # wire frames down to this host
        self.msgs_sent = 0               # logical messages inside them
        self.frames_recv = 0             # frames up from this host (not hb)
        self.msgs_recv = 0

    def send(self, msg: Any) -> None:
        """Dispatch-channel send; a broken pipe is not an error here -- the
        receiver thread will surface the death through _on_host_dead."""
        from repro.core.channel import ChannelClosed

        try:
            self.chan.send(msg)
        except ChannelClosed:
            return
        self.frames_sent += 1
        self.msgs_sent += (len(msg["msgs"])
                           if isinstance(msg, dict) and msg.get("t") == "batch"
                           else 1)

    def send_batch(self, msgs: list, max_batch: int = 64) -> None:
        """Send many messages as bounded batch frames, preserving order.
        A chunk of one goes bare, so ``max_batch=1`` reproduces the
        one-frame-per-message wire exactly."""
        max_batch = max(int(max_batch), 1)
        for i in range(0, len(msgs), max_batch):
            chunk = msgs[i:i + max_batch]
            self.send(chunk[0] if len(chunk) == 1
                      else {"t": "batch", "msgs": chunk})


class HostManager:
    def __init__(self, rt, *, codec: str = "auto",
                 task_fn_name: Optional[str] = None,
                 hb_interval_s: float = 0.25,
                 hb_timeout_s: float = 3.0,
                 spawn_timeout_s: float = 60.0,
                 bind_host: str = "127.0.0.1",
                 wire_batch: int = 64,
                 local_dispatch: bool = False,
                 observe_capacity: int = 0,
                 metrics_interval_s: float = 0.0) -> None:
        self.rt = rt
        self.codec = _resolve_codec(codec)
        self.task_fn_name = task_fn_name
        self.hb_interval_s = hb_interval_s
        self.hb_timeout_s = hb_timeout_s
        self.spawn_timeout_s = spawn_timeout_s
        self.bind_host = bind_host
        self.wire_batch = wire_batch
        self.local_dispatch = local_dispatch
        # >0: spawned hosts record lifecycle events into a ring of this
        # capacity and forward them upstream (0 = recording off, free)
        self.observe_capacity = observe_capacity
        # >0: spawned hosts sample their own MetricsRegistry every this
        # many seconds and ship {"t": "stats"} frames; the cluster view
        # holds the latest snapshot per host (0 = telemetry off, free)
        self.metrics_interval_s = metrics_interval_s
        self.cluster = ClusterView()
        self._ctx = multiprocessing.get_context("spawn")
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.handles: dict[str, HostHandle] = {}
        self._pending: dict[str, dict] = {}   # host_id -> handshake slot
        self._next_host = 0
        self.listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.listener.bind((bind_host, 0))
        self.listener.listen(64)
        self.addr = self.listener.getsockname()
        threading.Thread(target=self._accept_loop, daemon=True,
                         name="fleet-accept").start()
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         daemon=True, name="fleet-monitor")
        self._monitor.start()

    # ------------------------------------------------------------------
    def spawn_host(self) -> HostHandle:
        """Start one host process; blocks until its hello arrives."""
        if self._stop.is_set():
            raise RuntimeError("HostManager is shut down")
        with self._lock:
            host_id = f"h{self._next_host}"
            self._next_host += 1
            slot = {"event": threading.Event(), "sock": None, "hello": None}
            self._pending[host_id] = slot
        proc = self._ctx.Process(
            target=host_main,
            args=(self.addr[0], self.addr[1], host_id, self.codec,
                  self.task_fn_name, self.hb_interval_s, self.bind_host,
                  self.wire_batch, self.local_dispatch,
                  self.observe_capacity, self.metrics_interval_s),
            daemon=True, name=f"fleet-{host_id}")
        proc.start()
        if not slot["event"].wait(self.spawn_timeout_s):
            with self._lock:
                self._pending.pop(host_id, None)
            proc.terminate()
            raise RuntimeError(f"host {host_id} did not connect within "
                               f"{self.spawn_timeout_s}s")
        hello = slot["hello"]
        handle = HostHandle(host_id, proc,
                            SocketChannel(slot["sock"], self.codec),
                            # the host advertises the address its peer
                            # server bound (multi-machine seam); older
                            # hellos without it mean shared-loopback
                            peer_host=hello.get("peer_host") or "127.0.0.1",
                            peer_port=int(hello["peer_port"]))
        with self._lock:
            self.handles[host_id] = handle
        threading.Thread(target=self._receive, args=(handle,), daemon=True,
                         name=f"fleet-recv-{host_id}").start()
        return handle

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self.listener.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._handshake, args=(conn,),
                             daemon=True, name="fleet-handshake").start()

    def _handshake(self, conn: socket.socket) -> None:
        try:
            hello = recv_msg(conn, self.codec, timeout=self.spawn_timeout_s)
            conn.settimeout(None)
            if hello.get("t") != "hello":
                raise ValueError(f"expected hello, got {hello!r}")
            with self._lock:
                slot = self._pending.pop(hello["host_id"], None)
        except Exception:  # noqa: BLE001 - stray/late connection
            conn.close()
            return
        if slot is None:   # unknown host id: refuse
            conn.close()
            return
        slot["sock"], slot["hello"] = conn, hello
        slot["event"].set()

    # ------------------------------------------------------------------
    def _receive(self, handle: HostHandle) -> None:
        """Per-host update-channel consumer (the recv side of the pair).
        Processes messages in wire order, which is what guarantees a
        task's index updates are applied before its completion."""
        from repro.core.channel import ChannelClosed

        while True:
            try:
                msg = handle.chan.recv()
            except ChannelClosed:
                if not self._stop.is_set():
                    self.rt._on_host_dead(handle)
                return
            kind = msg["t"]
            handle.last_hb = time.monotonic()
            if kind == "hb":
                continue
            handle.frames_recv += 1
            if kind == "batch":
                # unwrap in list order: exactly equivalent to the messages
                # arriving as consecutive frames (ordering contract)
                inner = msg["msgs"]
                handle.msgs_recv += len(inner)
                self.rt._on_remote_batch(handle, inner)
            else:
                handle.msgs_recv += 1
                self.rt._on_remote_batch(handle, [msg])

    def _monitor_loop(self) -> None:
        period = max(self.hb_interval_s / 2, 0.05)
        while not self._stop.wait(period):
            now = time.monotonic()
            for handle in self.live_handles():
                if (not handle.proc.is_alive()
                        or now - handle.last_hb > self.hb_timeout_s):
                    self.rt._on_host_dead(handle)

    # ------------------------------------------------------------------
    def live_handles(self) -> list[HostHandle]:
        with self._lock:
            return [h for h in self.handles.values() if not h.dead]

    def broadcast(self, msg: Any) -> None:
        for h in self.live_handles():
            h.send(msg)

    def kill_host(self, host_id: str) -> int:
        """SIGKILL a host process (failure-injection surface for tests /
        benchmarks).  Returns the killed pid."""
        with self._lock:
            handle = self.handles[host_id]
        pid = handle.proc.pid
        os.kill(pid, signal.SIGKILL)
        return pid

    def reap(self, handle: HostHandle, graceful: bool = False) -> None:
        """Tear one host down.  Callers mark ``handle.dead`` (under the
        runtime lock) first; this only releases OS resources."""
        if graceful:
            handle.send({"t": "shutdown"})
        handle.chan.close()
        if handle.proc.is_alive():
            handle.proc.join(2.0 if graceful else 0.5)
            if handle.proc.is_alive():
                handle.proc.terminate()
                handle.proc.join(1.0)
        with self._lock:
            self.handles.pop(handle.host_id, None)
        self.cluster.drop(handle.host_id)

    def shutdown(self) -> None:
        self._stop.set()
        try:
            self.listener.close()
        except OSError:
            pass
        for handle in self.live_handles():
            # the dead flag's one-flip invariant lives under the RUNTIME
            # lock (see _on_host_dead): flipping it unlocked here would
            # let a mid-sweep monitor run a full requeue pass against the
            # tearing-down fleet
            with self.rt._lock:
                if handle.dead:
                    continue
                handle.dead = True
            self.reap(handle, graceful=True)
        # anything already marked dead but not yet reaped
        with self._lock:
            leftovers = list(self.handles.values())
        for handle in leftovers:
            self.reap(handle)

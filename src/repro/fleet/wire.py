"""Fleet wire protocol: length-prefixed frames of msgpack-or-JSON messages.

Framing
-------
Every message is one frame: a 4-byte big-endian unsigned length followed by
that many payload bytes.  Frames on one TCP stream are totally ordered,
which the fleet relies on (a host sends a task's index updates *before* its
completion; the central receiver applies them in arrival order -- the
Channel seam contract, DESIGN.md §8).

Codec
-----
Messages are plain dict/list/str/int/float/bool/None trees plus three
payload-bearing leaf types that need tagging:

  numpy arrays   {"__wire__": "ndarray", dtype, shape, data: <bytes>}
                 (C-contiguous copy; round-trips dtype and shape exactly)
  bytes          native in msgpack; {"__wire__": "bytes", b64} under JSON
  SHAPE_ONLY_PAYLOAD
                 {"__wire__": "shape_only"} -- the runtime's shape-only
                 store sentinel (PR 4): it must cross the wire as itself,
                 NOT as None, because a None payload reads as a cache miss.

Tuples are encoded as lists (consumers re-tuple where the runtime cares).
``msgpack`` is used when importable, JSON (with base64 bytes) otherwise;
the tests exercise both by forcing ``codec="json"``.  Both ends of a
connection must agree, so the codec is fixed per fleet: the central
process picks it and passes it to every host at spawn time.

Batched frames (DESIGN.md §9)
-----------------------------
A frame may carry one logical message or a bounded batch wrapper
``{"t": "batch", "msgs": [...]}``; receivers unwrap and process the inner
messages in list order, so a batch is exactly equivalent to its messages
sent as consecutive frames -- the updates-before-done ordering contract
holds within and across batches because batching (core.channel.
BatchingChannel / HostHandle.send_batch) never reorders the buffer.

Observability frames (DESIGN.md §10)
------------------------------------
When event recording is on, hosts additionally send
``{"t": "events", "host": host_id, "events": [...]}`` upstream: the
host-side `repro.obs.Recorder` ring drained into one message.  Events
ride the SAME BatchingChannel buffer as everything else -- a host
enqueues them (buffered) immediately before each flushed ``done`` and
before each heartbeat -- so an attempt's input/exec events arrive in the
frame that carries its completion, and recording piggybacks on the
updates-before-done contract instead of adding a side channel that could
reorder the seam.  Receivers that don't record simply drop the kind.
"""
from __future__ import annotations

import base64
import json
import socket
import struct
from typing import Any, Optional

import numpy as np

from repro.core.runtime import SHAPE_ONLY_PAYLOAD

try:  # the container has msgpack; JSON is the no-dependency fallback
    import msgpack  # type: ignore
    HAVE_MSGPACK = True
except ImportError:  # pragma: no cover - exercised by forcing codec="json"
    msgpack = None
    HAVE_MSGPACK = False

#: frames larger than this are a protocol error, not a payload (guards a
#: desynchronised stream from allocating garbage-length buffers)
MAX_FRAME = 1 << 30

_TAG = "__wire__"


class WireError(Exception):
    """Framing/codec violation (desync, oversized frame, unknown tag)."""


class PeerGone(Exception):
    """The other end of the stream closed (EOF mid-frame or on a read)."""


# --------------------------------------------------------------------------
# structure transform: tag payload leaves the codecs can't carry natively
# --------------------------------------------------------------------------

def _pack(obj: Any, *, binary: bool) -> Any:
    if obj is SHAPE_ONLY_PAYLOAD:
        return {_TAG: "shape_only"}
    if isinstance(obj, np.ndarray):
        return {_TAG: "ndarray", "dtype": obj.dtype.str,
                "shape": list(obj.shape),
                "data": _pack(np.ascontiguousarray(obj).tobytes(),
                              binary=binary)}
    if isinstance(obj, (bytes, bytearray, memoryview)):
        b = bytes(obj)
        if binary:
            return b
        return {_TAG: "bytes", "b64": base64.b64encode(b).decode("ascii")}
    if isinstance(obj, (list, tuple)):
        return [_pack(v, binary=binary) for v in obj]
    if isinstance(obj, dict):
        out = {}
        for k, v in obj.items():
            if not isinstance(k, str):
                raise WireError(f"wire dict keys must be str, got {k!r}")
            if k == _TAG:
                raise WireError(f"reserved key {_TAG!r} in message")
            out[k] = _pack(v, binary=binary)
        return out
    if obj is None or isinstance(obj, (str, int, float, bool)):
        return obj
    raise WireError(f"unserialisable wire value of type {type(obj).__name__}")


def _unpack(obj: Any) -> Any:
    if isinstance(obj, dict):
        tag = obj.get(_TAG)
        if tag == "shape_only":
            return SHAPE_ONLY_PAYLOAD
        if tag == "ndarray":
            data = _unpack(obj["data"])
            arr = np.frombuffer(data, dtype=np.dtype(obj["dtype"]))
            return arr.reshape(tuple(obj["shape"])).copy()
        if tag == "bytes":
            return base64.b64decode(obj["b64"])
        if tag is not None:
            raise WireError(f"unknown wire tag {tag!r}")
        return {k: _unpack(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_unpack(v) for v in obj]
    return obj


def encode(obj: Any, codec: str = "auto") -> bytes:
    codec = _resolve_codec(codec)
    if codec == "msgpack":
        return msgpack.packb(_pack(obj, binary=True), use_bin_type=True)
    return json.dumps(_pack(obj, binary=False),
                      separators=(",", ":")).encode("utf-8")


def decode(data: bytes, codec: str = "auto") -> Any:
    codec = _resolve_codec(codec)
    if codec == "msgpack":
        return _unpack(msgpack.unpackb(data, raw=False))
    return _unpack(json.loads(data.decode("utf-8")))


def _resolve_codec(codec: str) -> str:
    if codec == "auto":
        return "msgpack" if HAVE_MSGPACK else "json"
    if codec == "msgpack" and not HAVE_MSGPACK:
        raise WireError("msgpack codec requested but msgpack is missing")
    if codec not in ("msgpack", "json"):
        raise WireError(f"unknown codec {codec!r}")
    return codec


# --------------------------------------------------------------------------
# framed socket I/O
# --------------------------------------------------------------------------

def send_msg(sock: socket.socket, obj: Any, codec: str = "auto") -> int:
    """Frame + send one message; returns bytes put on the wire (header
    included -- the bench's bandwidth ledger counts real socket bytes)."""
    payload = encode(obj, codec)
    if len(payload) > MAX_FRAME:
        raise WireError(f"frame of {len(payload)} bytes exceeds MAX_FRAME")
    frame = struct.pack(">I", len(payload)) + payload
    sock.sendall(frame)
    return len(frame)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout:
            # socket.timeout IS an OSError (TimeoutError) on 3.10+:
            # re-raise before the peer-death translation below, or a
            # quiet interval on a healthy connection reads as the peer
            # dying (recv_msg documents timeouts pass through untouched)
            raise
        except (ConnectionError, OSError) as e:
            raise PeerGone(str(e)) from None
        if not chunk:
            raise PeerGone("EOF")
        buf += chunk
    return bytes(buf)


def recv_msg(sock: socket.socket, codec: str = "auto",
             timeout: Optional[float] = None) -> Any:
    """Read one framed message (blocking; ``timeout`` uses the socket
    timeout and raises ``socket.timeout`` untouched so pollers can spin)."""
    if timeout is not None:
        sock.settimeout(timeout)
    header = _recv_exact(sock, 4)
    (length,) = struct.unpack(">I", header)
    if length > MAX_FRAME:
        raise WireError(f"incoming frame of {length} bytes exceeds MAX_FRAME "
                        f"(stream desync?)")
    return decode(_recv_exact(sock, length), codec)


class SocketChannel:
    """`repro.core.Channel` over one direction of a framed TCP stream.

    The fleet's channel *pair* is the two directions of one connection:
    central->host carries dispatches (central holds the send side), and
    host->central carries updates/completions/heartbeats (central holds
    the recv side).  ``send`` is locked (many executor threads share the
    host's upstream); ``recv`` assumes a single consumer thread, which is
    exactly the receiver-thread-per-host structure in manager.py.
    """

    def __init__(self, sock: socket.socket, codec: str = "auto") -> None:
        import threading

        self.sock = sock
        self.codec = _resolve_codec(codec)
        self._send_lock = threading.Lock()
        self._closed = False
        self.bytes_sent = 0
        self.frames_sent = 0

    def send(self, msg: Any) -> None:
        from repro.core.channel import ChannelClosed

        if self._closed:
            raise ChannelClosed("send on closed SocketChannel")
        try:
            with self._send_lock:
                self.bytes_sent += send_msg(self.sock, msg, self.codec)
                self.frames_sent += 1
        except (PeerGone, ConnectionError, OSError) as e:
            raise ChannelClosed(str(e)) from None

    def recv(self, timeout: Optional[float] = None) -> Any:
        from repro.core.channel import ChannelClosed

        if self._closed:
            raise ChannelClosed("recv on closed SocketChannel")
        try:
            return recv_msg(self.sock, self.codec, timeout)
        except socket.timeout:
            raise TimeoutError("SocketChannel.recv timed out") from None
        except PeerGone as e:
            raise ChannelClosed(str(e)) from None

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            try:
                self.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            self.sock.close()

"""Data diffusion core (the paper's contribution).

Public API re-exports; see DESIGN.md §3 for the inventory.
"""
from .cache import EvictionPolicy, ExecutorCache
from .channel import CallbackChannel, Channel, ChannelClosed, LocalChannel
from .index import IndexUpdate, LocationIndex, ShardedIndex, prls_aggregate_throughput, prls_latency_model
from .objects import DataObject, Task, TaskState, make_objects, uniform_tasks
from .policies import Decision, DispatchPolicy, decide
from .provisioner import AllocationPolicy, DynamicResourceProvisioner
from .runtime import SHAPE_ONLY_PAYLOAD, DiffusionRuntime, ObjectStore
from .scheduler import Dispatcher
from .simulator import DiffusionSim, SimConfig, SimResult
from .testbeds import ANL_UC, TPU_V5E_HOSTS, TestbedSpec

__all__ = [
    "ANL_UC",
    "AllocationPolicy",
    "CallbackChannel",
    "Channel",
    "ChannelClosed",
    "DataObject",
    "Decision",
    "DiffusionRuntime",
    "DiffusionSim",
    "DispatchPolicy",
    "Dispatcher",
    "DynamicResourceProvisioner",
    "EvictionPolicy",
    "ExecutorCache",
    "IndexUpdate",
    "LocalChannel",
    "LocationIndex",
    "ObjectStore",
    "SHAPE_ONLY_PAYLOAD",
    "ShardedIndex",
    "SimConfig",
    "SimResult",
    "TPU_V5E_HOSTS",
    "Task",
    "TaskState",
    "TestbedSpec",
    "decide",
    "make_objects",
    "prls_aggregate_throughput",
    "prls_latency_model",
    "uniform_tasks",
]

"""Core data model for data diffusion.

The paper's execution model (§3.2.2): data objects are *immutable after
creation* -- this is the assumption that lets diffusion avoid cache-coherence
protocols entirely and keep only a loosely-coherent location index.  We encode
immutability by making :class:`DataObject` frozen and giving the system no
mutation API at all: objects are created (by the store or by task outputs) and
replicated, never rewritten.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

# ---------------------------------------------------------------------------
# Data objects
# ---------------------------------------------------------------------------


@dataclass(frozen=True, slots=True)
class DataObject:
    """An immutable, replicable unit of data (a file in the paper)."""

    oid: str
    size_bytes: int

    def __post_init__(self) -> None:
        if self.size_bytes < 0:
            raise ValueError(f"negative size for {self.oid}")


class TaskState(enum.Enum):
    SUBMITTED = "submitted"      # in the dispatcher wait queue
    PENDING = "pending"          # bound to a busy executor (max-cache-hit waits)
    DISPATCHED = "dispatched"    # sent to an executor, not yet running
    FETCHING = "fetching"        # executor staging inputs
    RUNNING = "running"          # compute phase
    DONE = "done"
    FAILED = "failed"            # will be retried unless attempts exhausted


_task_counter = itertools.count()


@dataclass(slots=True)
class Task:
    """A unit of work reading immutable inputs and creating new objects.

    ``compute_seconds`` drives the discrete-event simulator; ``fn`` drives the
    real threaded runtime (both may be set -- the runtime ignores
    ``compute_seconds`` and the simulator ignores ``fn``).
    """

    inputs: tuple[str, ...]
    outputs: tuple[DataObject, ...] = ()
    compute_seconds: float = 0.0
    fn: Optional[Callable[..., Any]] = None
    # metadata-operation count against the persistent store (the paper's
    # "wrapper" sandbox: mkdir + symlink + rmdir = 3 metadata ops per task).
    store_metadata_ops: int = 0
    tid: str = field(default_factory=lambda: f"t{next(_task_counter)}")
    tag: Any = None
    # tids of producer tasks that must complete before this task may run.
    # The dispatcher holds tasks with unmet deps out of the queue entirely.
    deps: tuple[str, ...] = ()

    # -- mutable bookkeeping (owned by the dispatcher) ----------------------
    state: TaskState = TaskState.SUBMITTED
    executor: Optional[str] = None
    attempts: int = 0
    max_attempts: int = 3
    submit_time: float = 0.0
    # when the task became runnable: == submit_time for dep-free tasks,
    # stamped at release for tasks that waited on producers.
    ready_time: float = 0.0
    dispatch_time: float = 0.0
    start_time: float = 0.0
    end_time: float = 0.0
    # filled by the dispatcher for cache-aware policies: oid -> executors
    # known (at dispatch time) to cache it.  first-available ships none.
    location_hints: dict[str, tuple[str, ...]] = field(default_factory=dict)
    # byte ledger filled in by whoever executed the task.  Multi-input
    # (join) tasks accumulate one entry per input: ``cache_hits`` counts
    # local-cache inputs, ``peer_hits`` inputs served cache-to-cache, and
    # ``cache_misses`` inputs not found locally (peer + store) -- so
    # ``cache_misses - peer_hits`` inputs touched the persistent store.
    bytes_local: int = 0
    bytes_cache_to_cache: int = 0
    bytes_store: int = 0
    cache_hits: int = 0
    peer_hits: int = 0
    cache_misses: int = 0
    result: Any = None

    def reset_for_retry(self) -> None:
        self.state = TaskState.SUBMITTED
        self.executor = None
        self.location_hints = {}
        self.bytes_local = self.bytes_cache_to_cache = self.bytes_store = 0
        self.cache_hits = self.peer_hits = self.cache_misses = 0


def make_objects(prefix: str, n: int, size_bytes: int) -> list[DataObject]:
    """Convenience: n equally-sized immutable objects."""
    return [DataObject(f"{prefix}{i}", size_bytes) for i in range(n)]


def uniform_tasks(
    objects: Sequence[DataObject],
    accesses_per_object: int = 1,
    compute_seconds: float = 0.0,
    store_metadata_ops: int = 0,
) -> list[Task]:
    """One task per (object, access) -- the microbenchmark workload shape."""
    tasks = []
    for _ in range(accesses_per_object):
        for ob in objects:
            tasks.append(
                Task(
                    inputs=(ob.oid,),
                    compute_seconds=compute_seconds,
                    store_metadata_ops=store_metadata_ops,
                )
            )
    return tasks

"""Byte-accounting transport fabric for the discrete-event simulator.

Fluid-flow model: every transfer is a Flow crossing one or more
BandwidthResources (store ports, node disks, NICs, a per-flow protocol cap
standing in for the paper's per-executor GridFTP server).  A flow's
instantaneous rate is

    rate(f) = min over r in f.resources of  capacity(r) / nflows(r)

recomputed whenever any flow starts or finishes.  This equal-share rule is
conservative w.r.t. max-min fairness (never oversubscribes a resource, may
under-fill one when a flow is bottlenecked elsewhere) and is deterministic,
which we value more than the last few percent of model fidelity.  Calibration
constants live in testbeds.py; see DESIGN.md §2 for the calibration story.

Rebalancing is *incremental* (``solver="incremental"``, the default): a flow
start/finish/cancel only reprices flows sharing a resource whose flow count
changed, and a flow whose rate is unchanged keeps its generation and its
already-scheduled completion event.  The O(F)-scan-per-event reference
implementation is retained as ``solver="naive"`` — it produces bit-identical
results (tests/test_flow_equivalence.py) because both solvers advance a
flow's byte clock only at rate changes, from the same float anchors.  The
invariants that make this equivalence hold are documented in DESIGN.md §3.

MetadataService models the persistent store's metadata path (file open,
mkdir/symlink/rmdir for the paper's sandbox wrapper) as a single FIFO server
with fixed per-op latency -- this is what produces the paper's ~21 tasks/s
small-file wrapper floor (Figure 5).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

EPS = 1e-12


class BandwidthResource:
    __slots__ = ("name", "capacity", "flows")

    def __init__(self, name: str, capacity_bytes_per_s: float) -> None:
        self.name = name
        self.capacity = float(capacity_bytes_per_s)
        self.flows: set[int] = set()

    def __repr__(self) -> str:  # pragma: no cover
        return f"<BW {self.name} {self.capacity:.3g}B/s x{len(self.flows)}>"


@dataclass(slots=True)
class Flow:
    fid: int
    size: float
    resources: tuple[BandwidthResource, ...]
    on_done: Callable[[float], None]
    kind: str = ""
    # (done, last_t, rate) is an *anchor*: done is exact as of last_t and the
    # flow progresses at ``rate`` since.  The anchor moves only when the rate
    # changes -- this is what keeps the two solvers float-identical.
    done: float = 0.0
    rate: float = 0.0
    last_t: float = 0.0
    gen: int = 0          # invalidates stale completion events
    alive: bool = True
    t_start: float = 0.0


@dataclass(order=True, slots=True)
class _Event:
    t: float
    seq: int
    fn: Callable[[float], None] = field(compare=False)


class EventLoop:
    """Deterministic discrete-event loop (time, insertion-order tie-break)."""

    def __init__(self) -> None:
        self._heap: list[_Event] = []
        self._seq = itertools.count()
        self.now = 0.0
        self.n_scheduled = 0   # total heap pushes (engine-cost observability)
        self.n_fired = 0

    def at(self, t: float, fn: Callable[[float], None]) -> None:
        self.n_scheduled += 1
        heapq.heappush(self._heap, _Event(max(t, self.now), next(self._seq), fn))

    def after(self, dt: float, fn: Callable[[float], None]) -> None:
        self.at(self.now + max(dt, 0.0), fn)

    def run(self, until: float = float("inf")) -> float:
        while self._heap and self._heap[0].t <= until:
            ev = heapq.heappop(self._heap)
            self.now = ev.t
            self.n_fired += 1
            ev.fn(ev.t)
        return self.now

    @property
    def empty(self) -> bool:
        return not self._heap


class FlowNetwork:
    """Manages fluid flows over shared resources on an EventLoop.

    ``solver``:
      * ``"incremental"`` (default) -- dirty-resource propagation: only flows
        sharing a resource whose flow count changed are repriced, and an ETA
        event is (re)scheduled only when the rate actually changed.
      * ``"naive"`` -- the retained reference: every rebalance scans every
        live flow and re-pushes its ETA event (the O(F²) event storm).  Kept
        for the golden-equivalence test and as the benchmark baseline.
    """

    def __init__(self, loop: EventLoop, solver: str = "incremental") -> None:
        if solver not in ("incremental", "naive"):
            raise ValueError(f"unknown flow solver {solver!r}")
        self.loop = loop
        self.solver = solver
        self._flows: dict[int, Flow] = {}
        self._fid = itertools.count()
        # byte ledger: kind -> bytes completed
        self.bytes_by_kind: dict[str, float] = {}
        self.flow_log: list[tuple[float, float, float, str]] = []  # (t0, t1, bytes, kind)
        # engine-cost observability
        self.n_rebalances = 0
        self.n_rate_recomputes = 0
        self.n_events_scheduled = 0
        self.n_event_skips = 0         # repriced but rate unchanged: no push

    # -- public API -----------------------------------------------------------
    def start(
        self,
        size_bytes: float,
        resources: tuple[BandwidthResource, ...],
        on_done: Callable[[float], None],
        kind: str = "",
        flow_cap: Optional[float] = None,
    ) -> int:
        """Start a flow; on_done(now) fires at completion. Zero-size flows
        complete immediately (still via the loop, preserving event order)."""
        fid = next(self._fid)
        if flow_cap is not None:
            resources = resources + (BandwidthResource(f"flowcap{fid}", flow_cap),)
        f = Flow(fid=fid, size=float(size_bytes), resources=resources,
                 on_done=on_done, kind=kind, last_t=self.loop.now,
                 t_start=self.loop.now)
        if f.size <= EPS:
            self.loop.after(0.0, lambda t, f=f: self._finish(f, t))
            return fid
        self._flows[fid] = f
        for r in f.resources:
            r.flows.add(fid)
        self._rebalance(f.resources)
        return fid

    def cancel(self, fid: int) -> None:
        f = self._flows.pop(fid, None)
        if f is None:
            return
        f.alive = False
        for r in f.resources:
            r.flows.discard(f.fid)
        self._rebalance(f.resources)

    @property
    def live_flows(self) -> int:
        return len(self._flows)

    # -- internals --------------------------------------------------------------
    def _rebalance(self, dirty: Iterable[BandwidthResource]) -> None:
        """Reprice flows after the flow count of ``dirty`` resources changed."""
        self.n_rebalances += 1
        if self.solver == "naive":
            self._rebalance_naive()
            return
        now = self.loop.now
        # Dirty-resource worklist.  Under equal-share, a flow's rate depends
        # only on the flow counts of its own resources, and repricing never
        # changes a count -- so the fixed point is reached after one wave and
        # the worklist never grows.  (A max-min refinement would append a
        # flow's other resources when its rate drops below their fair share.)
        affected: set[int] = set()
        for r in dirty:
            affected |= r.flows
        # ascending fid == _flows insertion order == the naive scan order,
        # so same-timestamp completion events pop identically in both solvers
        for fid in sorted(affected):
            f = self._flows.get(fid)
            if f is not None:
                self._reprice(f, now)

    def _rebalance_naive(self) -> None:
        """Reference solver: global scan, unconditional ETA re-push."""
        now = self.loop.now
        for f in self._flows.values():
            self._reprice(f, now, always_push=True)

    def _reprice(self, f: Flow, now: float, always_push: bool = False) -> None:
        self.n_rate_recomputes += 1
        new_rate = min(r.capacity / max(len(r.flows), 1) for r in f.resources)
        if new_rate != f.rate:
            # advance the byte clock to `now` and move the anchor; the
            # previously scheduled event (old gen) becomes stale
            f.done += f.rate * (now - f.last_t)
            f.last_t = now
            f.rate = new_rate
            f.gen += 1
            self._push_eta(f)
        elif always_push:
            # naive mode re-pushes a duplicate of the live event (same
            # anchor => same eta float, later heap seq => pops after it)
            self._push_eta(f)
        else:
            self.n_event_skips += 1

    def _push_eta(self, f: Flow) -> None:
        remaining = max(f.size - f.done, 0.0)
        eta = f.last_t + (remaining / f.rate if f.rate > EPS else float("inf"))
        if eta != float("inf"):
            self.n_events_scheduled += 1
            gen = f.gen
            self.loop.at(eta, lambda t, f=f, g=gen: self._maybe_finish(f, g, t))

    def _maybe_finish(self, f: Flow, gen: int, now: float) -> None:
        if not f.alive or f.gen != gen or f.fid not in self._flows:
            return
        # gen matches => no repricing occurred since this ETA was computed,
        # so the rate has been constant and the flow is exactly done now
        # (modulo float drift, which we therefore clamp away).
        f.done = f.size
        f.last_t = now
        del self._flows[f.fid]
        for r in f.resources:
            r.flows.discard(f.fid)
        self._rebalance(f.resources)
        self._finish(f, now)

    def _finish(self, f: Flow, now: float) -> None:
        f.alive = False
        self.bytes_by_kind[f.kind] = self.bytes_by_kind.get(f.kind, 0.0) + f.size
        self.flow_log.append((f.t_start, now, f.size, f.kind))
        f.on_done(now)


class MetadataService:
    """FIFO metadata server: per-op latency, one op at a time (GPFS MDS)."""

    def __init__(self, loop: EventLoop, op_latency_s: float) -> None:
        self.loop = loop
        self.op_latency = op_latency_s
        self._next_free = 0.0
        self.n_ops = 0

    def submit(self, n_ops: int, on_done: Callable[[float], None]) -> None:
        if n_ops <= 0 or self.op_latency <= 0:
            self.loop.after(0.0, on_done)
            return
        start = max(self.loop.now, self._next_free)
        end = start + n_ops * self.op_latency
        self._next_free = end
        self.n_ops += n_ops
        self.loop.at(end, on_done)


class FifoServer:
    """Serialized service with fixed per-item time (dispatcher CPU model)."""

    def __init__(self, loop: EventLoop, service_time_s: float) -> None:
        self.loop = loop
        self.service_time = service_time_s
        self._next_free = 0.0
        self.n_served = 0

    def submit(self, on_done: Callable[[float], None], cost_s: Optional[float] = None) -> None:
        cost = self.service_time if cost_s is None else cost_s
        start = max(self.loop.now, self._next_free)
        end = start + cost
        self._next_free = end
        self.n_served += 1
        self.loop.at(end, on_done)

"""Per-executor data cache with the paper's four eviction policies (§3.2.2).

Each executor manages its own cache with a *local* eviction policy and
communicates content changes to the dispatcher's central index.  The paper
implements Random, FIFO, LRU and LFU and runs its experiments with LRU; we
implement all four behind one structure.

Invariants (property-tested in tests/test_cache_properties.py):
  * used_bytes == sum(size of resident objects)  and  used_bytes <= capacity
  * an object larger than capacity is never admitted
  * pinned objects (inputs of a running task) are never evicted
  * LRU evicts the least-recently *touched*, FIFO the earliest-inserted,
    LFU the least-frequently-touched (ties broken FIFO), Random any unpinned.
"""
from __future__ import annotations

import enum
import heapq
import random
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Iterable, Optional

from .objects import DataObject


class EvictionPolicy(enum.Enum):
    RANDOM = "random"
    FIFO = "fifo"
    LRU = "lru"
    LFU = "lfu"


@dataclass(slots=True)
class CacheStats:
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0
    rejected: int = 0  # objects bigger than the whole cache
    readmits: int = 0  # re-admissions of previously pressure-evicted oids

    @property
    def hit_ratio(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class ExecutorCache:
    """Byte-budgeted object cache. Not thread-safe; callers lock."""

    def __init__(
        self,
        capacity_bytes: int,
        policy: EvictionPolicy = EvictionPolicy.LRU,
        seed: int = 0,
    ) -> None:
        if capacity_bytes < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity_bytes = capacity_bytes
        self.policy = policy
        self._rng = random.Random(seed)
        # oid -> size.  Ordering carries policy meaning:
        #   FIFO: insertion order;  LRU: recency order (oldest first).
        self._entries: OrderedDict[str, int] = OrderedDict()
        self._freq: dict[str, int] = {}        # LFU counters
        self._tick = 0                         # LFU FIFO tie-break
        self._order: dict[str, int] = {}       # oid -> insertion tick
        self._pinned: dict[str, int] = {}      # oid -> pin count
        # LFU victim heap of (freq, order, oid), lazily pruned: an entry is
        # stale once the oid's freq moved on (every touch pushes a fresh
        # entry) or the oid left the cache.  Eviction bursts are O(log n)
        # each instead of a full min() scan over the candidate list.
        self._lfu_heap: list[tuple[int, int, str]] = []
        # resident oids in arbitrary order with O(1) swap-remove, so RANDOM
        # eviction samples instead of materializing the candidate list.
        self._resident: list[str] = []
        self._resident_pos: dict[str, int] = {}
        # oids pressure-evicted at least once and not yet re-admitted: a
        # later put() of one of these counts as a re-admit (cache thrash --
        # the working set no longer fits).  Explicit drop()s don't qualify.
        self._evicted_once: set[str] = set()
        self.used_bytes = 0
        self.stats = CacheStats()

    # -- queries ------------------------------------------------------------
    def __contains__(self, oid: str) -> bool:
        return oid in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def contents(self) -> frozenset[str]:
        return frozenset(self._entries)

    def size_of(self, oid: str) -> int:
        return self._entries[oid]

    # -- pinning (inputs of in-flight tasks must not be evicted) ------------
    def pin(self, oid: str) -> None:
        if oid in self._entries:
            self._pinned[oid] = self._pinned.get(oid, 0) + 1

    def unpin(self, oid: str) -> None:
        c = self._pinned.get(oid, 0)
        if c <= 1:
            self._pinned.pop(oid, None)
        else:
            self._pinned[oid] = c - 1

    # -- access -------------------------------------------------------------
    def get(self, oid: str) -> bool:
        """True on hit; updates recency/frequency metadata."""
        if oid not in self._entries:
            self.stats.misses += 1
            return False
        self.stats.hits += 1
        self._touch(oid)
        return True

    def _touch(self, oid: str) -> None:
        if self.policy is EvictionPolicy.LRU:
            self._entries.move_to_end(oid)
        f = self._freq.get(oid, 0) + 1
        self._freq[oid] = f
        if self.policy is EvictionPolicy.LFU:
            self._lfu_push(oid, f)

    def _lfu_push(self, oid: str, freq: int) -> None:
        heapq.heappush(self._lfu_heap, (freq, self._order.get(oid, self._tick), oid))
        if len(self._lfu_heap) > 4 * len(self._entries) + 64:
            self._lfu_heap = [(self._freq[o], self._order[o], o)
                              for o in self._entries]
            heapq.heapify(self._lfu_heap)

    # -- insertion / eviction ------------------------------------------------
    def put(self, obj: DataObject) -> list[str]:
        """Insert (idempotent); returns the list of evicted oids."""
        if obj.oid in self._entries:
            self._touch(obj.oid)
            return []
        if obj.size_bytes > self.capacity_bytes:
            self.stats.rejected += 1
            return []
        evicted: list[str] = []
        while self.used_bytes + obj.size_bytes > self.capacity_bytes:
            victim = self._pick_victim()
            if victim is None:  # everything pinned -- over-admit is forbidden
                self.stats.rejected += 1
                return evicted
            self._remove(victim)
            evicted.append(victim)
            self.stats.evictions += 1
            self._evicted_once.add(victim)
        if obj.oid in self._evicted_once:
            self._evicted_once.discard(obj.oid)
            self.stats.readmits += 1
        self._entries[obj.oid] = obj.size_bytes
        self._freq[obj.oid] = 1
        self._order[obj.oid] = self._tick
        self._tick += 1
        self._resident_pos[obj.oid] = len(self._resident)
        self._resident.append(obj.oid)
        if self.policy is EvictionPolicy.LFU:
            self._lfu_push(obj.oid, 1)
        self.used_bytes += obj.size_bytes
        self.stats.insertions += 1
        return evicted

    def _pick_victim(self) -> Optional[str]:
        if len(self._entries) <= len(self._pinned):
            return None                        # everything resident is pinned
        p = self.policy
        if p is EvictionPolicy.RANDOM:
            # rejection-sample the resident list; pinned objects are few
            # (inputs of running tasks), so this is O(1) expected
            for _ in range(32):
                o = self._resident[self._rng.randrange(len(self._resident))]
                if o not in self._pinned:
                    return o
            candidates = [o for o in self._resident if o not in self._pinned]
            return self._rng.choice(candidates) if candidates else None
        if p in (EvictionPolicy.FIFO, EvictionPolicy.LRU):
            # _entries order is insertion (FIFO) or recency (LRU); first
            # unpinned in order is the victim.
            for o in self._entries:
                if o not in self._pinned:
                    return o
            return None
        # LFU, FIFO tie-break: lazily-pruned min-heap.  Pop stale entries
        # (freq/order moved on, or oid gone); defer valid-but-pinned ones
        # and restore them afterwards.
        deferred: list[tuple[int, int, str]] = []
        victim: Optional[str] = None
        while self._lfu_heap:
            f, ordr, o = heapq.heappop(self._lfu_heap)
            if self._freq.get(o) != f or self._order.get(o) != ordr:
                continue                       # stale: pruned for good
            if o in self._pinned:
                deferred.append((f, ordr, o))
                continue
            victim = o
            deferred.append((f, ordr, o))      # pruned once actually removed
            break
        for item in deferred:
            heapq.heappush(self._lfu_heap, item)
        return victim

    def _remove(self, oid: str) -> None:
        self.used_bytes -= self._entries.pop(oid)
        self._freq.pop(oid, None)
        self._order.pop(oid, None)
        # swap-remove from the resident list
        pos = self._resident_pos.pop(oid)
        last = self._resident.pop()
        if last != oid:
            self._resident[pos] = last
            self._resident_pos[last] = pos

    def drop(self, oid: str) -> bool:
        """Explicit invalidation (executor release / failure handling)."""
        if oid in self._entries and oid not in self._pinned:
            self._remove(oid)
            return True
        return False

    def drop_all(self) -> list[str]:
        dropped = [o for o in list(self._entries) if o not in self._pinned]
        for o in dropped:
            self._remove(o)
        return dropped

    def warm(self, objs: Iterable[DataObject]) -> None:
        """Pre-populate (the paper's 100%-locality warm-cache experiments)."""
        for ob in objs:
            self.put(ob)

"""The paper's four task-dispatch policies (§3.2.2).

All policies are pure functions over (task, executor states, index) returning
a :class:`Decision`; the dispatcher in scheduler.py owns queues and state.

  first-available        ignore locality; no location hints shipped.
  first-cache-available  same executor choice; ship index lookups with the
                         task so the executor can peer-fetch instead of
                         hitting the persistent store.
  max-cache-hit          place on the executor caching the most input bytes
                         even if busy (WAIT for it) -- max locality.
  max-compute-util       among AVAILABLE executors pick the one caching the
                         most input bytes -- max utilization.

Scores are *partial-overlap bytes*: for a k-input (join) task every policy
sums the bytes of whichever subset of inputs each executor caches, so an
executor holding 2 of 3 stacked files outranks one holding a single smaller
file -- the overlap-scoring problem §4.3's stacked reads pose (and the win
condition of 0808.3535's data-aware dispatch).  The dispatcher's windowed
max-compute-util path keeps these scores incrementally (scheduler.py §6
invariants); this module stays the pure per-task reference.

``next-available`` (used for the paper's GPFS baseline runs) is an alias of
first-available.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Mapping, Optional, Protocol, Sequence

from .objects import Task


class DispatchPolicy(enum.Enum):
    FIRST_AVAILABLE = "first-available"
    FIRST_CACHE_AVAILABLE = "first-cache-available"
    MAX_CACHE_HIT = "max-cache-hit"
    MAX_COMPUTE_UTIL = "max-compute-util"
    # paper uses this name for the data-unaware GPFS baseline
    NEXT_AVAILABLE = "next-available"

    @property
    def data_aware(self) -> bool:
        return self in (DispatchPolicy.MAX_CACHE_HIT, DispatchPolicy.MAX_COMPUTE_UTIL)

    @property
    def ships_hints(self) -> bool:
        return self is not DispatchPolicy.FIRST_AVAILABLE and self is not DispatchPolicy.NEXT_AVAILABLE


class IndexLike(Protocol):
    def lookup(self, oid: str) -> frozenset[str]: ...


@dataclass(slots=True)
class Decision:
    """Outcome of a placement decision.

    ``executor is None`` and ``wait_for`` set => task must wait for that busy
    executor (max-cache-hit semantics).  ``executor is None`` and ``wait_for``
    None => no executor exists yet (queue stays, provisioner signal).
    """

    executor: Optional[str] = None
    wait_for: Optional[str] = None
    hints: dict[str, tuple[str, ...]] = field(default_factory=dict)
    cached_bytes: int = 0  # bytes of task input the chosen executor caches


def _hints_for(task: Task, index: IndexLike) -> dict[str, tuple[str, ...]]:
    out: dict[str, tuple[str, ...]] = {}
    for oid in task.inputs:
        locs = index.lookup(oid)
        if locs:
            out[oid] = tuple(sorted(locs))
    return out


def _cached_bytes(
    task: Task,
    executor: str,
    hints: Mapping[str, tuple[str, ...]],
    sizes: Mapping[str, int],
) -> int:
    return sum(
        sizes.get(oid, 1)
        for oid, locs in hints.items()
        if executor in locs
    )


def decide(
    policy: DispatchPolicy,
    task: Task,
    available: Sequence[str],
    busy: Sequence[str],
    index: IndexLike,
    sizes: Mapping[str, int],
) -> Decision:
    """Pure placement decision. ``available``/``busy`` are live executors in
    dispatcher arrival order (FIFO -- the paper's 'first available')."""
    if policy in (DispatchPolicy.FIRST_AVAILABLE, DispatchPolicy.NEXT_AVAILABLE):
        if not available:
            return Decision()
        return Decision(executor=available[0])

    hints = _hints_for(task, index)

    if policy is DispatchPolicy.FIRST_CACHE_AVAILABLE:
        if not available:
            return Decision(hints=hints)
        ex = available[0]
        return Decision(executor=ex, hints=hints,
                        cached_bytes=_cached_bytes(task, ex, hints, sizes))

    if policy is DispatchPolicy.MAX_COMPUTE_UTIL:
        if not available:
            return Decision(hints=hints)
        best = max(available,
                   key=lambda ex: (_cached_bytes(task, ex, hints, sizes),))
        return Decision(executor=best, hints=hints,
                        cached_bytes=_cached_bytes(task, best, hints, sizes))

    if policy is DispatchPolicy.MAX_CACHE_HIT:
        everyone = list(available) + list(busy)
        if not everyone:
            return Decision(hints=hints)
        scored = [(_cached_bytes(task, ex, hints, sizes), ex) for ex in everyone]
        best_bytes = max(s for s, _ in scored)
        if best_bytes == 0:
            # nothing cached anywhere: degrade to first-cache-available
            if available:
                ex = available[0]
                return Decision(executor=ex, hints=hints)
            return Decision(hints=hints)
        # prefer an available executor among the best-scoring ones
        best_avail = [ex for s, ex in scored if s == best_bytes and ex in set(available)]
        if best_avail:
            ex = best_avail[0]
            return Decision(executor=ex, hints=hints, cached_bytes=best_bytes)
        # best holder is busy: WAIT for it (the policy's defining behaviour)
        holder = next(ex for s, ex in scored if s == best_bytes)
        return Decision(wait_for=holder, hints=hints, cached_bytes=best_bytes)

    raise ValueError(f"unknown policy {policy}")

"""Discrete-event simulator for data diffusion (calibrated to §4's testbed).

Executes the *same* Dispatcher / ExecutorCache / LocationIndex / policy code
as the real threaded runtime, replacing task execution and byte movement with
a fluid-flow clock (transport.py).  One simulated executor == one node with
``cpus_per_node`` compute slots (the paper maps executors 1:1 to nodes; the
stacking runs use both CPUs per node).

Task lifecycle (mirrors §3.2.2):
  dispatch (serialized dispatcher CPU + RTT)
  -> [wrapper metadata ops on the store MDS, if any]
  -> per input: local-cache read | peer fetch (GridFTP-analogue) | store read
     (misses are cached locally unless caching is disabled; evictions and
      insertions emit loosely-coherent index updates)
  -> compute (slot-bound, optionally slowed for straggler injection)
  -> outputs written locally / to the store
  -> completion -> dispatcher -> next dispatches.

Fault tolerance exercised here: executor failure at a configured time
(flows cancelled, index invalidated, tasks re-queued), straggler speculation
(dispatcher twins), elastic pool via the DRP.

Submission is either closed-loop (``submit``: a batch lands on the wait
queue at once) or open-loop (``submit_workload``: one heap-scheduled ARRIVAL
event per task at its ``repro.workloads`` arrival time, so queue depth
tracks *demand* and the DRP grows/shrinks the pool against it; pool-size
changes are sampled into ``pool_log`` for the workload metrics layer).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

from .cache import EvictionPolicy, ExecutorCache
from .index import IndexUpdate
from .objects import DataObject, Task, TaskState
from .policies import DispatchPolicy
from .provisioner import DynamicResourceProvisioner
from .scheduler import Dispatcher, Dispatch
from .testbeds import TestbedSpec
from .transport import BandwidthResource, EventLoop, FifoServer, FlowNetwork, MetadataService


@dataclass(slots=True)
class SimNodeRes:
    eid: str
    disk_read: BandwidthResource
    disk_write: BandwidthResource
    nic_in: BandwidthResource
    nic_out: BandwidthResource
    cache: ExecutorCache
    slowdown: float = 1.0
    alive: bool = True


@dataclass
class SimConfig:
    testbed: TestbedSpec
    n_nodes: int
    policy: DispatchPolicy
    cpus_per_node: int = 1
    cache_policy: EvictionPolicy = EvictionPolicy.LRU
    cache_capacity_bytes: int = 50 * 10**9
    caching_enabled: bool = True          # False => paper's first-available mode
    write_outputs_to: str = "local"       # local | store | none
    index_update_interval_s: float = 0.0  # 0 => synchronous (tight coherence)
    # paper §6 future work: what happens to cached data when an executor is
    # RELEASED (not failed)? "discard" drops it (paper default assumption);
    # "rebalance" migrates it to live peers (beyond-paper), so later tasks
    # still find it via the index instead of re-reading the store.
    release_policy: str = "discard"       # discard | rebalance
    # flow-rate solver: "incremental" (dirty-resource repricing, the default)
    # or "naive" (global rescan per event; retained reference -- see
    # tests/test_flow_equivalence.py and benchmarks/bench_engine.py)
    flow_solver: str = "incremental"
    speculation_factor: float = 0.0
    provisioner: Optional[DynamicResourceProvisioner] = None
    provisioner_period_s: float = 1.0
    seed: int = 0
    executor_slowdown: dict[str, float] = field(default_factory=dict)
    fail_at: dict[str, float] = field(default_factory=dict)
    # optional repro.obs.Recorder (lifecycle events on the simulated clock);
    # None = recording off, zero hot-path cost
    recorder: Optional[object] = None
    # optional repro.obs.metrics.Telemetry bundle; samples are taken on the
    # simulated clock every ``metrics.interval_s`` virtual seconds (same
    # free-when-off contract as the recorder; DESIGN.md §13)
    metrics: Optional[object] = None


@dataclass
class SimResult:
    makespan: float
    t_first_dispatch: float
    t_last_complete: float
    bytes_by_kind: dict[str, float]
    n_completed: int
    n_failed: int
    local_hits: int
    peer_hits: int
    store_reads: int
    dispatcher: Dispatcher
    flow_log: list[tuple[float, float, float, str]]
    # (t, live-executor count) samples; one initial entry, then one per
    # membership change.  MetricsCollector integrates this for the
    # provisioning metrics (executor-seconds, performance index).
    pool_log: list[tuple[float, int]] = field(default_factory=list)

    @property
    def busy_span(self) -> float:
        return max(self.t_last_complete - self.t_first_dispatch, 1e-12)

    def read_throughput(self) -> float:
        """Bytes/s of task input consumption (local + c2c + store reads)."""
        b = self.bytes_by_kind
        total = b.get("local", 0) + b.get("c2c", 0) + b.get("store_read", 0)
        return total / self.busy_span

    def moved_throughput(self) -> float:
        """Bytes/s of all reads+writes (the paper's read+write metric)."""
        return sum(self.bytes_by_kind.values()) / self.busy_span

    def throughput_of(self, kinds: Sequence[str]) -> float:
        return sum(self.bytes_by_kind.get(k, 0) for k in kinds) / self.busy_span

    @property
    def local_hit_ratio(self) -> float:
        n = self.local_hits + self.peer_hits + self.store_reads
        return self.local_hits / n if n else 0.0

    @property
    def global_hit_ratio(self) -> float:
        """Paper's cache-hit metric: any access avoiding persistent storage."""
        n = self.local_hits + self.peer_hits + self.store_reads
        return (self.local_hits + self.peer_hits) / n if n else 0.0

    def tasks_per_second(self) -> float:
        return self.n_completed / self.busy_span


class DiffusionSim:
    def __init__(self, cfg: SimConfig) -> None:
        self.cfg = cfg
        tb = cfg.testbed
        self.loop = EventLoop()
        self.net = FlowNetwork(self.loop, solver=cfg.flow_solver)
        self.store_read = BandwidthResource("store_read", tb.store_read_bw)
        self.store_write = BandwidthResource("store_write", tb.store_write_bw)
        self.store_meta = MetadataService(self.loop, tb.store_meta_latency_s)
        self.dispatch_cpu = FifoServer(self.loop, tb.dispatch_service_s)
        self.dispatcher = Dispatcher(
            cfg.policy, speculation_factor=cfg.speculation_factor)
        self.recorder = cfg.recorder
        if self.recorder is not None:
            # events are stamped on the simulated clock, so sim and runtime
            # traces line up phase-for-phase (not second-for-second)
            self.recorder.clock = lambda: self.loop.now
            self.dispatcher.recorder = self.recorder
        self.telemetry = cfg.metrics
        self.metrics = cfg.metrics.registry if cfg.metrics is not None else None
        self.dispatcher.metrics = self.metrics
        if cfg.provisioner is not None:
            cfg.provisioner.metrics = self.metrics
        self.nodes: dict[str, SimNodeRes] = {}
        self.store_catalog: dict[str, DataObject] = {}
        self._rng = random.Random(cfg.seed)
        self._pending_updates: dict[str, list[IndexUpdate]] = {}
        self._task_gen: dict[str, int] = {}
        self._task_flows: dict[str, list[int]] = {}
        self._inflight_alloc = 0
        self._next_node_id = 0
        self._t_first_dispatch: Optional[float] = None
        self._t_last_complete = 0.0
        self.local_hits = 0
        self.peer_hits = 0
        self.store_reads = 0
        self.pool_log: list[tuple[float, int]] = []
        self.n_submitted = 0
        for _ in range(cfg.n_nodes):
            self._add_node(0.0)
        self._log_pool(0.0)
        for eid, t in cfg.fail_at.items():
            self.loop.at(t, lambda now, e=eid: self._fail_node(e, now))
        self._prov_tick_live = False
        if cfg.provisioner is not None:
            self._prov_tick_live = True
            self.loop.after(cfg.provisioner_period_s, self._provision_tick)
        if cfg.speculation_factor > 0:
            self.loop.after(1.0, self._speculation_tick)
        self._metrics_tick_live = False
        if self.telemetry is not None:
            self._metrics_tick_live = True
            self.loop.after(self.telemetry.interval_s, self._metrics_tick)

    # ------------- membership -------------------------------------------------
    def _log_pool(self, now: float) -> None:
        live = sum(1 for n in self.nodes.values() if n.alive)
        self.pool_log.append((now, live))
        if self.recorder is not None:
            self.recorder.emit("pool", t=now, size=live)

    def _add_node(self, now: float) -> str:
        tb = self.cfg.testbed
        eid = f"e{self._next_node_id}"
        self._next_node_id += 1
        self.nodes[eid] = SimNodeRes(
            eid=eid,
            disk_read=BandwidthResource(f"{eid}.dr", tb.disk_read_bw),
            disk_write=BandwidthResource(f"{eid}.dw", tb.disk_write_bw),
            nic_in=BandwidthResource(f"{eid}.ni", tb.nic_in_bw),
            nic_out=BandwidthResource(f"{eid}.no", tb.nic_out_bw),
            cache=ExecutorCache(self.cfg.cache_capacity_bytes,
                                self.cfg.cache_policy,
                                seed=self.cfg.seed + self._next_node_id),
            slowdown=self.cfg.executor_slowdown.get(eid, 1.0),
        )
        self.dispatcher.executor_joined(eid, now, slots=self.cfg.cpus_per_node)
        self._pending_updates[eid] = []
        return eid

    def _fail_node(self, eid: str, now: float) -> None:
        node = self.nodes.get(eid)
        if node is None or not node.alive:
            return
        node.alive = False
        node.cache.drop_all()
        st = self.dispatcher.executors.get(eid)
        running = list(st.running) if st else []
        for tid in running:
            # invalidate the in-flight attempt: its queued events must not
            # complete the (re-queued) task a second time
            self._task_gen[tid] = self._task_gen.get(tid, 0) + 1
            for fid in self._task_flows.pop(tid, []):
                self.net.cancel(fid)
        self.dispatcher.executor_left(eid, now, failed=True)
        self._log_pool(now)
        self._pump(now)

    def _release_node(self, eid: str, now: float) -> None:
        node = self.nodes.get(eid)
        if node is None or not node.alive:
            return
        node.alive = False
        if self.cfg.release_policy == "rebalance":
            # migrate cached objects to live peers (round-robin), charging
            # the network: one c2c flow per object.  Index follows the data.
            peers = sorted(e for e, n in self.nodes.items()
                           if n.alive and e != eid)
            if peers:
                for i, oid in enumerate(sorted(node.cache.contents())):
                    dst = self.nodes[peers[i % len(peers)]]
                    size = node.cache.size_of(oid)
                    obj = self.store_catalog.get(oid) or DataObject(oid, size)
                    evicted = dst.cache.put(obj)
                    self._emit_update(dst.eid, IndexUpdate(
                        dst.eid, added=(oid,), removed=tuple(evicted)), now)
                    self.net.start(size, (node.nic_out, dst.nic_in),
                                   lambda tt: None, kind="c2c")
        node.cache.drop_all()
        self.dispatcher.executor_left(eid, now, failed=False)
        self._log_pool(now)

    # ------------- data placement ----------------------------------------------
    def add_objects(self, objs: Iterable[DataObject]) -> None:
        for ob in objs:
            self.store_catalog[ob.oid] = ob
        self.dispatcher.register_objects(self.store_catalog.values())

    def warm_caches(self, objs: Sequence[DataObject], replicas: int = 1) -> None:
        """Round-robin pre-population (the paper's untimed warm-up runs)."""
        eids = sorted(self.nodes)
        for i, ob in enumerate(objs):
            for r in range(replicas):
                eid = eids[(i + r) % len(eids)]
                self.nodes[eid].cache.put(ob)
                # route through the dispatcher hook so its incremental
                # placement state stays coherent with the index
                self.dispatcher.apply_index_updates(
                    (IndexUpdate(eid, added=(ob.oid,)),))

    # ------------- submission / run ----------------------------------------------
    def submit(self, tasks: Iterable[Task]) -> None:
        ts = list(tasks)
        self.dispatcher.submit(ts, self.loop.now)
        self.n_submitted += len(ts)
        for t in ts:
            self._task_gen.setdefault(t.tid, 0)
        # resurrect the provisioner tick if it parked after a drained run
        if self.cfg.provisioner is not None and not self._prov_tick_live:
            self._prov_tick_live = True
            self.loop.after(self.cfg.provisioner_period_s, self._provision_tick)
        if self.telemetry is not None and not self._metrics_tick_live:
            self._metrics_tick_live = True
            self.loop.after(self.telemetry.interval_s, self._metrics_tick)
        self._pump(self.loop.now)

    def submit_workload(self, wl) -> int:
        """Open-loop submission: register the workload's catalog and heap-
        schedule one ARRIVAL event per task at its arrival time.  The wait
        queue then reflects *demand* rather than a pre-staged batch, which
        is what drives the DynamicResourceProvisioner's grow/shrink cycle.
        Returns the number of arrivals scheduled."""
        self.add_objects(wl.objects)
        n = 0
        for t_arr, task in wl.tasks():
            self.loop.at(t_arr, lambda now, tk=task: self.submit((tk,)))
            n += 1
        return n

    def run(self, until: float = float("inf")) -> SimResult:
        self.loop.run(until)
        d = self.dispatcher
        return SimResult(
            makespan=self.loop.now,
            t_first_dispatch=self._t_first_dispatch or 0.0,
            t_last_complete=self._t_last_complete,
            bytes_by_kind=dict(self.net.bytes_by_kind),
            n_completed=len(d.completed),
            n_failed=len(d.failed),
            local_hits=self.local_hits,
            peer_hits=self.peer_hits,
            store_reads=self.store_reads,
            dispatcher=d,
            flow_log=self.net.flow_log,
            pool_log=list(self.pool_log),
        )

    # ------------- scheduling pump -----------------------------------------------
    def _pump(self, now: float) -> None:
        dispatches = self.dispatcher.next_dispatches(now)
        if self.recorder is not None:
            self.recorder.emit("pump", t=now, n=len(dispatches),
                               queue=self.dispatcher.queue_len)
        if self.metrics is not None:
            # no pump-latency histogram here: virtual time has no meaningful
            # dispatcher CPU hold (the FifoServer models it explicitly)
            self.metrics.inc("sched.pump_calls")
            if dispatches:
                self.metrics.inc("sched.dispatches", len(dispatches))
        for disp in dispatches:
            cost = self.cfg.testbed.dispatch_service_s
            if self.cfg.policy.ships_hints:
                cost += len(disp.task.inputs) * self.cfg.testbed.index_lookup_s
            self.dispatch_cpu.submit(
                lambda t, d=disp: self.loop.after(
                    self.cfg.testbed.dispatch_rtt_s,
                    lambda t2, d=d: self._start_task(d, t2)),
                cost_s=cost,
            )

    def _start_task(self, disp: Dispatch, now: float) -> None:
        t = disp.task
        if t.state is TaskState.DONE:   # satisfied by a speculative twin
            self.dispatcher.task_finished(t, now, ok=True)
            return
        gen = self._task_gen.get(t.tid, 0) + 1
        self._task_gen[t.tid] = gen
        node = self.nodes.get(disp.executor)
        if node is None or not node.alive:
            self.dispatcher.task_finished(t, now, ok=False)
            self._pump(now)
            return
        if self._t_first_dispatch is None:
            self._t_first_dispatch = now
        t.state = TaskState.FETCHING
        t.start_time = now
        self._task_flows[t.tid] = []
        if t.store_metadata_ops > 0:
            self.store_meta.submit(
                t.store_metadata_ops,
                lambda tt, t=t, n=node, g=gen: self._fetch_inputs(t, n, 0, g, tt))
        else:
            self._fetch_inputs(t, node, 0, gen, now)

    # ------------- input staging -----------------------------------------------
    def _fetch_inputs(self, t: Task, node: SimNodeRes, i: int, gen: int,
                      now: float) -> None:
        if self._task_gen.get(t.tid, 0) != gen:
            return
        if i >= len(t.inputs):
            self._compute(t, node, gen, now)
            return
        oid = t.inputs[i]
        size = self.store_catalog[oid].size_bytes if oid in self.store_catalog \
            else self.dispatcher.sizes.get(oid, 0)
        nxt = lambda tt, t=t, n=node, i=i, g=gen: self._fetch_inputs(t, n, i + 1, g, tt)

        if self.cfg.caching_enabled and node.cache.get(oid):
            node.cache.pin(oid)
            self.local_hits += 1
            t.cache_hits += 1
            t.bytes_local += size
            if self.recorder is not None:
                self.recorder.emit("input", t=now, tid=t.tid, eid=node.eid,
                                   oid=oid, source="local", bytes=size)
            fid = self.net.start(
                size, (node.disk_read,),
                lambda tt, t=t, n=node, o=oid, f=nxt: (n.cache.unpin(o), f(tt)),
                kind="local")
            self._task_flows[t.tid].append(fid)
            return

        t.cache_misses += 1
        # peer fetch using the dispatcher-shipped hints (no extra lookups at
        # the executor -- §3.2.2), falling back to the store on staleness.
        peers = [p for p in t.location_hints.get(oid, ())
                 if p != node.eid and p in self.nodes and self.nodes[p].alive
                 and oid in self.nodes[p].cache]
        if peers:
            src = self.nodes[self._rng.choice(sorted(peers))]
            src.cache.pin(oid)
            self.peer_hits += 1
            t.peer_hits += 1
            t.bytes_cache_to_cache += size
            if self.recorder is not None:
                self.recorder.emit("input", t=now, tid=t.tid, eid=node.eid,
                                   oid=oid, source="peer", bytes=size,
                                   peer=src.eid)
            tb = self.cfg.testbed

            def done_peer(tt, t=t, n=node, o=oid, s=src, sz=size, f=nxt):
                s.cache.unpin(o)
                self._admit(n, o, sz, tt, f)

            self.loop.after(
                tb.peer_setup_latency_s,
                lambda tt, sz=size, s=src, n=node, cb=done_peer: self._task_flows[t.tid].append(
                    self.net.start(sz, (s.disk_read, s.nic_out, n.nic_in),
                                   cb, kind="c2c", flow_cap=tb.peer_flow_cap)))
            return

        # persistent store read
        self.store_reads += 1
        t.bytes_store += size
        if self.recorder is not None:
            self.recorder.emit("input", t=now, tid=t.tid, eid=node.eid,
                               oid=oid, source="store", bytes=size)
        tb = self.cfg.testbed

        def done_store(tt, t=t, n=node, o=oid, sz=size, f=nxt):
            self._admit(n, o, sz, tt, f)

        self.loop.after(
            tb.store_open_latency_s,
            lambda tt, sz=size, n=node, cb=done_store: self._task_flows[t.tid].append(
                self.net.start(sz, (self.store_read, n.nic_in), cb,
                               kind="store_read")))

    def _admit(self, node: SimNodeRes, oid: str, size: int, now: float, then) -> None:
        """Write a fetched object into the local cache (if enabled)."""
        if not self.cfg.caching_enabled:
            then(now)
            return
        obj = self.store_catalog.get(oid) or DataObject(oid, size)

        def written(tt):
            evicted = node.cache.put(obj)
            upd = IndexUpdate(node.eid, added=(oid,), removed=tuple(evicted))
            self._emit_update(node.eid, upd, tt)
            node.cache.pin(oid)
            then(tt)

        self.net.start(size, (node.disk_write,), written, kind="local_write")

    def _emit_update(self, eid: str, upd: IndexUpdate, now: float) -> None:
        if self.cfg.index_update_interval_s <= 0:
            # synchronous (tight coherence) path still goes through the
            # dispatcher hook, which patches the queued-task hint cache and
            # the inverted executor->score map incrementally
            self.dispatcher.apply_index_updates((upd,))
            return
        buf = self._pending_updates.setdefault(eid, [])
        if not buf:
            self.loop.after(self.cfg.index_update_interval_s,
                            lambda tt, e=eid: self._flush_updates(e))
        buf.append(upd)

    def _flush_updates(self, eid: str) -> None:
        buf = self._pending_updates.get(eid, [])
        self._pending_updates[eid] = []
        self.dispatcher.apply_index_updates(buf)

    # ------------- compute + outputs --------------------------------------------
    def _compute(self, t: Task, node: SimNodeRes, gen: int, now: float) -> None:
        if self._task_gen.get(t.tid, 0) != gen:
            return
        t.state = TaskState.RUNNING
        if self.recorder is not None:
            self.recorder.emit("exec_start", t=now, tid=t.tid, eid=node.eid)
        dt = (t.compute_seconds + self.cfg.testbed.task_overhead_s) * node.slowdown
        self.loop.after(dt, lambda tt, t=t, n=node, g=gen: self._write_outputs(t, n, 0, g, tt))

    def _write_outputs(self, t: Task, node: SimNodeRes, i: int, gen: int,
                       now: float) -> None:
        if self._task_gen.get(t.tid, 0) != gen:
            return
        if i >= len(t.outputs) or self.cfg.write_outputs_to == "none":
            self._complete(t, node, now)
            return
        ob = t.outputs[i]
        nxt = lambda tt, t=t, n=node, i=i, g=gen: self._write_outputs(t, n, i + 1, g, tt)
        if self.cfg.write_outputs_to == "store":
            fid = self.net.start(ob.size_bytes, (node.nic_out, self.store_write),
                                 nxt, kind="store_write")
        else:
            def written(tt, n=node, ob=ob, f=nxt):
                if self.cfg.caching_enabled:
                    evicted = n.cache.put(ob)
                    self._emit_update(
                        n.eid, IndexUpdate(n.eid, added=(ob.oid,),
                                           removed=tuple(evicted)), tt)
                f(tt)
            fid = self.net.start(ob.size_bytes, (node.disk_write,), written,
                                 kind="local_write")
        self._task_flows[t.tid].append(fid)

    def _complete(self, t: Task, node: SimNodeRes, now: float) -> None:
        for oid in t.inputs:
            node.cache.unpin(oid)
        for ob in t.outputs:
            self.dispatcher.sizes[ob.oid] = ob.size_bytes
        self._task_flows.pop(t.tid, None)
        self._t_last_complete = now
        if self.recorder is not None:
            self.recorder.emit("exec_end", t=now, tid=t.tid, eid=node.eid,
                               ok=True)
        cancel_tid = self.dispatcher.task_finished(t, now, ok=True)
        if cancel_tid is not None:
            self._cancel_task(cancel_tid)
        self._pump(now)

    def _cancel_task(self, tid: str) -> None:
        self._task_gen[tid] = self._task_gen.get(tid, 0) + 1
        for fid in self._task_flows.pop(tid, []):
            self.net.cancel(fid)
        t = self.dispatcher.tasks.get(tid)
        if t is not None and t.executor in self.dispatcher.executors:
            st = self.dispatcher.executors[t.executor]
            if tid in st.running:
                st.busy = max(st.busy - 1, 0)
                st.running.discard(tid)

    # ------------- periodic services ------------------------------------------
    def _provision_tick(self, now: float) -> None:
        prov = self.cfg.provisioner
        assert prov is not None
        live = sum(1 for n in self.nodes.values() if n.alive)
        acts = prov.step(now, self.dispatcher.queue_len, live,
                         self._inflight_alloc,
                         self.dispatcher.idle_executors(
                             now, prov.idle_timeout_s))
        for _ in range(acts.allocate):
            self._inflight_alloc += 1
            self.loop.after(self.cfg.testbed.executor_startup_s,
                            self._alloc_arrived)
        for eid in acts.release:
            self._release_node(eid, now)
        live_after = sum(1 for n in self.nodes.values() if n.alive)
        # keep ticking while work remains OR the pool is above its floor
        # (releases need idle_timeout to elapse after the last completion)
        if (not (self.loop.empty and self.dispatcher.queue_len == 0)
                or live_after > prov.min_executors):
            self.loop.after(self.cfg.provisioner_period_s, self._provision_tick)
        else:
            self._prov_tick_live = False

    def _alloc_arrived(self, now: float) -> None:
        self._inflight_alloc -= 1
        self._add_node(now)
        self._log_pool(now)
        self._pump(now)

    def sample_metrics(self) -> None:
        """Refresh telemetry gauges from current sim state (virtual time)."""
        m = self.metrics
        if m is None:
            return
        live = [n for n in self.nodes.values() if n.alive]
        m.gauge_set("sched.queue_depth", self.dispatcher.queue_len)
        m.gauge_set("pool.size", len(live))
        m.gauge_set("cache.bytes", sum(n.cache.used_bytes for n in live))
        m.gauge_set("cache.hits", sum(n.cache.stats.hits for n in live))
        m.gauge_set("cache.misses", sum(n.cache.stats.misses for n in live))
        m.gauge_set("cache.evictions",
                    sum(n.cache.stats.evictions for n in live))
        m.gauge_set("cache.insertions",
                    sum(n.cache.stats.insertions for n in live))
        m.gauge_set("cache.readmits",
                    sum(n.cache.stats.readmits for n in live))
        b = self.net.bytes_by_kind
        m.gauge_set("bw.bytes_local", int(b.get("local", 0)))
        m.gauge_set("bw.bytes_c2c", int(b.get("c2c", 0)))
        m.gauge_set("bw.bytes_store", int(b.get("store_read", 0)))
        if self.recorder is not None:
            m.gauge_set("obs.recorder_dropped", self.recorder.dropped)

    def _metrics_tick(self, now: float) -> None:
        tel = self.telemetry
        assert tel is not None
        self.sample_metrics()
        tel.record_sample(now)
        # park when the run drained (mirrors the provisioner tick); submit()
        # resurrects it
        if not (self.loop.empty and self.dispatcher.queue_len == 0):
            self.loop.after(tel.interval_s, self._metrics_tick)
        else:
            self._metrics_tick_live = False

    def _speculation_tick(self, now: float) -> None:
        for t in self.dispatcher.speculation_candidates(now):
            twin = self.dispatcher.make_twin(t, now)
            self._task_gen.setdefault(twin.tid, 0)
        self._pump(now)
        if not self.loop.empty or self.dispatcher.queue_len:
            self.loop.after(1.0, self._speculation_tick)

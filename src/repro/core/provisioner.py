"""Dynamic Resource Provisioner (DRP) -- Falkon §3.1.

Watches the dispatcher wait queue and grows/shrinks the executor pool with
tunable allocation policies (the Falkon provisioner exposes the same knobs):

  one-at-a-time   +1 executor per trigger
  additive        +k executors per trigger
  exponential     doubles the request size per consecutive trigger
  all-at-once     jump straight to max_executors

De-allocation: release executors idle longer than ``idle_timeout_s``
(down to ``min_executors``).  The paper's experiments hold the pool fixed
(\"do not investigate the effects of dynamic resource provisioning\"); the
microbenchmarks therefore run with allocation=all-at-once and releases
disabled.  The DRP's policy matrix is covered by tests/test_provisioner.py,
and the full grow/shrink cycle is driven end-to-end by the open-loop
sine-wave workloads (repro.workloads + DiffusionSim.submit_workload; see
benchmarks/bench_workloads.py and tests/test_workloads.py).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class AllocationPolicy(enum.Enum):
    ONE_AT_A_TIME = "one-at-a-time"
    ADDITIVE = "additive"
    EXPONENTIAL = "exponential"
    ALL_AT_ONCE = "all-at-once"


@dataclass(slots=True)
class ProvisionerActions:
    allocate: int = 0
    release: list[str] = field(default_factory=list)


class DynamicResourceProvisioner:
    def __init__(
        self,
        min_executors: int = 0,
        max_executors: int = 64,
        policy: AllocationPolicy = AllocationPolicy.ALL_AT_ONCE,
        additive_k: int = 8,
        queue_threshold: int = 1,
        idle_timeout_s: float = 60.0,
        trigger_cooldown_s: float = 1.0,
        allocate_quantum: int = 1,
    ) -> None:
        if allocate_quantum < 1:
            raise ValueError("allocate_quantum must be >= 1")
        self.min_executors = min_executors
        self.max_executors = max_executors
        self.policy = policy
        self.additive_k = additive_k
        self.queue_threshold = queue_threshold
        self.idle_timeout_s = idle_timeout_s
        self.trigger_cooldown_s = trigger_cooldown_s
        # executors are acquired/released in multiples of this (the fleet
        # sets it to threads_per_host so grow/shrink moves whole hosts;
        # 1 = the classic per-executor behaviour, bit-identical).
        self.allocate_quantum = allocate_quantum
        self._exp_burst = 1
        self._last_trigger = -float("inf")
        self.n_allocated = 0
        self.n_released = 0
        # optional repro.obs.Recorder; the owning engine installs it so DRP
        # decisions land in the same event stream as the pool transitions
        # they cause (one "provision" event per non-empty step)
        self.recorder = None
        # optional repro.obs.metrics.MetricsRegistry (same install-and-
        # None-guard contract; DESIGN.md §13)
        self.metrics = None

    def step(
        self,
        now: float,
        queue_len: int,
        live_executors: int,
        inflight_allocations: int,
        idle_executors: list[str],
    ) -> ProvisionerActions:
        acts = ProvisionerActions()
        q = self.allocate_quantum
        total = live_executors + inflight_allocations
        # -- grow ---------------------------------------------------------
        if (queue_len >= self.queue_threshold and total < self.max_executors
                and now - self._last_trigger >= self.trigger_cooldown_s):
            # room rounds DOWN to whole quanta (no partial hosts), the
            # policy's request UP (a one-at-a-time trigger on a fleet still
            # buys one whole host).  room == 0 (max not a quantum multiple,
            # remainder too small for a whole host) is NOT a trigger: the
            # policy state (exponential burst, cooldown clock) must not
            # churn on an allocation that can never happen.
            room = ((self.max_executors - total) // q) * q
            if room > 0:
                if self.policy is AllocationPolicy.ONE_AT_A_TIME:
                    want = 1
                elif self.policy is AllocationPolicy.ADDITIVE:
                    want = self.additive_k
                elif self.policy is AllocationPolicy.EXPONENTIAL:
                    want = self._exp_burst
                    self._exp_burst *= 2
                else:  # ALL_AT_ONCE
                    want = room
                want = ((want + q - 1) // q) * q
                acts.allocate = min(want, room)
                self.n_allocated += acts.allocate
                self._last_trigger = now
        elif queue_len < self.queue_threshold:
            self._exp_burst = 1
        # -- shrink --------------------------------------------------------
        if queue_len == 0 and live_executors > self.min_executors:
            releasable = ((live_executors - self.min_executors) // q) * q
            acts.release = idle_executors[:releasable]
            self.n_released += len(acts.release)
        if self.recorder is not None and (acts.allocate or acts.release):
            self.recorder.emit("provision", allocate=acts.allocate,
                               release=len(acts.release), queue=queue_len,
                               live=live_executors)
        m = self.metrics
        if m is not None:
            m.gauge_set("drp.pool_live", live_executors)
            if acts.allocate:
                m.inc("drp.grows")
                m.inc("drp.executors_allocated", acts.allocate)
            if acts.release:
                m.inc("drp.shrinks")
                m.inc("drp.executors_released", len(acts.release))
        return acts

    def snapshot(self) -> dict:
        """JSON-able provisioning outcome for a finished run (consumed by
        the experiment layer's RunReport)."""
        return {
            "policy": self.policy.value,
            "min_executors": self.min_executors,
            "max_executors": self.max_executors,
            "n_allocated": self.n_allocated,
            "n_released": self.n_released,
        }

"""The Falkon-style dispatcher extended with data-aware scheduling (§3.2).

Engine-agnostic state machine: the discrete-event simulator and the real
threaded runtime both drive this same object, so the policy behaviour that
the paper evaluates (queueing, placement, waiting-on-busy-executor for
max-cache-hit, hint shipping, retries, speculation) is one code path.

Responsibilities:
  * wait queue + per-executor pending queues (max-cache-hit binds tasks to a
    busy executor and waits for it);
  * placement via policies.decide() against the loosely-coherent LocationIndex;
  * executor membership (join/leave/fail) with index invalidation and
    re-queueing of in-flight work  -> fault tolerance;
  * straggler speculation: duplicate the oldest running task when it exceeds
    ``speculation_factor x p95(completed durations)``; first copy wins;
  * byte/hit accounting handoff to metrics.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Optional

from .index import IndexUpdate, LocationIndex
from .objects import Task, TaskState
from .policies import Decision, DispatchPolicy, decide


@dataclass(slots=True)
class ExecutorState:
    eid: str
    alive: bool = True
    busy: int = 0                 # running task count
    slots: int = 1
    joined_at: float = 0.0
    last_busy_at: float = 0.0
    running: set[str] = field(default_factory=set)

    @property
    def available(self) -> bool:
        return self.alive and self.busy < self.slots


@dataclass(slots=True)
class Dispatch:
    task: Task
    executor: str
    hints: dict[str, tuple[str, ...]]
    speculative_of: Optional[str] = None


class Dispatcher:
    def __init__(
        self,
        policy: DispatchPolicy,
        index: Optional[LocationIndex] = None,
        speculation_factor: float = 0.0,  # 0 disables speculation
        min_completions_for_speculation: int = 10,
    ) -> None:
        self.policy = policy
        self.index = index if index is not None else LocationIndex()
        self.sizes: dict[str, int] = {}
        self.executors: dict[str, ExecutorState] = {}
        self._exec_order: list[str] = []          # arrival order (FIFO choice)
        self.queue: deque[Task] = deque()
        self.pending: dict[str, deque[Task]] = {} # max-cache-hit waits
        self.tasks: dict[str, Task] = {}
        self.completed: list[Task] = []
        self.failed: list[Task] = []
        self.durations: list[float] = []
        self.speculation_factor = speculation_factor
        self.min_completions_for_speculation = min_completions_for_speculation
        self._speculated: set[str] = set()        # tids with a live twin
        self._twins: dict[str, str] = {}          # twin tid -> original tid
        self.n_decisions = 0
        self.decision_lookups = 0

    # ---------------- membership -------------------------------------------
    def executor_joined(self, eid: str, now: float, slots: int = 1) -> None:
        self.executors[eid] = ExecutorState(eid=eid, slots=slots, joined_at=now,
                                            last_busy_at=now)
        if eid not in self._exec_order:
            self._exec_order.append(eid)
        self.pending.setdefault(eid, deque())

    def executor_left(self, eid: str, now: float, failed: bool = False) -> list[Task]:
        """Remove an executor; returns tasks that must be re-dispatched."""
        st = self.executors.get(eid)
        if st is None:
            return []
        st.alive = False
        self._exec_order = [e for e in self._exec_order if e != eid]
        self.index.drop_executor(eid)
        requeue: list[Task] = []
        for tid in list(st.running):
            t = self.tasks.get(tid)
            if t is not None and t.state not in (TaskState.DONE, TaskState.FAILED):
                t.attempts += 1
                if t.attempts >= t.max_attempts:
                    t.state = TaskState.FAILED
                    self.failed.append(t)
                else:
                    t.reset_for_retry()
                    requeue.append(t)
        st.running.clear()
        st.busy = 0
        # re-home pending (max-cache-hit) tasks bound to the dead executor
        for t in self.pending.pop(eid, deque()):
            t.state = TaskState.SUBMITTED
            requeue.append(t)
        del self.executors[eid]
        for t in requeue:
            self.queue.appendleft(t)
        return requeue

    # ---------------- submission -------------------------------------------
    def submit(self, tasks: Iterable[Task], now: float) -> int:
        n = 0
        for t in tasks:
            t.submit_time = now
            t.state = TaskState.SUBMITTED
            self.tasks[t.tid] = t
            for ob in t.outputs:
                self.sizes[ob.oid] = ob.size_bytes
            self.queue.append(t)
            n += 1
        return n

    def register_objects(self, objs) -> None:
        for ob in objs:
            self.sizes[ob.oid] = ob.size_bytes

    # ---------------- placement --------------------------------------------
    def _avail_busy(self) -> tuple[list[str], list[str]]:
        avail = [e for e in self._exec_order if self.executors[e].available]
        busy = [e for e in self._exec_order
                if self.executors[e].alive and not self.executors[e].available]
        return avail, busy

    #: how deep into the wait queue max-compute-util searches for a task
    #: matching a freed executor's cache.  Falkon's data-aware dispatcher
    #: examines queued tasks to "send tasks to nodes that have cached the
    #: most needed data" (§3.2.1); a bounded window keeps decisions O(W).
    queue_window: int = 256

    def next_dispatches(self, now: float) -> list[Dispatch]:
        """Pop as many placeable tasks as possible (engine applies them)."""
        out: list[Dispatch] = []
        # 1) pending queues of executors that became available
        for eid, dq in self.pending.items():
            st = self.executors.get(eid)
            while dq and st is not None and st.available:
                out.append(self._bind(dq.popleft(), eid, now))
        if not self.queue:
            return out
        if self.policy is DispatchPolicy.MAX_COMPUTE_UTIL:
            out.extend(self._dispatch_mcu(now))
        else:
            out.extend(self._dispatch_fifo(now))
        return out

    def _dispatch_fifo(self, now: float) -> list[Dispatch]:
        """Head-of-queue placement (FA / NA / FCA / MCH semantics)."""
        out: list[Dispatch] = []
        deferred: list[Task] = []
        progressed = True
        while progressed and self.queue:
            progressed = False
            avail, busy = self._avail_busy()
            if not avail and self.policy is not DispatchPolicy.MAX_CACHE_HIT:
                break
            t = self.queue.popleft()
            d = decide(self.policy, t, avail, busy, self.index, self.sizes)
            self.n_decisions += 1
            self.decision_lookups += len(t.inputs) if self.policy.ships_hints else 0
            if d.executor is not None:
                t.location_hints = d.hints
                out.append(self._bind(t, d.executor, now))
                progressed = True
            elif d.wait_for is not None:
                t.state = TaskState.PENDING
                t.location_hints = d.hints
                self.pending.setdefault(d.wait_for, deque()).append(t)
                progressed = True
            else:
                deferred.append(t)
        for t in reversed(deferred):
            self.queue.appendleft(t)
        return out

    def _dispatch_mcu(self, now: float) -> list[Dispatch]:
        """max-compute-util: for each available executor, pick the queued
        task (within the window) whose inputs it caches the most bytes of;
        fall back to the queue head when nothing matches."""
        out: list[Dispatch] = []
        while self.queue:
            avail, _ = self._avail_busy()
            if not avail:
                break
            window = list(self.queue)[: self.queue_window]
            # hints once per task in the window
            hinted: list[tuple[Task, dict[str, tuple[str, ...]]]] = []
            for t in window:
                hints = {}
                for oid in t.inputs:
                    locs = self.index.lookup(oid)
                    if locs:
                        hints[oid] = tuple(sorted(locs))
                self.decision_lookups += len(t.inputs)
                hinted.append((t, hints))
            self.n_decisions += 1
            bound_any = False
            taken: set[str] = set()
            for eid in avail:
                best_i, best_score = -1, 0
                for i, (t, hints) in enumerate(hinted):
                    if t.tid in taken:
                        continue
                    score = sum(self.sizes.get(oid, 1)
                                for oid, locs in hints.items() if eid in locs)
                    if score > best_score:
                        best_i, best_score = i, score
                if best_i < 0:
                    # nothing cached for this executor: take earliest unclaimed
                    best_i = next((i for i, (t, _) in enumerate(hinted)
                                   if t.tid not in taken), -1)
                    if best_i < 0:
                        break
                t, hints = hinted[best_i]
                taken.add(t.tid)
                self.queue.remove(t)
                t.location_hints = hints
                out.append(self._bind(t, eid, now))
                bound_any = True
            if not bound_any:
                break
        return out

    def _bind(self, t: Task, eid: str, now: float) -> Dispatch:
        st = self.executors[eid]
        st.busy += 1
        st.running.add(t.tid)
        st.last_busy_at = now
        t.state = TaskState.DISPATCHED
        t.executor = eid
        t.dispatch_time = now
        return Dispatch(task=t, executor=eid, hints=t.location_hints)

    # ---------------- completion -------------------------------------------
    def task_finished(self, t: Task, now: float, ok: bool = True) -> Optional[str]:
        """Returns the tid of a twin to cancel, if this was a speculated task."""
        eid = t.executor
        st = self.executors.get(eid) if eid else None
        if st is not None:
            st.busy = max(st.busy - 1, 0)
            st.running.discard(t.tid)
            st.last_busy_at = now
        cancel: Optional[str] = None
        orig_tid = self._twins.pop(t.tid, None)
        if ok:
            t.state = TaskState.DONE
            t.end_time = now
            self.durations.append(now - t.dispatch_time)
            if orig_tid is not None:
                # a speculative twin won; cancel the original
                cancel = orig_tid
                self._speculated.discard(orig_tid)
                orig = self.tasks.get(orig_tid)
                if orig is not None and orig.state not in (TaskState.DONE,):
                    orig.state = TaskState.DONE  # satisfied by twin
            elif t.tid in self._speculated:
                # original won; cancel its twin
                twin_tid = next((k for k, v in self._twins.items() if v == t.tid), None)
                if twin_tid:
                    cancel = twin_tid
                    del self._twins[twin_tid]
                self._speculated.discard(t.tid)
            self.completed.append(t)
        else:
            t.attempts += 1
            if t.attempts >= t.max_attempts:
                t.state = TaskState.FAILED
                self.failed.append(t)
            else:
                t.reset_for_retry()
                self.queue.appendleft(t)
        return cancel

    # ---------------- index coherence ---------------------------------------
    def apply_index_updates(self, updates: Iterable[IndexUpdate]) -> None:
        self.index.apply_batch(updates)

    # ---------------- speculation -------------------------------------------
    def speculation_candidates(self, now: float) -> list[Task]:
        if (self.speculation_factor <= 0
                or len(self.durations) < self.min_completions_for_speculation):
            return []
        ds = sorted(self.durations)
        p95 = ds[min(int(0.95 * len(ds)), len(ds) - 1)]
        threshold = self.speculation_factor * max(p95, 1e-9)
        out = []
        for st in self.executors.values():
            for tid in st.running:
                t = self.tasks[tid]
                if (t.state is TaskState.RUNNING or t.state is TaskState.DISPATCHED) \
                        and t.tid not in self._speculated \
                        and t.tid not in self._twins \
                        and now - t.dispatch_time > threshold:
                    out.append(t)
        return out

    def make_twin(self, t: Task, now: float) -> Task:
        twin = Task(inputs=t.inputs, outputs=t.outputs,
                    compute_seconds=t.compute_seconds, fn=t.fn,
                    store_metadata_ops=t.store_metadata_ops, tag=t.tag)
        twin.submit_time = now
        self.tasks[twin.tid] = twin
        self._speculated.add(t.tid)
        self._twins[twin.tid] = t.tid
        self.queue.appendleft(twin)
        return twin

    # ---------------- introspection -----------------------------------------
    @property
    def queue_len(self) -> int:
        return len(self.queue) + sum(len(q) for q in self.pending.values())

    def idle_executors(self, now: float, idle_for_s: float) -> list[str]:
        return [
            st.eid for st in self.executors.values()
            if st.alive and st.busy == 0 and now - st.last_busy_at >= idle_for_s
        ]

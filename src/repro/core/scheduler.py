"""The Falkon-style dispatcher extended with data-aware scheduling (§3.2).

Engine-agnostic state machine: the discrete-event simulator and the real
threaded runtime both drive this same object, so the policy behaviour that
the paper evaluates (queueing, placement, waiting-on-busy-executor for
max-cache-hit, hint shipping, retries, speculation) is one code path.

Responsibilities:
  * wait queue + per-executor pending queues (max-cache-hit binds tasks to a
    busy executor and waits for it);
  * placement via policies.decide() against the loosely-coherent LocationIndex;
  * executor membership (join/leave/fail) with index invalidation and
    re-queueing of in-flight work  -> fault tolerance;
  * straggler speculation: duplicate the oldest running task when it exceeds
    ``speculation_factor x p95(completed durations)``; first copy wins;
  * byte/hit accounting handoff to metrics.

Scaling note (DESIGN.md §4): max-compute-util placement is *incremental*.
Hints for a queued task are resolved against the index once at enqueue time
and then kept coherent by the ``apply_index_updates`` / ``drop_executor``
hooks; an inverted ``executor -> {queued tid: cached-byte score}`` map makes
"which queued task does this freed executor cache the most of" an O(|tasks
that executor caches data for|) probe instead of an O(window x inputs)
index rescan; and the wait queue supports O(1) removal by tid via tombstones
instead of ``deque.remove``'s O(n) scan.

Multi-input (join) scoring (DESIGN.md §6): a task may read k inputs and an
executor may cache any subset of them, so a score is *bytes of this task's
inputs the executor caches* -- partial overlap counts, which is exactly
where data-aware dispatch wins (0808.3535): a 2-of-3-inputs overlap out-
scores a smaller full hit.  Byte-score ties break toward the higher overlap
*fraction* (cached bytes / total input bytes -- equivalently, same cached
bytes over fewer total bytes, i.e. less left to fetch), then toward the
earlier queue position.  ``reference_scores()`` is the retained brute-force
scorer the incremental maps must bit-match (tests/test_join_scoring.py).

DAG tasks (DESIGN.md §11): a task may declare producer ``deps``.  Tasks
with unmet deps are *held* outside the wait queue (so no dispatch path --
window scan, FIFO pop, or host lease -- can ever see them); the producer's
``task_finished`` releases them through the ordinary ``_enqueue`` path.
A released task's placement score covers its inputs PLUS everything its
producers created (``score_oids``), folded into the same cached-byte score
and tie-break chain, so downstream work lands where its inputs were just
written.  Dep-free tasks take the exact pre-DAG code path bit-identically.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Iterator, Optional

from .index import IndexUpdate, LocationIndex
from .objects import Task, TaskState
from .policies import Decision, DispatchPolicy, decide


class _QEntry:
    __slots__ = ("task", "pos", "alive")

    def __init__(self, task: Task, pos: int) -> None:
        self.task = task
        self.pos = pos
        self.alive = True


class TaskQueue:
    """FIFO wait queue with O(1) removal-by-tid.

    Removal marks the entry dead (a tombstone) instead of scanning the deque;
    dead entries are skipped on pop/iteration and compacted away once they
    outnumber live ones.  ``pos`` is a stable total order (appendleft counts
    down, append counts up) used for "earliest in queue" tie-breaks without
    walking the deque.
    """

    def __init__(self) -> None:
        self._dq: deque[_QEntry] = deque()
        self._by_tid: dict[str, _QEntry] = {}
        self._dead = 0
        self._front = 0   # next appendleft pos (counts down)
        self._back = 1    # next append pos (counts up)

    def append(self, t: Task) -> None:
        self._discard(t.tid)
        e = _QEntry(t, self._back)
        self._back += 1
        self._dq.append(e)
        self._by_tid[t.tid] = e

    def appendleft(self, t: Task) -> None:
        self._discard(t.tid)
        e = _QEntry(t, self._front)
        self._front -= 1
        self._dq.appendleft(e)
        self._by_tid[t.tid] = e

    def popleft(self) -> Task:
        while self._dq:
            e = self._dq.popleft()
            if e.alive:
                del self._by_tid[e.task.tid]
                return e.task
            self._dead -= 1
        raise IndexError("pop from empty TaskQueue")

    def remove(self, tid: str) -> bool:
        """Tombstone the entry for ``tid``; O(1) amortized."""
        if self._discard(tid):
            if self._dead > 64 and self._dead > len(self._by_tid):
                self._compact()
            return True
        return False

    def _discard(self, tid: str) -> bool:
        e = self._by_tid.pop(tid, None)
        if e is None:
            return False
        e.alive = False
        self._dead += 1
        return True

    def _compact(self) -> None:
        self._dq = deque(e for e in self._dq if e.alive)
        self._dead = 0

    def position(self, tid: str) -> int:
        return self._by_tid[tid].pos

    def first_live(self, n: int) -> list[Task]:
        out: list[Task] = []
        for e in self._dq:
            if e.alive:
                out.append(e.task)
                if len(out) >= n:
                    break
        return out

    def __contains__(self, tid: str) -> bool:
        return tid in self._by_tid

    def __iter__(self) -> Iterator[Task]:
        return (e.task for e in list(self._dq) if e.alive)

    def __len__(self) -> int:
        return len(self._by_tid)

    def __bool__(self) -> bool:
        return bool(self._by_tid)


@dataclass(slots=True)
class ExecutorState:
    eid: str
    alive: bool = True
    busy: int = 0                 # running task count
    slots: int = 1
    joined_at: float = 0.0
    last_busy_at: float = 0.0
    running: set[str] = field(default_factory=set)

    @property
    def available(self) -> bool:
        return self.alive and self.busy < self.slots


@dataclass(slots=True)
class Dispatch:
    task: Task
    executor: str
    hints: dict[str, tuple[str, ...]]
    speculative_of: Optional[str] = None


class Dispatcher:
    def __init__(
        self,
        policy: DispatchPolicy,
        index: Optional[LocationIndex] = None,
        speculation_factor: float = 0.0,  # 0 disables speculation
        min_completions_for_speculation: int = 10,
    ) -> None:
        self.policy = policy
        self.index = index if index is not None else LocationIndex()
        self.sizes: dict[str, int] = {}
        self.executors: dict[str, ExecutorState] = {}
        self._exec_order: list[str] = []          # arrival order (FIFO choice)
        self.queue: TaskQueue = TaskQueue()
        self.pending: dict[str, deque[Task]] = {} # max-cache-hit waits
        self.tasks: dict[str, Task] = {}
        self.completed: list[Task] = []
        self.failed: list[Task] = []
        self.durations: list[float] = []
        self.speculation_factor = speculation_factor
        self.min_completions_for_speculation = min_completions_for_speculation
        self._speculated: set[str] = set()        # tids with a live twin
        self._twins: dict[str, str] = {}          # twin tid -> original tid
        self._twin_of: dict[str, str] = {}        # original tid -> twin tid
        self.n_decisions = 0
        self.decision_lookups = 0
        # optional repro.obs.Recorder: the owning engine installs it; every
        # emission below is None-guarded so recording-off costs one attribute
        # load per lifecycle transition (never per queue scan).
        self.recorder = None
        # optional repro.obs.metrics.MetricsRegistry, same contract: the
        # owning engine installs it, hooks are None-guarded (DESIGN.md §13).
        self.metrics = None
        # ---- incremental max-compute-util placement state -----------------
        # tid -> oid -> executors known (per the loosely-coherent index) to
        # cache it; resolved once at enqueue, patched by index-update hooks.
        self._hint_cache: dict[str, dict[str, set[str]]] = {}
        # eid -> tid -> bytes of that queued task's inputs this executor
        # caches (the inverted map the freed-executor probe reads).
        self._exec_scores: dict[str, dict[str, int]] = {}
        # oid -> queued tids with oid among their inputs (update fan-out).
        self._oid_waiters: dict[str, set[str]] = {}
        # ---- DAG ready-set (DESIGN.md §11) --------------------------------
        # held tid -> producer tids still outstanding.  Held tasks are in
        # ``tasks`` but never in ``queue``/``pending``, so no dispatch or
        # lease path can reach them until every dep completes.
        self._held: dict[str, set[str]] = {}
        # producer tid -> held dependents in submission order (dict-as-
        # ordered-set: release order is deterministic across engines).
        self._dependents: dict[str, dict[str, None]] = {}
        # dependents terminally failed by a producer's failure, awaiting
        # pickup by the owning engine's accounting (drain_dep_failed).
        self._dep_failed: list[Task] = []
        # every oid some submitted task produces (for the outputs-ignored
        # baseline below; disjoint from the catalog by Workload validation).
        self._produced: set[str] = set()
        # benchmark baseline knob: when False, produced outputs are invisible
        # to placement (no hints resolved, no bytes scored) -- the "outputs-
        # ignored" dispatcher that bench_dags compares against.  Scoring is
        # unaffected either way for workloads whose inputs are all catalog
        # objects, i.e. every dep-free workload.
        self.score_outputs: bool = True

    @property
    def _mcu(self) -> bool:
        return self.policy is DispatchPolicy.MAX_COMPUTE_UTIL

    # ---------------- membership -------------------------------------------
    def executor_joined(self, eid: str, now: float, slots: int = 1) -> None:
        self.executors[eid] = ExecutorState(eid=eid, slots=slots, joined_at=now,
                                            last_busy_at=now)
        if eid not in self._exec_order:
            self._exec_order.append(eid)
        self.pending.setdefault(eid, deque())

    def executor_left(self, eid: str, now: float, failed: bool = False) -> list[Task]:
        """Remove an executor; returns tasks that must be re-dispatched."""
        st = self.executors.get(eid)
        if st is None:
            return []
        st.alive = False
        self._exec_order = [e for e in self._exec_order if e != eid]
        self.index.drop_executor(eid)
        self._drop_executor_hints(eid)
        requeue: list[Task] = []
        for tid in list(st.running):
            t = self.tasks.get(tid)
            if t is not None and t.state not in (TaskState.DONE, TaskState.FAILED):
                t.attempts += 1
                if t.attempts >= t.max_attempts:
                    t.state = TaskState.FAILED
                    self.failed.append(t)
                    self._fail_dependents(t.tid)
                else:
                    t.reset_for_retry()
                    requeue.append(t)
        st.running.clear()
        st.busy = 0
        # re-home pending (max-cache-hit) tasks bound to the dead executor
        for t in self.pending.pop(eid, deque()):
            t.state = TaskState.SUBMITTED
            requeue.append(t)
        del self.executors[eid]
        rec = self.recorder
        for t in requeue:
            if rec is not None:
                rec.emit("task_requeued", tid=t.tid, eid=eid,
                         reason="executor_left")
            self._enqueue(t, front=True)
        return requeue

    # ---------------- submission -------------------------------------------
    def submit(self, tasks: Iterable[Task], now: float) -> int:
        n = 0
        rec = self.recorder
        for t in tasks:
            t.submit_time = now
            t.state = TaskState.SUBMITTED
            self.tasks[t.tid] = t
            for ob in t.outputs:
                self.sizes[ob.oid] = ob.size_bytes
                self._produced.add(ob.oid)
            if rec is not None:
                rec.emit("task_arrived", tid=t.tid)
            n += 1
            if t.deps and self._hold_if_unready(t, rec):
                continue
            t.ready_time = now
            self._enqueue(t)
        if n and self.metrics is not None:
            self.metrics.inc("sched.tasks_submitted", n)
        return n

    # ---------------- DAG ready-set (DESIGN.md §11) -------------------------
    def _hold_if_unready(self, t: Task, rec) -> bool:
        """Hold ``t`` until its producers complete.  Returns True if held
        (or failed because a producer already terminally failed)."""
        unmet: set[str] = set()
        for d in t.deps:
            p = self.tasks.get(d)
            if p is not None and p.state is TaskState.DONE:
                continue
            if p is not None and p.state is TaskState.FAILED:
                t.state = TaskState.FAILED
                self.failed.append(t)
                self._dep_failed.append(t)
                if rec is not None:
                    rec.emit("task_failed", tid=t.tid, reason="dep_failed",
                             dep=d)
                return True
            unmet.add(d)
        if not unmet:
            return False
        self._held[t.tid] = unmet
        for d in unmet:
            self._dependents.setdefault(d, {})[t.tid] = None
        if rec is not None:
            rec.emit("task_held", tid=t.tid, n_deps=len(unmet))
        return True

    def _release_dependents(self, tid: str, now: float) -> None:
        """A producer completed: enqueue every held dependent whose last
        unmet dep this was.  Runs inside ``task_finished``, i.e. after the
        producer's outputs were admitted/indexed by the engine, so the
        released task's enqueue-time hint resolution sees them."""
        deps = self._dependents.pop(tid, None)
        if not deps:
            return
        rec = self.recorder
        for dtid in deps:
            unmet = self._held.get(dtid)
            if unmet is None:
                continue            # stale entry (already failed elsewhere)
            unmet.discard(tid)
            if unmet:
                continue
            del self._held[dtid]
            dt = self.tasks[dtid]
            dt.ready_time = now
            if rec is not None:
                rec.emit("task_ready", tid=dtid)
            self._enqueue(dt)

    def _fail_dependents(self, tid: str) -> None:
        """A producer terminally failed: its held dependents (transitively)
        can never run -- fail them now so engines don't wait forever."""
        rec = self.recorder
        stack = [tid]
        while stack:
            cur = stack.pop()
            for dtid in self._dependents.pop(cur, ()):
                if self._held.pop(dtid, None) is None:
                    continue
                dt = self.tasks[dtid]
                dt.state = TaskState.FAILED
                self.failed.append(dt)
                self._dep_failed.append(dt)
                if rec is not None:
                    rec.emit("task_failed", tid=dtid, reason="dep_failed",
                             dep=cur)
                stack.append(dtid)

    def drain_dep_failed(self) -> list[Task]:
        """Tasks terminally failed by producer failure since the last call
        (never dispatched, so the owning runtime must account them)."""
        out, self._dep_failed = self._dep_failed, []
        return out

    def register_objects(self, objs) -> None:
        for ob in objs:
            self.sizes[ob.oid] = ob.size_bytes

    # ---------------- incremental hint maintenance --------------------------
    def _enqueue(self, t: Task, front: bool = False) -> None:
        if self.recorder is not None:
            self.recorder.emit("task_queued", tid=t.tid, front=front)
        if front:
            self.queue.appendleft(t)
        else:
            self.queue.append(t)
        if self._mcu:
            self._hints_resolve(t)

    def score_oids(self, t: Task) -> tuple[str, ...]:
        """Oids whose cached placement should attract this task: its inputs
        plus -- for DAG tasks -- every output its producers created (the
        producer-placement term; dep-free tasks return ``inputs`` as-is)."""
        if not t.deps:
            return t.inputs
        seen = dict.fromkeys(t.inputs)
        for d in t.deps:
            p = self.tasks.get(d)
            if p is not None:
                for ob in p.outputs:
                    seen.setdefault(ob.oid, None)
        return tuple(seen)

    def _hint_oids(self, t: Task) -> tuple[str, ...]:
        """``score_oids`` minus produced outputs when the outputs-ignored
        baseline is active.  MUST be used symmetrically by resolve/drop."""
        oids = self.score_oids(t)
        if not self.score_outputs:
            oids = tuple(o for o in oids if o not in self._produced)
        return oids

    def _hints_resolve(self, t: Task) -> None:
        """One index resolution at enqueue; hooks keep it coherent after."""
        hints: dict[str, set[str]] = {}
        touched: set[str] = set()
        oids = self._hint_oids(t)
        for oid in oids:
            self._oid_waiters.setdefault(oid, set()).add(t.tid)
            locs = self.index.lookup(oid)
            if locs:
                hints[oid] = set(locs)
                touched |= locs
        self.decision_lookups += len(oids)
        self._hint_cache[t.tid] = hints
        for eid in touched:
            self._rescore(t.tid, eid)

    def _hints_drop(self, t: Task) -> dict[str, set[str]]:
        """Forget a task leaving the wait queue; returns its final hints."""
        hints = self._hint_cache.pop(t.tid, None) or {}
        for oid in self._hint_oids(t):
            waiters = self._oid_waiters.get(oid)
            if waiters is not None:
                waiters.discard(t.tid)
                if not waiters:
                    del self._oid_waiters[oid]
        for eid in {e for locs in hints.values() for e in locs}:
            scores = self._exec_scores.get(eid)
            if scores is not None:
                scores.pop(t.tid, None)
        return hints

    def _rescore(self, tid: str, eid: str) -> None:
        """Recompute one (executor, queued task) cached-byte score exactly."""
        hints = self._hint_cache.get(tid)
        if hints is None:
            return
        score = sum(self.sizes.get(oid, 1)
                    for oid, locs in hints.items() if eid in locs)
        scores = self._exec_scores.setdefault(eid, {})
        if score > 0:
            scores[tid] = score
        else:
            scores.pop(tid, None)

    def _drop_executor_hints(self, eid: str) -> None:
        for tid in self._exec_scores.pop(eid, {}):
            hints = self._hint_cache.get(tid)
            if hints is None:
                continue
            for oid in list(hints):
                hints[oid].discard(eid)
                if not hints[oid]:
                    del hints[oid]

    def _hints_tuple(self, hints: dict[str, set[str]]) -> dict[str, tuple[str, ...]]:
        return {oid: tuple(sorted(locs)) for oid, locs in hints.items() if locs}

    def invalidate_executor(self, eid: str) -> None:
        """Drop every index entry (and its hint-cache shadow) for ``eid``
        without removing the executor -- e.g. its cache was cleared."""
        self.index.drop_executor(eid)
        self._drop_executor_hints(eid)

    # ---------------- index coherence ---------------------------------------
    def apply_index_updates(self, updates: Iterable[IndexUpdate]) -> None:
        if not self._mcu:
            self.index.apply_batch(updates)
            return
        for u in updates:
            self.index.apply(u)
            eid = u.executor
            dirty: set[str] = set()
            for oid in u.added:
                for tid in self._oid_waiters.get(oid, ()):
                    locs = self._hint_cache[tid].setdefault(oid, set())
                    if eid not in locs:
                        locs.add(eid)
                        dirty.add(tid)
            for oid in u.removed:
                for tid in self._oid_waiters.get(oid, ()):
                    hints = self._hint_cache[tid]
                    locs = hints.get(oid)
                    if locs and eid in locs:
                        locs.discard(eid)
                        if not locs:
                            del hints[oid]
                        dirty.add(tid)
            for tid in dirty:
                self._rescore(tid, eid)

    # ---------------- placement --------------------------------------------
    def _avail_busy(self) -> tuple[list[str], list[str]]:
        avail = [e for e in self._exec_order if self.executors[e].available]
        busy = [e for e in self._exec_order
                if self.executors[e].alive and not self.executors[e].available]
        return avail, busy

    #: how deep into the wait queue max-compute-util searches for a task
    #: matching a freed executor's cache.  Falkon's data-aware dispatcher
    #: examines queued tasks to "send tasks to nodes that have cached the
    #: most needed data" (§3.2.1); a bounded window keeps decisions O(W).
    queue_window: int = 256

    def next_dispatches(self, now: float) -> list[Dispatch]:
        """Pop as many placeable tasks as possible (engine applies them)."""
        out: list[Dispatch] = []
        # 1) pending queues of executors that became available
        for eid, dq in self.pending.items():
            st = self.executors.get(eid)
            while dq and st is not None and st.available:
                out.append(self._bind(dq.popleft(), eid, now))
        if not self.queue:
            return out
        if self._mcu:
            out.extend(self._dispatch_mcu(now))
        else:
            out.extend(self._dispatch_fifo(now))
        return out

    def _dispatch_fifo(self, now: float) -> list[Dispatch]:
        """Head-of-queue placement (FA / NA / FCA / MCH semantics)."""
        out: list[Dispatch] = []
        deferred: list[Task] = []
        progressed = True
        while progressed and self.queue:
            progressed = False
            avail, busy = self._avail_busy()
            if not avail and self.policy is not DispatchPolicy.MAX_CACHE_HIT:
                break
            t = self.queue.popleft()
            d = decide(self.policy, t, avail, busy, self.index, self.sizes)
            self.n_decisions += 1
            self.decision_lookups += len(t.inputs) if self.policy.ships_hints else 0
            if d.executor is not None:
                t.location_hints = d.hints
                out.append(self._bind(t, d.executor, now))
                progressed = True
            elif d.wait_for is not None:
                t.state = TaskState.PENDING
                t.location_hints = d.hints
                self.pending.setdefault(d.wait_for, deque()).append(t)
                progressed = True
            else:
                deferred.append(t)
        for t in reversed(deferred):
            self.queue.appendleft(t)
        return out

    def input_bytes_total(self, tid: str) -> int:
        """Total bytes of a task's (distinct) scored oids, late-size aware --
        the overlap-fraction denominator (same size default as _rescore).
        For dep-free tasks this is exactly the distinct-input byte total."""
        ins = self._hint_oids(self.tasks[tid])
        if len(ins) == 1:               # classic single-input fast path
            return self.sizes.get(ins[0], 1)
        return sum(self.sizes.get(oid, 1) for oid in dict.fromkeys(ins))

    def reference_scores(self) -> dict[str, dict[str, int]]:
        """Brute-force reference for the incremental ``_exec_scores`` maps.

        Rebuilds executor -> {queued tid: cached input bytes} from scratch
        with fresh index lookups over every live queued task.  The
        incremental maps must equal this exactly at any quiescent point
        (``scores_match_reference``); kept as the correctness oracle for
        tests/test_join_scoring.py and benchmarks/bench_joins.py, the same
        way transport.py retains its naive flow solver."""
        ref: dict[str, dict[str, int]] = {}
        for t in self.queue:
            for oid in dict.fromkeys(self._hint_oids(t)):
                sz = self.sizes.get(oid, 1)
                for eid in self.index.lookup(oid):
                    if eid in self.executors:
                        scores = ref.setdefault(eid, {})
                        scores[t.tid] = scores.get(t.tid, 0) + sz
        return ref

    def scores_match_reference(self) -> bool:
        """Bit-exact equality of the incremental maps vs reference_scores()."""
        live = {eid: dict(s) for eid, s in self._exec_scores.items() if s}
        return live == self.reference_scores()

    def _dispatch_mcu(self, now: float) -> list[Dispatch]:
        """max-compute-util: for each available executor, pick the queued
        task (within the window) whose inputs it caches the most bytes of --
        read straight off the inverted score map -- falling back to the
        queue head when nothing matches.  Byte ties prefer the higher
        overlap fraction (= smaller input total for equal cached bytes),
        then the earlier queue position."""
        out: list[Dispatch] = []
        while self.queue:
            avail, _ = self._avail_busy()
            if not avail:
                break
            window = self.queue.first_live(self.queue_window)
            if not window:
                break
            window_tids = {t.tid for t in window}
            self.n_decisions += 1
            bound_any = False
            taken: set[str] = set()
            for eid in avail:
                best_tid: Optional[str] = None
                best_score, best_pos, best_total = 0, 0, -1
                for tid, score in self._exec_scores.get(eid, {}).items():
                    if score < best_score or tid in taken \
                            or tid not in window_tids:
                        continue
                    if score > best_score:
                        best_tid, best_score = tid, score
                        best_pos = self.queue.position(tid)
                        best_total = -1          # lazily filled on first tie
                        continue
                    # equal cached bytes: fraction score/total is larger for
                    # the smaller total (exact int compare, no division);
                    # equal totals fall back to queue order
                    if best_total < 0:
                        best_total = self.input_bytes_total(best_tid)
                    total = self.input_bytes_total(tid)
                    pos = self.queue.position(tid)
                    if total < best_total \
                            or (total == best_total and pos < best_pos):
                        best_tid, best_pos, best_total = tid, pos, total
                if best_tid is None:
                    # nothing cached for this executor: take earliest unclaimed
                    t = next((w for w in window if w.tid not in taken), None)
                    if t is None:
                        break
                else:
                    t = self.tasks[best_tid]
                taken.add(t.tid)
                self.queue.remove(t.tid)
                t.location_hints = self._hints_tuple(self._hints_drop(t))
                out.append(self._bind(t, eid, now))
                bound_any = True
            if not bound_any:
                break
        return out

    # ---------------- hierarchical lease / claim (DESIGN.md §9) --------------
    def lease_next(self) -> Optional[Task]:
        """Pop the head-of-queue live task for leasing to a host-local
        dispatcher.  The task leaves the wait queue (and the incremental
        hint maps, keeping its resolved hints as ``location_hints``) but
        is NOT bound to an executor -- the owning runtime parks it in a
        per-host lease table until a claim arrives or the host dies."""
        if not self.queue:
            return None
        t = self.queue.popleft()
        if self._mcu:
            t.location_hints = self._hints_tuple(self._hints_drop(t))
        t.state = TaskState.PENDING
        if self.recorder is not None:
            self.recorder.emit("task_leased", tid=t.tid)
        return t

    def bind_claim(self, t: Task, eid: str, now: float) -> Dispatch:
        """Reconcile a host's local claim: bind the leased task to the
        claiming executor.  A claim may transiently over-commit ``busy``
        past ``slots`` (the host has already started the attempt);
        ``task_finished`` decrements through the normal path."""
        self.n_decisions += 1
        if self.recorder is not None:
            self.recorder.emit("task_claimed", tid=t.tid, eid=eid)
        return self._bind(t, eid, now)

    def requeue_leased(self, tasks: Iterable[Task]) -> None:
        """Return unclaimed leased tasks (their host died or was removed)
        to the FRONT of the wait queue in their original lease order.
        They were never dispatched, so no attempt is charged."""
        rec = self.recorder
        for t in reversed(list(tasks)):
            t.state = TaskState.SUBMITTED
            if rec is not None:
                rec.emit("task_requeued", tid=t.tid, reason="lease_returned")
            self._enqueue(t, front=True)

    def _bind(self, t: Task, eid: str, now: float) -> Dispatch:
        st = self.executors[eid]
        st.busy += 1
        st.running.add(t.tid)
        st.last_busy_at = now
        t.state = TaskState.DISPATCHED
        t.executor = eid
        t.dispatch_time = now
        if self.recorder is not None:
            self.recorder.emit("task_dispatched", tid=t.tid, eid=eid)
        return Dispatch(task=t, executor=eid, hints=t.location_hints)

    # ---------------- completion -------------------------------------------
    def task_finished(self, t: Task, now: float, ok: bool = True) -> Optional[str]:
        """Returns the tid of a twin to cancel, if this was a speculated task."""
        eid = t.executor
        st = self.executors.get(eid) if eid else None
        if st is not None:
            st.busy = max(st.busy - 1, 0)
            st.running.discard(t.tid)
            st.last_busy_at = now
        cancel: Optional[str] = None
        orig_tid = self._twins.pop(t.tid, None)
        rec = self.recorder
        if ok:
            t.state = TaskState.DONE
            t.end_time = now
            if rec is not None:
                rec.emit("task_done", tid=t.tid, eid=eid)
            self.durations.append(now - t.dispatch_time)
            if orig_tid is not None:
                # a speculative twin won; cancel the original
                cancel = orig_tid
                self._speculated.discard(orig_tid)
                self._twin_of.pop(orig_tid, None)
                orig = self.tasks.get(orig_tid)
                if orig is not None and orig.state not in (TaskState.DONE,):
                    orig.state = TaskState.DONE  # satisfied by twin
                self._release_dependents(orig_tid, now)
            elif t.tid in self._speculated:
                # original won; cancel its twin (reverse map, not an O(n) scan)
                twin_tid = self._twin_of.pop(t.tid, None)
                if twin_tid:
                    cancel = twin_tid
                    del self._twins[twin_tid]
                self._speculated.discard(t.tid)
            self.completed.append(t)
            self._release_dependents(t.tid, now)
            if self.metrics is not None:
                self.metrics.inc("sched.tasks_completed")
        else:
            if orig_tid is not None:
                self._twins[t.tid] = orig_tid  # still a live twin; retry below
            t.attempts += 1
            if t.attempts >= t.max_attempts:
                t.state = TaskState.FAILED
                self.failed.append(t)
                if rec is not None:
                    rec.emit("task_failed", tid=t.tid, eid=eid,
                             attempts=t.attempts)
                if self.metrics is not None:
                    self.metrics.inc("sched.tasks_failed")
                self._fail_dependents(t.tid)
                if orig_tid is not None:
                    self._twins.pop(t.tid, None)
                    self._twin_of.pop(orig_tid, None)
                    self._speculated.discard(orig_tid)
            else:
                t.reset_for_retry()
                if rec is not None:
                    rec.emit("task_requeued", tid=t.tid, eid=eid,
                             reason="retry")
                self._enqueue(t, front=True)
        if cancel is not None and cancel in self.queue:
            # the losing copy never left the wait queue: dequeue it now so it
            # is not pointlessly executed (and double-counted) later
            ct = self.tasks.get(cancel)
            self.queue.remove(cancel)
            if ct is not None:
                if self._mcu:
                    self._hints_drop(ct)
                ct.state = TaskState.DONE   # satisfied by the winning copy
        return cancel

    # ---------------- speculation -------------------------------------------
    def speculation_candidates(self, now: float) -> list[Task]:
        if (self.speculation_factor <= 0
                or len(self.durations) < self.min_completions_for_speculation):
            return []
        ds = sorted(self.durations)
        p95 = ds[min(int(0.95 * len(ds)), len(ds) - 1)]
        threshold = self.speculation_factor * max(p95, 1e-9)
        out = []
        for st in self.executors.values():
            for tid in st.running:
                t = self.tasks[tid]
                if (t.state is TaskState.RUNNING or t.state is TaskState.DISPATCHED) \
                        and t.tid not in self._speculated \
                        and t.tid not in self._twins \
                        and now - t.dispatch_time > threshold:
                    out.append(t)
        return out

    def make_twin(self, t: Task, now: float) -> Task:
        twin = Task(inputs=t.inputs, outputs=t.outputs,
                    compute_seconds=t.compute_seconds, fn=t.fn,
                    store_metadata_ops=t.store_metadata_ops, tag=t.tag)
        twin.submit_time = now
        self.tasks[twin.tid] = twin
        self._speculated.add(t.tid)
        self._twins[twin.tid] = t.tid
        self._twin_of[t.tid] = twin.tid
        self._enqueue(twin, front=True)
        return twin

    def twin_of(self, tid: str) -> Optional[str]:
        """tid of the live speculative twin of ``tid``, if any (O(1))."""
        return self._twin_of.get(tid)

    # ---------------- introspection -----------------------------------------
    @property
    def queue_len(self) -> int:
        """Runnable backlog (held dep-waiters are NOT demand: adding
        executors cannot serve them, so the provisioner must not see them)."""
        return len(self.queue) + sum(len(q) for q in self.pending.values())

    @property
    def held_len(self) -> int:
        """Tasks held on unmet deps (outside every dispatch path)."""
        return len(self._held)

    def idle_executors(self, now: float, idle_for_s: float) -> list[str]:
        return [
            st.eid for st in self.executors.values()
            if st.alive and st.busy == 0 and now - st.last_busy_at >= idle_for_s
        ]

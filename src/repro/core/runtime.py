"""Real threaded data-diffusion runtime.

Drives the *same* Dispatcher / policies / ExecutorCache / LocationIndex as
the simulator, but executors are worker threads running real Python
callables, and objects carry real payloads (numpy arrays / bytes) held in
per-executor in-memory caches -- this is the engine behind the training data
pipeline (repro.data.pipeline) and the serving router.

Here executors are threads and a peer fetch is a memcpy plus a byte-ledger
entry, so scheduling behaviour (placement, hit ratios, byte ledgers --
everything the paper evaluates) stays identical while runnable in one
process.  The `repro.core.channel.Channel` abstraction marks exactly the
two seams (task dispatch down to each worker, index updates / completions
back up) that become RPCs on a real fleet: every dispatch goes through the
worker's dispatch channel (`ExecutorWorker.dispatch`) and every cache
admission through the runtime's ``update_channel``.  `repro.fleet` swaps
these in-process channels for socket-backed ones and runs the same
dispatcher over executors in other OS processes.

Submission is closed-loop (``submit``) or open-loop (``submit_workload``: a
paced submitter thread replays a ``repro.workloads`` arrival schedule on the
wall clock, optionally time-scaled, or -- with ``barrier_every`` -- in
deterministic batch-synchronous rounds).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional

from .cache import EvictionPolicy, ExecutorCache
from .channel import CallbackChannel, Channel, ChannelClosed, LocalChannel
from .index import IndexUpdate
from .objects import DataObject, Task, TaskState
from .policies import DispatchPolicy
from .scheduler import Dispatcher, Dispatch

#: store payload for shape-only runs (tasks with no ``fn``).  Must NOT be
#: None -- the cache-hit test is ``payload is not None``, so a None payload
#: would turn every cache lookup into a store read.  Lives here (not in the
#: experiment layer) so the fleet wire protocol can give it a stable
#: encoding: byte accounting uses DataObject sizes, never payload length.
SHAPE_ONLY_PAYLOAD = object()


class ObjectStore:
    """Persistent-store stand-in: oid -> payload (immutable after put)."""

    def __init__(self) -> None:
        self._data: dict[str, Any] = {}
        self._meta: dict[str, DataObject] = {}
        self._lock = threading.Lock()
        self.reads = 0
        self.bytes_read = 0

    def put(self, obj: DataObject, payload: Any) -> None:
        with self._lock:
            if obj.oid in self._data:
                raise ValueError(f"object {obj.oid} is immutable (already stored)")
            self._data[obj.oid] = payload
            self._meta[obj.oid] = obj

    def get(self, oid: str) -> tuple[DataObject, Any]:
        with self._lock:
            self.reads += 1
            self.bytes_read += self._meta[oid].size_bytes
            return self._meta[oid], self._data[oid]

    def meta(self, oid: str) -> DataObject:
        return self._meta[oid]

    def items(self) -> list[tuple[DataObject, Any]]:
        """Consistent snapshot of the catalog (fleet hosts replicate it --
        the store stands in for a shared filesystem every node can read)."""
        with self._lock:
            return [(self._meta[oid], self._data[oid]) for oid in self._data]

    def __contains__(self, oid: str) -> bool:
        return oid in self._data


@dataclass
class _InputLedger:
    """Per-attempt input accounting, merged into the Task under the runtime
    lock once the attempt is known to still count (see _execute)."""

    bytes_local: int = 0
    bytes_cache_to_cache: int = 0
    bytes_store: int = 0
    cache_hits: int = 0
    peer_hits: int = 0
    cache_misses: int = 0

    def merge_into(self, t: Task) -> None:
        t.bytes_local += self.bytes_local
        t.bytes_cache_to_cache += self.bytes_cache_to_cache
        t.bytes_store += self.bytes_store
        t.cache_hits += self.cache_hits
        t.peer_hits += self.peer_hits
        t.cache_misses += self.cache_misses


@dataclass
class DispatchStats:
    """Lightweight counters/timers on the central dispatch loop.

    Mutated only under the runtime lock (or folded in from per-connection
    wire counters at read time on the fleet), surfaced through
    ``RunReport.dispatch_stats`` and the dispatch benchmark.  ``lock_hold_s``
    accumulates time spent inside the runtime lock on the pump path -- the
    quantity the central-dispatcher bottleneck (Falkon's ~1k tasks/s wall)
    is made of."""

    pump_calls: int = 0
    dispatch_batches: int = 0      # pumps that produced >= 1 dispatch
    dispatches: int = 0
    max_dispatch_batch: int = 0
    updates_applied: int = 0
    lock_hold_s: float = 0.0
    frames_sent: int = 0           # wire frames (fleet); 0 in-process
    frames_recv: int = 0
    msgs_sent: int = 0             # logical messages inside those frames
    msgs_recv: int = 0
    leases: int = 0                # tasks leased to hosts (hierarchical)
    claims: int = 0                # claims accepted by the central
    claim_conflicts: int = 0       # claims rejected (dead host / reclaim)

    def as_dict(self) -> dict:
        return {
            "pump_calls": self.pump_calls,
            "dispatch_batches": self.dispatch_batches,
            "dispatches": self.dispatches,
            "max_dispatch_batch": self.max_dispatch_batch,
            "updates_applied": self.updates_applied,
            "lock_hold_s": self.lock_hold_s,
            "frames_sent": self.frames_sent,
            "frames_recv": self.frames_recv,
            "msgs_sent": self.msgs_sent,
            "msgs_recv": self.msgs_recv,
            "leases": self.leases,
            "claims": self.claims,
            "claim_conflicts": self.claim_conflicts,
        }


@dataclass
class RuntimeLedger:
    lock: threading.Lock = field(default_factory=threading.Lock)
    bytes_local: int = 0
    bytes_c2c: int = 0
    bytes_store: int = 0
    local_hits: int = 0
    peer_hits: int = 0
    store_reads: int = 0

    def account(self, kind: str, n: int) -> None:
        with self.lock:
            if kind == "local":
                self.bytes_local += n
                self.local_hits += 1
            elif kind == "c2c":
                self.bytes_c2c += n
                self.peer_hits += 1
            else:
                self.bytes_store += n
                self.store_reads += 1

    def account_attempt(self, acc: "_InputLedger") -> None:
        """Fold one *counted* attempt's per-input ledger in atomically.
        Store-read occurrences are ``cache_misses - peer_hits`` (a miss is
        served either cache-to-cache or from the store)."""
        with self.lock:
            self.bytes_local += acc.bytes_local
            self.bytes_c2c += acc.bytes_cache_to_cache
            self.bytes_store += acc.bytes_store
            self.local_hits += acc.cache_hits
            self.peer_hits += acc.peer_hits
            self.store_reads += acc.cache_misses - acc.peer_hits

    @property
    def global_hit_ratio(self) -> float:
        n = self.local_hits + self.peer_hits + self.store_reads
        return (self.local_hits + self.peer_hits) / n if n else 0.0

    @property
    def local_hit_ratio(self) -> float:
        n = self.local_hits + self.peer_hits + self.store_reads
        return self.local_hits / n if n else 0.0


class CacheExecutorBase:
    """Executor-local payload cache + dispatch inbox Channel -- the parts
    of an executor that are identical whether it lives in this process
    (:class:`ExecutorWorker`) or inside a fleet host process
    (``repro.fleet.host.HostExecutor``).  ONE implementation of
    lookup/peek/admit semantics, so the two runtimes the fleet's
    trace-replay parity canary compares cannot silently drift."""

    def __init__(self, eid: str, cache_capacity: int,
                 policy: EvictionPolicy, seed: int) -> None:
        self.eid = eid
        self.cache = ExecutorCache(cache_capacity, policy, seed=seed)
        self.payloads: dict[str, Any] = {}
        self.lock = threading.Lock()
        self.inbox: Channel = LocalChannel()
        self.alive = True

    def stop(self) -> None:
        self.alive = False
        self.inbox.close()

    # -- cache ops (thread-safe) ---------------------------------------------
    def cache_lookup(self, oid: str) -> Optional[Any]:
        with self.lock:
            if self.cache.get(oid):
                return self.payloads[oid]
        return None

    def cache_peek(self, oid: str) -> Optional[Any]:
        """Peer-side read: no recency update on the *owner's* policy state
        (the paper's peer reads go through GridFTP, not the local app)."""
        with self.lock:
            if oid in self.cache:
                return self.payloads[oid]
        return None

    def cache_admit(self, obj: DataObject,
                    payload: Any) -> tuple[tuple[str, ...], tuple[str, ...]]:
        """Admit one object; returns ``(added, removed)`` oid tuples (the
        payload of an IndexUpdate, transport-agnostic)."""
        with self.lock:
            evicted = self.cache.put(obj)
            if obj.oid in self.cache:
                self.payloads[obj.oid] = payload
            for oid in evicted:
                self.payloads.pop(oid, None)
            return (obj.oid,), tuple(evicted)


class ExecutorWorker(CacheExecutorBase):
    """A worker thread with a local payload cache.

    Receives work exclusively through its dispatch :class:`Channel`
    (``dispatch()`` is the only way the runtime hands it a task), so the
    executor side of the dispatch seam is already message-shaped -- the
    fleet's remote executors implement the same ``dispatch``/``stop``
    surface over a socket."""

    def __init__(self, eid: str, rt: "DiffusionRuntime",
                 cache_capacity: int, policy: EvictionPolicy, seed: int) -> None:
        super().__init__(eid, cache_capacity, policy, seed)
        self.rt = rt
        self.thread = threading.Thread(target=self._run, daemon=True,
                                       name=f"executor-{eid}")

    def start(self) -> None:
        self.thread.start()

    def dispatch(self, disp: Dispatch) -> None:
        """Dispatch-seam entry point (dispatcher -> this executor)."""
        try:
            self.inbox.send(disp)
        except ChannelClosed:
            pass   # racing a stop(); the membership guard already dropped us

    def admit_update(self, obj: DataObject, payload: Any) -> IndexUpdate:
        added, removed = self.cache_admit(obj, payload)
        return IndexUpdate(self.eid, added=added, removed=removed)

    # -- task loop --------------------------------------------------------------
    def _run(self) -> None:
        while self.alive:
            try:
                disp = self.inbox.recv()
            except ChannelClosed:
                return
            self.rt._execute(self, disp)


class DiffusionRuntime:
    """In-process multi-executor diffusion runtime."""

    def __init__(
        self,
        n_executors: int,
        policy: DispatchPolicy = DispatchPolicy.MAX_COMPUTE_UTIL,
        cache_policy: EvictionPolicy = EvictionPolicy.LRU,
        cache_capacity_bytes: int = 1 << 30,
        store: Optional[ObjectStore] = None,
        seed: int = 0,
        index_update_batch: int = 1,   # >1 demonstrates loose coherence
        recorder=None,                 # optional repro.obs.Recorder
        metrics=None,                  # optional repro.obs.metrics.Telemetry
    ) -> None:
        self.store = store if store is not None else ObjectStore()
        self.dispatcher = Dispatcher(policy)
        # lifecycle observability (repro.obs): None = recording off, and
        # every hot-path hook below is a None-guard -- off-by-default free.
        self.recorder = recorder
        self.dispatcher.recorder = recorder
        # live telemetry plane (repro.obs.metrics, DESIGN.md §13): the
        # ``metrics`` kwarg carries the whole Telemetry bundle (registry +
        # sampling interval + sink + health); hot paths only ever touch the
        # registry, through the same None-guard contract as the recorder.
        self.telemetry = metrics
        self.metrics = metrics.registry if metrics is not None else None
        self.dispatcher.metrics = self.metrics
        self.ledger = RuntimeLedger()
        self.stats = DispatchStats()
        self.workers: dict[str, ExecutorWorker] = {}
        # the update seam: executors send IndexUpdates here; in process the
        # channel is a synchronous callback into the (locked) batcher.  The
        # fleet's hosts send the same records over a socket instead.
        self.update_channel: Channel = CallbackChannel(self._on_update)
        self._lock = threading.Lock()
        self._done = threading.Condition(self._lock)
        self._outstanding = 0
        self._update_buf: list[IndexUpdate] = []
        self._update_batch = max(index_update_batch, 1)
        self._stop_pacing = threading.Event()
        self._seed = seed
        self._next_worker_id = 0
        # store the cache shape BEFORE spawning workers: historically these
        # ctor kwargs were never persisted, so _cache_capacity()/
        # _cache_policy() fell back to their getattr defaults (1 GiB LRU)
        # and only configure_caches() could change worker caches -- every
        # caller's ctor cache args were silently dead
        self._cap = cache_capacity_bytes
        self._cpol = cache_policy
        # membership log mirroring DiffusionSim.pool_log: (seconds since
        # construction, live workers) per change -- the experiment layer's
        # RunReport reads pool history from the same-shaped field on both
        # engines.
        self._t0 = time.monotonic()
        self.pool_log: list[tuple[float, int]] = []
        for i in range(n_executors):
            self.add_executor()
        # collapse the construction ramp into one t=0 sample (mirrors
        # DiffusionSim logging its initial pool once, after all adds)
        self.pool_log = [(0.0, len(self.workers))]

    # -- membership ----------------------------------------------------------------
    def add_executor(self) -> str:
        with self._lock:
            # monotonic ids: len(workers) would reuse a live eid after a
            # removal and silently overwrite that worker (losing its task)
            wid = self._next_worker_id
            self._next_worker_id += 1
            eid = f"w{wid}"
            w = ExecutorWorker(eid, self,
                               cache_capacity=self._cache_capacity(),
                               policy=self._cache_policy(),
                               seed=self._seed + wid)
            self.workers[eid] = w
            self.dispatcher.executor_joined(eid, time.monotonic())
            self.pool_log.append((time.monotonic() - self._t0,
                                  len(self.workers)))
            if self.recorder is not None:
                self.recorder.emit("pool", eid=eid, size=len(self.workers),
                                   delta=1)
        w.start()
        return eid

    def _cache_capacity(self) -> int:
        return self._cap

    def _cache_policy(self) -> EvictionPolicy:
        return self._cpol

    def configure_caches(self, capacity_bytes: int, policy: EvictionPolicy) -> None:
        self._cap = capacity_bytes
        self._cpol = policy
        with self._lock:
            self._update_buf = []   # drop updates for caches we just cleared
            for w in self.workers.values():
                w.cache = ExecutorCache(capacity_bytes, policy)
                w.payloads.clear()
                # the index (and the dispatcher's queued-task hint cache)
                # must forget the cleared contents
                self.dispatcher.invalidate_executor(w.eid)

    def remove_executor(self, eid: str, failed: bool = False) -> None:
        with self._lock:
            w = self.workers.pop(eid, None)
            if w is None:
                return
            self.pool_log.append((time.monotonic() - self._t0,
                                  len(self.workers)))
            if self.recorder is not None:
                self.recorder.emit("pool", eid=eid, size=len(self.workers),
                                   delta=-1)
            self._deregister_locked(eid, failed)
        w.stop()
        self._pump()

    def _deregister_locked(self, eid: str, failed: bool) -> None:
        """Hand a (popped) executor back to the dispatcher, under the lock.
        Shared by thread removal and fleet host death -- both must account
        terminally-failed in-flight tasks or ``wait()`` leaks."""
        st = self.dispatcher.executors.get(eid)
        running = set(st.running) if st is not None else set()
        self.dispatcher.executor_left(eid, time.monotonic(), failed=failed)
        # in-flight completions from the dead executor are dropped by the
        # membership guard in _finish_attempt.  Re-queued retries keep their
        # outstanding count, but a task whose attempts were exhausted by
        # executor_left is terminally FAILED and will never complete --
        # account it here or wait() leaks forever.
        terminal = sum(
            1 for tid in running
            if (t := self.dispatcher.tasks.get(tid)) is not None
            and t.state is TaskState.FAILED)
        # a producer failed out above may have cascade-failed held
        # dependents (never dispatched): account them here too
        terminal += len(self.dispatcher.drain_dep_failed())
        if terminal:
            self._outstanding -= terminal
            if self._outstanding == 0:
                self._done.notify_all()

    # -- provisioning hooks ------------------------------------------------------
    # The wall-clock DRP driver (repro.experiments._ProvisionerDriver) talks
    # to the pool only through these three methods, in executor units.  The
    # fleet overrides them with whole-host granularity (a "node" there is an
    # OS process running threads_per_host executors).

    def provision_grow(self, n: int) -> None:
        for _ in range(n):
            self.add_executor()

    def provision_release(self, eids: Iterable[str]) -> None:
        for eid in eids:
            self.remove_executor(eid)

    def provision_idle(self, now: float, idle_for_s: float) -> list[str]:
        """Executors eligible for release (called under ``self._lock``)."""
        return self.dispatcher.idle_executors(now, idle_for_s)

    # -- data -------------------------------------------------------------------------
    def put_object(self, obj: DataObject, payload: Any) -> None:
        self.store.put(obj, payload)
        self.dispatcher.sizes[obj.oid] = obj.size_bytes

    # -- execution -------------------------------------------------------------------
    def submit(self, tasks: Iterable[Task]) -> int:
        ts = list(tasks)
        with self._lock:
            self.dispatcher.submit(ts, time.monotonic())
            self._outstanding += len(ts)
            # a task submitted after its producer terminally failed is
            # failed on arrival; it will never dispatch, account it now
            dead = len(self.dispatcher.drain_dep_failed())
            if dead:
                self._outstanding -= dead
                if self._outstanding == 0:
                    self._done.notify_all()
        self._pump()
        return len(ts)

    def submit_workload(self, wl, *, task_fn: Optional[Callable[..., Any]] = None,
                        payload_factory: Optional[Callable[[DataObject], Any]] = None,
                        time_scale: float = 1.0,
                        block: bool = False,
                        barrier_every: Optional[int] = None) -> threading.Thread:
        """Open-loop submission: a paced submitter thread sleeps each task's
        ``repro.workloads`` arrival gap (wall-clock, scaled by ``time_scale``;
        0 collapses to as-fast-as-possible) and submits it, so demand arrives
        on its own clock instead of as one pre-staged batch.

        ``task_fn`` is attached to tasks that carry no callable (workload
        events describe *shape*, not code); ``payload_factory`` materialises
        store payloads for catalog objects not yet put.  ``wait()`` counts
        tasks only after they arrive, so to drain a paced run: join the
        returned thread, then ``wait()``.  ``shutdown()`` aborts any
        in-flight paced schedule (the thread exits at its next arrival).

        ``barrier_every=B`` replaces pacing with *batch-synchronous replay*:
        events are submitted in chunks of B (one ``submit`` call per chunk,
        so all of a chunk's placement decisions happen against a quiescent
        pool) and the run drains fully between chunks.  With eviction-free
        caches, a fixed pool, and ``B <= pool size`` (a whole chunk
        dispatches in ONE pump against the all-idle pool; a larger B leaves
        a tail whose placement follows racy completion order) this makes
        the scheduling outcome (placement sequence, per-input
        hit/peer/store split, byte ledger) a pure function of the workload
        -- identical across thread interleavings AND across the
        in-process/fleet runtimes, which is what the fleet trace-replay
        parity canary runs on.
        """
        if time_scale < 0:
            raise ValueError("time_scale must be >= 0")
        if barrier_every is not None and barrier_every < 1:
            raise ValueError("barrier_every must be >= 1")
        if payload_factory is not None:
            for ob in wl.objects:
                if ob.oid not in self.store:
                    self.put_object(ob, payload_factory(ob))
        events = wl.tasks()

        def _prep(task) -> Task:
            if task.fn is None:
                task.fn = task_fn
            return task

        def _pace() -> None:
            t0 = time.monotonic()
            for t_arr, task in events:
                if self._stop_pacing.is_set():
                    return
                _prep(task)
                if time_scale > 0:
                    delay = t_arr * time_scale - (time.monotonic() - t0)
                    # interruptible sleep: shutdown() aborts the schedule
                    if delay > 0 and self._stop_pacing.wait(delay):
                        return
                self.submit((task,))

        def _pace_barriers() -> None:
            for i in range(0, len(events), barrier_every):
                if self._stop_pacing.is_set():
                    return
                self.submit(_prep(task) for _, task in
                            events[i:i + barrier_every])
                if not self.wait(timeout=600.0):
                    return   # wedged; the caller's drain check reports it

        th = threading.Thread(
            target=_pace_barriers if barrier_every is not None else _pace,
            daemon=True, name="workload-submitter")
        th.start()
        if block:
            th.join()
        return th

    def _pump(self) -> None:
        rec = self.recorder
        with self._lock:
            t0 = time.perf_counter()
            dispatches = self.dispatcher.next_dispatches(time.monotonic())
            self._note_pump_locked(len(dispatches), time.perf_counter() - t0)
            qlen = self.dispatcher.queue_len if rec is not None else 0
        if rec is not None:
            # emitted OUTSIDE the runtime lock: the recorder's own lock is
            # the only one recording ever takes on this path
            rec.emit("pump", n=len(dispatches), queue=qlen)
        for d in dispatches:
            w = self.workers.get(d.executor)
            if w is None:
                with self._lock:
                    self.dispatcher.task_finished(d.task, time.monotonic(), ok=False)
                continue
            w.dispatch(d)

    def _resolve(self, acc: "_InputLedger", w: ExecutorWorker, oid: str,
                 hints: dict[str, tuple[str, ...]], tid: str = "") -> Any:
        """Stage one input, accounting a per-attempt accumulator (joins
        need the per-task split: a k-input task may hit locally on some
        inputs, peer-fetch others, miss the rest).  Only the accumulator --
        never the task or the global ledger -- is written here because this
        runs lock-free on the worker thread: if the worker is removed mid-
        execution, executor_left resets and re-queues the task, and a
        zombie attempt must not race its counters against the retry's.
        _finish_attempt merges the accumulator into the task AND the global
        ledger under the lock, after the membership guard drops
        de-registered workers -- so ledger totals always equal the sum of
        counted attempts (fleet hosts report through the same path)."""
        size = self.dispatcher.sizes.get(oid, 0)
        rec = self.recorder
        payload = w.cache_lookup(oid)
        if payload is not None:
            acc.cache_hits += 1
            acc.bytes_local += size
            if rec is not None:
                rec.emit("input", tid=tid, eid=w.eid, oid=oid,
                         source="local", bytes=size)
            return payload
        acc.cache_misses += 1
        for peer_id in hints.get(oid, ()):
            if peer_id == w.eid:
                continue
            peer = self.workers.get(peer_id)
            if peer is None:
                continue
            payload = peer.cache_peek(oid)
            if payload is not None:
                acc.peer_hits += 1
                acc.bytes_cache_to_cache += size
                if rec is not None:
                    rec.emit("input", tid=tid, eid=w.eid, oid=oid,
                             source="peer", bytes=size, peer=peer_id)
                obj = self.store.meta(oid) if oid in self.store else DataObject(oid, size)
                self._emit(w.admit_update(obj, payload))
                return payload
        obj, payload = self.store.get(oid)
        acc.bytes_store += obj.size_bytes
        if rec is not None:
            rec.emit("input", tid=tid, eid=w.eid, oid=oid,
                     source="store", bytes=obj.size_bytes)
        self._emit(w.admit_update(obj, payload))
        return payload

    def _emit(self, upd: IndexUpdate) -> None:
        self.update_channel.send(upd)

    def _on_update(self, upd: IndexUpdate) -> None:
        """Consumer side of the update seam (same code path for in-process
        sends and for updates arriving from fleet hosts)."""
        with self._lock:
            self._on_update_locked(upd)

    def _on_update_locked(self, upd: IndexUpdate) -> None:
        self._update_buf.append(upd)
        self.stats.updates_applied += 1
        if len(self._update_buf) >= self._update_batch:
            self.dispatcher.apply_index_updates(self._update_buf)
            self._update_buf = []

    def _note_pump_locked(self, n_dispatches: int, hold_s: float) -> None:
        st = self.stats
        st.pump_calls += 1
        st.lock_hold_s += hold_s
        if n_dispatches:
            st.dispatch_batches += 1
            st.dispatches += n_dispatches
            if n_dispatches > st.max_dispatch_batch:
                st.max_dispatch_batch = n_dispatches
        m = self.metrics
        if m is not None:
            m.inc("sched.pump_calls")
            if n_dispatches:
                m.inc("sched.dispatches", n_dispatches)
            m.observe("sched.pump_latency_s", hold_s)

    def dispatch_stats(self) -> dict:
        """Central-loop counter snapshot for RunReport / the benchmark."""
        with self._lock:
            return self.stats.as_dict()

    def sample_metrics(self) -> None:
        """Refresh the registry's gauges from live runtime state (the
        telemetry sampler calls this each tick; DESIGN.md §13).  Gauges are
        absolute totals for THIS source, so re-sampling is idempotent and a
        cluster merge sums per-source values.  On a fleet the workers are
        remote proxies without local caches, so the cache/bandwidth gauges
        here stay 0 and the per-host stats frames carry them instead."""
        m = self.metrics
        if m is None:
            return
        with self._lock:
            qlen = self.dispatcher.queue_len
            pool = len(self.workers)
            caches = [w.cache for w in self.workers.values()
                      if getattr(w, "cache", None) is not None]
            used = sum(c.used_bytes for c in caches)
            hits = sum(c.stats.hits for c in caches)
            misses = sum(c.stats.misses for c in caches)
            evictions = sum(c.stats.evictions for c in caches)
            insertions = sum(c.stats.insertions for c in caches)
            readmits = sum(c.stats.readmits for c in caches)
        led = self.ledger
        with led.lock:
            b_local, b_c2c, b_store = (led.bytes_local, led.bytes_c2c,
                                       led.bytes_store)
        m.gauge_set("sched.queue_depth", qlen)
        m.gauge_set("pool.size", pool)
        m.gauge_set("cache.bytes", used)
        m.gauge_set("cache.hits", hits)
        m.gauge_set("cache.misses", misses)
        m.gauge_set("cache.evictions", evictions)
        m.gauge_set("cache.insertions", insertions)
        m.gauge_set("cache.readmits", readmits)
        m.gauge_set("bw.bytes_local", b_local)
        m.gauge_set("bw.bytes_c2c", b_c2c)
        m.gauge_set("bw.bytes_store", b_store)
        if self.recorder is not None:
            m.gauge_set("obs.recorder_dropped", self.recorder.dropped)

    def _execute(self, w: ExecutorWorker, disp: Dispatch) -> None:
        t = disp.task
        t.state = TaskState.RUNNING
        t.start_time = time.monotonic()
        ok = True
        acc = _InputLedger()
        rec = self.recorder
        try:
            inputs = {oid: self._resolve(acc, w, oid, disp.hints, tid=t.tid)
                      for oid in t.inputs}
            if rec is not None:
                rec.emit("exec_start", tid=t.tid, eid=w.eid)
            if t.fn is not None:
                t.result = t.fn(**inputs) if _wants_kwargs(t.fn) else t.fn(inputs)
            for ob in t.outputs:
                # shape-only tasks (no fn) produce no real payload; admit the
                # sentinel so downstream DAG reads still count as cache hits
                # (a None payload would read as a miss on every lookup)
                if t.fn is None:
                    payload = SHAPE_ONLY_PAYLOAD
                else:
                    payload = t.result if len(t.outputs) == 1 else t.result[ob.oid]
                self._emit(w.admit_update(ob, payload))
                self.dispatcher.sizes[ob.oid] = ob.size_bytes
        except Exception as e:  # noqa: BLE001 - task failure is data, not a crash
            ok = False
            t.result = e
        if rec is not None:
            rec.emit("exec_end", tid=t.tid, eid=w.eid, ok=ok)
        self._finish_attempt(w, t, acc, ok)
        self._pump()

    def _finish_attempt(self, w, t: Task, acc: _InputLedger, ok: bool) -> None:
        """Complete one execution attempt under the lock.  ``w`` is
        whatever object ``self.workers`` maps the executor id to -- a
        thread worker here, a remote-executor proxy on a fleet -- and the
        identity check is the membership guard for both."""
        with self._lock:
            self._finish_attempt_locked(w, t, acc, ok)

    def _finish_attempt_locked(self, w, t: Task, acc: _InputLedger,
                               ok: bool) -> None:
        if self.workers.get(w.eid) is not w:
            # this worker was removed mid-execution: executor_left already
            # re-queued (or failed out) the task, so this attempt's
            # outcome must not complete it a second time -- that would
            # double-decrement _outstanding and wake wait() early while
            # the retry is still in flight -- and its input ledger must
            # not pollute the retry's counters (acc is dropped here)
            return
        acc.merge_into(t)
        self.ledger.account_attempt(acc)
        self.dispatcher.task_finished(t, time.monotonic(), ok=ok)
        # a completion may also release held dependents (they re-enter the
        # queue and stay outstanding) or -- on terminal failure -- cascade-
        # fail them; cascaded tasks never dispatch, so account them here.
        terminal = 1 if (ok or t.state is TaskState.FAILED) else 0
        terminal += len(self.dispatcher.drain_dep_failed())
        if terminal:
            self._outstanding -= terminal
            if self._outstanding == 0:
                self._done.notify_all()

    def wait(self, timeout: float = 60.0) -> bool:
        deadline = time.monotonic() + timeout
        with self._lock:
            while self._outstanding > 0:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return False
                self._done.wait(remaining)
        # flush any buffered (loose) index updates at quiescence
        with self._lock:
            if self._update_buf:
                self.dispatcher.apply_index_updates(self._update_buf)
                self._update_buf = []
        return True

    def shutdown(self) -> None:
        self._stop_pacing.set()    # abort any paced submitter threads
        for w in self.workers.values():
            w.stop()


def _wants_kwargs(fn: Callable[..., Any]) -> bool:
    import inspect

    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return False
    params = list(sig.parameters.values())
    return not (len(params) == 1 and params[0].kind is params[0].POSITIONAL_OR_KEYWORD
                and params[0].name in ("inputs", "payloads"))

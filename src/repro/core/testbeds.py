"""Calibrated testbed models.

ANL_UC reproduces the paper's Table 1 testbed (TG_ANL_IA32/IA64 + GPFS with
8 I/O servers + UC_x64 dispatcher host).  Calibration is anchored on the
paper's own measured envelope (§4.2, Figures 3-5):

  * GPFS aggregate read tops out at 3.4 Gb/s  -> store_read = 425 MB/s
  * GPFS read+write tops out at 1.1 Gb/s      -> store_write = 68.75 MB/s
    (mixed workload saturates writes first: 2 * 68.75 MB/s = 1.1 Gb/s moved)
  * Figure 3 ideal at 64 nodes = 65.6 Gb/s    -> disk_read = 128 MB/s/node
  * Figure 4 ideal at 64 nodes = 23.6 Gb/s    -> disk_write = 28 MB/s/node
    (2 / (1/128 + 1/28) ~= 46 MB/s moved per node * 64 ~= 23.6 Gb/s)
  * per-node GigE                              -> nic = 125 MB/s each way
  * data-unaware 100%-locality read = 5.7 Gb/s at 64 nodes (Fig 3)
    -> per-flow GridFTP cap ~= 18 MB/s + 50 ms session setup (the fetched
       copy is also written through to the local disk cache, serialized)
  * config-8 efficiency ~94% of ideal -> per-task executor overhead 50 ms
    (Falkon executor launch + JVM + notification round-trip)
  * wrapper floor ~21 tasks/s on 64 nodes with 3 metadata ops/task (Fig 5)
    -> GPFS metadata op latency ~= 15 ms serialized (=> ~22 tasks/s)
  * dispatcher: 3800 tasks/s non-data-aware (§3.2.3) -> 0.26 ms service;
    data-aware adds ~2 us/lookup (hash-table scale, §3.2.3), budget 2.1 ms.
  * UC_x64 <-> cluster latency 1-2 ms (Table 1)  -> 1.5 ms dispatch RTT.

TPU_V5E_HOSTS is the same economic structure, 2026 edition, used by the
training data pipeline model: blob-store egress is fixed; per-host cache
bandwidth scales linearly; peer fetches ride DCN.
"""
from __future__ import annotations

from dataclasses import dataclass

MB = 1e6
GB = 1e9
Gbps = 1e9 / 8.0


@dataclass(frozen=True)
class TestbedSpec:
    name: str
    # persistent store
    store_read_bw: float            # aggregate bytes/s
    store_write_bw: float
    store_meta_latency_s: float     # serialized metadata op
    store_open_latency_s: float     # per-file open on the store path
    # per node
    disk_read_bw: float
    disk_write_bw: float
    nic_in_bw: float
    nic_out_bw: float
    local_open_latency_s: float
    # peer (GridFTP-analogue) transport
    peer_flow_cap: float            # single-stream cap, bytes/s
    peer_setup_latency_s: float
    # dispatcher
    dispatch_service_s: float       # non-data-aware per-task service
    index_lookup_s: float           # per-lookup add-on when data-aware
    dispatch_rtt_s: float           # service<->executor one-way latency
    # per-task executor-side overhead (launch + notify)
    task_overhead_s: float = 0.0
    # provisioning
    executor_startup_s: float = 30.0

    def ideal_read_bw(self, n_nodes: int) -> float:
        return n_nodes * self.disk_read_bw

    def ideal_readwrite_bw(self, n_nodes: int) -> float:
        # bytes moved per second when each task reads S then writes S locally
        per_node = 2.0 / (1.0 / self.disk_read_bw + 1.0 / self.disk_write_bw)
        return n_nodes * per_node


ANL_UC = TestbedSpec(
    name="ANL_UC",
    store_read_bw=425 * MB,
    store_write_bw=68.75 * MB,
    store_meta_latency_s=15e-3,
    store_open_latency_s=10e-3,
    disk_read_bw=128 * MB,
    disk_write_bw=28 * MB,
    nic_in_bw=125 * MB,
    nic_out_bw=125 * MB,
    local_open_latency_s=1e-3,
    peer_flow_cap=18 * MB,
    peer_setup_latency_s=50e-3,
    dispatch_service_s=1.0 / 3800.0,
    index_lookup_s=2e-6,
    dispatch_rtt_s=1.5e-3,
    task_overhead_s=50e-3,
    executor_startup_s=30.0,
)

# Modern analogue for the training-pipeline integration: numbers are
# per-HOST (a v5e host: 8 chips, 2x100GbE DCN, NVMe scratch, and a blob
# store whose per-bucket egress is finite and *shared*).
TPU_V5E_HOSTS = TestbedSpec(
    name="TPU_V5E_HOSTS",
    store_read_bw=40 * GB,          # blob-store bucket egress (aggregate)
    store_write_bw=20 * GB,
    store_meta_latency_s=2e-3,
    store_open_latency_s=5e-3,      # blob GET first-byte
    disk_read_bw=6 * GB,            # host NVMe / page-cache
    disk_write_bw=3 * GB,
    nic_in_bw=12.5 * GB,            # 100 GbE
    nic_out_bw=12.5 * GB,
    local_open_latency_s=50e-6,
    peer_flow_cap=5 * GB,           # single gRPC stream
    peer_setup_latency_s=1e-3,
    dispatch_service_s=50e-6,
    index_lookup_s=1e-6,
    dispatch_rtt_s=200e-6,
    task_overhead_s=1e-3,
    executor_startup_s=60.0,
)

#: canonical name -> spec registry (the experiment layer binds testbeds by
#: name so an ExperimentSpec stays a plain JSON document)
TESTBEDS: dict[str, TestbedSpec] = {
    "anl_uc": ANL_UC,
    "tpu_v5e": TPU_V5E_HOSTS,
}

"""Centralized (and optionally sharded) cache-location index (§3.2.3).

The dispatcher keeps an in-memory map ``oid -> {executor ids caching it}``,
kept *loosely coherent* with executor caches via update batches.  The paper
measures a Java hash table at ~1-3 us inserts / 0.25-1 us lookups and an
upper bound of ~4.18M lookups/s, and argues a centralized index beats a
distributed one (P-RLS) until ~32K index nodes; ``benchmarks/bench_index.py``
reproduces that comparison for this implementation.

Loose coherence protocol: executors enqueue ``IndexUpdate`` records (adds on
cache insertion, removes on eviction) which the dispatcher applies in batches.
Between batches the index may be stale in both directions; the scheduler
treats hints as advisory (a peer fetch that misses falls back to the store)
so staleness costs performance, never correctness -- exactly the paper's
"hybrid but essentially centralized" design.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterable, Optional


@dataclass(frozen=True, slots=True)
class IndexUpdate:
    executor: str
    added: tuple[str, ...] = ()
    removed: tuple[str, ...] = ()


class LocationIndex:
    """Single-node in-memory location index (the paper's choice)."""

    def __init__(self) -> None:
        self._by_oid: dict[str, set[str]] = {}
        self._by_executor: dict[str, set[str]] = {}
        self.n_inserts = 0
        self.n_removes = 0
        self.n_lookups = 0

    # -- point ops -----------------------------------------------------------
    def insert(self, oid: str, executor: str) -> None:
        self._by_oid.setdefault(oid, set()).add(executor)
        self._by_executor.setdefault(executor, set()).add(oid)
        self.n_inserts += 1

    def remove(self, oid: str, executor: str) -> None:
        locs = self._by_oid.get(oid)
        if locs is not None:
            locs.discard(executor)
            if not locs:
                del self._by_oid[oid]
        exo = self._by_executor.get(executor)
        if exo is not None:
            exo.discard(oid)
        self.n_removes += 1

    def lookup(self, oid: str) -> frozenset[str]:
        self.n_lookups += 1
        locs = self._by_oid.get(oid)
        return frozenset(locs) if locs else frozenset()

    # -- bulk / maintenance ----------------------------------------------------
    def apply(self, update: IndexUpdate) -> None:
        for oid in update.added:
            self.insert(oid, update.executor)
        for oid in update.removed:
            self.remove(oid, update.executor)

    def apply_batch(self, updates: Iterable[IndexUpdate]) -> None:
        for u in updates:
            self.apply(u)

    def apply_wire(self, triples: Iterable[Iterable]) -> None:
        """Apply ``[executor, added, removed]`` triples as they cross the
        fleet wire (host index replicas decode straight into this -- no
        IndexUpdate re-tupling on the hot path)."""
        for eid, added, removed in triples:
            for oid in added:
                self.insert(oid, eid)
            for oid in removed:
                self.remove(oid, eid)

    def drop_executor(self, executor: str) -> int:
        """Invalidate every entry for a released/failed executor."""
        oids = self._by_executor.pop(executor, set())
        for oid in oids:
            locs = self._by_oid.get(oid)
            if locs is not None:
                locs.discard(executor)
                if not locs:
                    del self._by_oid[oid]
        return len(oids)

    def holdings(self, executor: str) -> frozenset[str]:
        return frozenset(self._by_executor.get(executor, ()))

    def __len__(self) -> int:
        return len(self._by_oid)

    # -- micro-benchmark hooks (paper §3.2.3 / Figure 2) -----------------------
    def time_ops(self, n: int = 100_000) -> dict[str, float]:
        """Measure insert/lookup latency; returns seconds-per-op."""
        return _time_ops(self, n)


class ShardedIndex:
    """Hash-sharded variant (beyond-paper).

    Addresses the two §3.2.3 limitations the paper itself raises -- memory
    footprint and single point of failure -- while keeping per-shard lookups
    O(1).  Shards can live on different service processes; here they are
    in-process but the interface is shard-local so the split is mechanical.
    """

    def __init__(self, n_shards: int = 8) -> None:
        if n_shards < 1:
            raise ValueError("need >= 1 shard")
        self._shards = [LocationIndex() for _ in range(n_shards)]

    def _shard(self, oid: str) -> LocationIndex:
        return self._shards[hash(oid) % len(self._shards)]

    def insert(self, oid: str, executor: str) -> None:
        self._shard(oid).insert(oid, executor)

    def remove(self, oid: str, executor: str) -> None:
        self._shard(oid).remove(oid, executor)

    def lookup(self, oid: str) -> frozenset[str]:
        return self._shard(oid).lookup(oid)

    def apply(self, update: IndexUpdate) -> None:
        for oid in update.added:
            self.insert(oid, update.executor)
        for oid in update.removed:
            self.remove(oid, update.executor)

    def apply_batch(self, updates: Iterable[IndexUpdate]) -> None:
        for u in updates:
            self.apply(u)

    def apply_wire(self, triples: Iterable[Iterable]) -> None:
        for eid, added, removed in triples:
            for oid in added:
                self.insert(oid, eid)
            for oid in removed:
                self.remove(oid, eid)

    def drop_executor(self, executor: str) -> int:
        return sum(s.drop_executor(executor) for s in self._shards)

    def holdings(self, executor: str) -> frozenset[str]:
        out: set[str] = set()
        for s in self._shards:
            out |= s.holdings(executor)
        return frozenset(out)

    def __len__(self) -> int:
        return sum(len(s) for s in self._shards)

    # -- aggregate op counters: drop-in observable like LocationIndex ---------
    @property
    def n_inserts(self) -> int:
        return sum(s.n_inserts for s in self._shards)

    @property
    def n_removes(self) -> int:
        return sum(s.n_removes for s in self._shards)

    @property
    def n_lookups(self) -> int:
        return sum(s.n_lookups for s in self._shards)

    def time_ops(self, n: int = 100_000) -> dict[str, float]:
        """Measure insert/lookup latency across shards; seconds-per-op
        (same contract as LocationIndex.time_ops, for bench_index.py)."""
        return _time_ops(self, n)


def _time_ops(index, n: int) -> dict[str, float]:
    t0 = time.perf_counter()
    for i in range(n):
        index.insert(f"__bench{i}", "e0")
    t1 = time.perf_counter()
    for i in range(n):
        index.lookup(f"__bench{i}")
    t2 = time.perf_counter()
    for i in range(n):
        index.remove(f"__bench{i}", "e0")
    return {"insert_s": (t1 - t0) / n, "lookup_s": (t2 - t1) / n}


def prls_latency_model(n_nodes: int) -> float:
    """Chervenak et al. P-RLS lookup latency (seconds) vs node count.

    Log-fit through the published 1..15-node points (0.5 ms .. ~3 ms),
    the same extrapolation the paper uses for Figure 2:
        latency_ms ~= 0.5 + 0.74 * ln(n)
    (~3.0ms at 15 nodes, ~15ms at 1M nodes -- matches the text.)
    """
    import math

    return (0.5 + 0.74 * math.log(max(n_nodes, 1))) * 1e-3


def prls_aggregate_throughput(n_nodes: int) -> float:
    """Predicted aggregate P-RLS lookups/s (n nodes, each 1/latency)."""
    return n_nodes / prls_latency_model(n_nodes)

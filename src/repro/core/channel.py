"""The Channel abstraction: the two RPC seams of the threaded runtime.

`DiffusionRuntime` keeps every scheduling decision (placement, hints,
retries, membership) in one authoritative `Dispatcher`/`LocationIndex`
stack; executors only ever talk to it through two message streams:

  dispatch channel   dispatcher -> executor: `Dispatch` records (task +
                     location hints).  One channel per executor; messages
                     for one executor are totally ordered.
  update channel     executor -> dispatcher: `IndexUpdate` records (cache
                     admissions/evictions) and attempt completions.  Updates
                     for one attempt are sent *before* its completion, so a
                     consumer that processes the stream in order sees a
                     task's cache effects no later than its completion.

Everything else the runtime does is shared-nothing, which makes these two
seams exactly the cut points where the single-process runtime becomes a
multi-process fleet (`repro.fleet`): swap the queue-backed channels below
for socket-backed ones and the same dispatcher drives executors in other
OS processes without a single scheduling-logic change.

In-process implementations:

  `LocalChannel`     a `queue.Queue` with the Channel interface -- the
                     per-worker dispatch inbox.
  `CallbackChannel`  a synchronous send-side-only channel: `send` invokes
                     the consumer inline (the dispatcher applying an index
                     update under its own lock).  This is what "the update
                     seam, in process" degenerates to; the fleet replaces
                     it with a socket and a receiver thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Optional


class ChannelClosed(Exception):
    """recv() on a channel whose peer is gone / send() after close()."""


class Channel:
    """One-directional ordered message stream (see module docstring)."""

    def send(self, msg: Any) -> None:
        raise NotImplementedError

    def recv(self, timeout: Optional[float] = None) -> Any:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError


#: sentinel a closing LocalChannel enqueues so a blocked recv() wakes up
_CLOSED = object()


class LocalChannel(Channel):
    """In-process Channel over a `queue.Queue` (the worker dispatch inbox)."""

    def __init__(self) -> None:
        self._q: "queue.Queue[Any]" = queue.Queue()
        self._closed = threading.Event()

    def send(self, msg: Any) -> None:
        if self._closed.is_set():
            raise ChannelClosed("send on closed LocalChannel")
        self._q.put(msg)

    def recv(self, timeout: Optional[float] = None) -> Any:
        try:
            msg = self._q.get(timeout=timeout)
        except queue.Empty:
            raise TimeoutError("LocalChannel.recv timed out") from None
        if msg is _CLOSED:
            # wake any other blocked reader, then report closure
            self._q.put(_CLOSED)
            raise ChannelClosed("LocalChannel closed")
        return msg

    def close(self) -> None:
        if not self._closed.is_set():
            self._closed.set()
            self._q.put(_CLOSED)

    def empty(self) -> bool:
        """Best-effort emptiness probe (racy by nature -- callers use it
        as an idleness *hint*, never as a correctness gate)."""
        return self._q.empty()


class BatchingChannel(Channel):
    """Coalesce messages into bounded batches on another Channel.

    Buffers sends and delivers them downstream as one
    ``{"t": "batch", "msgs": [...]}`` frame once ``max_batch`` messages
    have accumulated, or immediately when a send is marked ``flush=True``
    (a single buffered message is forwarded bare -- no batch wrapper -- so
    ``max_batch=1`` degenerates to the inner channel exactly).

    Ordering contract (DESIGN.md §8/§9): the buffer append and the inner
    ``send`` happen under ONE lock.  Flushing outside the lock would let
    two concurrent flushes swap buffers and then race their inner sends,
    which can reorder one thread's update *after* its own completion
    across batch boundaries -- precisely the inversion the updates-
    before-done contract forbids.  Holding the lock across the inner
    send serialises batch emission in buffer order, so wire order is a
    legal interleaving of the per-thread send orders, batched or not.
    """

    def __init__(self, inner: Channel, max_batch: int = 64) -> None:
        self.inner = inner
        self.max_batch = max(int(max_batch), 1)
        self._buf: list[Any] = []
        self._lock = threading.Lock()
        self.batches_sent = 0
        self.msgs_sent = 0

    def send(self, msg: Any, flush: bool = False) -> None:
        with self._lock:
            self._buf.append(msg)
            if flush or len(self._buf) >= self.max_batch:
                self._flush_locked()

    def flush(self) -> None:
        with self._lock:
            if self._buf:
                self._flush_locked()

    def _flush_locked(self) -> None:
        buf, self._buf = self._buf, []
        self.msgs_sent += len(buf)
        self.batches_sent += 1
        if len(buf) == 1:
            self.inner.send(buf[0])
        else:
            self.inner.send({"t": "batch", "msgs": buf})

    def recv(self, timeout: Optional[float] = None) -> Any:
        raise ChannelClosed("BatchingChannel is send-side only")

    def close(self) -> None:
        self.flush()
        self.inner.close()


class CallbackChannel(Channel):
    """Send-only synchronous channel: `send(msg)` runs the handler inline.

    The in-process form of the update seam -- an executor thread "sending"
    an index update simply calls into the dispatcher (which serialises
    under its own lock).  `recv` is meaningless here by construction: the
    consumer IS the handler.
    """

    def __init__(self, handler: Callable[[Any], None]) -> None:
        self._handler = handler
        self._closed = False

    def send(self, msg: Any) -> None:
        if self._closed:
            raise ChannelClosed("send on closed CallbackChannel")
        self._handler(msg)

    def recv(self, timeout: Optional[float] = None) -> Any:
        raise ChannelClosed("CallbackChannel delivers synchronously; "
                            "there is nothing to recv")

    def close(self) -> None:
        self._closed = True

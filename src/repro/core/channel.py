"""The Channel abstraction: the two RPC seams of the threaded runtime.

`DiffusionRuntime` keeps every scheduling decision (placement, hints,
retries, membership) in one authoritative `Dispatcher`/`LocationIndex`
stack; executors only ever talk to it through two message streams:

  dispatch channel   dispatcher -> executor: `Dispatch` records (task +
                     location hints).  One channel per executor; messages
                     for one executor are totally ordered.
  update channel     executor -> dispatcher: `IndexUpdate` records (cache
                     admissions/evictions) and attempt completions.  Updates
                     for one attempt are sent *before* its completion, so a
                     consumer that processes the stream in order sees a
                     task's cache effects no later than its completion.

Everything else the runtime does is shared-nothing, which makes these two
seams exactly the cut points where the single-process runtime becomes a
multi-process fleet (`repro.fleet`): swap the queue-backed channels below
for socket-backed ones and the same dispatcher drives executors in other
OS processes without a single scheduling-logic change.

In-process implementations:

  `LocalChannel`     a `queue.Queue` with the Channel interface -- the
                     per-worker dispatch inbox.
  `CallbackChannel`  a synchronous send-side-only channel: `send` invokes
                     the consumer inline (the dispatcher applying an index
                     update under its own lock).  This is what "the update
                     seam, in process" degenerates to; the fleet replaces
                     it with a socket and a receiver thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Optional


class ChannelClosed(Exception):
    """recv() on a channel whose peer is gone / send() after close()."""


class Channel:
    """One-directional ordered message stream (see module docstring)."""

    def send(self, msg: Any) -> None:
        raise NotImplementedError

    def recv(self, timeout: Optional[float] = None) -> Any:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError


#: sentinel a closing LocalChannel enqueues so a blocked recv() wakes up
_CLOSED = object()


class LocalChannel(Channel):
    """In-process Channel over a `queue.Queue` (the worker dispatch inbox)."""

    def __init__(self) -> None:
        self._q: "queue.Queue[Any]" = queue.Queue()
        self._closed = threading.Event()

    def send(self, msg: Any) -> None:
        if self._closed.is_set():
            raise ChannelClosed("send on closed LocalChannel")
        self._q.put(msg)

    def recv(self, timeout: Optional[float] = None) -> Any:
        try:
            msg = self._q.get(timeout=timeout)
        except queue.Empty:
            raise TimeoutError("LocalChannel.recv timed out") from None
        if msg is _CLOSED:
            # wake any other blocked reader, then report closure
            self._q.put(_CLOSED)
            raise ChannelClosed("LocalChannel closed")
        return msg

    def close(self) -> None:
        if not self._closed.is_set():
            self._closed.set()
            self._q.put(_CLOSED)


class CallbackChannel(Channel):
    """Send-only synchronous channel: `send(msg)` runs the handler inline.

    The in-process form of the update seam -- an executor thread "sending"
    an index update simply calls into the dispatcher (which serialises
    under its own lock).  `recv` is meaningless here by construction: the
    consumer IS the handler.
    """

    def __init__(self, handler: Callable[[Any], None]) -> None:
        self._handler = handler
        self._closed = False

    def send(self, msg: Any) -> None:
        if self._closed:
            raise ChannelClosed("send on closed CallbackChannel")
        self._handler(msg)

    def recv(self, timeout: Optional[float] = None) -> Any:
        raise ChannelClosed("CallbackChannel delivers synchronously; "
                            "there is nothing to recv")

    def close(self) -> None:
        self._closed = True

"""Pallas TPU kernel for astronomy image stacking (the paper's application).

Per §5.2 the per-ROI pipeline is: calibrate (roi - SKY) * CAL, interpolate
(sub-pixel shift so the object center lands on a whole pixel), and coadd
(doStacking).  The 2008 code ran this scalar-per-CPU; the TPU formulation
tiles the ROI stack across the sequential grid axis and keeps the
accumulator tile in VMEM scratch: one pass over N ROIs, one (H, W) live
tile, bilinear interpolation expressed as four shifted multiply-adds on the
VPU (no gather -- TPU-native).

  rois (N, H, W) f32 | sky (N,) | cal (N,) | dy, dx (N,) in [0, 1)
  out  (H, W) = sum_n shift(calibrate(roi_n))  (caller divides for mean)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 8


def _stack_kernel(roi_ref, sky_ref, cal_ref, dy_ref, dx_ref, o_ref, acc_ref,
                  *, block_n: int, num_blocks: int, n_total: int):
    ib = pl.program_id(0)

    @pl.when(ib == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    H, W = acc_ref.shape
    acc = acc_ref[...]
    for j in range(block_n):  # static unroll over the ROI tile
        n_idx = ib * block_n + j
        roi = roi_ref[j].astype(jnp.float32)              # (H, W)
        sky = sky_ref[0, j]
        cal = cal_ref[0, j]
        dy = dy_ref[0, j]
        dx = dx_ref[0, j]
        img = (roi - sky) * cal                           # calibration
        # bilinear shift by (dy, dx) via four shifted copies (interpolation)
        w00 = (1 - dy) * (1 - dx)
        w01 = (1 - dy) * dx
        w10 = dy * (1 - dx)
        w11 = dy * dx
        down = jnp.concatenate([img[:1], img[:-1]], axis=0)      # shift +1 row
        right = jnp.concatenate([img[:, :1], img[:, :-1]], axis=1)
        downright = jnp.concatenate([down[:, :1], down[:, :-1]], axis=1)
        shifted = w00 * img + w01 * right + w10 * down + w11 * downright
        valid = jnp.where(n_idx < n_total, 1.0, 0.0)      # tail padding
        acc = acc + shifted * valid
    acc_ref[...] = acc

    @pl.when(ib == num_blocks - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def stack_rois_fwd(
    rois: jax.Array,   # (N, H, W)
    sky: jax.Array,    # (N,)
    cal: jax.Array,    # (N,)
    dy: jax.Array,     # (N,)
    dx: jax.Array,     # (N,)
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
) -> jax.Array:
    N, H, W = rois.shape
    block_n = min(block_n, N)
    pad = (-N) % block_n
    if pad:
        rois = jnp.pad(rois, ((0, pad), (0, 0), (0, 0)))
        sky = jnp.pad(sky, (0, pad))
        cal = jnp.pad(cal, (0, pad))
        dy = jnp.pad(dy, (0, pad))
        dx = jnp.pad(dx, (0, pad))
    nb = (N + pad) // block_n
    kernel = functools.partial(_stack_kernel, block_n=block_n,
                               num_blocks=nb, n_total=N)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_n, H, W), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, block_n), lambda b: (0, b)),
            pl.BlockSpec((1, block_n), lambda b: (0, b)),
            pl.BlockSpec((1, block_n), lambda b: (0, b)),
            pl.BlockSpec((1, block_n), lambda b: (0, b)),
        ],
        out_specs=pl.BlockSpec((H, W), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((H, W), jnp.float32)],
        interpret=interpret,
    )(rois, sky[None], cal[None], dy[None], dx[None])

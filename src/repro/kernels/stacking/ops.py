"""Jit'd wrapper for the stacking kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .stacking import DEFAULT_BLOCK_N, stack_rois_fwd


@functools.partial(jax.jit, static_argnames=("block_n", "interpret", "mean"))
def stack_rois(rois, sky, cal, dy, dx, *, block_n: int = DEFAULT_BLOCK_N,
               interpret: bool = True, mean: bool = True):
    out = stack_rois_fwd(rois.astype(jnp.float32), sky.astype(jnp.float32),
                         cal.astype(jnp.float32), dy.astype(jnp.float32),
                         dx.astype(jnp.float32), block_n=block_n,
                         interpret=interpret)
    if mean:
        out = out / rois.shape[0]
    return out

"""Pure-jnp oracle for the stacking kernel."""
from __future__ import annotations

import jax.numpy as jnp


def stack_rois_ref(rois, sky, cal, dy, dx):
    """rois (N,H,W); sky/cal/dy/dx (N,). Returns (H,W) fp32 coadd."""
    img = (rois.astype(jnp.float32) - sky[:, None, None]) * cal[:, None, None]
    down = jnp.concatenate([img[:, :1], img[:, :-1]], axis=1)
    right = jnp.concatenate([img[:, :, :1], img[:, :, :-1]], axis=2)
    downright = jnp.concatenate([down[:, :, :1], down[:, :, :-1]], axis=2)
    w00 = ((1 - dy) * (1 - dx))[:, None, None]
    w01 = ((1 - dy) * dx)[:, None, None]
    w10 = (dy * (1 - dx))[:, None, None]
    w11 = (dy * dx)[:, None, None]
    shifted = w00 * img + w01 * right + w10 * down + w11 * downright
    return jnp.sum(shifted, axis=0)

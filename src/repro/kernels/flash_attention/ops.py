"""Jit'd wrapper: model layout (B,S,H,D) -> kernel layout, head-dim padding.

Models call ``flash_attention`` with (B, S, H, Dh)/(B, S, KV, Dh); the
wrapper transposes to head-major, pads head_dim to a 128 lane multiple
(gemma2's Dh=144 -> 256) and pads sequence to the block size, then strips
padding.  Custom VJP falls back to the reference backward (the kernel is
forward-only; training uses remat over the ref path on non-hot layers)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention_fwd
from .ref import attention_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,            # (B, S, H, Dh)
    k: jax.Array,            # (B, S, KV, Dh)
    v: jax.Array,            # (B, S, KV, Dh)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    B, S, H, Dh = q.shape
    qt = jnp.swapaxes(q, 1, 2)               # (B,H,S,D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    qt, dpad = _pad_to(qt, 3, 128)
    kt, _ = _pad_to(kt, 3, 128)
    vt, _ = _pad_to(vt, 3, 128)
    bq = min(block_q, S)
    bk = min(block_k, S)
    qt, spadq = _pad_to(qt, 2, bq)
    kt, spadk = _pad_to(kt, 2, bk)
    vt, _ = _pad_to(vt, 2, bk)
    if spadk:
        # padded keys must never win the softmax: causal masking handles
        # q-side padding; mask k padding via a window-free validity trick --
        # give padded keys positions beyond every query (causal mask kills
        # them).  For non-causal use, ref fallback handles ragged shapes.
        assert causal, "non-causal ragged seq falls back to ref"
    # undo the sqrt(D) change from padding: kernel scales by padded D
    scale_fix = ((Dh + dpad) / Dh) ** 0.5 if dpad else 1.0
    out = flash_attention_fwd(qt * scale_fix, kt, vt, causal=causal,
                              window=window, softcap=softcap,
                              block_q=bq, block_k=bk, interpret=interpret)
    out = out[:, :, :S, :Dh]
    return jnp.swapaxes(out, 1, 2)


def flash_attention_with_ref_vjp(q, k, v, **kw):
    """Forward via the kernel, backward via the jnp reference (exact same
    math, so gradients match the ref path)."""

    @jax.custom_vjp
    def fa(q, k, v):
        return flash_attention(q, k, v, **kw)

    def fwd(q, k, v):
        return fa(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res

        def ref_model_layout(q, k, v):
            return jnp.swapaxes(
                attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                              jnp.swapaxes(v, 1, 2),
                              causal=kw.get("causal", True),
                              window=kw.get("window", 0),
                              softcap=kw.get("softcap", 0.0)), 1, 2)

        _, vjp = jax.vjp(ref_model_layout, q, k, v)
        return vjp(g)

    fa.defvjp(fwd, bwd)
    return fa(q, k, v)

"""Pallas TPU flash attention (forward): causal / sliding-window / softcap / GQA.

TPU-native design (vs. the CUDA formulation):
  * grid = (batch, q_heads, num_q_blocks, num_kv_blocks) with the kv-block
    dimension iterated sequentially by the TPU grid -- the online-softmax
    running state (m, l, acc) lives in VMEM scratch and persists across kv
    steps of the same q block (no atomics / warp shuffles needed: the grid
    *is* the reduction loop).
  * BlockSpecs keep one (block_q x head_dim) q tile and one
    (block_k x head_dim) k/v tile resident in VMEM; defaults 128x128 match
    the MXU systolic tile.  head_dim is padded to a lane multiple by ops.py.
  * GQA is expressed in the k/v index_map (kv_head = q_head // group) --
    no materialized head broadcast.
  * sliding-window + causal masks are position arithmetic inside the tile;
    logit softcap (gemma2) is tanh-rescaling applied pre-mask.

Validated in interpret mode against ref.py (tests/test_kernels.py sweeps
shapes/dtypes); on real TPUs the same pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -2.0 ** 30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, causal: bool, window: int, softcap: float,
               block_q: int, block_k: int, num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = jnp.ones(s.shape, jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # (bq, bk)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        # fully-masked rows (SWA lookback past the window) have l == 0
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,                 # (B, H, Sq, D)
    k: jax.Array,                 # (B, KV, Sk, D)
    v: jax.Array,                 # (B, KV, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    group = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            # online-softmax running state; persists across the sequential
            # kv-block grid dimension of one q block
            pltpu.VMEM((block_q, D), jnp.float32),    # acc
            pltpu.VMEM((block_q, 1), jnp.float32),    # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),    # l (running sum)
        ],
        interpret=interpret,
    )(q, k, v)

"""Pure-jnp oracle for the flash-attention kernel (fp32 end to end)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(
    q: jax.Array,            # (B, H, Sq, D)
    k: jax.Array,            # (B, KV, Sk, D)
    v: jax.Array,            # (B, KV, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    group = H // KV
    kg = jnp.repeat(k, group, axis=1)
    vg = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kg.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key (SWA beyond-window) produce uniform probs in
    # softmax; zero them to match the kernel's l==0 guard.
    any_ok = ok.any(-1)[None, None, :, None]
    p = jnp.where(any_ok, p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vg.astype(jnp.float32))
    return out.astype(q.dtype)

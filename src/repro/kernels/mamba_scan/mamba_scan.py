"""Pallas TPU selective-scan (Mamba-1) kernel.

TPU adaptation of the CUDA selective-scan: instead of one threadblock per
(batch, channel-slab) doing a warp-level scan, we tile the channel dim
(d_inner) across the parallel grid axes and run the *sequence* as the last,
sequential grid dimension in chunks, carrying the SSM state h (block_i x N)
in VMEM scratch between chunks.  Inside a chunk the recurrence is a
fori_loop over time steps on (block_i, N) tiles -- elementwise VPU work with
no MXU involvement, so block_i is sized to the 8x128 VREG lanes rather than
the 128x128 MXU tile.

Layouts (time-major for contiguous chunk slabs):
  u, dt : (B, S, I)    A: (I, N)    Bm, Cm: (B, S, N)    D: (I,)
  y     : (B, S, I)    h_last: (B, I, N)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_I = 128
DEFAULT_CHUNK = 128


def _scan_kernel(u_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref,
                 y_ref, hlast_ref, h_ref, *, chunk: int, num_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    A = A_ref[...].astype(jnp.float32)                    # (bi, N)
    D = D_ref[...].astype(jnp.float32)                    # (1, bi)

    def step(t, h):
        u_t = u_ref[0, t].astype(jnp.float32)             # (bi,)
        dt_t = dt_ref[0, t].astype(jnp.float32)           # (bi,)
        B_t = B_ref[0, t].astype(jnp.float32)             # (N,)
        C_t = C_ref[0, t].astype(jnp.float32)             # (N,)
        dA = jnp.exp(dt_t[:, None] * A)                   # (bi, N)
        dBu = (dt_t * u_t)[:, None] * B_t[None, :]        # (bi, N)
        h = dA * h + dBu
        y_t = jnp.sum(h * C_t[None, :], axis=1) + u_t * D[0]
        y_ref[0, t] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ic == num_chunks - 1)
    def _finish():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def mamba_scan_fwd(
    u: jax.Array,      # (B, S, I) fp32
    dt: jax.Array,     # (B, S, I) fp32
    A: jax.Array,      # (I, N) fp32
    Bm: jax.Array,     # (B, S, N) fp32
    Cm: jax.Array,     # (B, S, N) fp32
    D: jax.Array,      # (I,) fp32
    h0: jax.Array,     # (B, I, N) fp32
    *,
    block_i: int = DEFAULT_BLOCK_I,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    B, S, I = u.shape
    N = A.shape[1]
    block_i = min(block_i, I)
    chunk = min(chunk, S)
    assert I % block_i == 0 and S % chunk == 0, (I, block_i, S, chunk)
    ni, nc = I // block_i, S // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk, num_chunks=nc)
    y, hlast = pl.pallas_call(
        kernel,
        grid=(B, ni, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_i), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((1, chunk, block_i), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((block_i, N), lambda b, i, c: (i, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),
            pl.BlockSpec((1, block_i), lambda b, i, c: (0, i)),
            pl.BlockSpec((1, block_i, N), lambda b, i, c: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_i), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((1, block_i, N), lambda b, i, c: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, I), u.dtype),
            jax.ShapeDtypeStruct((B, I, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_i, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, A, Bm, Cm, D[None, :], h0)
    return y, hlast

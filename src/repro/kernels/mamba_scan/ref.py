"""Pure-jnp oracle for the selective scan (sequential formulation)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def mamba_scan_ref(u, dt, A, Bm, Cm, D, h0: Optional[jax.Array] = None):
    """u,dt: (B,S,I); A: (I,N); Bm,Cm: (B,S,N); D: (I,); h0: (B,I,N).
    Returns (y (B,S,I), h_last (B,I,N)). fp32 math."""
    B, S, I = u.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, I, N), jnp.float32)

    def step(h, xs):
        u_t, dt_t, B_t, C_t = xs                       # (B,I),(B,I),(B,N),(B,N)
        dA = jnp.exp(dt_t[..., None] * A[None])        # (B,I,N)
        dBu = (dt_t * u_t)[..., None] * B_t[:, None]   # (B,I,N)
        h = dA * h + dBu
        y = jnp.einsum("bin,bn->bi", h, C_t) + u_t * D[None]
        return h, y

    xs = (jnp.moveaxis(u, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    h_last, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_last

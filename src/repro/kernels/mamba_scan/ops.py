"""Jit'd wrapper for the selective-scan kernel (pads I and S to blocks)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .mamba_scan import DEFAULT_BLOCK_I, DEFAULT_CHUNK, mamba_scan_fwd


@functools.partial(jax.jit, static_argnames=("block_i", "chunk", "interpret"))
def mamba_scan(u, dt, A, Bm, Cm, D,
               h0: Optional[jax.Array] = None,
               *, block_i: int = DEFAULT_BLOCK_I, chunk: int = DEFAULT_CHUNK,
               interpret: bool = True):
    B, S, I = u.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, I, N), jnp.float32)
    bi = min(block_i, I)
    ck = min(chunk, S)
    pad_i = (-I) % bi
    pad_s = (-S) % ck
    if pad_i:
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pad_i)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad_i)))
        A = jnp.pad(A, ((0, pad_i), (0, 0)))
        D = jnp.pad(D, (0, pad_i))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_i), (0, 0)))
    if pad_s:
        # padded steps: dt=0 => dA=exp(0)=1, dBu=0 -> state unchanged; safe.
        u = jnp.pad(u, ((0, 0), (0, pad_s), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_s), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad_s), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad_s), (0, 0)))
    y, hlast = mamba_scan_fwd(u, dt, A, Bm, Cm, D, h0,
                              block_i=bi, chunk=ck, interpret=interpret)
    return y[:, :S, :I], hlast[:, :I]

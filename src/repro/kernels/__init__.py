"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three modules: <name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper with padding/layout), ref.py (pure-jnp oracle used by
the allclose test sweeps).  All validate under interpret=True on CPU; the
TPU is the compile target.  The paper itself contributes no kernel (it is a
scheduling/caching paper) -- these cover the model substrate's hot spots
plus the paper application's stacking loop.
"""

"""Seed-paired cartesian sweeps over `ExperimentSpec` fields.

A :class:`Sweep` expands ``{dotted.path: [values...]}`` grids into cells
(one spec per combination, insertion-ordered keys x value order), executes
each cell on a fresh engine, and optionally writes:

  manifest.json   base spec + grid + per-cell overrides/fingerprints --
                  enough to regenerate any cell without the results file
  results.jsonl   one line per cell: {"index", "overrides", "report"}
                  with the full RunReport dict (RunReport.from_dict reads
                  it back)

Seed pairing.  Comparative claims (policy A vs policy B) need every cell
to see the *same arrival sequence and object draws*.  Within one sweep all
cells share the base spec's workload seed (sweeping ``workload.seed``
directly is rejected); the ``seeds=[...]`` axis adds paired replications:
replication r re-runs EVERY cell with ``seed`` and ``workload.seed`` both
set to ``seeds[r]``, so cells stay comparable within each replication.

Workloads are generated once per distinct binding and shared across cells
(a `Workload` is immutable; engines materialise fresh Tasks per run), so an
8-cell policy sweep pays one generation, not eight.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Mapping, Optional, Sequence

from .engines import build_workload, make_engine
from .report import RunReport
from .spec import ExperimentSpec, with_overrides


@dataclass(frozen=True)
class SweepCell:
    index: int
    overrides: dict          # dotted path -> value (JSON-able)
    spec: ExperimentSpec


class Sweep:
    def __init__(self, base: ExperimentSpec,
                 grid: Mapping[str, Sequence],
                 *, seeds: Optional[Sequence[int]] = None,
                 engine: str = "sim",
                 name: Optional[str] = None) -> None:
        for key in grid:
            if key in ("workload.seed", "seed"):
                raise ValueError(
                    f"do not sweep {key!r} in the grid -- use seeds=[...] "
                    f"for seed-paired replications (pairing is the point)")
        self.base = base
        self.grid = {k: list(v) for k, v in grid.items()}
        self.seeds = list(seeds) if seeds is not None else None
        self.engine = engine
        self.name = name or f"{base.name}-sweep"

    # ------------------------------------------------------------------
    def cells(self) -> list[SweepCell]:
        keys = list(self.grid)
        value_combos = list(itertools.product(*(self.grid[k] for k in keys)))
        reps = self.seeds if self.seeds is not None else [None]
        out: list[SweepCell] = []
        for seed in reps:
            for combo in value_combos:
                overrides = dict(zip(keys, combo))
                if seed is not None:
                    overrides["seed"] = seed
                    overrides["workload.seed"] = seed
                out.append(SweepCell(
                    index=len(out), overrides=overrides,
                    spec=with_overrides(self.base, overrides)))
        return out

    # ------------------------------------------------------------------
    def run(self, out_dir: Optional[str] = None,
            run_kw: Optional[dict] = None,
            progress: Optional[Callable[[SweepCell, RunReport], None]] = None,
            ) -> list[tuple[SweepCell, RunReport]]:
        """Execute every cell; returns [(cell, report), ...] in cell order.
        ``run_kw`` is forwarded to every engine ``run()`` call."""
        cells = self.cells()
        out_path = Path(out_dir) if out_dir is not None else None
        if out_path is not None:
            out_path.mkdir(parents=True, exist_ok=True)
            (out_path / "manifest.json").write_text(json.dumps({
                "sweep": self.name,
                "engine": self.engine,
                "seed_paired": True,
                "seeds": self.seeds,
                "grid": self.grid,
                "n_cells": len(cells),
                "base": self.base.to_dict(),
                "cells": [{"index": c.index, "overrides": c.overrides,
                           "spec_sha": c.spec.fingerprint()}
                          for c in cells],
            }, indent=2, sort_keys=True) + "\n")
        wl_cache: dict[str, object] = {}
        results: list[tuple[SweepCell, RunReport]] = []
        results_f = (out_path / "results.jsonl").open("w") \
            if out_path is not None else None
        try:
            for cell in cells:
                wkey = json.dumps(dataclasses.asdict(cell.spec.workload),
                                  sort_keys=True)
                if wkey not in wl_cache:
                    wl_cache[wkey] = build_workload(cell.spec.workload)
                eng = make_engine(self.engine)
                try:
                    eng.prepare(cell.spec, workload=wl_cache[wkey])
                    report = eng.run(**(run_kw or {}))
                finally:
                    eng.shutdown()   # runtime workers must not outlive a cell
                results.append((cell, report))
                if results_f is not None:
                    results_f.write(json.dumps({
                        "index": cell.index,
                        "overrides": cell.overrides,
                        "report": report.as_dict(),
                    }, sort_keys=True) + "\n")
                    results_f.flush()
                if progress is not None:
                    progress(cell, report)
        finally:
            if results_f is not None:
                results_f.close()
        return results


def load_results(out_dir: str) -> list[tuple[dict, RunReport]]:
    """Read a sweep's results.jsonl back as [(line dict sans report,
    RunReport), ...]."""
    out = []
    with (Path(out_dir) / "results.jsonl").open() as f:
        for ln in f:
            if not ln.strip():
                continue
            rec = json.loads(ln)
            rep = RunReport.from_dict(rec.pop("report"))
            out.append((rec, rep))
    return out

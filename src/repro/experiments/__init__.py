"""One experiment API over both engines (DESIGN.md §7).

  ExperimentSpec  declarative, frozen, JSON-round-trippable run description
                  (spec.py; unknown fields hard-error)
  Engine          prepare(spec) -> run() -> RunReport protocol with
                  SimEngine / RuntimeEngine adapters (engines.py)
  RunReport       one result schema for both engines, every metric computed
                  by the shared MetricsCollector formulas (report.py)
  Sweep           seed-paired cartesian grids over spec fields, with
                  manifest + results JSONL (sweep.py)

Quick use::

    from repro.experiments import ExperimentSpec, WorkloadSpec, run_experiment
    spec = ExperimentSpec(
        name="demo",
        workload=WorkloadSpec(arrivals={"kind": "PoissonArrivals",
                                        "rate_per_s": 8.0},
                              popularity={"kind": "ZipfPopularity",
                                          "alpha": 1.1, "k": 1, "corr": 1.0},
                              n_tasks=500, n_objects=50,
                              object_bytes=10**7),
    )
    report_sim = run_experiment(spec, engine="sim")
    report_rt = run_experiment(spec, engine="runtime")
    report_sim.diff(report_rt)     # field-by-field, shared schema
"""
from .engines import (ENGINES, LAZY_ENGINES, Engine, RuntimeEngine, SimEngine,
                      build_provisioner, build_recorder, build_sim_config,
                      build_workload, engine_names, make_engine,
                      run_experiment)
from .report import IDENTITY_FIELDS, RunReport, build_report
from .spec import (ALIASES, DOCUMENTED_DIVERGENCES, CacheSpec, ClusterSpec,
                   ExperimentSpec, ObserveSpec, ProvisionerSpec, WorkloadSpec,
                   check_alias_map, with_overrides)
from .sweep import Sweep, SweepCell, load_results

__all__ = [
    "ALIASES",
    "CacheSpec",
    "ClusterSpec",
    "DOCUMENTED_DIVERGENCES",
    "ENGINES",
    "Engine",
    "ExperimentSpec",
    "IDENTITY_FIELDS",
    "LAZY_ENGINES",
    "ObserveSpec",
    "ProvisionerSpec",
    "RunReport",
    "RuntimeEngine",
    "SimEngine",
    "Sweep",
    "SweepCell",
    "WorkloadSpec",
    "build_provisioner",
    "build_recorder",
    "build_report",
    "build_sim_config",
    "build_workload",
    "check_alias_map",
    "engine_names",
    "load_results",
    "make_engine",
    "run_experiment",
    "with_overrides",
]
